"""End-to-end driver: train a ~100M-param llama-style model for a few hundred
steps on synthetic data, with checkpointing/resume and (optionally) the
paper-technique optimizer hooks (PowerSGD gradient compression).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--powersgd]
"""
import argparse
import dataclasses
import json
import pathlib

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import data_iterator
from repro.models import init_model
from repro.models.transformer import count_params
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

# ~100M-param llama-flavored config (trainable on this CPU container)
CFG_100M = ModelConfig(
    name="llama-100m",
    family="dense",
    num_layers=10,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=32064,
    rope_theta=10000.0,
    block_pattern=("global",),
    tie_embeddings=True,
    dtype="float32",
    attn_chunk=256,
    powersgd_rank=0,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--powersgd", action="store_true", help="rank-32 gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CFG_100M
    if args.powersgd:
        cfg = dataclasses.replace(cfg, powersgd_rank=32)
    shape = ShapeConfig("train", args.seq_len, args.batch, "train")

    params = init_model(cfg, jax.random.key(0))
    print(f"model: {cfg.name}  params: {count_params(params)/1e6:.1f}M")

    ocfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=100,
        log_every=10,
        checkpoint_dir=args.ckpt_dir,
    )
    trainer = Trainer(cfg, ocfg, tcfg)
    params, _, metrics = trainer.run(params, data_iterator(cfg, shape), resume=True)

    log = [json.loads(l) for l in open(pathlib.Path(args.ckpt_dir) / "train_log.jsonl")]
    losses = [r["loss"] for r in log if "loss" in r]
    print(f"first-loss {losses[0]:.4f} -> last-loss {losses[-1]:.4f}")
    print(f"final metrics: loss={float(metrics['loss']):.4f} "
          f"straggler_flags={trainer.straggler.flagged_steps}")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
