"""Serve a small model with batched requests, with and without RSVD low-rank
weight compression (the paper's factorization applied at serve time).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_model
from repro.serve.engine import Engine, Request
from repro.serve.lowrank import factorize_params, memory_report

CFG = ModelConfig(
    name="llama-30m-serve",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=1024,
    vocab_size=8192,
    block_pattern=("global",),
    tie_embeddings=True,
    dtype="float32",
    attn_chunk=128,
)


def _impose_decaying_spectrum(params, power=1.2):
    """Random-init weights are full-rank (flat spectrum), so rank-k serving
    compression would be meaningless on them.  Trained transformer weights
    have decaying spectra; emulate that here so the example reflects the
    real serve-time trade-off."""
    import jax.numpy as jnp

    def reshape(path, leaf):
        if getattr(leaf, "ndim", 0) != 2 or min(leaf.shape) < 64:
            return leaf
        u, s, vt = jnp.linalg.svd(leaf.astype(jnp.float32), full_matrices=False)
        decay = s[0] / jnp.arange(1, s.shape[0] + 1, dtype=jnp.float32) ** power
        return ((u * decay[None, :]) @ vt).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(reshape, params)


def main():
    params = _impose_decaying_spectrum(init_model(CFG, jax.random.key(0)))
    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, CFG.vocab_size, size=n).astype(np.int32),
                max_new_tokens=16)
        for n in [9, 17, 33, 12, 25, 8]
    ]

    engine = Engine(params, CFG, max_batch=4, max_len=128)
    t0 = time.perf_counter()
    outs = engine.generate(requests)
    t_dense = time.perf_counter() - t0
    print(f"dense engine: {len(outs)} completions in {t_dense:.2f}s")
    for i, c in enumerate(outs[:3]):
        print(f"  req{i} prompt_len={c.prompt_len} -> {c.tokens[:8].tolist()}...")

    # --- low-rank compressed weights (paper's RSVD on the projections) ----
    fact, report = factorize_params(params, rank=48)
    mem = memory_report(params, fact)
    engine_lr = Engine(fact, CFG, max_batch=4, max_len=128)
    t0 = time.perf_counter()
    outs_lr = engine_lr.generate(requests)
    t_lr = time.perf_counter() - t0
    agree = np.mean([
        np.mean(a.tokens[:8] == b.tokens[:8]) for a, b in zip(outs, outs_lr)
    ])
    print(f"low-rank engine: {t_lr:.2f}s  weight-bytes {mem['dense_bytes']:,} -> "
          f"{mem['factorized_bytes']:,}")
    print(f"per-matrix rel-err (worst): {max(report.values()):.3f}; "
          f"greedy-token agreement on first 8: {agree:.2f}")
    print("OK")


if __name__ == "__main__":
    main()
