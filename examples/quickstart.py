"""Quickstart: the paper's randomized k-SVD in five lines, plus what the
TPU-oriented fast path buys.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import RSVDConfig, low_rank_error, randomized_svd, truncation_error
from repro.core.spectra import make_test_matrix

# A 2000 x 1000 matrix with the paper's 'fast decay' spectrum (sigma_i = 1/i^2)
A, sigma = make_test_matrix(2000, 1000, "fast", seed=0)
k = 50

# --- paper-faithful Algorithm 1 (Householder QR + LAPACK small SVD) --------
U, S, Vt = randomized_svd(A, k, RSVDConfig.faithful())
err = low_rank_error(A, U, S, Vt)
opt = truncation_error(sigma, k)
print(f"faithful : rank-{k} rel-error {err:.3e}  (optimal {opt:.3e})")

# --- TPU fast path: CholeskyQR2 + Gram-Jacobi + fused counter-RNG sketch ---
U, S, Vt = randomized_svd(A, k, RSVDConfig.fast())
err = low_rank_error(A, U, S, Vt)
print(f"fast     : rank-{k} rel-error {err:.3e}  (optimal {opt:.3e})")

# --- eigenvalues-only mode (the paper's benchmark setting) -----------------
from repro.core import randomized_eigvals

S_only = randomized_eigvals(A, 10, RSVDConfig.fast())
print("top-10 singular values:", [f"{float(s):.4f}" for s in S_only])
print("exact                 :", [f"{float(s):.4f}" for s in sigma[:10]])
