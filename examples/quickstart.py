"""Quickstart: the paper's randomized k-SVD in five lines, plus what the
TPU-oriented fast path buys.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import RSVDConfig, low_rank_error, randomized_svd, truncation_error
from repro.core.spectra import make_test_matrix

# A 2000 x 1000 matrix with the paper's 'fast decay' spectrum (sigma_i = 1/i^2)
A, sigma = make_test_matrix(2000, 1000, "fast", seed=0)
k = 50

# --- paper-faithful Algorithm 1 (Householder QR + LAPACK small SVD) --------
U, S, Vt = randomized_svd(A, k, RSVDConfig.faithful())
err = low_rank_error(A, U, S, Vt)
opt = truncation_error(sigma, k)
print(f"faithful : rank-{k} rel-error {err:.3e}  (optimal {opt:.3e})")

# --- TPU fast path: CholeskyQR2 + Gram-Jacobi + fused counter-RNG sketch ---
U, S, Vt = randomized_svd(A, k, RSVDConfig.fast())
err = low_rank_error(A, U, S, Vt)
print(f"fast     : rank-{k} rel-error {err:.3e}  (optimal {opt:.3e})")

# --- eigenvalues-only mode (the paper's benchmark setting) -----------------
from repro.core import randomized_eigvals

S_only = randomized_eigvals(A, 10, RSVDConfig.fast())
print("top-10 singular values:", [f"{float(s):.4f}" for s in S_only])
print("exact                 :", [f"{float(s):.4f}" for s in sigma[:10]])

# --- out-of-core: stream a host-resident matrix in row panels --------------
# A is device-resident one block_rows x n panel at a time; only sketch-width
# (m x s) state stays on device (DESIGN.md §3).  The result matches the
# dense path to ~1e-6 relative Frobenius error.
import numpy as np

A_host = np.asarray(A)  # pretend this is bigger than device memory
U, S, Vt = randomized_svd(A_host, k, RSVDConfig.streaming(block_rows=512))
err = low_rank_error(jnp.asarray(A_host), U, S, Vt)
print(f"streamed : rank-{k} rel-error {err:.3e}  (optimal {opt:.3e})")

# --- batched: a fleet of small SVDs under one vmap -------------------------
stack = jnp.stack([make_test_matrix(256, 96, "fast", seed=i)[0] for i in range(8)])
Ub, Sb, Vtb = randomized_svd(stack, 10)  # [8, 256, 96] -> per-slice factors
errs = [float(low_rank_error(stack[i], Ub[i], Sb[i], Vtb[i])) for i in range(8)]
print("batched  : rank-10 rel-errors", [f"{e:.3e}" for e in errs[:3]], "...")
