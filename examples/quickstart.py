"""Quickstart: the paper's randomized k-SVD behind ONE call-site pattern.

`repro.linalg` takes an *operator source* — a device array, a host numpy
array, a 3-D stack, a sharded array, or a composed operator — plans an
execution (inspectable!), and runs the same Algorithm 1 numerics on the
path the source calls for.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core import RSVDConfig, truncation_error
from repro.core.spectra import make_test_matrix

# A 1024 x 512 matrix with the paper's 'fast decay' spectrum (sigma_i = 1/i^2)
A, sigma = make_test_matrix(1024, 512, "fast", seed=0)
k = 32
opt = truncation_error(sigma, k)

# --- look before you leap: the planner's decision is an inspectable object
pl = linalg.plan(linalg.DenseOp(A), k)
print("plan     :", pl.describe())

# --- paper-faithful Algorithm 1 (Householder QR + LAPACK small SVD) --------
U, S, Vt = linalg.svd(A, k, overrides=RSVDConfig.faithful())
print(f"faithful : rank-{k} rel-error {linalg.residual(A, (U, S, Vt)):.3e}  (optimal {opt:.3e})")

# --- TPU fast path: CholeskyQR2 + Gram-Jacobi + fused one-pass kernels -----
# (the plan's fused_power flag is the EFFECTIVE decision: the VMEM budget
# gate can veto it, at which point the unfused body runs instead)
fast = linalg.plan(linalg.DenseOp(A), k, overrides=RSVDConfig.fast())
U, S, Vt = linalg.svd(A, k, plan=fast)
print(f"fast     : rank-{k} rel-error {linalg.residual(A, (U, S, Vt)):.3e}  ({fast.describe()})")

# --- eigenvalues-only mode (the paper's benchmark setting) -----------------
S_only = linalg.eigvals(A, 10, overrides=RSVDConfig.fast())
print("top-10 singular values:", [f"{float(s):.4f}" for s in S_only])
print("exact                 :", [f"{float(s):.4f}" for s in sigma[:10]])

# --- out-of-core: a host-resident matrix streams row panels ----------------
# HostOp keeps A on the host; only one block_rows x n panel is device-
# resident at a time, and the panel-wise residual never forms an m x n
# reconstruction either (DESIGN.md §3).
A_host = np.asarray(A)  # pretend this is bigger than device memory
host = linalg.HostOp(A_host, block_rows=256)
res = linalg.svd(host, k)
print(f"streamed : rank-{k} rel-error {linalg.residual(host, res):.3e}  "
      f"({linalg.plan(host, k).describe()})")

# --- batched: a fleet of small SVDs under one vmap -------------------------
stack = jnp.stack([make_test_matrix(256, 96, "fast", seed=i)[0] for i in range(8)])
Ub, Sb, Vtb = linalg.svd(stack, 10)  # [8, 256, 96] -> per-slice factors
print(f"batched  : stack rel-error {linalg.residual(stack, (Ub, Sb, Vtb)):.3e}")

# --- spec-driven: state the ACCURACY, let the engine find the rank ---------
# Tolerance(eps) grows the basis panel by panel (posterior error estimator,
# DESIGN.md §Specs) and stops as soon as the requested Frobenius error is
# certified — the plan records the full-rank fallback schedule, the result
# records the prefix that actually ran.
dec = linalg.decompose(A, linalg.Tolerance(1e-2))
print(f"tol 1e-2 : found rank {dec.rank} in {len(dec.rank_history)}/"
      f"{len(dec.plan.rank_schedule)} panels, rel-error "
      f"{linalg.residual(A, dec.factors):.3e}  ({dec.plan.describe()})")

# Other registry kinds ride the same spec machinery:
Q, B = linalg.decompose(A, linalg.Rank(k), kind="qb")        # basis only
print(f"qb       : Q {Q.shape} B {B.shape}  (A ~= Q @ B)")
pr, L, Umat, pc = linalg.decompose(A, linalg.Tolerance(2e-2), kind="lu")
print(f"lu       : L {L.shape} U {Umat.shape}  (A[pr][:, pc] ~= L @ U)")

# --- composed operators: the new workload class ----------------------------
# PCA without materializing the centered matrix ...
pca_res = linalg.pca(A, 8)
print("pca      : top-8 explained variance",
      [f"{float(v):.4f}" for v in pca_res.explained_variance[:3]], "...")
# ... or with the variance stated instead of the count:
pca_e = linalg.pca(A, linalg.Energy(0.99))
print(f"pca      : Energy(0.99) kept {pca_e.components.shape[0]} components")
# ... and deflation A - U_k S_k V_k^T as an operator: the next solve sees
# the residual spectrum (sigma_{k+1} and below) without forming it.
defl = linalg.deflated(linalg.DenseOp(A), U, S, Vt)
S_next = linalg.svd(defl, 5)[1]
print(f"deflated : leading residual sigma {float(S_next[0]):.4e}"
      f"  (exact sigma_{k + 1} = {float(sigma[k]):.4e})")
