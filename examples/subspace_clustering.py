"""Paper experiment 3: SuMC subspace clustering with the RSVD solver.

Run:  PYTHONPATH=src python examples/subspace_clustering.py
"""
import time

from repro.core.sumc import (
    adjusted_rand_index,
    eigh_solver,
    rsvd_solver,
    sumc,
    synthetic_subspace_data,
)

# Paper 'first' dataset structure (scaled ambient dim for the CPU container):
# 3 clusters from 8/12/17-dim subspaces of a 250-dim space.
X, y = synthetic_subspace_data(sizes=[250, 500, 1000], dims=[8, 12, 17], ambient=250, seed=0)
print(f"data: {X.shape[0]} points in {X.shape[1]}-dim space; 3 true subspaces")

for name, solver in [("dense eigh (paper CPU column)", eigh_solver),
                     ("randomized SVD (paper GPU column)", rsvd_solver)]:
    t0 = time.perf_counter()
    res = sumc(X, n_clusters=3, subspace_dims=[8, 12, 17], solver=solver, seed=1, n_init=3)
    dt = time.perf_counter() - t0
    ari = adjusted_rand_index(res.labels, y)
    print(f"{name:36s} elapsed {dt:6.1f}s  solver-calls {res.solver_calls:4d}  ARI {ari:.3f}")
