"""Insert the final roofline table into EXPERIMENTS.md (run after the sweep)."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.roofline.analysis import format_table, load_all

rows = load_all("artifacts/dryrun")
table = format_table(rows, "single")
p = pathlib.Path("EXPERIMENTS.md")
text = p.read_text()
assert "TABLE_SINGLE_POD_PLACEHOLDER" in text
p.write_text(text.replace("TABLE_SINGLE_POD_PLACEHOLDER", table))
live = [r for r in rows if not r.skipped]
print(f"inserted table: {len(live)} live cells, {len(rows)-len(live)} skips")
