"""Paper Figs 2-4: speed of k-largest-singular-value computation, ours vs
baselines, on fast/sharp/slow-decay spectra.

Methods (paper column -> our implementation):
  GESVD        -> jnp.linalg.svd (full dense SVD)
  dsyevr       -> jnp.linalg.eigh on the Gram matrix (full spectrum)
  SVDS         -> core.lanczos (Golub-Kahan with full reorth)
  RSVD (CRAN)  -> Algorithm 1 with Householder QR + LAPACK small SVD
  ours         -> Algorithm 1, BLAS-3 path: CholeskyQR2 + Gram-Jacobi +
                  fused counter-RNG sketch

Timings are CPU wall-clock (this container); the deliverable is the RATIO
(paper reports speedup ratios too).  Accuracy column verifies the paper's
<=1e-8 claim holds for the f64 configuration.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core import RSVDConfig
from repro.core.lanczos import lanczos_singular_values
from repro.core.spectra import make_test_matrix

# 'ours' on THIS HOST is the faithful Algorithm 1 (the paper's method): the
# TPU fast path's fused Pallas kernel runs in interpret mode on CPU, which is
# a correctness harness, not a performance mode — its wins are structural
# (HBM-traffic model in bench_kernels + §Perf).  The naive-'RSVD'-package
# column is emulated with plain (unstabilized) power iteration.
OURS = RSVDConfig()  # householder QR + LAPACK small SVD + q=2 QR iteration
NAIVE = RSVDConfig(power_scheme="plain", oversample=10, power_iters=2)


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps, out


def run(sizes=(512, 1024), fracs=(0.01, 0.05, 0.10), kinds=("fast", "sharp", "slow"), m=2000):
    rows = []
    for kind in kinds:
        for n in sizes:
            A, sig = make_test_matrix(m, n, kind, seed=0)
            for frac in fracs:
                k = max(1, int(np.ceil(frac * n)))

                t_ours, s_ours = _time(
                    lambda a: linalg.eigvals(a, k, overrides=OURS), A
                )
                t_rsvd, _ = _time(
                    lambda a: linalg.eigvals(a, k, overrides=NAIVE), A
                )
                t_svds, _ = _time(
                    functools.partial(lanczos_singular_values, k=k, extra=10), A
                )
                t_gesvd, s_full = _time(
                    functools.partial(jnp.linalg.svd, compute_uv=False), A
                )
                t_eigh, _ = _time(lambda x: jnp.linalg.eigh(x.T @ x)[0], A)

                err = float(
                    jnp.max(jnp.abs(s_ours - s_full[:k]) / jnp.maximum(s_full[:k], 1e-30))
                )
                rows.append(
                    dict(
                        kind=kind, n=n, k=k,
                        us_ours=t_ours * 1e6,
                        speedup_gesvd=t_gesvd / t_ours,
                        speedup_eigh=t_eigh / t_ours,
                        speedup_svds=t_svds / t_ours,
                        speedup_rsvd_naive=t_rsvd / t_ours,
                        rel_err=err,
                    )
                )
    return rows


def main():
    for r in run():
        print(
            f"spectra_{r['kind']}_n{r['n']}_k{r['k']},{r['us_ours']:.0f},"
            f"gesvd_x{r['speedup_gesvd']:.2f};eigh_x{r['speedup_eigh']:.2f};"
            f"svds_x{r['speedup_svds']:.2f};rsvd_x{r['speedup_rsvd_naive']:.2f};"
            f"err{r['rel_err']:.2e}"
        )


if __name__ == "__main__":
    main()
