"""rSVD variant benchmark + the analytic HBM-traffic model, persisted.

Emits ``BENCH_rsvd.json`` (cwd, or --out PATH; CI uploads it as a workflow
artifact): per-variant wall time on the current backend (CPU-container
numbers are interpret-mode correctness proxies, NOT TPU performance), the
structural HBM-traffic model that the fused one-pass range finder is built
on (now shared with the execution planner — repro/roofline/rsvd_model.py),
the EXECUTED `ExecutionPlan` for every variant, the ADAPTIVE
(fixed-precision) mode (schema v3: rank-growth trajectory, per-step
roofline bytes, adaptive-vs-oracle walltime), the OUT-OF-CORE PIPELINE
(schema v4: synchronous vs double-buffered streamed SVD walltime, the
measured per-pass transfer vs compute split, and the overlap model's
predictions, asserted equal to the plan's own `pipeline_depth` /
`predicted_walltime_s` fields), the SPARSE path (schema v5: a density
sweep (nnz/mn in {0.001, 0.01, 0.1}) of SpMM-sketch vs dense walltime
with the plan's bytes asserted equal to the sparse roofline and the
density-0.01 sketch priced >= 10x below dense), and — schema v6 — the
GUARD overhead: guard off vs report-mode walltime on the dense and
streamed paths, with report-mode factors asserted bit-identical to off
and the report plan's predicted HBM bytes asserted EQUAL to the off
plan's (the probes read byproducts, never A); the <= 1.05x walltime bar
is gated on TPU only (on CPU the probe reductions compete with compute
for the same cores).  Schema v7 adds the DECOMPOSITION SERVICE
(repro/serve/decomp): mixed small-request traffic through the coalescing
service vs a serial service — throughput, p50/p99 latency, coalescing
factor, executable-cache hit rate, with per-request bit-identity to the
standalone solve and the hit-rate threshold asserted on every backend
(latency ratios TPU-gated).  Schema v8 adds the STATIC-ANALYSIS gates
(repro/analysis): the AST lint over src/ and the jaxpr contract sweep
over the golden dispatch table, recording findings/suppression counts and
both walltimes, with zero findings and zero contract violations asserted
(the report itself gates on the invariants).  Schema v9 adds RESUMABLE
EXECUTION (linalg/snapshot.py): the streamed and adaptive solves run
checkpoint-off, checkpoint-on (panel-granular snapshots every boundary),
and interrupted-then-resumed — the checkpoint overhead ratio and
host-side snapshot walltime are recorded, and resumed factors are
asserted BIT-identical to the uninterrupted run on EVERY backend
(snapshot writes are host-side only, so they add zero HBM traffic by
construction); the service row surfaces the resilience counters
(cancelled / deadline_exceeded / restarts / resumed_jobs / checkpoint
overhead).  EXPERIMENTS.md records the history; the model derivations
live in rsvd_model.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.roofline.rsvd_model import hbm_bytes_per_power_iter  # noqa: F401  (model home)


def traffic_rows(shapes=((2000, 2000, 100), (8192, 8192, 256), (65536, 4096, 128))):
    rows = []
    for m, n, s in shapes:
        unfused = hbm_bytes_per_power_iter(m, n, s, fused=False)
        fused = hbm_bytes_per_power_iter(m, n, s, fused=True)
        rows.append(
            dict(m=m, n=n, s=s, unfused_bytes_per_iter=unfused,
                 fused_bytes_per_iter=fused, saving=round(unfused / fused, 3))
        )
    return rows


def _time(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def variant_rows(m=512, n=256, k=16):
    from repro import linalg
    from repro.core.rsvd import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "fast", seed=0)
    variants = [
        ("faithful", RSVDConfig.faithful()),
        ("cqr2_unfused", RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                                    small_svd="gram_jacobi")),
        ("fast_fused", RSVDConfig.fast()),
    ]
    rows = []
    for name, cfg in variants:
        # Plan once, execute the pinned plan: the recorded plan IS what ran
        # (fused_power in the plan is the EFFECTIVE decision — the VMEM
        # guard or f64 can veto the config flag).
        pl = linalg.plan(linalg.DenseOp(A), k, overrides=cfg)
        t = _time(lambda a, p=pl: linalg.svd(a, k, plan=p), A)
        q = pl.power_iters
        rows.append(
            dict(name=name, m=m, n=n, k=k, wall_s=round(t, 4),
                 reads_of_A=(1 + q) if pl.fused_power else (2 * q + 2),
                 backend=jax.default_backend(),
                 plan=dataclasses.asdict(pl))
        )
    return rows


def adaptive_rows(m=512, n=256, eps=1e-2, panel=16):
    """Fixed-precision mode: `decompose(A, Tolerance(eps))` on the paper's
    sharp-decay (exponential drop) spectrum.  Records the executed rank
    trajectory and the plan's per-step roofline bytes, and times the
    adaptive solve against the oracle fixed-rank solve (the rank the
    adaptive run discovered — the walltime a clairvoyant caller would pay).
    """
    from repro import linalg
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "sharp", seed=0)
    spec = linalg.Tolerance(eps, panel=panel)
    dec = linalg.decompose(A, spec, seed=0)  # warm the per-panel programs
    t_adaptive = _time(lambda a: linalg.decompose(a, spec, seed=0).factors, A)
    t_oracle = _time(lambda a: linalg.svd(a, dec.rank), A)
    achieved = float(linalg.residual(A, dec.factors))
    pl = dec.plan
    row = dict(
        m=m, n=n, eps=eps, panel=panel,
        rank=dec.rank,
        achieved_rel_error=round(achieved, 6),
        rank_trajectory=list(dec.rank_history),
        err_trajectory=[round(e, 6) for e in dec.err_history],
        plan_rank_schedule=list(pl.rank_schedule),
        plan_step_bytes=list(pl.schedule_hbm_bytes),
        panels_run=len(dec.rank_history),
        panels_full=len(pl.rank_schedule),
        wall_s_adaptive=round(t_adaptive, 4),
        wall_s_oracle_rank=round(t_oracle, 4),
        backend=jax.default_backend(),
    )
    # acceptance invariants, checked before the JSON is written
    assert achieved <= eps, row
    assert row["panels_run"] < row["panels_full"], row
    from repro.roofline import rsvd_model

    assert tuple(row["plan_step_bytes"]) == rsvd_model.adaptive_schedule_bytes(
        pl.m, pl.n, pl.rank_schedule, pl.power_iters,
        dtype_bytes=4, fused_sketch=pl.fused_sketch), row
    return [row]


def pipeline_rows(m=16384, n=2048, k=64, block_rows=2048):
    """Out-of-core overlap: synchronous vs double-buffered streamed SVD on a
    HOST (numpy) source, plus the measured transfer/compute split the
    overlap model prices.

    `transfer_s_per_pass` times one DEPTH-1 (bare synchronous, no staging
    ring) walk over the panels with no compute attached — the per-pass
    `sum(transfer)` term of the model, which the overlapped mode hides
    under compute; `compute_s_est` is the synchronous walltime minus all
    transfer passes.
    On TPU the overlapped solve must land at <= 0.7x the synchronous one
    (the acceptance bar; asserted there only — on CPU/interpret hosts the
    "link" is a memcpy sharing the compute cores' bandwidth, so the ratio
    is recorded but not gated).  Bit-identity of the overlapped factors is
    asserted on EVERY backend: prefetch reorders transfers, not arithmetic.
    """
    import numpy as np

    from repro import linalg
    from repro.core.blocked import svd_streamed
    from repro.core.spectra import make_test_matrix
    from repro.linalg import pipeline
    from repro.roofline import rsvd_model

    from repro.core.rsvd import RSVDConfig

    A = np.asarray(make_test_matrix(m, n, "fast", seed=0)[0])
    op = linalg.HostOp(A, block_rows=block_rows)
    # the streaming preset pins double-buffering explicitly, so the bench
    # exercises the overlapped mode on every backend (the planner's
    # backend-aware DEFAULT stays synchronous on CPU hosts)
    pl = linalg.plan(op, k, overrides=RSVDConfig.streaming(block_rows=block_rows))
    assert pl.path == "streamed" and pl.pipeline_depth >= 2, pl.describe()
    cfg = pl.to_config()
    sync_cfg = dataclasses.replace(cfg, pipeline_depth=1)
    out_sync = svd_streamed(A, k, sync_cfg, seed=0)
    out_over = svd_streamed(A, k, cfg, seed=0)
    for a, b in zip(out_sync, out_over):  # bit-identity, every backend
        assert (jnp.asarray(a) == jnp.asarray(b)).all(), "prefetch changed bits"
    t_sync = _time(lambda a: svd_streamed(a, k, sync_cfg, seed=0), A)
    t_over = _time(lambda a: svd_streamed(a, k, cfg, seed=0), A)

    bounds = pipeline.panel_bounds(pl.m, pl.block_rows)

    def _transfer_only(a):
        # depth 1: the SYNCHRONOUS per-panel host->device leg — the
        # sum(transfer) term of the model, which depth >= 2 hides under
        # compute; passes * this is what the overlapped mode saves
        last = None
        for p in pipeline.stream_host_panels(a, bounds, 1):
            last = p
        return last

    t_pass = _time(_transfer_only, A if m >= n else A.T)
    passes = rsvd_model.streamed_pass_count(pl.power_iters)
    dtype_bytes = jnp.dtype(pl.dtype).itemsize
    row = dict(
        m=m, n=n, k=k, block_rows=pl.block_rows,
        pipeline_depth=pl.pipeline_depth,
        wall_s_sync=round(t_sync, 4),
        wall_s_overlapped=round(t_over, 4),
        overlap_ratio=round(t_over / t_sync, 3),
        transfer_s_per_pass=round(t_pass, 4),
        transfer_s_total=round(t_pass * passes, 4),
        compute_s_est=round(max(t_sync - t_pass * passes, 0.0), 4),
        passes=passes,
        model_wall_s_sync=rsvd_model.streamed_walltime_s(
            pl.m, pl.n, pl.s, pl.block_rows, pl.power_iters, 1,
            dtype_bytes=dtype_bytes, fused_sketch=pl.fused_sketch),
        model_wall_s_overlapped=pl.predicted_walltime_s,
        backend=jax.default_backend(),
        plan=dataclasses.asdict(pl),
    )
    if jax.default_backend() == "tpu":
        # the acceptance bar holds only where a real host link exists
        assert row["overlap_ratio"] <= 0.7, row
    return [row]


def sparse_rows(m=2048, n=1024, k=16, densities=(0.001, 0.01, 0.1)):
    """Schema v5: the sparse path across a density sweep.

    For each density: SpMM-sketch SVD walltime on a `SparseOp` vs the dense
    solve on the densified matrix, the executed sparse plan, and the model
    ratio dense-sketch-bytes / sparse-sketch-bytes.  Two asserts gate the
    sweep on EVERY backend: the plan's whole-solve bytes equal the sparse
    roofline model, and the density-0.01 sketch is priced >= 10x below the
    dense sketch.  The measured walltime ratio is gated on TPU only — in
    interpret mode SpMM runs as a trace, not a kernel, so the CPU ratio is
    recorded for trend-tracking, never asserted.
    """
    import numpy as np
    from jax.experimental import sparse as jsparse

    from repro import linalg
    from repro.roofline import rsvd_model

    rows = []
    for density in densities:
        rng = np.random.default_rng(int(density * 1e6))
        mask = rng.random((m, n)) < density
        A_np = (rng.standard_normal((m, n)) * mask).astype(np.float32)
        A = jnp.asarray(A_np)
        op = linalg.SparseOp(jsparse.BCOO.fromdense(A))
        pl = linalg.plan(op, k)
        assert pl.path == "sparse" and pl.nnz == op.nnz, pl.describe()
        t_sparse = _time(lambda o, p=pl: linalg.svd(o, k, plan=p, seed=0), op)
        t_dense = _time(lambda a: linalg.svd(a, k, seed=0), A)
        sketch_sparse = rsvd_model.spmm_sketch_bytes(
            m, n, pl.s, pl.nnz, fused_sketch=pl.fused_sketch)
        sketch_dense = rsvd_model.sketch_bytes(
            m, n, pl.s, fused_sketch=False)
        rows.append(dict(
            m=m, n=n, k=k, density=density, nnz=pl.nnz,
            wall_s_sparse=round(t_sparse, 4),
            wall_s_dense=round(t_dense, 4),
            walltime_ratio=round(t_sparse / t_dense, 3),
            sketch_bytes_sparse=sketch_sparse,
            sketch_bytes_dense=sketch_dense,
            sketch_pricing_ratio=round(sketch_dense / sketch_sparse, 2),
            backend=jax.default_backend(),
            plan=dataclasses.asdict(pl),
        ))
    return rows


def guard_rows(m=2048, n=512, k=32, host_m=4096, block_rows=512):
    """Schema v6: what report-mode guarding costs.

    Dense and streamed solves, guard off vs guard="report": the report
    factors must be BIT-identical to off (every backend — probes never
    touch the arithmetic), the report plan's `predicted_hbm_bytes` must
    EQUAL the off plan's (the roofline statement of "no extra reads of
    A"), and the walltime ratio is recorded; the <= 1.05x bar is asserted
    on TPU only, where probe reductions hide under HBM bandwidth instead
    of competing for the compute cores.
    """
    import numpy as np

    from repro import linalg
    from repro.core.spectra import make_test_matrix

    rows = []

    A = make_test_matrix(m, n, "fast", seed=0)[0]
    pl_off = linalg.plan(A, k)
    pl_rep = linalg.plan(A, k, guard="report")
    assert pl_rep.predicted_hbm_bytes == pl_off.predicted_hbm_bytes, (
        "report-mode probes changed the plan's HBM traffic")
    off = linalg.svd(A, k, plan=pl_off, seed=0)
    rep = linalg.decompose(A, k, plan=pl_rep, seed=0)
    for a, b in zip(off, rep.factors):
        assert (jnp.asarray(a) == jnp.asarray(b)).all(), "report changed bits"
    assert rep.health is not None and rep.health.ok
    t_off = _time(lambda a: linalg.svd(a, k, plan=pl_off, seed=0), A)
    t_rep = _time(lambda a: linalg.decompose(a, k, plan=pl_rep, seed=0).factors, A)
    rows.append(dict(
        path="dense", m=m, n=n, k=k,
        wall_s_off=round(t_off, 4), wall_s_report=round(t_rep, 4),
        overhead_ratio=round(t_rep / t_off, 3),
        predicted_hbm_bytes=pl_off.predicted_hbm_bytes,
        backend=jax.default_backend(),
        plan=dataclasses.asdict(pl_rep),
    ))

    H = np.asarray(make_test_matrix(host_m, n, "fast", seed=1)[0])

    def _op():
        return linalg.HostOp(H, block_rows=block_rows, pipeline_depth=2)

    pl_off = linalg.plan(_op(), k)
    pl_rep = linalg.plan(_op(), k, guard="report")
    assert pl_rep.predicted_hbm_bytes == pl_off.predicted_hbm_bytes
    off = linalg.svd(_op(), k, plan=pl_off, seed=0)
    rep = linalg.decompose(_op(), k, plan=pl_rep, seed=0)
    for a, b in zip(off, rep.factors):
        assert (jnp.asarray(a) == jnp.asarray(b)).all(), "report changed bits"
    t_off = _time(lambda _: linalg.svd(_op(), k, plan=pl_off, seed=0), 0)
    t_rep = _time(lambda _: linalg.decompose(_op(), k, plan=pl_rep, seed=0).factors, 0)
    rows.append(dict(
        path="streamed", m=host_m, n=n, k=k, block_rows=block_rows,
        wall_s_off=round(t_off, 4), wall_s_report=round(t_rep, 4),
        overhead_ratio=round(t_rep / t_off, 3),
        predicted_hbm_bytes=pl_off.predicted_hbm_bytes,
        backend=jax.default_backend(),
        plan=dataclasses.asdict(pl_rep),
    ))
    if jax.default_backend() == "tpu":
        for row in rows:
            # the <5% bar holds where the probes ride the memory system
            assert row["overhead_ratio"] <= 1.05, row
    return rows


def service_rows(n_requests=64, m=64, n=32, k=8, max_batch=8):
    """Schema v7: the decomposition service under mixed PCA-style traffic.

    `n_requests` same-shaped dense requests pushed through a coalescing
    `DecompositionService` vs the same requests served serially (a
    max_batch=1 service — identical executors, no batching): throughput,
    p50/p99 latency, coalescing factor, executable-cache hit rate.  Two
    asserts gate the row on EVERY backend: each coalesced result is
    BIT-identical to its standalone `decompose(StackedOp(x[None]))`
    baseline at the request's seed, and the steady-state cache hit rate
    clears the threshold (>= 0.5 — only the first wave of batch shapes may
    miss).  The serial-vs-coalesced latency ratio is recorded always and
    gated on TPU only, per the bench's precedent: on CPU containers the
    "batched win" competes with the harness threads for the same cores.
    """
    import numpy as np

    from repro import linalg
    from repro.serve.decomp import DecompositionService

    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
          for _ in range(n_requests)]
    baselines = [
        tuple(np.asarray(f[0]) for f in linalg.decompose(
            linalg.StackedOp(x[None]), linalg.Rank(k), seed=i).factors)
        for i, x in enumerate(xs)]

    def _drive(batch: int):
        with DecompositionService(window_s=0.005, max_batch=batch) as svc:
            t0 = time.perf_counter()
            futs = [svc.submit(x, linalg.Rank(k), seed=i)
                    for i, x in enumerate(xs)]
            svc.flush()
            decs = [f.result(timeout=600) for f in futs]
            wall = time.perf_counter() - t0
            return decs, wall, svc.metrics.export()

    decs, wall_c, metrics = _drive(max_batch)
    _, wall_serial, _ = _drive(1)
    for i, dec in enumerate(decs):
        for got, want in zip(dec.factors, baselines[i]):
            assert np.array_equal(np.asarray(got), want), (
                f"coalesced request {i} diverged from its standalone solve")
    assert metrics["cache_hit_rate"] >= 0.5, metrics
    assert metrics["failed"] == 0, metrics
    row = dict(
        n_requests=n_requests, m=m, n=n, k=k, max_batch=max_batch,
        wall_s=round(wall_c, 4),
        wall_s_serial=round(wall_serial, 4),
        throughput_rps=round(n_requests / wall_c, 1),
        latency_ratio_vs_serial=round(wall_c / wall_serial, 3),
        coalescing_factor=round(metrics["coalescing_factor"], 3),
        cache_hit_rate=round(metrics["cache_hit_rate"], 3),
        compiles=metrics["compiles"],
        latency_s_p50=round(metrics["latency_s_p50"], 5),
        latency_s_p99=round(metrics["latency_s_p99"], 5),
        queue_s_p50=round(metrics["queue_s_p50"], 5),
        predicted_walltime_err_p50=round(
            metrics["predicted_walltime_err_p50"], 4),
        backend=jax.default_backend(),
    )
    # schema v9: the resilience counters ride the service row (all zero in
    # this fault-free traffic run — the resume_rows lane exercises them)
    row.update(
        cancelled=metrics["cancelled"],
        deadline_exceeded=metrics["deadline_exceeded"],
        restarts=metrics["restarts"],
        resumed_jobs=metrics["resumed_jobs"],
        checkpoint_overhead_s=round(metrics["checkpoint_overhead_s"], 5),
    )
    assert row["coalescing_factor"] > 1.0, row  # batching actually happened
    if jax.default_backend() == "tpu":
        # where the batched executors own the device, coalescing must win
        assert row["latency_ratio_vs_serial"] <= 1.0, row
    return [row]


def resume_rows(m=4096, n=512, k=32, block_rows=512,
                am=512, an=256, interrupt_at=5):
    """Schema v9: what panel-granular checkpointing costs, and proof that
    an interrupted solve resumes bit-identically.

    Both resumable engines run three ways: checkpoint-off (the baseline),
    checkpoint-on every boundary (uninterrupted — the overhead ratio), and
    interrupted by an injected transient fault at a panel-group boundary,
    then resumed from the surviving snapshots.  Bit-identity of the
    checkpointed AND the resumed factors against the off baseline is
    asserted on EVERY backend: snapshots capture host-side state between
    panels, they never touch the arithmetic or re-read A (the plan's
    predicted HBM bytes are checkpoint-blind by construction).  The
    overhead ratio is recorded, never gated — it is fsync-bound, not
    device-bound.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro import linalg
    from repro.core.blocked import svd_streamed
    from repro.core.rsvd import RSVDConfig
    from repro.core.spectra import make_test_matrix
    from repro.linalg import faults
    from repro.linalg import snapshot as snap

    rows = []
    workdir = tempfile.mkdtemp(prefix="bench_resume_")
    try:
        # ---- streamed ----------------------------------------------------
        A = np.asarray(make_test_matrix(m, n, "fast", seed=0)[0])
        cfg = RSVDConfig(qr_method="cqr2", power_iters=2,
                         block_rows=block_rows)
        ref = svd_streamed(A, k, cfg, seed=0)
        t_off = _time(lambda a: svd_streamed(a, k, cfg, seed=0), A)

        def _ck_streamed(a):
            ck = snap.Checkpointer(tempfile.mkdtemp(dir=workdir), every=1)
            with snap.scope(snap.RunControl(checkpointer=ck)):
                return svd_streamed(a, k, cfg, seed=0), ck
        out_ck, _ = _ck_streamed(A)
        for x, y in zip(ref, out_ck):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                "checkpointing changed bits")
        t_on = _time(lambda a: _ck_streamed(a)[0], A)

        ckdir = tempfile.mkdtemp(dir=workdir)
        ck = snap.Checkpointer(ckdir, every=1)
        try:
            with faults.inject("preempt", panel=interrupt_at):
                with snap.scope(snap.RunControl(checkpointer=ck)):
                    svd_streamed(A, k, cfg, seed=0)
            raise AssertionError("injected preemption never fired")
        except faults.PreemptionError:
            pass
        t0 = time.perf_counter()
        with snap.scope(snap.RunControl(
                checkpointer=snap.Checkpointer(ckdir))):
            resumed = svd_streamed(A, k, cfg, seed=0)
        t_resume = time.perf_counter() - t0
        for x, y in zip(ref, resumed):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                "resume changed bits")
        rows.append(dict(
            path="streamed", m=m, n=n, k=k, block_rows=block_rows,
            wall_s_off=round(t_off, 4),
            wall_s_checkpointed=round(t_on, 4),
            checkpoint_overhead_ratio=round(t_on / t_off, 3),
            snapshot_saves=ck.saves,
            snapshot_overhead_s=round(ck.overhead_s, 4),
            interrupted_at=interrupt_at,
            wall_s_resumed=round(t_resume, 4),
            resume_bit_identical=True,
            backend=jax.default_backend(),
        ))

        # ---- adaptive ----------------------------------------------------
        A2 = jnp.asarray(make_test_matrix(am, an, "sharp", seed=0)[0])
        spec = linalg.Tolerance(1e-2, panel=16)
        dref = linalg.decompose(A2, spec, seed=0)
        t_off = _time(lambda a: linalg.decompose(a, spec, seed=0).factors, A2)
        t_on = _time(lambda a: linalg.decompose(
            a, spec, seed=0,
            checkpoint=tempfile.mkdtemp(dir=workdir)).factors, A2)

        ckdir = tempfile.mkdtemp(dir=workdir)
        try:
            with faults.inject("device_lost", panel=2):
                linalg.decompose(A2, spec, seed=0, checkpoint=ckdir)
            raise AssertionError("injected device loss never fired")
        except faults.DeviceLostError:
            pass
        ck = snap.Checkpointer(ckdir)
        t0 = time.perf_counter()
        dres = linalg.decompose(A2, spec, seed=0, checkpoint=ck)
        t_resume = time.perf_counter() - t0
        for x, y in zip(dref.factors, dres.factors):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                "adaptive resume changed bits")
        assert dres.rank_history == dref.rank_history
        rows.append(dict(
            path="adaptive", m=am, n=an, eps=1e-2, panel=16, rank=dres.rank,
            wall_s_off=round(t_off, 4),
            wall_s_checkpointed=round(t_on, 4),
            checkpoint_overhead_ratio=round(t_on / t_off, 3),
            snapshot_saves=ck.saves,
            snapshot_overhead_s=round(ck.overhead_s, 4),
            interrupted_at=2,
            wall_s_resumed=round(t_resume, 4),
            resume_bit_identical=True,
            backend=jax.default_backend(),
        ))
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return rows


def analysis_rows():
    """Schema v8: the static-analysis gates as a recorded bench row.

    The AST lint over src/ and the jaxpr contract sweep over the planner's
    golden dispatch table both run to completion here; their walltimes land
    in the report (the analyzer is part of the CI budget, so its runtime is
    tracked like any other lane's) and their outcomes gate the report —
    findings or contract violations fail the bench, not just the lint lane.
    """
    from repro.analysis import engine
    from repro.analysis import contracts as contracts_mod

    t0 = time.perf_counter()
    lint = engine.lint_paths(["src"])
    lint_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep = contracts_mod.sweep()
    sweep_s = time.perf_counter() - t0
    row = dict(
        lint_files=lint.files,
        lint_findings=len(lint.findings),
        lint_suppressions=len(lint.suppressed),
        lint_walltime_s=round(lint_s, 3),
        contract_plans=len(sweep.plans),
        contract_checks=len(sweep.results),
        contract_violations=len(sweep.violations),
        contract_sweep_walltime_s=round(sweep_s, 3),
    )
    assert row["lint_findings"] == 0, [f.format() for f in lint.findings]
    assert row["contract_violations"] == 0, [
        f"{r.contract}[{r.plan_label}]: {r.detail}" for r in sweep.violations]
    return [row]


def build_report(smoke: bool = False) -> dict:
    report = {
        "schema": "bench_rsvd/v9",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "traffic_model_per_power_iter": traffic_rows(),
        "variants": variant_rows(*((128, 64, 8) if smoke else (512, 256, 16))),
        "adaptive": adaptive_rows(*((192, 96, 1e-2, 16) if smoke
                                    else (512, 256, 1e-2, 16))),
        "pipeline": pipeline_rows(*((1024, 256, 8, 256) if smoke
                                    else (16384, 2048, 64, 2048))),
        "sparse": sparse_rows(*((512, 256, 8) if smoke else (2048, 1024, 16))),
        "guard": guard_rows(*((256, 64, 8, 512, 64) if smoke
                              else (2048, 512, 32, 4096, 512))),
        "service": service_rows(*((16, 32, 16, 4, 4) if smoke
                                  else (64, 64, 32, 8, 8))),
        "resume": resume_rows(*((1024, 256, 8, 256, 192, 96, 5) if smoke
                                else (4096, 512, 32, 512, 512, 256, 5))),
        "analysis": analysis_rows(),
    }
    for row in report["traffic_model_per_power_iter"]:
        assert row["saving"] >= 1.5, (
            f"fused power step must save >=1.5x HBM bytes/iter, got {row}")
    from repro.roofline import rsvd_model

    for row in report["variants"]:
        # the executed plan's whole-solve prediction must come from the SAME
        # roofline model the planner uses (guards model drift)
        p = row["plan"]
        assert p["predicted_hbm_bytes"] == rsvd_model.predicted_hbm_bytes(
            p["m"], p["n"], p["s"], p["power_iters"], p["fused_power"],
            p["fused_sketch"], dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
            batch=p["batch"],
        ), row
    for row in report["pipeline"]:
        # the plan's pipeline fields must equal the overlap model evaluated
        # at the plan's own fields — predicted == recorded, no drift
        p = row["plan"]
        assert p["predicted_walltime_s"] == rsvd_model.streamed_walltime_s(
            p["m"], p["n"], p["s"], p["block_rows"], p["power_iters"],
            p["pipeline_depth"], dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
            fused_sketch=p["fused_sketch"],
        ), row
        assert row["model_wall_s_overlapped"] == p["predicted_walltime_s"], row
        assert p["predicted_hbm_bytes"] == rsvd_model.predicted_hbm_bytes(
            p["m"], p["n"], p["s"], p["power_iters"], p["fused_power"],
            p["fused_sketch"], dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
            batch=p["batch"],
        ), row
        assert p["pipeline_depth"] >= 2, row
    for row in report["sparse"]:
        # the executed sparse plan's bytes ARE the sparse roofline model —
        # same guard against model drift as the dense variants above
        p = row["plan"]
        assert p["predicted_hbm_bytes"] == rsvd_model.sparse_predicted_hbm_bytes(
            p["m"], p["n"], p["s"], p["power_iters"], p["nnz"],
            fused_sketch=p["fused_sketch"],
            dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
        ), row
        if row["density"] <= 0.01 and not smoke:
            # the acceptance bar holds at the full sweep shape; the smoke
            # shape's m*s / n*s output terms dominate and cap the ratio
            assert row["sketch_pricing_ratio"] >= 10.0, row
        if jax.default_backend() == "tpu":
            # the walltime bar holds only where SpMM runs as a real kernel
            assert row["walltime_ratio"] <= 0.5, row
    for row in report["resume"]:
        # resumability is a durability upgrade, never a numerics change —
        # bit-identity holds on every backend, and snapshots were written
        assert row["resume_bit_identical"] is True, row
        assert row["snapshot_saves"] > 0, row
        assert row["checkpoint_overhead_ratio"] > 0, row
    for row in report["service"]:
        # fault-free traffic: the resilience counters must all stay zero
        assert row["cancelled"] == 0 and row["deadline_exceeded"] == 0, row
        assert row["restarts"] == 0 and row["resumed_jobs"] == 0, row
    return report


def main(out_path: str = "BENCH_rsvd.json", smoke: bool = False) -> None:
    report = build_report(smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for row in report["traffic_model_per_power_iter"]:
        print(f"rsvd_traffic_m{row['m']}_n{row['n']}_s{row['s']},0,"
              f"saving{row['saving']}x")
    for row in report["variants"]:
        print(f"rsvd_variant_{row['name']},{row['wall_s'] * 1e6:.0f},"
              f"readsA{row['reads_of_A']};path={row['plan']['path']}")
    for row in report["adaptive"]:
        print(f"rsvd_adaptive_eps{row['eps']},{row['wall_s_adaptive'] * 1e6:.0f},"
              f"rank{row['rank']};panels{row['panels_run']}/{row['panels_full']};"
              f"oracle{row['wall_s_oracle_rank'] * 1e6:.0f}us")
    for row in report["pipeline"]:
        print(f"rsvd_pipeline_d{row['pipeline_depth']},"
              f"{row['wall_s_overlapped'] * 1e6:.0f},"
              f"sync{row['wall_s_sync'] * 1e6:.0f}us;"
              f"ratio{row['overlap_ratio']};"
              f"xfer{row['transfer_s_total'] * 1e6:.0f}us")
    for row in report["sparse"]:
        print(f"rsvd_sparse_d{row['density']},"
              f"{row['wall_s_sparse'] * 1e6:.0f},"
              f"dense{row['wall_s_dense'] * 1e6:.0f}us;"
              f"nnz{row['nnz']};"
              f"pricing{row['sketch_pricing_ratio']}x")
    for row in report["guard"]:
        print(f"rsvd_guard_{row['path']},"
              f"{row['wall_s_report'] * 1e6:.0f},"
              f"off{row['wall_s_off'] * 1e6:.0f}us;"
              f"overhead{row['overhead_ratio']}x")
    for row in report["service"]:
        print(f"rsvd_service_b{row['max_batch']},"
              f"{row['wall_s'] * 1e6:.0f},"
              f"rps{row['throughput_rps']};"
              f"coalesce{row['coalescing_factor']}x;"
              f"hit{row['cache_hit_rate']};"
              f"p99_{row['latency_s_p99'] * 1e6:.0f}us")
    for row in report["resume"]:
        print(f"rsvd_resume_{row['path']},"
              f"{row['wall_s_resumed'] * 1e6:.0f},"
              f"ckpt{row['checkpoint_overhead_ratio']}x;"
              f"saves{row['snapshot_saves']};"
              f"interrupt@{row['interrupted_at']}")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="BENCH_rsvd.json")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes for the CI interpret-mode smoke lane")
    args = p.parse_args()
    main(args.out, smoke=args.smoke)
