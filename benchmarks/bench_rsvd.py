"""rSVD variant benchmark + the analytic HBM-traffic model, persisted.

Emits ``BENCH_rsvd.json`` (cwd, or --out PATH): per-variant wall time on the
current backend (CPU-container numbers are interpret-mode correctness
proxies, NOT TPU performance), the structural HBM-traffic model that the
fused one-pass range finder is built on (now shared with the execution
planner — repro/roofline/rsvd_model.py), and the EXECUTED `ExecutionPlan`
for every variant, so a BENCH_rsvd.json row says exactly which path / fused
flags / block sizes produced its number.  EXPERIMENTS.md records the
history; the traffic-model derivation lives in rsvd_model.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.roofline.rsvd_model import hbm_bytes_per_power_iter  # noqa: F401  (model home)


def traffic_rows(shapes=((2000, 2000, 100), (8192, 8192, 256), (65536, 4096, 128))):
    rows = []
    for m, n, s in shapes:
        unfused = hbm_bytes_per_power_iter(m, n, s, fused=False)
        fused = hbm_bytes_per_power_iter(m, n, s, fused=True)
        rows.append(
            dict(m=m, n=n, s=s, unfused_bytes_per_iter=unfused,
                 fused_bytes_per_iter=fused, saving=round(unfused / fused, 3))
        )
    return rows


def _time(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def variant_rows(m=512, n=256, k=16):
    from repro import linalg
    from repro.core.rsvd import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "fast", seed=0)
    variants = [
        ("faithful", RSVDConfig.faithful()),
        ("cqr2_unfused", RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                                    small_svd="gram_jacobi")),
        ("fast_fused", RSVDConfig.fast()),
    ]
    rows = []
    for name, cfg in variants:
        # Plan once, execute the pinned plan: the recorded plan IS what ran
        # (fused_power in the plan is the EFFECTIVE decision — the VMEM
        # guard or f64 can veto the config flag).
        pl = linalg.plan(linalg.DenseOp(A), k, overrides=cfg)
        t = _time(lambda a, p=pl: linalg.svd(a, k, plan=p), A)
        q = pl.power_iters
        rows.append(
            dict(name=name, m=m, n=n, k=k, wall_s=round(t, 4),
                 reads_of_A=(1 + q) if pl.fused_power else (2 * q + 2),
                 backend=jax.default_backend(),
                 plan=dataclasses.asdict(pl))
        )
    return rows


def build_report(smoke: bool = False) -> dict:
    report = {
        "schema": "bench_rsvd/v2",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "traffic_model_per_power_iter": traffic_rows(),
        "variants": variant_rows(*((128, 64, 8) if smoke else (512, 256, 16))),
    }
    for row in report["traffic_model_per_power_iter"]:
        assert row["saving"] >= 1.5, (
            f"fused power step must save >=1.5x HBM bytes/iter, got {row}")
    for row in report["variants"]:
        # the executed plan's whole-solve prediction must come from the SAME
        # roofline model the planner uses (guards model drift)
        from repro.roofline import rsvd_model

        p = row["plan"]
        assert p["predicted_hbm_bytes"] == rsvd_model.predicted_hbm_bytes(
            p["m"], p["n"], p["s"], p["power_iters"], p["fused_power"],
            p["fused_sketch"], dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
            batch=p["batch"],
        ), row
    return report


def main(out_path: str = "BENCH_rsvd.json", smoke: bool = False) -> None:
    report = build_report(smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for row in report["traffic_model_per_power_iter"]:
        print(f"rsvd_traffic_m{row['m']}_n{row['n']}_s{row['s']},0,"
              f"saving{row['saving']}x")
    for row in report["variants"]:
        print(f"rsvd_variant_{row['name']},{row['wall_s'] * 1e6:.0f},"
              f"readsA{row['reads_of_A']};path={row['plan']['path']}")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="BENCH_rsvd.json")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes for the CI interpret-mode smoke lane")
    args = p.parse_args()
    main(args.out, smoke=args.smoke)
