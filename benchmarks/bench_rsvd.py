"""rSVD variant benchmark + the analytic HBM-traffic model, persisted.

Emits ``BENCH_rsvd.json`` (cwd, or --out PATH): per-variant wall time on the
current backend (CPU-container numbers are interpret-mode correctness
proxies, NOT TPU performance) plus the structural HBM-traffic model that the
fused one-pass range finder is built on — the perf trajectory the ROADMAP's
"fast as the hardware allows" is measured against.  EXPERIMENTS.md records
the history.

Traffic model (fp32 words, per stabilized power iteration, A is m x n with
sketch width s; reads+writes of every operand, Grams/TRSMs included):

  unfused:  Z = AᵀQ and Y' = A·Qz are separate GEMMs  -> A read TWICE
            + CQR2 of Y reads Y twice and round-trips Q1/Q
  fused:    kernels/power_step.py reads A ONCE, returns (Y, W=AᵀY, G=YᵀY);
            Z = W R⁻¹ is a sketch-width TRSM, G kills CQR's first pass

so bytes/iter drop from ~2mn + 8ms + 8ns to ~mn + 4ms + 10ns — asymptotically
2x, and >= 1.5x at every paper benchmark shape (asserted in the smoke lane).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def hbm_bytes_per_power_iter(m: int, n: int, s: int, fused: bool, dtype_bytes: int = 4) -> int:
    """Analytic HBM traffic of ONE stabilized power iteration (see module doc)."""
    if fused:
        # power_step: read A + read Qz + write Y + write W (G is s x s, ~0)
        kernel = m * n + n * s + m * s + n * s
        # CQR2 with free first Gram: TRSM(Y)->Q1 (read Y, write Q1), gram(Q1)
        cqr = 3 * m * s
        # Z = W R^-1 (read W, write Z) + orthonormalize(Z) ~ CQR2 on n x s
        small = 2 * n * s + 6 * n * s
        return (kernel + cqr + small) * dtype_bytes
    # Z = A^T Q (read A, read Q, write Z) + Y' = A Qz (read A, read Qz, write Y)
    gemms = (m * n + m * s + n * s) + (m * n + n * s + m * s)
    # CQR2 of Y: gram(Y) + TRSM(Y)->Q1 + gram(Q1) + TRSM(Q1)->Q
    cqr = 6 * m * s
    small = 6 * n * s  # orthonormalize(Z)
    return (gemms + cqr + small) * dtype_bytes


def traffic_rows(shapes=((2000, 2000, 100), (8192, 8192, 256), (65536, 4096, 128))):
    rows = []
    for m, n, s in shapes:
        unfused = hbm_bytes_per_power_iter(m, n, s, fused=False)
        fused = hbm_bytes_per_power_iter(m, n, s, fused=True)
        rows.append(
            dict(m=m, n=n, s=s, unfused_bytes_per_iter=unfused,
                 fused_bytes_per_iter=fused, saving=round(unfused / fused, 3))
        )
    return rows


def _time(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def variant_rows(m=512, n=256, k=16):
    from repro.core.rsvd import RSVDConfig, _use_fused_power, randomized_svd
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "fast", seed=0)
    variants = [
        ("faithful", RSVDConfig.faithful()),
        ("cqr2_unfused", RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                                    small_svd="gram_jacobi")),
        ("fast_fused", RSVDConfig.fast()),
    ]
    rows = []
    for name, cfg in variants:
        t = _time(lambda a, c=cfg: randomized_svd(a, k, c), A)
        q = cfg.power_iters
        # fused (when it actually DISPATCHES at this shape/dtype — the VMEM
        # guard or f64 can veto the flag): sketch_power emits W=AᵀY, each
        # iteration reads A once, and the final projection reuses the last
        # W.  unfused: sketch + two reads per iteration + final B = QᵀA.
        s = min(k + cfg.oversample, min(m, n))
        fused = _use_fused_power(A, cfg, s)
        rows.append(
            dict(name=name, m=m, n=n, k=k, wall_s=round(t, 4),
                 reads_of_A=(1 + q) if fused else (2 * q + 2),
                 backend=jax.default_backend())
        )
    return rows


def build_report(smoke: bool = False) -> dict:
    report = {
        "schema": "bench_rsvd/v1",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "traffic_model_per_power_iter": traffic_rows(),
        "variants": variant_rows(*((128, 64, 8) if smoke else (512, 256, 16))),
    }
    for row in report["traffic_model_per_power_iter"]:
        assert row["saving"] >= 1.5, (
            f"fused power step must save >=1.5x HBM bytes/iter, got {row}")
    return report


def main(out_path: str = "BENCH_rsvd.json", smoke: bool = False) -> None:
    report = build_report(smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for row in report["traffic_model_per_power_iter"]:
        print(f"rsvd_traffic_m{row['m']}_n{row['n']}_s{row['s']},0,"
              f"saving{row['saving']}x")
    for row in report["variants"]:
        print(f"rsvd_variant_{row['name']},{row['wall_s'] * 1e6:.0f},"
              f"readsA{row['reads_of_A']}")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="BENCH_rsvd.json")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes for the CI interpret-mode smoke lane")
    args = p.parse_args()
    main(args.out, smoke=args.smoke)
