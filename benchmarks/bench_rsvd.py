"""rSVD variant benchmark + the analytic HBM-traffic model, persisted.

Emits ``BENCH_rsvd.json`` (cwd, or --out PATH; CI uploads it as a workflow
artifact): per-variant wall time on the current backend (CPU-container
numbers are interpret-mode correctness proxies, NOT TPU performance), the
structural HBM-traffic model that the fused one-pass range finder is built
on (now shared with the execution planner — repro/roofline/rsvd_model.py),
the EXECUTED `ExecutionPlan` for every variant, and — schema v3 — the
ADAPTIVE (fixed-precision) mode: the rank-growth trajectory, the per-step
roofline bytes from the plan's schedule, and adaptive-vs-oracle-rank wall
time.  EXPERIMENTS.md records the history; the traffic-model derivation
lives in rsvd_model.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.roofline.rsvd_model import hbm_bytes_per_power_iter  # noqa: F401  (model home)


def traffic_rows(shapes=((2000, 2000, 100), (8192, 8192, 256), (65536, 4096, 128))):
    rows = []
    for m, n, s in shapes:
        unfused = hbm_bytes_per_power_iter(m, n, s, fused=False)
        fused = hbm_bytes_per_power_iter(m, n, s, fused=True)
        rows.append(
            dict(m=m, n=n, s=s, unfused_bytes_per_iter=unfused,
                 fused_bytes_per_iter=fused, saving=round(unfused / fused, 3))
        )
    return rows


def _time(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def variant_rows(m=512, n=256, k=16):
    from repro import linalg
    from repro.core.rsvd import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "fast", seed=0)
    variants = [
        ("faithful", RSVDConfig.faithful()),
        ("cqr2_unfused", RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                                    small_svd="gram_jacobi")),
        ("fast_fused", RSVDConfig.fast()),
    ]
    rows = []
    for name, cfg in variants:
        # Plan once, execute the pinned plan: the recorded plan IS what ran
        # (fused_power in the plan is the EFFECTIVE decision — the VMEM
        # guard or f64 can veto the config flag).
        pl = linalg.plan(linalg.DenseOp(A), k, overrides=cfg)
        t = _time(lambda a, p=pl: linalg.svd(a, k, plan=p), A)
        q = pl.power_iters
        rows.append(
            dict(name=name, m=m, n=n, k=k, wall_s=round(t, 4),
                 reads_of_A=(1 + q) if pl.fused_power else (2 * q + 2),
                 backend=jax.default_backend(),
                 plan=dataclasses.asdict(pl))
        )
    return rows


def adaptive_rows(m=512, n=256, eps=1e-2, panel=16):
    """Fixed-precision mode: `decompose(A, Tolerance(eps))` on the paper's
    sharp-decay (exponential drop) spectrum.  Records the executed rank
    trajectory and the plan's per-step roofline bytes, and times the
    adaptive solve against the oracle fixed-rank solve (the rank the
    adaptive run discovered — the walltime a clairvoyant caller would pay).
    """
    from repro import linalg
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(m, n, "sharp", seed=0)
    spec = linalg.Tolerance(eps, panel=panel)
    dec = linalg.decompose(A, spec, seed=0)  # warm the per-panel programs
    t_adaptive = _time(lambda a: linalg.decompose(a, spec, seed=0).factors, A)
    t_oracle = _time(lambda a: linalg.svd(a, dec.rank), A)
    achieved = float(linalg.residual(A, dec.factors))
    pl = dec.plan
    row = dict(
        m=m, n=n, eps=eps, panel=panel,
        rank=dec.rank,
        achieved_rel_error=round(achieved, 6),
        rank_trajectory=list(dec.rank_history),
        err_trajectory=[round(e, 6) for e in dec.err_history],
        plan_rank_schedule=list(pl.rank_schedule),
        plan_step_bytes=list(pl.schedule_hbm_bytes),
        panels_run=len(dec.rank_history),
        panels_full=len(pl.rank_schedule),
        wall_s_adaptive=round(t_adaptive, 4),
        wall_s_oracle_rank=round(t_oracle, 4),
        backend=jax.default_backend(),
    )
    # acceptance invariants, checked before the JSON is written
    assert achieved <= eps, row
    assert row["panels_run"] < row["panels_full"], row
    from repro.roofline import rsvd_model

    assert tuple(row["plan_step_bytes"]) == rsvd_model.adaptive_schedule_bytes(
        pl.m, pl.n, pl.rank_schedule, pl.power_iters,
        dtype_bytes=4, fused_sketch=pl.fused_sketch), row
    return [row]


def build_report(smoke: bool = False) -> dict:
    report = {
        "schema": "bench_rsvd/v3",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "traffic_model_per_power_iter": traffic_rows(),
        "variants": variant_rows(*((128, 64, 8) if smoke else (512, 256, 16))),
        "adaptive": adaptive_rows(*((192, 96, 1e-2, 16) if smoke
                                    else (512, 256, 1e-2, 16))),
    }
    for row in report["traffic_model_per_power_iter"]:
        assert row["saving"] >= 1.5, (
            f"fused power step must save >=1.5x HBM bytes/iter, got {row}")
    for row in report["variants"]:
        # the executed plan's whole-solve prediction must come from the SAME
        # roofline model the planner uses (guards model drift)
        from repro.roofline import rsvd_model

        p = row["plan"]
        assert p["predicted_hbm_bytes"] == rsvd_model.predicted_hbm_bytes(
            p["m"], p["n"], p["s"], p["power_iters"], p["fused_power"],
            p["fused_sketch"], dtype_bytes=jnp.dtype(p["dtype"]).itemsize,
            batch=p["batch"],
        ), row
    return report


def main(out_path: str = "BENCH_rsvd.json", smoke: bool = False) -> None:
    report = build_report(smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for row in report["traffic_model_per_power_iter"]:
        print(f"rsvd_traffic_m{row['m']}_n{row['n']}_s{row['s']},0,"
              f"saving{row['saving']}x")
    for row in report["variants"]:
        print(f"rsvd_variant_{row['name']},{row['wall_s'] * 1e6:.0f},"
              f"readsA{row['reads_of_A']};path={row['plan']['path']}")
    for row in report["adaptive"]:
        print(f"rsvd_adaptive_eps{row['eps']},{row['wall_s_adaptive'] * 1e6:.0f},"
              f"rank{row['rank']};panels{row['panels_run']}/{row['panels_full']};"
              f"oracle{row['wall_s_oracle_rank'] * 1e6:.0f}us")
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="BENCH_rsvd.json")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes for the CI interpret-mode smoke lane")
    args = p.parse_args()
    main(args.out, smoke=args.smoke)
