"""Render the §Roofline table from dry-run artifacts (benchmarks entry)."""
from __future__ import annotations

from repro.roofline.analysis import format_table, load_all, pick_hillclimb_cells


def main():
    rows = load_all()
    if not rows:
        print("roofline_report,0,no artifacts found (run repro.launch.dryrun first)")
        return
    live = [r for r in rows if not r.skipped and r.mesh == "single"]
    for r in live:
        print(
            f"roofline_{r.arch}_{r.shape}_{r.mesh},{r.dominant_time()*1e6:.0f},"
            f"bottleneck={r.bottleneck};frac{r.roofline_fraction():.3f};"
            f"useful{r.useful_ratio:.3f}"
        )
    picks = pick_hillclimb_cells(rows)
    for label, r in picks.items():
        print(f"hillclimb_pick_{label},0,{r.arch}__{r.shape}")


if __name__ == "__main__":
    main()
