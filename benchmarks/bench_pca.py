"""Paper Fig 1: PCA of flattened images at increasing resolution.

CelebA is not available offline; we use image-statistics-like synthetic
matrices with identical shapes (N x 3hw) and the paper's component
fractions.  Columns: ours vs dense-SVD PCA (GESVD) and vs the faithful
RSVD configuration.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pca import pca, pca_exact, synthetic_image_dataset
from repro.core.rsvd import RSVDConfig

# see bench_spectra: on this CPU host 'ours' is the faithful Algorithm 1;
# the TPU-path columns are structural (interpret mode is not a perf mode).
OURS = RSVDConfig()
FAITHFUL = RSVDConfig(power_scheme="plain")  # naive-RSVD-package emulation


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run(resolutions=(8, 16, 24, 32), n_images=2048, fracs=(0.05, 0.30)):
    rows = []
    for res in resolutions:
        X = synthetic_image_dataset(n_images, res, res, seed=res)
        d = X.shape[1]
        for frac in fracs:
            k = max(1, int(frac * d))
            t_ours, r_ours = _time(functools.partial(pca, k=k, cfg=OURS), X)
            t_faith, _ = _time(functools.partial(pca, k=k, cfg=FAITHFUL), X)
            t_exact, r_exact = _time(functools.partial(pca_exact, k=k), X)
            # quality: explained variance captured vs exact
            ev_ratio = float(
                jnp.sum(r_ours.explained_variance) / jnp.sum(r_exact.explained_variance)
            )
            rows.append(
                dict(
                    res=res, d=d, k=k,
                    us_ours=t_ours * 1e6,
                    speedup_gesvd=t_exact / t_ours,
                    speedup_rsvd_naive=t_faith / t_ours,
                    explained_var_ratio=ev_ratio,
                )
            )
    return rows


def main():
    for r in run():
        print(
            f"pca_res{r['res']}_k{r['k']},{r['us_ours']:.0f},"
            f"gesvd_x{r['speedup_gesvd']:.2f};rsvd_x{r['speedup_rsvd_naive']:.2f};"
            f"ev{r['explained_var_ratio']:.4f}"
        )


if __name__ == "__main__":
    main()
