# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_spectra   -> Figs 2-4 (fast/sharp/slow-decay k-SV speed vs baselines)
  bench_pca       -> Fig 1    (PCA at increasing image resolution)
  bench_sumc      -> Table 1  (SuMC subspace clustering, solver swap)
  bench_kernels   -> kernel microbenches + fused-sketch HBM-traffic model
  bench_rsvd      -> rSVD variants + fused-power traffic model -> BENCH_rsvd.json
  roofline_report -> §Roofline terms from the dry-run artifacts
"""
import pathlib
import sys
import traceback

# Make `benchmarks` importable when invoked as `python benchmarks/run.py`
# (script dir, not the repo root, lands on sys.path by default).
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def main() -> None:
    from benchmarks import bench_kernels, bench_pca, bench_rsvd, bench_spectra
    from benchmarks import bench_sumc, roofline_report

    modules = [
        ("spectra", bench_spectra),
        ("pca", bench_pca),
        ("sumc", bench_sumc),
        ("kernels", bench_kernels),
        ("rsvd", bench_rsvd),
        ("roofline", roofline_report),
    ]
    failures = 0
    for name, mod in modules:
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
