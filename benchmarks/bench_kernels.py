"""Kernel microbenchmarks (interpret-mode correctness + CPU-proxy timings) and
the structural HBM-traffic model for the fused sketch (the paper's RNG claim,
TPU edition): materialized Omega costs 2ns extra HBM bytes (write+read);
the fused kernel costs zero.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import sketch_matrix
from repro.kernels import ops, ref


def _time(fn, *args, reps=2):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def hbm_traffic_model(m, n, s, dtype_bytes=4):
    """(bytes with materialized Omega, bytes with fused kernel)."""
    base = m * n * dtype_bytes + m * s * dtype_bytes      # read A, write C
    omega = 2 * n * s * dtype_bytes                        # write + read Omega
    return base + omega, base


def block_size_sweep(m=2048, n=192, k=16, block_rows=(128, 256, 512, 2048)):
    """Blocked (panel-streaming) rSVD across block sizes vs the dense path.

    On this CPU container the numbers are correctness-proxy timings; the
    structural payload is the working-set column: device-resident floats
    drop from m*n to block_rows*n + n*s while the result stays within 1e-4
    (test_blocked.py).
    """
    from repro import linalg
    from repro.core.rsvd import RSVDConfig

    rows = []
    A = sketch_matrix(m, n, 0)
    s = k + 10
    t_dense = _time(lambda a: linalg.svd(a, k, overrides=RSVDConfig()), A, reps=1)
    rows.append(
        dict(name=f"rsvd_dense_m{m}_n{n}_k{k}", us=t_dense * 1e6,
             derived=f"workset{m * n}")
    )
    for b in block_rows:
        cfg = RSVDConfig.streaming(block_rows=b)
        t = _time(lambda a, cfg=cfg: linalg.svd(a, k, overrides=cfg), A, reps=1)
        rows.append(
            dict(name=f"rsvd_blocked_m{m}_n{n}_k{k}_b{b}", us=t * 1e6,
                 derived=f"workset{b * n + n * s};dense_us{t_dense * 1e6:.0f}")
        )
    return rows


def batch_count_sweep(counts=(1, 4, 16), m=128, n=64, k=8):
    """Batched (vmap) rSVD vs a per-slice Python loop at growing batch sizes."""
    from repro import linalg
    from repro.core.rsvd import RSVDConfig

    cfg = RSVDConfig()  # same numerical variant on both sides of the ratio
    rows = []
    for B in counts:
        A = sketch_matrix(B * m, n, 1).reshape(B, m, n)
        t_b = _time(lambda a: linalg.svd(a, k, overrides=cfg), A, reps=1)

        def loop(a):
            return [linalg.svd(a[i], k, overrides=cfg, seed=i) for i in range(a.shape[0])]

        t_l = _time(loop, A, reps=1)
        rows.append(
            dict(name=f"rsvd_batched_B{B}_m{m}_n{n}_k{k}", us=t_b * 1e6,
                 derived=f"loop_us{t_l * 1e6:.0f};speedup{t_l / max(t_b, 1e-9):.2f}x")
        )
    return rows


def kernel_block_autotune(m=512, k=512, n=256):
    """Sweep (bm, bn, bk) for the matmul kernel and record the winner in the
    autotune cache (persisted iff $REPRO_AUTOTUNE_CACHE is set); ops.matmul
    consults the cache at trace time for every shape in the same bucket."""
    import jax.numpy as jnp

    from repro.kernels import autotune as at
    from repro.kernels.matmul import matmul_padded

    a = sketch_matrix(m, k, 0)
    b = sketch_matrix(k, n, 1)

    def run_cand(blocks):
        pad = lambda x, ms: jnp.pad(x, [(0, (-d) % mm) for d, mm in zip(x.shape, ms)])
        return matmul_padded(
            pad(a, (blocks.bm, blocks.bk)), pad(b, (blocks.bk, blocks.bn)),
            bm=blocks.bm, bn=blocks.bn, bk=blocks.bk, interpret=True,
        )

    from repro.kernels import ops as kops

    best = at.autotune(
        "matmul", run_cand, (m, n, k), "float32", kops._backend_name(),
        candidates=((128, 128, 128), (256, 128, 128), (128, 128, 256)),
    )
    path = at.save()
    return [dict(name=f"autotune_matmul_{m}x{k}x{n}", us=0.0,
                 derived=f"best{best.astuple()};cache{path or 'in-memory'}")]


def run():
    rows = []
    # traffic model at the paper's scales
    for (m, n, s) in [(2000, 2000, 100), (8192, 8192, 256), (65536, 4096, 128)]:
        mat, fused = hbm_traffic_model(m, n, s)
        rows.append(
            dict(name=f"sketch_traffic_m{m}_n{n}_s{s}",
                 us=0.0,
                 derived=f"materialized{mat};fused{fused};saving{mat/fused:.3f}x")
        )
    rows += block_size_sweep()
    rows += batch_count_sweep()
    rows += kernel_block_autotune()
    # interpret-mode sanity timings (NOT TPU performance — correctness proxy)
    a = sketch_matrix(512, 512, 0)
    b = sketch_matrix(512, 256, 1)
    t_mm = _time(ops.matmul, a, b)
    t_ref = _time(ref.matmul_ref, a, b)
    rows.append(dict(name="matmul_512x512x256_interp", us=t_mm * 1e6,
                     derived=f"ref_us{t_ref*1e6:.0f}"))
    t_sk = _time(lambda x: ops.sketch_matmul(x, 64, seed=3), a)
    t_skref = _time(lambda x: ref.sketch_matmul_ref(x, 64, seed=3), a)
    rows.append(dict(name="sketch_512x512x64_interp", us=t_sk * 1e6,
                     derived=f"ref_us{t_skref*1e6:.0f}"))
    t_gram = _time(ops.gram, b)
    rows.append(dict(name="gram_512x256_interp", us=t_gram * 1e6, derived=""))
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
