"""Paper Table 1: SuMC subspace clustering, dense-eigensolver vs RSVD solver.

Scaled-down versions of the paper's synthetic datasets (the paper's 'first'
is 3500 x 1000 with 30/50/70-dim subspaces; we keep the structure at reduced
ambient dim so the CPU-container run finishes in seconds).  Reported:
elapsed time, solver calls, ARI — the paper's three columns.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.sumc import (
    adjusted_rand_index,
    eigh_solver,
    rsvd_solver,
    sumc,
    synthetic_subspace_data,
)


def run():
    rows = []
    datasets = {
        "first_scaled": dict(sizes=[125, 250, 500], dims=[8, 12, 17], ambient=250),
        "second_scaled": dict(sizes=[500, 1000, 2000], dims=[8, 12, 17], ambient=250),
    }
    for name, spec in datasets.items():
        X, y = synthetic_subspace_data(**spec, seed=0)
        for solver_name, solver in [("eigh(CPU-col)", eigh_solver), ("rsvd(GPU-col)", rsvd_solver)]:
            t0 = time.perf_counter()
            res = sumc(
                X, n_clusters=3, subspace_dims=spec["dims"], solver=solver,
                seed=1, n_init=3,
            )
            dt = time.perf_counter() - t0
            ari = adjusted_rand_index(res.labels, y)
            rows.append(
                dict(dataset=name, solver=solver_name, elapsed_s=dt,
                     solver_calls=res.solver_calls, ari=ari)
            )
    return rows


def main():
    for r in run():
        print(
            f"sumc_{r['dataset']}_{r['solver']},{r['elapsed_s']*1e6:.0f},"
            f"calls{r['solver_calls']};ari{r['ari']:.3f}"
        )


if __name__ == "__main__":
    main()
