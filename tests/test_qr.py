"""Property tests for the CholeskyQR family (hypothesis + fixed cases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.compat import enable_x64
from repro.core import qr as qr_mod
from repro.core.sketch import sketch_matrix


def _cond_matrix(m, s, cond, seed=0):
    """Tall matrix with prescribed 2-norm condition number."""
    G = sketch_matrix(m, s, seed, dtype=jnp.float32)
    Q, _ = jnp.linalg.qr(G)
    G2 = sketch_matrix(s, s, seed + 1, dtype=jnp.float32)
    Q2, _ = jnp.linalg.qr(G2)
    sig = jnp.logspace(0, -np.log10(cond), s)
    return (Q * sig[None, :]) @ Q2.T


@pytest.mark.parametrize("method", ["cqr", "cqr2", "cqr3", "householder"])
def test_orthogonality_well_conditioned(method):
    Y = _cond_matrix(300, 40, cond=10.0)
    Q = qr_mod.orthonormalize(Y, method)
    err = np.abs(np.asarray(Q.T @ Q) - np.eye(40)).max()
    # single-pass CQR carries the rank-deficiency floor shift at first order;
    # the multi-pass variants (the production paths) restore O(eps).
    tol = 5e-3 if method == "cqr" else 5e-5
    assert err < tol, (method, err)


def test_cqr2_beats_cqr_on_moderate_condition():
    """CQR loses orthogonality as kappa^2*eps; CQR2 restores it to O(eps)."""
    Y = _cond_matrix(400, 30, cond=3e3)
    Q1 = qr_mod.orthonormalize(Y, "cqr")
    Q2 = qr_mod.orthonormalize(Y, "cqr2")
    e1 = np.abs(np.asarray(Q1.T @ Q1) - np.eye(30)).max()
    e2 = np.abs(np.asarray(Q2.T @ Q2) - np.eye(30)).max()
    assert e2 < 1e-4
    assert e2 < e1 / 10


def test_cqr3_survives_ill_conditioning():
    """Shifted CQR3 stays orthonormal where plain CQR's Cholesky breaks."""
    with enable_x64():
        Y = _cond_matrix(500, 20, cond=1e9).astype(jnp.float64)
        Q = qr_mod.orthonormalize(Y, "cqr3")
        err = np.abs(np.asarray(Q.T @ Q) - np.eye(20)).max()
        assert err < 1e-12, err


@pytest.mark.parametrize("method", ["cqr2", "householder"])
def test_qr_reproduces_input(method):
    """Y = Q R up to rounding."""
    Y = _cond_matrix(200, 25, cond=100.0)
    Q, R = qr_mod.qr_decompose(Y, method)
    np.testing.assert_allclose(np.asarray(Q @ R), np.asarray(Y), atol=5e-5)


def test_range_preserved():
    """range(Q) == range(Y): projection of Y onto Q recovers Y."""
    Y = _cond_matrix(300, 16, cond=50.0)
    Q = qr_mod.orthonormalize(Y, "cqr2")
    resid = Y - Q @ (Q.T @ Y)
    assert float(jnp.max(jnp.abs(resid))) < 5e-5


@settings(deadline=None, max_examples=20)
@given(
    m=st.integers(40, 300),
    s=st.integers(2, 32),
    seed=st.integers(0, 2**16),
)
def test_cqr2_orthogonality_property(m, s, seed):
    """Hypothesis sweep: random shapes/seeds, Gaussian (well-conditioned) Y."""
    if s > m // 2:
        s = m // 2
    Y = sketch_matrix(m, s, seed, dtype=jnp.float32)
    Q = qr_mod.orthonormalize(Y, "cqr2")
    err = np.abs(np.asarray(Q.T @ Q) - np.eye(s)).max()
    assert err < 1e-4, err
