"""Kill -9 resume drivers (run by tests/test_snapshot.py, slow lane).

Each mode spawns a CHILD copy of this script that runs a checkpointed
solve under a `Checkpointer` subclass that SIGKILLs its own process right
after the N-th completed (durable) save — a real, unhandled process death
mid-solve: no Python cleanup, no atexit, no flushing.  The parent then
verifies the durability contract on the survivors:

  streamed   the streamed stage machine (core/blocked.py) resumes from
             the surviving snapshots to factors BIT-identical to an
             uninterrupted run at the same seed;
  adaptive   same for the adaptive growth loop (core/adaptive.py) behind
             `linalg.decompose(A, Tolerance(...), checkpoint=...)`;
  service    the decomposition service dies mid-solve; the write-ahead
             job record survives, `DecompositionService.restore(dir)`
             re-enqueues the job, and its future resolves bit-identical
             to an uninterrupted reference — with the job store drained;
  ckpt       repro.checkpoint's `CheckpointManager` is killed with an
             async save in flight and `.tmp` debris on disk: the previous
             step stays loadable and no debris is ever picked up.

Sentinels ("RESUME_STREAMED_OK", ...) are printed only after every
assertion passed; the pytest wrappers assert on them plus returncode 0.
"""
import os
import pathlib
import signal
import subprocess
import sys
import time

import numpy as np

M, N, BLOCK, RANK, SEED = 2048, 128, 128, 8, 5
ADAPTIVE_SHAPE = (160, 64)
KILL_AFTER_SAVES = 2


def _decay(m, n, seed=0):
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((m, n)))
    V, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.exp(-np.arange(n) / 6.0)
    return (U @ (s[:, None] * V.T)).astype(np.float32)


def _same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _kill_after(directory, saves):
    """A Checkpointer that SIGKILLs its own process right AFTER the given
    number of completed saves: the snapshots are published (renamed and
    parent-fsynced) before death, the in-flight solve is not."""
    from repro.linalg import snapshot as snap

    class KillAfter(snap.Checkpointer):
        def save_now(self, step, capture):
            path = super().save_now(step, capture)
            if self.saves >= saves:
                os.kill(os.getpid(), signal.SIGKILL)
            return path

    return KillAfter(directory, every=1, keep_last=2)


def _spawn_child(mode, workdir):
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), mode + "_child", workdir],
        capture_output=True, text=True, timeout=600)
    if proc.returncode != -signal.SIGKILL:
        raise SystemExit(
            f"child should have died by SIGKILL, got rc={proc.returncode}\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")


# ---------------------------------------------------------------------------
# streamed engine
# ---------------------------------------------------------------------------

def _streamed_solve(A, ck=None):
    from repro.core import blocked
    from repro.core.rsvd import RSVDConfig
    from repro.linalg import snapshot as snap

    cfg = RSVDConfig(qr_method="cqr2", power_iters=2, block_rows=BLOCK)
    ctl = None if ck is None else snap.RunControl(checkpointer=ck)
    with snap.maybe_scope(ctl):
        return blocked.svd_streamed(A, RANK, cfg, seed=SEED)


def streamed_child(workdir):
    A = _decay(M, N)
    ck = _kill_after(pathlib.Path(workdir) / "ck", KILL_AFTER_SAVES)
    _streamed_solve(A, ck)
    raise SystemExit("streamed solve finished before the kill fired")


def run_streamed(workdir):
    _spawn_child("streamed", workdir)
    from repro.linalg import snapshot as snap

    A = _decay(M, N)
    ref = _streamed_solve(A)
    ckdir = pathlib.Path(workdir) / "ck"
    survivors = [p for p in ckdir.glob("snap_*") if p.suffix != ".tmp"]
    assert survivors, "no durable snapshot survived the SIGKILL"
    out = _streamed_solve(A, snap.Checkpointer(ckdir))
    _same(ref, out)
    print("RESUME_STREAMED_OK")


# ---------------------------------------------------------------------------
# adaptive engine
# ---------------------------------------------------------------------------

def _adaptive_solve(checkpoint=None):
    import jax.numpy as jnp
    from repro import linalg

    A = jnp.asarray(_decay(*ADAPTIVE_SHAPE, seed=1))
    return linalg.decompose(A, linalg.Tolerance(1e-3, panel=8, max_rank=48),
                            seed=3, checkpoint=checkpoint)


def adaptive_child(workdir):
    ck = _kill_after(pathlib.Path(workdir) / "ck", KILL_AFTER_SAVES)
    _adaptive_solve(checkpoint=ck)
    raise SystemExit("adaptive solve finished before the kill fired")


def run_adaptive(workdir):
    _spawn_child("adaptive", workdir)
    ref = _adaptive_solve()
    out = _adaptive_solve(checkpoint=str(pathlib.Path(workdir) / "ck"))
    _same(ref.factors, out.factors)
    assert out.rank == ref.rank
    assert out.rank_history == ref.rank_history
    print("RESUME_ADAPTIVE_OK")


# ---------------------------------------------------------------------------
# service crash + restore
# ---------------------------------------------------------------------------

def service_child(workdir):
    from repro import linalg
    from repro.serve.decomp import DecompositionService

    wd = pathlib.Path(workdir)
    arr = _decay(M, N, seed=2)
    svc = DecompositionService(jobstore=str(wd / "store"))
    fut = svc.submit(linalg.HostOp(arr, block_rows=BLOCK), linalg.Rank(RANK),
                     seed=SEED, checkpoint=str(wd / "ck"))
    deadline = time.monotonic() + 300
    while time.monotonic() < deadline and not fut.done():
        durable = [p for p in (wd / "ck").glob("snap_*") if p.suffix != ".tmp"]
        if len(durable) >= KILL_AFTER_SAVES:
            os.kill(os.getpid(), signal.SIGKILL)
        time.sleep(0.002)
    raise SystemExit("service solve finished (or stalled) before the kill")


def run_service(workdir):
    _spawn_child("service", workdir)
    from repro import linalg
    from repro.serve.decomp import DecompositionService
    from repro.serve.decomp.jobstore import JobStore

    wd = pathlib.Path(workdir)
    arr = _decay(M, N, seed=2)
    ref = linalg.decompose(linalg.HostOp(arr, block_rows=BLOCK),
                           linalg.Rank(RANK), seed=SEED)
    svc = DecompositionService.restore(str(wd / "store"))
    try:
        assert len(svc.restored_futures) == 1, sorted(svc.restored_futures)
        dec = next(iter(svc.restored_futures.values())).result(timeout=300)
        assert svc.metrics.export()["resumed_jobs"] == 1
    finally:
        svc.close()
    _same(ref.factors, dec.factors)
    assert JobStore(wd / "store").pending() == []
    print("SERVICE_RESTORE_OK")


# ---------------------------------------------------------------------------
# repro.checkpoint crash-mid-save
# ---------------------------------------------------------------------------

def _ckpt_tree():
    import jax.numpy as jnp

    return {"w": jnp.arange(12.0).reshape(3, 4)}


def ckpt_child(workdir):
    from repro.checkpoint.checkpoint import CheckpointManager

    wd = pathlib.Path(workdir)
    mgr = CheckpointManager(str(wd), keep_last=3)
    mgr.save(1, _ckpt_tree(), blocking=True)      # the durable previous step
    debris = wd / "step_00000007.tmp"             # a crash mid-publish...
    debris.mkdir()
    (debris / "shard_0.npz").write_bytes(b"partial bytes, never renamed")
    mgr.save(2, _ckpt_tree(), blocking=False)     # ...and an async save
    os.kill(os.getpid(), signal.SIGKILL)          # in flight when we die


def run_ckpt(workdir):
    _spawn_child("ckpt", workdir)
    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import CheckpointManager

    mgr = CheckpointManager(workdir)
    steps = mgr.all_steps()
    # step 1 is durable; step 2 may or may not have completed before the
    # kill; the .tmp debris must never appear either way
    assert 1 in steps and set(steps) <= {1, 2}, steps
    assert 7 not in steps
    restored, step = mgr.restore({"w": jnp.zeros((3, 4))})
    assert step == max(steps)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(12.0).reshape(3, 4))
    print("CKPT_CRASH_OK")


# ---------------------------------------------------------------------------

MODES = {
    "streamed": run_streamed, "streamed_child": streamed_child,
    "adaptive": run_adaptive, "adaptive_child": adaptive_child,
    "service": run_service, "service_child": service_child,
    "ckpt": run_ckpt, "ckpt_child": ckpt_child,
}


def main():
    mode, workdir = sys.argv[1], sys.argv[2]
    pathlib.Path(workdir).mkdir(parents=True, exist_ok=True)
    MODES[mode](workdir)


if __name__ == "__main__":
    main()
