"""The deprecated pre-facade entry points (`core.rsvd.randomized_svd` /
`randomized_eigvals`).

These are the ONLY tests allowed to call them: pytest.ini turns their
DeprecationWarning into an error suite-wide, and this module opts back out
per-test.  The contract: the shims warn, and they return BIT-identical
results to the facade across every historical dispatch shape.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core import RSVDConfig, randomized_eigvals, randomized_svd
from repro.core.spectra import make_test_matrix

shimtest = pytest.mark.filterwarnings("default::DeprecationWarning")


@shimtest
def test_shim_warns_and_matches_facade_dense():
    A, _ = make_test_matrix(128, 64, "fast", seed=0)
    cfg = RSVDConfig(power_scheme="stabilized", qr_method="cqr2")
    with pytest.warns(DeprecationWarning, match="use repro.linalg.svd"):
        U0, S0, Vt0 = randomized_svd(A, 8, cfg, seed=5)
    U1, S1, Vt1 = linalg.svd(A, 8, overrides=cfg, seed=5)
    np.testing.assert_array_equal(np.asarray(U0), np.asarray(U1))
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))
    np.testing.assert_array_equal(np.asarray(Vt0), np.asarray(Vt1))


@shimtest
def test_shim_streamed_dispatch():
    A_host = np.asarray(make_test_matrix(200, 48, "fast", seed=1)[0])
    cfg = RSVDConfig.streaming(block_rows=64)
    with pytest.warns(DeprecationWarning):
        U0, S0, Vt0 = randomized_svd(A_host, 6, cfg, seed=2)
    U1, S1, Vt1 = linalg.svd(A_host, 6, overrides=cfg, seed=2)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))
    np.testing.assert_array_equal(np.asarray(U0), np.asarray(U1))


@shimtest
def test_shim_batched_dispatch():
    A = jnp.stack([make_test_matrix(64, 32, "fast", seed=2 + i)[0] for i in range(2)])
    with pytest.warns(DeprecationWarning):
        U0, S0, Vt0 = randomized_svd(A, 4, seed=9)
    U1, S1, Vt1 = linalg.svd(linalg.StackedOp(A), 4, overrides=RSVDConfig(), seed=9)
    np.testing.assert_array_equal(np.asarray(U0), np.asarray(U1))
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))


@shimtest
def test_shim_batched_flag_still_rejects_2d():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            randomized_svd(jnp.zeros((8, 4)), 2, RSVDConfig(batched=True))


@shimtest
def test_shim_eigvals_warns_and_matches():
    A, _ = make_test_matrix(96, 48, "fast", seed=3)
    cfg = RSVDConfig()
    with pytest.warns(DeprecationWarning, match="use repro.linalg.eigvals"):
        S0 = randomized_eigvals(A, 6, cfg, seed=1)
    S1 = linalg.eigvals(A, 6, overrides=cfg, seed=1)
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))


def test_shim_deprecation_is_an_error_outside_this_marker():
    """Everywhere else in the suite the shims must FAIL loudly (pytest.ini
    filterwarnings) — this is the regression guard for that wiring."""
    A, _ = make_test_matrix(32, 16, "fast", seed=4)
    with pytest.raises(DeprecationWarning):
        randomized_svd(A, 4)


# ---------------------------------------------------------------------------
# Pre-facade aliased names from blocked.py / distributed.py: downstream code
# imported these directly, so they must stay bound to the renamed functions.
# ---------------------------------------------------------------------------

def test_blocked_aliases_are_the_renamed_functions():
    from repro.core import blocked

    assert blocked.blocked_randomized_svd is blocked.svd_streamed
    assert blocked.blocked_randomized_eigvals is blocked.eigvals_streamed
    assert blocked.batched_randomized_svd is blocked.svd_batched
    # and they re-export through the repro.core namespace
    from repro.core import (batched_randomized_svd,
                            blocked_randomized_eigvals, blocked_randomized_svd)

    assert blocked_randomized_svd is blocked.svd_streamed
    assert blocked_randomized_eigvals is blocked.eigvals_streamed
    assert batched_randomized_svd is blocked.svd_batched


def test_distributed_alias_is_the_renamed_function():
    from repro.core import distributed

    assert distributed.distributed_randomized_svd is distributed.svd_sharded


def test_blocked_alias_matches_facade_streamed_path():
    """The alias executes the SAME numerics the facade's streamed plan runs:
    bit-identical factors at fixed seed."""
    from repro.core.blocked import blocked_randomized_svd

    A_host = np.asarray(make_test_matrix(160, 48, "fast", seed=6)[0])
    cfg = RSVDConfig.streaming(block_rows=64)
    U0, S0, Vt0 = blocked_randomized_svd(A_host, 6, cfg, seed=3)
    U1, S1, Vt1 = linalg.svd(linalg.HostOp(A_host, block_rows=64), 6,
                             overrides=cfg, seed=3)
    np.testing.assert_array_equal(np.asarray(U0), np.asarray(U1))
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))
    np.testing.assert_array_equal(np.asarray(Vt0), np.asarray(Vt1))


def test_batched_alias_matches_facade_batched_path():
    from repro.core.blocked import batched_randomized_svd

    A = jnp.stack([make_test_matrix(48, 24, "fast", seed=7 + i)[0] for i in range(2)])
    U0, S0, Vt0 = batched_randomized_svd(A, 4, RSVDConfig(), seed=2)
    U1, S1, Vt1 = linalg.svd(linalg.StackedOp(A), 4, overrides=RSVDConfig(), seed=2)
    np.testing.assert_array_equal(np.asarray(U0), np.asarray(U1))
    np.testing.assert_array_equal(np.asarray(S0), np.asarray(S1))
    np.testing.assert_array_equal(np.asarray(Vt0), np.asarray(Vt1))


def test_blocked_eigvals_alias_runs():
    from repro.core.blocked import blocked_randomized_eigvals

    A_host = np.asarray(make_test_matrix(96, 32, "fast", seed=9)[0])
    S = blocked_randomized_eigvals(A_host, 5, RSVDConfig.streaming(block_rows=32),
                                   seed=1)
    S_ref = linalg.eigvals(linalg.HostOp(A_host, block_rows=32), 5,
                           overrides=RSVDConfig.streaming(block_rows=32), seed=1)
    np.testing.assert_array_equal(np.asarray(S), np.asarray(S_ref))
