"""Shared fixtures.

`assert_plan_contracts` surfaces the jaxpr contract checker
(repro.analysis.contracts) to any test that builds an ExecutionPlan:

    def test_my_path(assert_plan_contracts):
        pl = linalg.plan(op, k)
        assert_plan_contracts(pl)   # raises ContractViolation on breach

The import is deferred so the fixture costs nothing for the (majority of)
tests that never request it.
"""
import pytest


@pytest.fixture
def assert_plan_contracts():
    from repro.analysis.contracts import assert_plan_contracts as check

    return check
