"""Substrate tests: optimizer correctness, PowerSGD/GaLore properties,
checkpoint roundtrip + crash-safety + reshard semantics, trainer resume,
and serve-path consistency (prefill+decode == full forward)."""
import dataclasses
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import synthetic_batch, data_iterator
from repro.models import forward_model, init_model
from repro.optim import adamw, galore, powersgd
from repro.checkpoint.checkpoint import CheckpointManager
from repro.serve import kvcache, serve_step
from repro.serve.lowrank import dense_equivalent, factorize_params
from repro.train.train_step import compute_loss
from repro.train.trainer import Trainer, TrainerConfig

SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_impl():
    """One step of our AdamW == hand-rolled numpy Adam on a tiny problem."""
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.5]])}
    st = adamw.init_state(p)
    newp, st2, _ = adamw.apply_updates(p, g, st, cfg)

    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.05 * gn * gn
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    lr = adamw.schedule(cfg, jnp.zeros((), jnp.int32))
    want = np.asarray(p["w"]) - float(lr) * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    p = {"w": jnp.ones((4,)) * 5.0}
    st = adamw.init_state(p)
    for _ in range(150):
        g = {"w": 2 * p["w"]}  # grad of ||w||^2
        p, st, _ = adamw.apply_updates(p, g, st, cfg)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.3


# ---------------------------------------------------------------------------
# PowerSGD
# ---------------------------------------------------------------------------

def test_powersgd_error_feedback_invariant():
    """Error feedback conserves gradient mass exactly:
    sum_t g_hat_t + e_T == T * g  (no gradient information is ever lost,
    only delayed — the Vogels et al. convergence argument)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 48)), jnp.float32)}
    st = powersgd.init_state(g, rank=4)
    T = 10
    acc = jnp.zeros_like(g["w"])
    for _ in range(T):
        comp, st, m = powersgd.compress_tree_grads(g, st, rank=4)
        acc = acc + comp["w"]
    flat_e = jax.tree.leaves(st.e)
    lhs = np.asarray(acc + flat_e[0])
    np.testing.assert_allclose(lhs, T * np.asarray(g["w"]), rtol=2e-4, atol=2e-4)
    # and the error stays bounded (equilibrium, not divergence)
    assert float(jnp.linalg.norm(flat_e[0])) < 20 * float(jnp.linalg.norm(g["w"]))


def test_powersgd_exact_on_lowrank_grad():
    """A rank-2 gradient must be captured (near-)exactly at rank >= 2."""
    rng = np.random.default_rng(1)
    g_np = (rng.standard_normal((64, 3)) @ rng.standard_normal((3, 96))).astype(np.float32)
    g = {"w": jnp.asarray(g_np)}
    st = powersgd.init_state(g, rank=8)
    comp, st, m = powersgd.compress_tree_grads(g, st, rank=8)
    comp, st, m = powersgd.compress_tree_grads(g, st, rank=8)  # warm start
    assert float(m["psgd_rel_err"]) < 1e-2


def test_powersgd_bytes_model():
    full, comp = powersgd.collective_bytes((3072, 8192), rank=32)
    assert comp / full < 0.015  # >70x collective reduction


# ---------------------------------------------------------------------------
# GaLore
# ---------------------------------------------------------------------------

def test_galore_reduces_loss_and_memory():
    rng = np.random.default_rng(2)
    W_true = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    Y = X @ W_true
    params = {"w": jnp.zeros((32, 128), jnp.float32)}
    ocfg = adamw.AdamWConfig(lr=5e-2, warmup_steps=0, total_steps=100, weight_decay=0.0)
    st = galore.init_state(params, rank=8)

    def loss(p):
        return jnp.mean((X @ p["w"] - Y) ** 2)

    # jit the whole step: re-tracing apply_updates (with its cond over the
    # RSVD refresh) 60x from Python dominated this test's runtime.
    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        return galore.apply_updates(p, g, s, ocfg, rank=8, update_every=10)

    l0 = float(loss(params))
    for _ in range(60):
        params, st, _ = step(params, st)
    l1 = float(loss(params))
    assert l1 < 0.5 * l0, (l0, l1)

    dense, lowrank = galore.memory_savings({"w": jnp.zeros((1024, 4096))}, rank=64)
    assert lowrank < 0.2 * dense


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": (jnp.ones(4), jnp.zeros(2))}
    for s in [10, 20, 30]:
        mgr.save(s, jax.tree.map(lambda x: x + s, tree), blocking=True)
    assert mgr.all_steps() == [20, 30]  # keep_last=2 GC
    restored, step = mgr.restore(tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) + 30)


def test_checkpoint_rejects_wrong_structure(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones(3)}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones(4)})  # shape mismatch -> fingerprint differs


def test_checkpoint_crash_safety(tmp_path):
    """A stale .tmp directory (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"a": jnp.ones(3)}, blocking=True)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert mgr.latest_step() == 5
    restored, step = mgr.restore({"a": jnp.zeros(3)})
    assert step == 5


# ---------------------------------------------------------------------------
# Trainer: resume after interruption
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_runs_and_resumes(tmp_path):
    cfg = get_config("llama3.2-1b").reduced()
    cfg = dataclasses.replace(cfg, powersgd_rank=0)
    params = init_model(cfg, jax.random.key(0))
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)
    tcfg = TrainerConfig(
        total_steps=6, checkpoint_every=3, log_every=2, checkpoint_dir=str(tmp_path)
    )
    tr = Trainer(cfg, ocfg, tcfg)
    data = data_iterator(cfg, SMOKE)
    p1, o1, m1 = tr.run(params, data, resume=False)
    assert np.isfinite(float(m1["loss"]))

    # second run resumes from the saved step rather than starting over
    tr2 = Trainer(cfg, ocfg, dataclasses.replace(tcfg, total_steps=8))
    p2, o2, m2 = tr2.run(params, data_iterator(cfg, SMOKE), resume=True)
    log = [json.loads(l) for l in open(tmp_path / "train_log.jsonl")]
    assert any(r.get("event") == "resumed" for r in log)


# ---------------------------------------------------------------------------
# Serve: prefill + decode == full forward (incremental consistency)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name",
    [
        "llama3.2-1b",  # tier-1 representative; the rest are nightly (slow)
        pytest.param("gemma2-2b", marks=pytest.mark.slow),
        pytest.param("deepseek-v2-lite-16b", marks=pytest.mark.slow),
        pytest.param("recurrentgemma-9b", marks=pytest.mark.slow),
        pytest.param("xlstm-350m", marks=pytest.mark.slow),
    ],
)
def test_decode_matches_full_forward(name):
    cfg = get_config(name).reduced()
    # capacity_factor high enough that no MoE token ever drops: capacity
    # dropping is batch-dependent by design, so incremental-vs-full equality
    # only holds in the drop-free regime.
    cfg = dataclasses.replace(cfg, attn_chunk=16, capacity_factor=8.0)
    params = init_model(cfg, jax.random.key(1))
    B, T = 2, 24
    batch = synthetic_batch(cfg, ShapeConfig("s", T, B, "train"), step=0)
    tokens = batch["tokens"]

    logits_full, _ = forward_model(params, batch, cfg, mode="train")

    caches = kvcache.init_caches(cfg, B, max_len=T + 8, dtype=jnp.float32)
    lp, caches, enc = serve_step.prefill_step(params, tokens[:, : T - 4], cfg, caches)
    outs = [lp]
    for i in range(4):
        pos = T - 4 + i
        lo, caches = serve_step.decode_step(
            params, tokens[:, pos : pos + 1], jnp.asarray(pos, jnp.int32), cfg, caches,
            encoder_out=enc,
        )
        outs.append(lo)

    # compare the last 4 positions' logits (prefill's last + 3 decode steps)
    want = np.asarray(logits_full[:, T - 5 : T - 1, :], np.float32)
    got = np.stack([np.asarray(o, np.float32) for o in outs[:4]], axis=1)
    np.testing.assert_allclose(got, want, atol=2e-2, rtol=2e-2)


@pytest.mark.slow
def test_lowrank_serve_factorization():
    cfg = get_config("llama3.2-1b").reduced()
    params = init_model(cfg, jax.random.key(2))
    fact, report = factorize_params(params, rank=24)
    assert report, "no leaves were factorized"
    dense = dense_equivalent(fact)
    batch = synthetic_batch(cfg, SMOKE, step=0)
    l1, _ = forward_model(params, batch, cfg)
    l2, _ = forward_model(fact, batch, cfg)
    l3, _ = forward_model(dense, batch, cfg)
    # factorized and its densified twin agree exactly (associativity aside)
    np.testing.assert_allclose(
        np.asarray(l2, np.float32), np.asarray(l3, np.float32), atol=1e-3, rtol=1e-3
    )
    assert np.isfinite(np.asarray(l2, np.float32)).all()
