"""End-to-end behaviour tests for the paper's system: the full chain
train -> checkpoint -> restore -> low-rank-compress (paper's RSVD) -> serve.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import data_iterator, synthetic_batch
from repro.models import init_model
from repro.optim import adamw
from repro.serve.engine import Engine, Request
from repro.serve.lowrank import factorize_params
from repro.train.train_step import compute_loss
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = ShapeConfig("e2e", seq_len=64, global_batch=4, kind="train")

# Full train->checkpoint->restore->compress->serve chain: minutes of CPU work.
pytestmark = pytest.mark.slow


def test_end_to_end_train_checkpoint_serve(tmp_path):
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(), attn_chunk=32)
    params = init_model(cfg, jax.random.key(0))

    # --- train on learnable synthetic data ---------------------------------
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    tcfg = TrainerConfig(total_steps=12, checkpoint_every=6, log_every=4,
                         checkpoint_dir=str(tmp_path))
    trainer = Trainer(cfg, ocfg, tcfg)
    batch0 = synthetic_batch(cfg, SHAPE, step=0)
    loss0 = float(compute_loss(params, batch0, cfg)[0])
    params, opt_state, metrics = trainer.run(
        params, data_iterator(cfg, SHAPE), resume=False
    )
    loss1 = float(compute_loss(params, batch0, cfg)[0])
    assert np.isfinite(loss1)
    assert loss1 < loss0, (loss0, loss1)  # the periodic pattern is learnable

    # --- checkpoint exists and restores bitwise ----------------------------
    restored, step = trainer.ckpt.restore((params, opt_state))
    for a, b in zip(jax.tree.leaves(restored[0]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- serve the trained model, dense and RSVD-compressed ----------------
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=12).astype(np.int32),
                max_new_tokens=4)
    ]
    dense_out = Engine(params, cfg, max_batch=1, max_len=64).generate(reqs)
    assert dense_out[0].tokens.shape == (4,)

    fact, report = factorize_params(params, rank=24)
    lr_out = Engine(fact, cfg, max_batch=1, max_len=64).generate(reqs)
    assert lr_out[0].tokens.shape == (4,)
    assert all(np.isfinite(v) for v in report.values())
