"""SparseOp + the SpMM sketch path (PR 6): construction/coercion, SpMM
correctness against the densified matrix, the block-ELL pack + Pallas
kernel, planner routing/pricing against the sparse roofline model, and the
operator-layer bugfix regressions (row_panels fallback) that ride along."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import sparse as jsparse

from repro import linalg
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig
from repro.roofline import rsvd_model


def _sparse_pair(m, n, density, seed=0, dtype=np.float32):
    """(dense numpy M, SparseOp over its BCOO form) at a given density."""
    rng = np.random.default_rng(seed)
    M = (rng.standard_normal((m, n)) * (rng.random((m, n)) < density)).astype(dtype)
    return M, linalg.SparseOp(jsparse.BCOO.fromdense(jnp.asarray(M)))


# ---------------------------------------------------------------------------
# Construction and coercion
# ---------------------------------------------------------------------------

def test_sparseop_construction_and_stats():
    M, op = _sparse_pair(50, 40, 0.1)
    assert op.shape == (50, 40)
    assert op.dtype == jnp.float32
    assert op.nnz == int(np.count_nonzero(M))
    assert op.density == pytest.approx(np.count_nonzero(M) / (50 * 40))


def test_sparseop_accepts_scipy():
    scipy_sparse = pytest.importorskip("scipy.sparse")
    M, _ = _sparse_pair(30, 20, 0.2)
    for conv in (scipy_sparse.csr_matrix, scipy_sparse.csc_matrix,
                 scipy_sparse.coo_matrix):
        op = linalg.SparseOp(conv(M))
        assert op.nnz == int(np.count_nonzero(M))
        X = jnp.ones((20, 3), jnp.float32)
        np.testing.assert_allclose(np.asarray(op.matmat(X)), M @ np.ones((20, 3)),
                                   rtol=1e-5, atol=1e-5)


def test_as_linop_detects_sparse_before_ndim():
    """BCOO has ndim == 2 — the coercion must not fall through to DenseOp
    (which would densify A on the first matmat)."""
    M, _ = _sparse_pair(16, 12, 0.3)
    assert isinstance(linalg.as_linop(jsparse.BCOO.fromdense(jnp.asarray(M))),
                      linalg.SparseOp)
    scipy_sparse = pytest.importorskip("scipy.sparse")
    assert isinstance(linalg.as_linop(scipy_sparse.csr_matrix(M)),
                      linalg.SparseOp)


def test_sparseop_rejects_bad_inputs():
    with pytest.raises(TypeError, match="BCOO"):
        linalg.SparseOp(np.zeros((4, 4)))
    with pytest.raises(ValueError, match="2-D"):
        linalg.SparseOp(jsparse.BCOO.fromdense(jnp.zeros((2, 3, 4))))


# ---------------------------------------------------------------------------
# SpMM products match the densified matrix
# ---------------------------------------------------------------------------

def test_matmat_rmatmat_match_dense():
    M, op = _sparse_pair(64, 48, 0.08, seed=1)
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.standard_normal((48, 7)).astype(np.float32))
    Y = jnp.asarray(rng.standard_normal((64, 7)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(op.matmat(X)), M @ np.asarray(X),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.rmatmat(Y)), M.T @ np.asarray(Y),
                               rtol=1e-5, atol=1e-5)


def test_row_panels_stay_sparse_and_match_dense():
    """The inherited basis-slice fallback covers A panel-by-panel through
    nnz-proportional rmatmats — values equal the densified rows."""
    M, op = _sparse_pair(50, 40, 0.1, seed=3)
    panels = [np.asarray(p) for p in op.row_panels(16)]
    assert [p.shape[0] for p in panels] == [16, 16, 16, 2]
    np.testing.assert_allclose(np.concatenate(panels, axis=0), M,
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Block-ELL pack + the fused SpMM sketch kernel
# ---------------------------------------------------------------------------

def test_pack_block_ell_roundtrip():
    from repro.kernels.spmm_sketch import pack_block_ell

    M, op = _sparse_pair(40, 56, 0.05, seed=4)
    data, tilecols = pack_block_ell(op.bcoo, 16, 8)
    data, tilecols = np.asarray(data), np.asarray(tilecols)
    assert data.shape[0] == -(-40 // 16) and data.shape[2:] == (16, 8)
    # unpack: scatter every tile back at its (row block, column block)
    dense = np.zeros((data.shape[0] * 16, -(-56 // 8) * 8), np.float32)
    occupied = 0
    for i in range(data.shape[0]):
        for t in range(data.shape[1]):
            c = tilecols[i, t]
            assert not np.any(dense[i * 16:(i + 1) * 16, c * 8:(c + 1) * 8]
                              * data[i, t])  # slots don't collide
            dense[i * 16:(i + 1) * 16, c * 8:(c + 1) * 8] += data[i, t]
            occupied += np.any(data[i, t] != 0)
    np.testing.assert_array_equal(dense[:40, :56], M)
    assert occupied > 0


def test_pack_block_ell_rejects_dense_structure():
    """max_fill: a dense matrix padded into block-ELL stores >= the dense
    footprint — the pack must bail so the BCOO fallback runs instead."""
    from repro.kernels.spmm_sketch import pack_block_ell

    M = np.ones((32, 32), np.float32)
    bcoo = jsparse.BCOO.fromdense(jnp.asarray(M))
    assert pack_block_ell(bcoo, 8, 8, max_fill=0.5) is None
    assert pack_block_ell(bcoo, 8, 8, max_fill=None) is not None


def test_spmm_sketch_kernel_matches_materialized_omega():
    """The fused kernel (counter-RNG Omega tiles generated in VMEM) computes
    the same map as BCOO @ sketch_matrix — the RNG streams are bit-identical,
    the summation order is not."""
    M, op = _sparse_pair(70, 52, 0.07, seed=5)
    for kind in ("gaussian", "rademacher"):
        Y = np.asarray(op.sketch(9, seed=11, kind=kind))
        omega = np.asarray(sketch_mod.sketch_matrix(52, 9, 11, kind))
        np.testing.assert_allclose(Y, M @ omega, rtol=1e-4, atol=1e-4)


def test_sparseop_sketch_structured_kinds_fall_back():
    M, op = _sparse_pair(40, 32, 0.1, seed=6)
    for kind in ("srht", "countsketch"):
        Y = np.asarray(op.sketch(8, seed=3, kind=kind))
        omega = np.asarray(sketch_mod.sketch_matrix(32, 8, 3, kind))
        np.testing.assert_allclose(Y, M @ omega, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Planner: routing, nnz recording, and the SpMM traffic pricing
# ---------------------------------------------------------------------------

def test_plan_routes_sparse_and_prices_spmm():
    _, op = _sparse_pair(256, 128, 0.05, seed=7)
    pl = linalg.plan(op, 8)
    assert pl.path == "sparse"
    assert pl.nnz == op.nnz
    assert pl.density == pytest.approx(op.density)
    want = rsvd_model.sparse_predicted_hbm_bytes(
        pl.m, pl.n, pl.s, pl.power_iters, pl.nnz,
        fused_sketch=pl.fused_sketch, dtype_bytes=4,
    )
    assert pl.predicted_hbm_bytes == want


def test_sparse_sketch_priced_10x_below_dense_at_one_percent():
    """The acceptance property: at density 0.01 the sketch pass is priced
    at least 10x below the dense model at the same shape."""
    m, n, s = 2048, 1024, 26
    nnz = int(0.01 * m * n)
    sparse = rsvd_model.spmm_sketch_bytes(m, n, s, nnz, fused_sketch=False)
    dense = rsvd_model.sketch_bytes(m, n, s, fused_sketch=False)
    assert dense / sparse >= 10.0, dense / sparse


def test_plan_accepts_explicit_nnz():
    """Shape-only planning: nnz passed by the caller when no data exists."""
    _, op = _sparse_pair(128, 96, 0.05, seed=8)
    pl = linalg.plan(op, 8, nnz=100)
    assert pl.nnz == 100
    assert pl.density == pytest.approx(100 / (128 * 96))


def test_composed_over_sparse_keeps_spmm_pricing():
    """A CenteredOp over a SparseOp plans matfree, but every read of A is
    still an SpMM — the peeled nnz prices the plan."""
    _, op = _sparse_pair(200, 100, 0.05, seed=9)
    pl = linalg.plan(linalg.CenteredOp(op, mu=jnp.zeros((100,), jnp.float32)), 8)
    assert pl.path == "matfree"
    assert pl.nnz == op.nnz
    want = rsvd_model.sparse_predicted_hbm_bytes(
        pl.m, pl.n, pl.s, pl.power_iters, pl.nnz,
        fused_sketch=pl.fused_sketch, dtype_bytes=4,
    )
    assert pl.predicted_hbm_bytes == want


def test_adaptive_sparse_schedule_uses_nnz_pricing():
    _, op = _sparse_pair(192, 96, 0.05, seed=10)
    pl = linalg.plan(op, linalg.Tolerance(1e-2, panel=16))
    assert pl.path == "adaptive" and pl.nnz == op.nnz
    want = rsvd_model.adaptive_schedule_bytes(
        pl.m, pl.n, pl.rank_schedule, pl.power_iters,
        dtype_bytes=4, fused_sketch=pl.fused_sketch, nnz=pl.nnz,
    )
    assert pl.schedule_hbm_bytes == want
    assert pl.predicted_hbm_bytes == sum(want)
    # nnz pricing is strictly below the dense pricing at this density
    dense = rsvd_model.adaptive_schedule_bytes(
        pl.m, pl.n, pl.rank_schedule, pl.power_iters,
        dtype_bytes=4, fused_sketch=pl.fused_sketch,
    )
    assert sum(want) < sum(dense)


# ---------------------------------------------------------------------------
# decompose() over SparseOp: every kind, never densified
# ---------------------------------------------------------------------------

def test_decompose_kinds_run_on_sparse():
    M, op = _sparse_pair(128, 64, 0.08, seed=11)
    for kind in ("svd", "qb", "pca"):
        dec = linalg.decompose(op, 6, kind=kind, seed=0)
        assert dec.rank == 6
    psd = M.T @ M
    psd_op = linalg.SparseOp(jsparse.BCOO.fromdense(jnp.asarray(psd)))
    dec = linalg.decompose(psd_op, 6, kind="eigh", seed=0)
    w, V = dec.factors
    np.testing.assert_allclose(np.asarray(w),
                               np.linalg.eigvalsh(psd)[::-1][:6],
                               rtol=5e-2, atol=1e-3)
    assert V.shape == (64, 6)


def test_sparse_svd_matches_dense_on_densified(seed=0):
    """The satellite contract: SparseOp results match DenseOp on the
    densified matrix at a fixed seed for the non-fused path (both run the
    stabilized CQR2 variant; the sparse path is the operator body)."""
    M, op = _sparse_pair(160, 80, 0.1, seed=12)
    cfg = RSVDConfig(power_scheme="stabilized", qr_method="cqr2")
    Us, Ss, Vts = linalg.svd(op, 6, seed=seed)
    Ud, Sd, Vtd = linalg.svd(linalg.DenseOp(jnp.asarray(M)), 6, seed=seed,
                             overrides=cfg)
    np.testing.assert_allclose(np.asarray(Ss), np.asarray(Sd), rtol=1e-4)
    # factors agree up to per-column sign
    for Xs, Xd, axis in ((Us, Ud, 0), (Vts.T, Vtd.T, 0)):
        dots = np.sum(np.asarray(Xs) * np.asarray(Xd), axis=axis)
        np.testing.assert_allclose(np.abs(dots), 1.0, atol=1e-3)


def test_sparse_eigvals_runs_matfree():
    _, op = _sparse_pair(96, 96, 0.1, seed=13)
    s = linalg.eigvals(op, 4, seed=0)
    assert s.shape == (4,) and bool(jnp.all(s >= 0))


def test_sparse_tolerance_decompose_meets_eps():
    """Adaptive growth over a sparse low-rank-plus-noise source certifies
    the tolerance without ever densifying A."""
    rng = np.random.default_rng(14)
    L = (rng.standard_normal((200, 5)) @ rng.standard_normal((5, 100))).astype(np.float32)
    mask = rng.random((200, 100)) < 0.05
    M = np.where(mask, L, 0.0)
    op = linalg.SparseOp(jsparse.BCOO.fromdense(jnp.asarray(M)))
    dec = linalg.decompose(op, linalg.Tolerance(2e-2, panel=8), seed=1)
    achieved = float(linalg.residual(op, dec.factors))
    assert achieved <= 2e-2, achieved


# ---------------------------------------------------------------------------
# Operator-layer bugfix regression: the row_panels fallback (satellite 1)
# ---------------------------------------------------------------------------

class _ProtocolOnlyOp(linalg.LinOp):
    """Minimal LinOp with ONLY matmat/rmatmat — exercises the default
    row_panels fallback (no .array, no override)."""

    def __init__(self, a):
        self._a = jnp.asarray(a)

    @property
    def shape(self):
        return tuple(self._a.shape)

    @property
    def dtype(self):
        return self._a.dtype

    def matmat(self, X):
        return self._a @ X

    def rmatmat(self, Y):
        return self._a.T @ Y


def test_row_panels_fallback_bit_identical_to_rows():
    """The sliced-basis construction must reproduce A's rows EXACTLY: the
    basis entries are exact 0/1, so each rmatmat selects rows bit-for-bit
    (no scatter, no roundoff)."""
    rng = np.random.default_rng(15)
    A = jnp.asarray(rng.standard_normal((37, 24)).astype(np.float32))
    op = _ProtocolOnlyOp(A)
    got = [np.asarray(p) for p in op.row_panels(10)]
    assert [p.shape for p in got] == [(10, 24), (10, 24), (10, 24), (7, 24)]
    np.testing.assert_array_equal(np.concatenate(got, axis=0), np.asarray(A))


def test_row_panels_fallback_avoids_scatter():
    """The panel basis is built without gather/scatter ops — the fix
    replaced a per-panel m-sized scatter with an offset-diagonal eye."""
    op = _ProtocolOnlyOp(jnp.ones((64, 8), jnp.float32))

    def one_panel():
        return next(iter(op.row_panels(16)))

    jaxpr = str(jax.make_jaxpr(one_panel)())
    assert "scatter" not in jaxpr, jaxpr
