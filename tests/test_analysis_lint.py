"""AST rule engine: one negative fixture per rule, suppression semantics,
and the repo-wide zero-findings gate (the CI analysis lane's lint half)."""
import textwrap

import pytest

from repro.analysis import engine

pytestmark = pytest.mark.analysis


def _lint(src, name="repro.core.fake", **kw):
    return engine.lint_source(textwrap.dedent(src), name=name, **kw)


def _rules_hit(report):
    return {f.rule for f in report.findings}


# ---------------------------------------------------------------------------
# RL001 core-layering
# ---------------------------------------------------------------------------

def test_core_importing_linalg_flagged():
    rep = _lint("import repro.linalg\n", name="repro.core.fake")
    assert "RL001" in _rules_hit(rep)


def test_core_relative_parent_import_flagged():
    rep = _lint("from ..linalg import api\n", name="repro.core.fake")
    assert "RL001" in _rules_hit(rep)


def test_core_lazy_in_function_import_allowed():
    rep = _lint(
        """
        def f():
            from repro.linalg import api
            return api
        """,
        name="repro.core.fake",
    )
    assert "RL001" not in _rules_hit(rep)


def test_linalg_importing_core_allowed():
    rep = _lint("from repro.core import rsvd\n", name="repro.linalg.fake")
    assert "RL001" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# RL002 mutable-global (service-reachable modules)
# ---------------------------------------------------------------------------

UNGUARDED = """
_cache = {}

def put(k, v):
    _cache[k] = v
"""

LOCKED = """
import threading

_lock = threading.Lock()
_cache = {}

def put(k, v):
    with _lock:
        _cache[k] = v
"""


def test_unguarded_mutable_global_flagged():
    rep = _lint(UNGUARDED, reachable=True)
    assert "RL002" in _rules_hit(rep)


def test_lock_guarded_mutable_global_clean():
    rep = _lint(LOCKED, reachable=True)
    assert "RL002" not in _rules_hit(rep)


def test_threading_local_clean():
    rep = _lint(
        """
        import threading

        _state = threading.local()

        def put(v):
            _state.v = v
        """,
        reachable=True,
    )
    assert "RL002" not in _rules_hit(rep)


def test_unreachable_module_not_flagged():
    rep = _lint(UNGUARDED, reachable=False)
    assert "RL002" not in _rules_hit(rep)


def test_constant_by_convention_clean():
    # A module-level dict that no function ever mutates is configuration,
    # not shared state.
    rep = _lint("_DEFAULTS = {'a': 1}\n", reachable=True)
    assert "RL002" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# RL003 unfrozen-key
# ---------------------------------------------------------------------------

def test_unfrozen_plan_dataclass_flagged():
    rep = _lint(
        """
        import dataclasses

        @dataclasses.dataclass
        class ExecutionPlan:
            path: str
        """
    )
    assert "RL003" in _rules_hit(rep)


def test_frozen_plan_with_list_field_flagged():
    rep = _lint(
        """
        import dataclasses
        from typing import List

        @dataclasses.dataclass(frozen=True)
        class ExecutionPlan:
            panels: List[int]
        """
    )
    assert "RL003" in _rules_hit(rep)


def test_frozen_hashable_plan_clean():
    rep = _lint(
        """
        import dataclasses
        from typing import Tuple

        @dataclasses.dataclass(frozen=True)
        class ExecutionPlan:
            path: str
            dims: Tuple[int, ...]
        """
    )
    assert "RL003" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# RL004 host-rng
# ---------------------------------------------------------------------------

def test_stdlib_random_flagged():
    rep = _lint("import random\n")
    assert "RL004" in _rules_hit(rep)


def test_numpy_random_flagged():
    rep = _lint(
        """
        import numpy as np

        def noise(n):
            return np.random.rand(n)
        """
    )
    assert "RL004" in _rules_hit(rep)


def test_jax_counter_rng_clean():
    rep = _lint(
        """
        import jax

        def omega(seed, shape):
            return jax.random.normal(jax.random.PRNGKey(seed), shape)
        """
    )
    assert "RL004" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# RL005 bare-except
# ---------------------------------------------------------------------------

def test_bare_except_flagged():
    rep = _lint(
        """
        def f():
            try:
                return 1
            except:
                return 0
        """
    )
    assert "RL005" in _rules_hit(rep)


def test_typed_except_clean():
    rep = _lint(
        """
        def f():
            try:
                return 1
            except ValueError:
                return 0
        """
    )
    assert "RL005" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# RL006 dense-lapack
# ---------------------------------------------------------------------------

def test_dense_svd_outside_finisher_flagged():
    rep = _lint(
        """
        import jax.numpy as jnp

        def solve(a):
            return jnp.linalg.svd(a)
        """
    )
    assert "RL006" in _rules_hit(rep)


def test_dense_svd_in_core_qr_allowed():
    rep = _lint(
        """
        import jax.numpy as jnp

        def householder(a):
            return jnp.linalg.qr(a)
        """,
        name="repro.core.qr",
    )
    assert "RL006" not in _rules_hit(rep)


def test_registered_finisher_allowed():
    rep = _lint(
        """
        import jax.numpy as jnp

        def _execute_svd(op, spec, pl, seed):
            return jnp.linalg.svd(op)

        register(DecompositionKind("svd", _execute_svd))
        """
    )
    assert "RL006" not in _rules_hit(rep)


# ---------------------------------------------------------------------------
# Suppression semantics
# ---------------------------------------------------------------------------

def test_noqa_with_reason_suppresses():
    rep = _lint(
        "import random  # repro: noqa[RL004]: synthetic host-side ids only\n"
    )
    assert "RL004" not in _rules_hit(rep)
    assert any(f.rule == "RL004" for f, _ in rep.suppressed)


def test_noqa_without_reason_does_not_suppress():
    rep = _lint("import random  # repro: noqa[RL004]\n")
    assert "RL004" in _rules_hit(rep)


def test_noqa_by_rule_name_suppresses():
    rep = _lint(
        "import random  # repro: noqa[host-rng]: deterministic demo ids\n"
    )
    assert "RL004" not in _rules_hit(rep)


def test_noqa_wrong_rule_does_not_suppress():
    rep = _lint("import random  # repro: noqa[RL005]: wrong rule\n")
    assert "RL004" in _rules_hit(rep)


def test_unused_noqa_reported():
    rep = _lint("x = 1  # repro: noqa[RL004]: nothing to suppress\n")
    assert rep.unused_noqa


# ---------------------------------------------------------------------------
# The repo-wide gate: `python -m repro.analysis src/` must stay clean
# ---------------------------------------------------------------------------

def test_repo_lint_is_clean():
    report = engine.lint_paths(["src"])
    assert report.ok, "\n".join(f.format() for f in report.findings)
