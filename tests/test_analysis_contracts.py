"""Jaxpr contract checker: the golden dispatch-table sweep plus one
negative test per contract (a checker that can't fail proves nothing)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import linalg
from repro.analysis import contracts as C

pytestmark = pytest.mark.analysis


def _sds(m, n, dt=jnp.float32):
    return jax.ShapeDtypeStruct((m, n), dt)


# ---------------------------------------------------------------------------
# Positive: every golden-table plan satisfies every applicable contract
# ---------------------------------------------------------------------------

def test_golden_table_covers_every_path_and_guard():
    entries = C.golden_plan_table()
    paths = {pl.path for _, pl, _ in entries}
    assert paths == {"dense", "streamed", "batched", "sharded", "matfree",
                     "sparse", "adaptive"}
    guards = {pl.guard.mode for _, pl, _ in entries}
    assert guards == {"off", "report"}


def test_golden_sweep_clean():
    report = C.sweep()
    assert report.ok, "\n".join(
        f"{r.contract}[{r.plan_label}]: {r.detail}" for r in report.violations)
    # every contract is exercised at least once across the table
    exercised = {r.contract for r in report.results}
    assert exercised == {"C1-peak-intermediate", "C2-donation",
                         "C3-row-panel-fallback", "C4-reads-of-a",
                         "C5-trace-accounting"}


def test_fixture_raises_on_breach(assert_plan_contracts, monkeypatch):
    pl = linalg.plan(linalg.DenseOp(_sds(96, 48)), 8)
    assert_plan_contracts(pl)  # sanity: the real plan passes
    # Tighten the C1 bound to an impossible value: the checker must raise.
    monkeypatch.setattr(C, "intermediate_bound_bytes", lambda _pl: 1)
    with pytest.raises(C.ContractViolation) as err:
        assert_plan_contracts(pl)
    assert any(r.contract == "C1-peak-intermediate" and not r.ok
               for r in err.value.results)


# ---------------------------------------------------------------------------
# C1 negative: a materialized m x n intermediate must be seen and priced
# ---------------------------------------------------------------------------

def test_peak_catches_materialized_dense_copy():
    m, n, k = 64, 32, 4

    def materializing(A, X):
        dense = A + 0.0          # a real m x n copy, not a view
        return dense @ X

    facts = C.trace_facts(
        materializing, (_sds(m, n), _sds(n, k)), {0: "A"})
    ok, detail = C.verify_peak(facts, m * n * 4 - 1)
    assert not ok, detail
    assert facts.peak_intermediate_bytes >= m * n * 4


def test_transposed_view_is_not_an_intermediate():
    facts = C.trace_facts(lambda A, X: A.T @ X, (_sds(64, 32), _sds(64, 4)),
                          {0: "A"})
    # A.T folds into dot_general dimension numbers — only the (32, 4)
    # result materializes.
    assert facts.peak_intermediate_bytes == 32 * 4 * 4


# ---------------------------------------------------------------------------
# C2 negative: an un-donated accumulator update aliases nothing
# ---------------------------------------------------------------------------

def test_donation_catches_missing_donate_argnums():
    undonated = jax.jit(lambda acc, x: acc + x)
    acc = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    ok, detail = C.verify_donation(undonated, (acc, acc), 16 * 8 * 4)
    assert not ok, detail


# ---------------------------------------------------------------------------
# C3 negative: a gather-based panel walk must be flagged
# ---------------------------------------------------------------------------

def test_panel_check_catches_gather():
    def gather_panel(X):
        return X[jnp.array([0, 2, 4])]

    ok, detail = C.verify_no_gather_scatter(gather_panel, (_sds(8, 4),))
    assert not ok
    assert "gather" in detail


def test_panel_check_catches_scatter():
    def scatter_panel(X):
        return X.at[jnp.array([0, 2])].set(0.0)

    ok, detail = C.verify_no_gather_scatter(scatter_panel, (_sds(8, 4),))
    assert not ok


# ---------------------------------------------------------------------------
# C4 negative: extra passes over A must be counted
# ---------------------------------------------------------------------------

def test_reads_catches_double_pass():
    def double_read(A, X):
        return (A @ X + A @ X) * 0.5

    facts = C.trace_facts(double_read, (_sds(64, 32), _sds(32, 4)), {0: "A"})
    ok, detail = C.verify_reads(facts, 1)
    assert not ok, detail
    assert facts.reads["A"] == 2


def test_reads_survive_padding_to_tile_quantum():
    # pad is layout staging: a kernel consuming the padded copy still reads A.
    def padded_read(A, X):
        Ap = jnp.pad(A, ((0, 2), (0, 0)))
        return Ap @ X

    facts = C.trace_facts(padded_read, (_sds(62, 32), _sds(32, 4)), {0: "A"})
    assert facts.reads.get("A", 0) == 1


# ---------------------------------------------------------------------------
# C5 negative: a body that re-traces per call must fail the accounting
# ---------------------------------------------------------------------------

def test_retrace_check_catches_trace_per_call():
    traces = []
    ok, detail = C.verify_no_retrace(lambda: traces.append(1),
                                     lambda: len(traces))
    assert not ok, detail


def test_retrace_check_accepts_trace_once():
    traces = []

    def solve():
        if not traces:
            traces.append(1)

    ok, detail = C.verify_no_retrace(solve, lambda: len(traces))
    assert ok, detail


# ---------------------------------------------------------------------------
# Model helpers
# ---------------------------------------------------------------------------

def test_expected_reads_match_rsvd_model():
    from repro.roofline import rsvd_model

    pl = linalg.plan(linalg.DenseOp(_sds(96, 48)), 8)
    if not pl.fused_power:
        assert C.expected_reads_of_a(pl) == \
            rsvd_model.streamed_pass_count(pl.power_iters)


def test_streamed_working_set_beats_dense_residency():
    from repro.core.rsvd import RSVDConfig

    pl = linalg.plan(linalg.DenseOp(_sds(65536, 4096)), 32,
                     overrides=RSVDConfig.streaming(4096))
    assert pl.path == "streamed"
    ws = C.streamed_working_set_bytes(pl)
    assert ws < 65536 * 4096 * 4
