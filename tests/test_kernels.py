"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sketch import sketch_matrix
from repro.kernels import ops, ref


def _rand(shape, seed, dtype=jnp.float32):
    flat = sketch_matrix(int(np.prod(shape[:-1])), shape[-1], seed)
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n", [(8, 8, 8), (128, 128, 128), (256, 384, 128), (100, 70, 30), (1, 5, 3), (130, 257, 129)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    x = _rand((m, k), 0, dtype)
    y = _rand((k, n), 1, dtype)
    got = ops.matmul(x, y)
    want = ref.matmul_ref(x, y)
    atol = 2e-5 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol, rtol=1e-2
    )


# ---------------------------------------------------------------------------
# fused sketch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,s", [(64, 64, 16), (128, 256, 32), (100, 90, 17), (256, 128, 128)])
@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
def test_sketch_matmul_matches_materialized(m, n, s, kind):
    """The kernel's in-VMEM Omega must equal the materialized Omega bit-wise,
    so the product matches the oracle to accumulation order."""
    a = _rand((m, n), 2)
    got = ops.sketch_matmul(a, s, seed=7, kind=kind)
    want = ref.sketch_matmul_ref(a, s, seed=7, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
def test_sketch_panel_offset_bit_identical(kind):
    """Panel p of the kernel's in-VMEM Omega (row_offset=p*b) must be BIT-
    identical to rows [p*b, (p+1)*b) of the monolithic sketch_matrix Omega —
    the contract that makes blocked streaming deterministic regardless of
    panelization.  Identity input reads Omega out exactly (1.0 * x sums with
    zeros are exact in fp32)."""
    n_total, s = 96, 17
    full = np.asarray(sketch_matrix(n_total, s, seed=5, kind=kind))
    for off, b in [(0, 32), (32, 32), (64, 16), (80, 16)]:
        eye = jnp.eye(b, dtype=jnp.float32)
        got = np.asarray(ops.sketch_matmul(eye, s, seed=5, kind=kind, row_offset=off))
        np.testing.assert_array_equal(got, full[off : off + b])


@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
def test_sketch_column_panel_accumulation(kind):
    """Y = sum_p A[:, p] @ Omega_p (kernel, row_offset) == A @ Omega (oracle)."""
    a = _rand((40, 96), 21)
    s, seed = 13, 7
    want = ref.sketch_matmul_ref(a, s, seed=seed, kind=kind)
    acc = jnp.zeros((40, s), jnp.float32)
    for lo in range(0, 96, 48):
        acc = acc + ops.sketch_matmul(
            a[:, lo : lo + 48], s, seed=seed, kind=kind,
            out_dtype=jnp.float32, row_offset=lo,
        )
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_sketch_matmul_independent_of_padding():
    """Same logical s on different padded widths -> identical result."""
    a = _rand((64, 64), 3)
    c1 = ops.sketch_matmul(a, 10, seed=1)
    # widen input so padding differs
    a2 = jnp.pad(a, ((0, 0), (0, 64)))
    c2 = ops.sketch_matmul(a2[:, :64], 10, seed=1)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s", [(64, 16), (256, 64), (300, 40), (128, 130)])
def test_gram_matches_oracle(m, s):
    y = _rand((m, s), 4)
    got = ops.gram(y)
    want = ref.gram_ref(y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)
    # exact symmetry by construction
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got).T)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

# One GQA case stays tier-1 (flash attention is a model kernel, not the
# rSVD core); the full sweep runs in the nightly slow lane.
@pytest.mark.parametrize(
    "hq,hkv",
    [(4, 4),
     pytest.param(8, 2, marks=pytest.mark.slow),
     pytest.param(8, 1, marks=pytest.mark.slow)],
)
@pytest.mark.parametrize(
    "causal", [True, pytest.param(False, marks=pytest.mark.slow)]
)
def test_flash_attention_gqa(hq, hkv, causal):
    B, T, D = 2, 64, 32
    q = _rand((B, hq, T, D), 5) * 0.3
    k = _rand((B, hkv, T, D), 6) * 0.3
    v = _rand((B, hkv, T, D), 7) * 0.3
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    B, H, T, D = 1, 2, 128, 32
    q = _rand((B, H, T, D), 8) * 0.3
    k = _rand((B, H, T, D), 9) * 0.3
    v = _rand((B, H, T, D), 10) * 0.3
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


@pytest.mark.slow
def test_flash_attention_softcap():
    B, H, T, D = 1, 2, 64, 32
    q = _rand((B, H, T, D), 11)
    k = _rand((B, H, T, D), 12)
    v = _rand((B, H, T, D), 13) * 0.3
    got = ops.flash_attention(q, k, v, causal=True, softcap=30.0)
    want = ref.flash_attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


@pytest.mark.slow
def test_flash_attention_decode_shape():
    """Tq=1 decode against a long key timeline (right-aligned queries)."""
    B, H, Tk, D = 2, 4, 96, 32
    q = _rand((B, H, 1, D), 14) * 0.3
    k = _rand((B, H, Tk, D), 15) * 0.3
    v = _rand((B, H, Tk, D), 16) * 0.3
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


@pytest.mark.slow
def test_flash_attention_nonmultiple_lengths():
    B, H, T, D = 1, 2, 100, 32  # pads to 128
    q = _rand((B, H, T, D), 17) * 0.3
    k = _rand((B, H, T, D), 18) * 0.3
    v = _rand((B, H, T, D), 19) * 0.3
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 100),
    seed=st.integers(0, 1000),
)
def test_matmul_property(m, k, n, seed):
    x = _rand((m, k), seed)
    y = _rand((k, n), seed + 1)
    np.testing.assert_allclose(
        np.asarray(ops.matmul(x, y)), np.asarray(ref.matmul_ref(x, y)), atol=1e-4, rtol=1e-3
    )


@settings(deadline=None, max_examples=10)
@given(m=st.integers(2, 150), n=st.integers(2, 150), s=st.integers(1, 48), seed=st.integers(0, 1000))
def test_sketch_property(m, n, s, seed):
    a = _rand((m, n), seed)
    np.testing.assert_allclose(
        np.asarray(ops.sketch_matmul(a, s, seed=seed)),
        np.asarray(ref.sketch_matmul_ref(a, s, seed=seed)),
        atol=1e-4,
        rtol=1e-3,
    )
