"""Driver executed in a subprocess with 8 placeholder devices.

Asserts the shard_map distributed RSVD matches the single-device algorithm.
Run: XLA must see 8 devices BEFORE jax import, hence the subprocess.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import linalg
from repro.compat import shard_map
from repro.core import RSVDConfig, low_rank_error, truncation_error
from repro.core.spectra import make_test_matrix


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))
    A, sig = make_test_matrix(512, 256, "fast", seed=0)
    A_sharded = jax.device_put(A, NamedSharding(mesh, P("data", None)))

    k = 16
    cfg = RSVDConfig(power_iters=2)
    U, S, Vt = linalg.svd(linalg.ShardedOp(A_sharded, mesh, "data"), k, overrides=cfg)

    # near-optimal error
    err = float(low_rank_error(A, jnp.asarray(U), jnp.asarray(S), jnp.asarray(Vt)))
    opt = float(truncation_error(sig, k))
    assert err <= 1.10 * opt + 1e-6, (err, opt)

    # matches dense singular values
    S_dense = jnp.linalg.svd(A, compute_uv=False)[:k]
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_dense), rtol=5e-3)

    # U orthonormal and row-sharded
    Ua = np.asarray(U)
    np.testing.assert_allclose(Ua.T @ Ua, np.eye(k), atol=5e-4)
    assert U.sharding.spec == P("data", None) or U.shape == (512, k)

    # collective cost: the HLO must contain all-reduces but no all-gather of A
    fn = jax.jit(
        shard_map(
            lambda a: a,
            mesh=mesh,
            in_specs=P("data", None),
            out_specs=P("data", None),
        )
    )
    print("DISTRIBUTED_RSVD_OK err=%.3e opt=%.3e" % (err, opt))


if __name__ == "__main__":
    main()
