"""Per-architecture smoke tests: REDUCED config, one forward + one train step
on CPU, asserting output shapes and absence of NaNs.  (The FULL configs are
exercised only via the dry-run.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import synthetic_batch
from repro.models import forward_model, init_model
from repro.models.transformer import count_params
from repro.optim import adamw
from repro.train.train_step import compute_loss, make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")

ALL_ARCHS = sorted(ARCHS)

# Tier-1 runs one cheap representative arch; the full per-arch sweep (each
# train step costs 5-15s of CPU compile) is slow-marked for the nightly lane.
FAST_ARCHS = {"llama3.2-1b"}
ARCH_PARAMS = [
    n if n in FAST_ARCHS else pytest.param(n, marks=pytest.mark.slow)
    for n in ALL_ARCHS
]


def _setup(name):
    cfg = get_config(name).reduced()
    if cfg.is_encoder_decoder:
        cfg = dataclasses.replace(cfg, encoder_seq_len=32)
    params = init_model(cfg, jax.random.key(0))
    batch = synthetic_batch(cfg, SMOKE_SHAPE, step=0)
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_forward_shapes_and_finite(name):
    cfg, params, batch = _setup(name)
    logits, aux = forward_model(params, batch, cfg, mode="train")
    B, T = batch["tokens"].shape
    extra = cfg.vision_tokens if cfg.vision_stub else 0
    assert logits.shape == (B, T + extra, cfg.padded_vocab_()), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), "NaN/inf in logits"
    assert count_params(params) > 0


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_train_step_reduces_loss_and_finite(name):
    cfg, params, batch = _setup(name)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10, clip_norm=1.0)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    opt_state = adamw.init_state(params)

    loss0 = float(compute_loss(params, batch, cfg)[0])
    params2, opt_state, metrics, _ = step_fn(params, opt_state, batch, None)
    loss1 = float(compute_loss(params2, batch, cfg)[0])

    assert np.isfinite(loss0) and np.isfinite(loss1), (loss0, loss1)
    assert float(metrics["grad_norm"]) > 0
    # one step on the same batch should not blow the loss up
    assert loss1 < loss0 * 1.5, (loss0, loss1)
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), "non-finite param"


@pytest.mark.slow
def test_param_counts_full_configs():
    """Full (non-reduced) configs must land near their advertised sizes.

    Counted via eval_shape — no memory is allocated.
    """
    import numpy as np
    from repro.models import abstract_params

    expected = {
        "phi3-mini-3.8b": (3.4e9, 4.4e9),
        "qwen3-4b": (3.2e9, 5.0e9),
        "gemma2-2b": (2.0e9, 3.4e9),
        "llama3.2-1b": (1.0e9, 1.7e9),
        # assigned config is 48L x 64 experts x 1408: the expert weights alone
        # are 48*64*3*2048*1408 ~ 26.5B — the assignment's layer count, not the
        # HF model's 27L, is authoritative (documented in DESIGN.md).
        "moonshot-v1-16b-a3b": (26e9, 30e9),
        "deepseek-v2-lite-16b": (13e9, 17e9),
        "whisper-base": (5e7, 1.1e8),
        "recurrentgemma-9b": (7.5e9, 11e9),
        # backbone only (Qwen2-0.5B ~ 0.49B); the InternViT-300M tower is a
        # stub per the assignment, so it contributes no parameters.
        "internvl2-1b": (4.4e8, 1.1e9),
        "xlstm-350m": (2.5e8, 5e8),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        shapes = abstract_params(cfg)
        n = sum(
            int(np.prod(l.shape))
            for l in jax.tree.leaves(shapes)
            if hasattr(l, "shape")
        )
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
