"""Spec-driven decompositions: the registry, facade validation, plan
summaries, and the non-SVD kinds (qb / lu / eigh / pca).

The Rank-spec svd path must be BIT-identical to `linalg.svd` (same planner,
same executors) — that is the contract that lets every historical call site
become a thin spec wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core.rsvd import RSVDConfig
from repro.core.spectra import make_test_matrix, random_orthogonal, spectrum


def _sds(m, n, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((m, n), dtype)


def _psd(n, kind="sharp", seed=0):
    """A = V diag(sig) V^T: symmetric PSD with a known spectrum."""
    V = random_orthogonal(n, n, seed)
    sig = spectrum(n, kind)
    return (V * sig[None, :]) @ V.T, sig


def _sparse_op_1000():
    """Deterministic 256x128 SparseOp with exactly 1000 nonzeros (explicit
    index construction — stable nnz/density for the describe goldens)."""
    from jax.experimental import sparse as jsparse

    i = np.arange(1000)
    idx = np.stack([i % 256, (7 * i + i // 256) % 128], axis=1)
    bcoo = jsparse.BCOO((jnp.ones((1000,), jnp.float32), jnp.asarray(idx)),
                        shape=(256, 128))
    return linalg.SparseOp(bcoo)


# ---------------------------------------------------------------------------
# Spec objects + coercion
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="positive"):
        linalg.Tolerance(0.0)
    with pytest.raises(ValueError, match="norm"):
        linalg.Tolerance(1e-2, norm="spectral")
    with pytest.raises(ValueError, match="in \\(0, 1\\]"):
        linalg.Energy(0.0)
    with pytest.raises(ValueError, match="in \\(0, 1\\]"):
        linalg.Energy(1.5)
    with pytest.raises(ValueError, match="integer"):
        linalg.Rank(2.5)
    with pytest.raises(ValueError, match="rank .* or a Spec"):
        linalg.as_spec("twelve")
    assert linalg.as_spec(8) == linalg.Rank(8)
    spec = linalg.Tolerance(1e-2)
    assert linalg.as_spec(spec) is spec


def test_sketch_knob_validation_and_describe():
    for mk in (lambda s: linalg.Rank(8, sketch=s),
               lambda s: linalg.Tolerance(1e-2, sketch=s),
               lambda s: linalg.Energy(0.9, sketch=s)):
        with pytest.raises(ValueError, match="unknown sketch kind"):
            mk("fourier")
        for s in ("gaussian", "rademacher", "srht", "countsketch"):
            assert f"sketch={s}" in mk(s).describe()
    assert linalg.Rank(8).describe() == "rank(k=8)"  # None stays silent


def test_sketch_knob_resolves_into_plan():
    """spec.sketch lands in the executed config; paths that stream panels
    can't apply a structured sketch and fall back to gaussian."""
    pl = linalg.plan(linalg.DenseOp(_sds(256, 128)), linalg.Rank(8, sketch="srht"))
    assert pl.sketch_kind == "srht"
    host = linalg.HostOp(np.zeros((4096, 64), np.float32), block_rows=512)
    pl_host = linalg.plan(host, linalg.Rank(8, sketch="srht"))
    assert pl_host.path == "streamed" and pl_host.sketch_kind == "gaussian"
    pl_rad = linalg.plan(host, linalg.Rank(8, sketch="rademacher"))
    assert pl_rad.sketch_kind == "rademacher"  # row-decomposable: kept


# ---------------------------------------------------------------------------
# select_rank boundary semantics (pinned): smallest rank, INCLUSIVE
# comparisons, >=1 clamp, full fallback.  All values dyadic-exact so the
# comparisons sit exactly ON the boundary without fp slack.
# ---------------------------------------------------------------------------

_SIG = np.asarray([2.0, 1.0, 1.0, 1.0, 1.0])  # sum of squares = 8 exactly


def test_tolerance_select_rank_inclusive_at_exact_tail():
    # target = 0.25 * 8 = 2.0 == tail after keeping 3 values -> rank 3,
    # not 4: the comparison is inclusive
    assert linalg.Tolerance(0.5).select_rank(_SIG, 0.0, 8.0) == 3


def test_tolerance_select_rank_clamps_to_one():
    # eps=1 accepts rank 0 (resid[0] = 8 <= 8) but the clamp keeps >= 1
    assert linalg.Tolerance(1.0).select_rank(_SIG, 0.0, 8.0) == 1


def test_tolerance_select_rank_counts_remaining_energy():
    # remaining 8 outside the basis, norm_sq 16: target = 0.75^2*16 = 9.0
    # == remaining + tail at rank 4 (8 + 1), inclusive again
    assert linalg.Tolerance(0.75).select_rank(_SIG, 8.0, 16.0) == 4


def test_tolerance_select_rank_unreachable_falls_back_to_all():
    # remaining alone (1.0) exceeds the target (0.5): keep every value
    assert linalg.Tolerance(0.25).select_rank(_SIG, 1.0, 8.0) == 5


def test_energy_select_rank_inclusive_at_exact_capture():
    # cumsum [4,5,6,7,8]; p*total = 4.0 is hit exactly by the first value
    assert linalg.Energy(0.5).select_rank(_SIG, 0.0, 8.0) == 1


def test_energy_select_rank_full_fraction_needs_all():
    assert linalg.Energy(1.0).select_rank(_SIG, 0.0, 8.0) == 5


def test_energy_select_rank_unreachable_falls_back_to_all():
    # remaining energy means the basis can never capture the fraction
    assert linalg.Energy(1.0).select_rank(_SIG, 1.0, 9.0) == 5


def test_select_rank_single_singular_value():
    one = np.asarray([2.0])
    assert linalg.Tolerance(0.5).select_rank(one, 0.0, 4.0) == 1
    assert linalg.Energy(1.0).select_rank(one, 0.0, 4.0) == 1


# ---------------------------------------------------------------------------
# Facade validation: clear ValueErrors at plan time, not deep in numerics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("entry", [linalg.svd, linalg.eigvals])
def test_bad_rank_raises_at_plan_time(entry):
    A = jnp.zeros((32, 16))
    with pytest.raises(ValueError, match="positive"):
        entry(A, 0)
    with pytest.raises(ValueError, match="positive"):
        entry(A, -3)
    with pytest.raises(ValueError, match="exceeds min"):
        entry(A, 17)


def test_bad_rank_raises_for_stacked_and_pca():
    with pytest.raises(ValueError, match="exceeds min"):
        linalg.svd(jnp.zeros((2, 32, 16)), 20)
    with pytest.raises(ValueError, match="positive"):
        linalg.pca(jnp.zeros((32, 16)), 0)


def test_empty_dimension_raises_at_plan_time():
    with pytest.raises(ValueError, match="empty dimension"):
        linalg.decompose(jnp.zeros((0, 8)), linalg.Tolerance(0.1))
    with pytest.raises(ValueError, match="empty dimension|exceeds min"):
        linalg.svd(jnp.zeros((8, 0)), 2)


def test_bad_ndim_raises_value_error():
    with pytest.raises(ValueError, match="2-D .* or 3-D"):
        linalg.svd(jnp.zeros((8,)), 2)
    with pytest.raises(ValueError, match="2-D .* or 3-D"):
        linalg.plan(jnp.zeros((2, 2, 2, 2)), 1)


def test_fixed_rank_wrappers_reject_adaptive_specs():
    """svd/eigvals are the Rank-spec thin wrappers: an adaptive spec must be
    redirected to decompose() with a clear message, not crash deep in the
    path dispatch."""
    A = jnp.zeros((32, 16))
    with pytest.raises(ValueError, match="use linalg.decompose"):
        linalg.svd(A, linalg.Tolerance(1e-2))
    with pytest.raises(ValueError, match="use linalg.decompose"):
        linalg.eigvals(A, linalg.Energy(0.9))
    pl = linalg.plan(A, linalg.Tolerance(1e-2))
    with pytest.raises(ValueError, match="decompose"):
        linalg.svd(A, 4, plan=pl)


def test_pinned_plan_must_match_spec_and_kind():
    """decompose with a pinned plan built for a DIFFERENT spec/kind fails
    with a clear re-plan message, not an internal AttributeError."""
    A, _ = make_test_matrix(96, 32, "fast", seed=30)
    pl = linalg.plan(A, 8)
    with pytest.raises(ValueError, match="re-plan"):
        linalg.decompose(A, linalg.Tolerance(1e-2), plan=pl)
    with pytest.raises(ValueError, match="re-plan"):
        linalg.decompose(A, 8, kind="qb", plan=pl)


def test_plan_facade_prepares_pca_sources():
    """linalg.plan(kind='pca') must describe the CenteredOp that decompose
    actually executes, so a pinned pca plan round-trips — and the lazy mu
    keeps shape-only planning data-free."""
    X = make_test_matrix(128, 32, "fast", seed=31)[0] + 0.5
    pl = linalg.plan(X, 6, kind="pca")
    assert pl.path == "matfree" and pl.kind == "pca"
    res = linalg.decompose(X, 6, kind="pca", plan=pl)
    direct = linalg.pca(X, 6)
    np.testing.assert_allclose(np.asarray(res.factors[2]),
                               np.asarray(direct.singular_values), rtol=1e-5)
    # shape-only: a ShapeDtypeStruct source plans without touching data
    pl_sds = linalg.plan(linalg.DenseOp(_sds(512, 64)), 6, kind="pca")
    assert pl_sds.path == "matfree"


def test_fro_norm_sq_bounds_composed_panel_height():
    """The ||A||_F^2 walk must not materialize the full centered matrix:
    the default panel height is bounded even when the source has no
    block_rows of its own."""
    from repro.core.adaptive import DEFAULT_NORM_PANEL_ROWS, fro_norm_sq

    seen = []

    class Recorder(linalg.DenseOp):
        def row_panels(self, block_rows=None):
            seen.append(block_rows)
            return super().row_panels(block_rows)

    X = make_test_matrix(96, 24, "fast", seed=32)[0] + 1.0
    op = linalg.CenteredOp(Recorder(X))
    got = fro_norm_sq(op)
    # two bounded walks: the lazy mu (column_means) and the norm itself
    assert seen == [linalg.HostOp.DEFAULT_BLOCK_ROWS, DEFAULT_NORM_PANEL_ROWS]
    Xc = X - jnp.mean(X, axis=0)[None, :]
    np.testing.assert_allclose(got, float(jnp.sum(Xc * Xc)), rtol=1e-5)


def test_unknown_kind_and_shape_constraints():
    A = jnp.zeros((16, 16))
    with pytest.raises(ValueError, match="unknown decomposition kind"):
        linalg.decompose(A, 4, kind="polar")
    with pytest.raises(ValueError, match="unknown decomposition kind"):
        linalg.plan(A, 4, kind="polar")
    with pytest.raises(ValueError, match="square"):
        linalg.plan(jnp.zeros((32, 16)), 4, kind="eigh")
    with pytest.raises(ValueError, match="2-D source"):
        linalg.plan(jnp.zeros((2, 16, 8)), linalg.Tolerance(1e-2))


# ---------------------------------------------------------------------------
# Plan summaries: golden describe() strings (kind/spec included)
# ---------------------------------------------------------------------------

DESCRIBE_GOLDEN = [
    (lambda: linalg.plan(linalg.DenseOp(_sds(1024, 512)), 32,
                         overrides=RSVDConfig()),
     "path=dense shape=1024x512 k=32 s=42 kind=svd spec=rank(k=32)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 pred_hbm=18.7MB"),
    (lambda: linalg.plan(linalg.DenseOp(_sds(1024, 512)),
                         linalg.Tolerance(1e-2, panel=64),
                         overrides=RSVDConfig()),
     "path=adaptive shape=1024x512 k=512 s=64 kind=svd spec=tol(eps=0.01)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 panel=64 steps=8 pred_hbm=260.0MB"),
    (lambda: linalg.plan(linalg.DenseOp(_sds(1024, 512)), linalg.Rank(16),
                         overrides=RSVDConfig(), kind="qb"),
     "path=adaptive shape=1024x512 k=26 s=26 kind=qb spec=rank(k=16)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 panel=26 steps=1 pred_hbm=17.7MB"),
    (lambda: linalg.plan(linalg.DenseOp(_sds(512, 512)),
                         linalg.Energy(0.9, panel=32),
                         overrides=RSVDConfig(), kind="eigh"),
     "path=adaptive shape=512x512 k=512 s=32 kind=eigh spec=energy(p=0.9)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 panel=32 steps=16 pred_hbm=224.4MB"),
    (lambda: linalg.plan(_sparse_op_1000(), 8, overrides=RSVDConfig()),
     "path=sparse shape=256x128 k=8 s=18 kind=svd spec=rank(k=8)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 nnz=1000 density=0.03052 pred_hbm=0.7MB"),
    (lambda: linalg.plan(_sparse_op_1000(), linalg.Rank(8, sketch="srht"),
                         overrides=RSVDConfig()),
     "path=sparse shape=256x128 k=8 s=18 kind=svd spec=rank(k=8, sketch=srht)"
     " qr=householder backend=jnp fused_sketch=False fused_power=False"
     " pipeline_depth=1 nnz=1000 density=0.03052 pred_hbm=0.7MB"),
    (lambda: linalg.plan(linalg.DenseOp(_sds(1024, 512)),
                         linalg.Rank(32, sketch="countsketch"),
                         overrides=RSVDConfig()),
     "path=dense shape=1024x512 k=32 s=42 kind=svd"
     " spec=rank(k=32, sketch=countsketch) qr=householder backend=jnp"
     " fused_sketch=False fused_power=False pipeline_depth=1 pred_hbm=18.7MB"),
]


@pytest.mark.parametrize("mk_plan,want", DESCRIBE_GOLDEN,
                         ids=["rank", "tol", "qb", "eigh", "sparse",
                              "sparse-srht", "countsketch"])
def test_describe_golden(mk_plan, want):
    assert mk_plan().describe() == want


def test_adaptive_plan_bytes_match_roofline_schedule():
    from repro.roofline import rsvd_model

    pl = linalg.plan(linalg.DenseOp(_sds(1024, 512)),
                     linalg.Tolerance(1e-2, panel=64), overrides=RSVDConfig())
    want = rsvd_model.adaptive_schedule_bytes(
        pl.m, pl.n, pl.rank_schedule, pl.power_iters,
        dtype_bytes=4, fused_sketch=pl.fused_sketch)
    assert pl.schedule_hbm_bytes == want
    assert pl.predicted_hbm_bytes == sum(want)


# ---------------------------------------------------------------------------
# Rank-spec svd is bit-identical to linalg.svd (the thin-wrapper contract)
# ---------------------------------------------------------------------------

def _assert_same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_decompose_rank_svd_bit_identical_dense():
    A, _ = make_test_matrix(192, 64, "fast", seed=0)
    dec = linalg.decompose(A, linalg.Rank(8), seed=3)
    _assert_same(dec.factors, linalg.svd(A, 8, seed=3))
    assert dec.rank == 8 and dec.plan.path == "dense"
    assert dec.rank_history == (8,)


def test_decompose_rank_svd_bit_identical_streamed_and_batched():
    A_host = np.asarray(make_test_matrix(200, 48, "fast", seed=1)[0])
    op = linalg.HostOp(A_host, block_rows=64)
    _assert_same(linalg.decompose(op, 6, seed=2).factors,
                 linalg.svd(op, 6, seed=2))
    stack = jnp.stack([make_test_matrix(64, 32, "fast", seed=4 + i)[0]
                       for i in range(2)])
    _assert_same(linalg.decompose(stack, 4, seed=9).factors,
                 linalg.svd(stack, 4, seed=9))


def test_decomposition_unpacks_like_its_factors():
    A, _ = make_test_matrix(96, 32, "fast", seed=2)
    dec = linalg.decompose(A, 5)
    U, S, Vt = dec
    assert U.shape == (96, 5) and S.shape == (5,) and Vt.shape == (5, 32)
    assert len(dec) == 3 and dec[1] is dec.factors[1]


# ---------------------------------------------------------------------------
# qb kind
# ---------------------------------------------------------------------------

def test_qb_rank_spec_shapes_orthonormality_and_residual():
    A, sig = make_test_matrix(192, 64, "fast", seed=5)
    k = 12
    Q, B = linalg.decompose(A, linalg.Rank(k), kind="qb", seed=1)
    assert Q.shape == (192, k) and B.shape == (k, 64)
    G = np.asarray(Q.T @ Q)
    assert np.max(np.abs(G - np.eye(k))) < 5e-5
    err = float(jnp.linalg.norm(A - Q @ B) / jnp.linalg.norm(A))
    from repro.core import truncation_error

    assert err <= 1.1 * float(truncation_error(sig, k)) + 1e-6


def test_qb_tolerance_meets_residual():
    A, _ = make_test_matrix(192, 64, "sharp", seed=6)
    dec = linalg.decompose(A, linalg.Tolerance(1e-2, panel=16), kind="qb", seed=2)
    Q, B = dec
    err = float(jnp.linalg.norm(A - Q @ B) / jnp.linalg.norm(A))
    assert err <= 1e-2 and Q.shape[1] == dec.rank


# ---------------------------------------------------------------------------
# lu kind: A[pr][:, pc] ~= L U on dense and host-streamed sources
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", ["dense", "host"])
def test_lu_reconstructs(source):
    A_dev, _ = make_test_matrix(160, 64, "sharp", seed=7)
    a = np.asarray(A_dev) if source == "host" else A_dev
    if source == "host":
        a = linalg.HostOp(np.asarray(A_dev), block_rows=48)
    dec = linalg.decompose(a, linalg.Tolerance(1e-2, panel=16), kind="lu", seed=3)
    pr, L, U, pc = dec
    r = dec.rank
    assert L.shape == (160, r) and U.shape == (r, 64)
    # structure: L lower-trapezoidal, U unit-upper-trapezoidal
    np.testing.assert_allclose(np.triu(np.asarray(L), 1), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.tril(np.asarray(U), -1), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.diagonal(np.asarray(U)), 1.0, atol=1e-5)
    R = np.asarray(A_dev)[np.asarray(pr)][:, np.asarray(pc)] - np.asarray(L @ U)
    err = np.linalg.norm(R) / np.linalg.norm(np.asarray(A_dev))
    assert err <= 1e-2, err


def test_lu_fixed_rank():
    A, sig = make_test_matrix(128, 48, "fast", seed=8)
    k = 10
    pr, L, U, pc = linalg.decompose(A, linalg.Rank(k), kind="lu", seed=1)
    assert L.shape == (128, k) and U.shape == (k, 48)
    R = np.asarray(A)[np.asarray(pr)][:, np.asarray(pc)] - np.asarray(L @ U)
    from repro.core import truncation_error

    err = np.linalg.norm(R) / np.linalg.norm(np.asarray(A))
    assert err <= 1.5 * float(truncation_error(sig, k)) + 1e-5


# ---------------------------------------------------------------------------
# eigh kind (Nystrom, PSD sources) on dense and host-streamed sources
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", ["dense", "host"])
def test_eigh_reconstructs_psd(source):
    A, sig = _psd(96, "sharp", seed=9)
    a = linalg.HostOp(np.asarray(A), block_rows=32) if source == "host" else A
    dec = linalg.decompose(a, linalg.Tolerance(1e-2, panel=16), kind="eigh", seed=4)
    w, V = dec
    assert w.shape == (dec.rank,) and V.shape == (96, dec.rank)
    # eigenvalues descend and match the known spectrum
    assert np.all(np.diff(np.asarray(w)) <= 1e-6)
    np.testing.assert_allclose(np.asarray(w[:8]), np.asarray(sig[:8]), rtol=5e-3)
    rec = (V * w[None, :]) @ V.T
    err = float(jnp.linalg.norm(A - rec) / jnp.linalg.norm(A))
    assert err <= 1.5e-2, err


def test_eigh_fixed_rank():
    A, sig = _psd(64, "fast", seed=10)
    w, V = linalg.decompose(A, linalg.Rank(6), kind="eigh", seed=2)
    assert w.shape == (6,) and V.shape == (64, 6)
    np.testing.assert_allclose(np.asarray(w), np.asarray(sig[:6]), rtol=1e-2)


# ---------------------------------------------------------------------------
# pca kind / energy-fraction PCA
# ---------------------------------------------------------------------------

def test_pca_energy_matches_exact_variance_fraction():
    from repro.core.pca import pca_exact

    X = make_test_matrix(200, 40, "fast", seed=11)[0] + 1.0
    p = 0.98
    res = linalg.pca(X, linalg.Energy(p, panel=4), seed=0)
    exact = pca_exact(X, 40)
    total = float(jnp.sum(exact.singular_values**2))
    captured = float(jnp.sum(res.singular_values**2))
    assert captured / total >= p - 1e-4
    # oracle rank from the exact spectrum
    e = np.cumsum(np.asarray(exact.singular_values, np.float64) ** 2)
    oracle = int(np.nonzero(e >= p * e[-1])[0][0]) + 1
    assert oracle <= res.components.shape[0] <= oracle + 4
    np.testing.assert_allclose(np.asarray(res.mean), np.asarray(exact.mean),
                               atol=1e-5)


def test_core_pca_accepts_specs():
    from repro.core import pca as pca_mod

    X = make_test_matrix(160, 32, "fast", seed=12)[0] + 0.5
    res = pca_mod.pca(X, linalg.Energy(0.95, panel=4))
    assert res.components.shape[1] == 32 and res.components.shape[0] < 32


def test_registry_is_extensible():
    """Third-party kinds: register, plan, execute, unregister."""
    from repro.linalg import registry

    def _execute_norm(op, spec, pl, seed):
        qb = registry._qb_core(op, spec, pl, seed)
        return (jnp.sqrt(jnp.asarray(qb.norm_sq - qb.remaining_sq)),), \
            qb.rank, qb.rank_history, qb.err_history

    entry = registry.DecompositionKind("lowrank_norm", _execute_norm,
                                       description="||QB||_F")
    registry.register(entry)
    try:
        assert "lowrank_norm" in linalg.kinds()
        A, _ = make_test_matrix(96, 32, "fast", seed=13)
        dec = linalg.decompose(A, linalg.Tolerance(0.05, panel=8),
                               kind="lowrank_norm", seed=1)
        want = float(jnp.linalg.norm(A))
        assert abs(float(dec.factors[0]) - want) / want < 5e-3
    finally:
        registry._REGISTRY.pop("lowrank_norm", None)


# ---------------------------------------------------------------------------
# serve/lowrank: accuracy-first factorization
# ---------------------------------------------------------------------------

def test_factorize_params_tol_mode():
    from repro.serve.lowrank import dense_equivalent, factorize_params

    params = {
        "blk": {
            "w_up": np.asarray(make_test_matrix(128, 96, "fast", seed=14)[0]),
            "w_gate": np.asarray(make_test_matrix(256, 192, "sharp", seed=15)[0]),
            "other": np.ones((128, 96), np.float32),  # not a target key
        }
    }
    params = jax.tree.map(jnp.asarray, params)
    fact, report = factorize_params(params, tol=0.02)
    assert set(report) == {"blk/w_up", "blk/w_gate"}
    assert all(v <= 0.02 for v in report.values()), report
    # different spectra -> different adaptive ranks
    r_up = fact["blk"]["w_up"]["lr_a"].shape[1]
    r_gate = fact["blk"]["w_gate"]["lr_a"].shape[1]
    assert r_up != r_gate
    dense = dense_equivalent(fact)
    assert dense["blk"]["other"].shape == (128, 96)
    with pytest.raises(ValueError, match="exactly one"):
        factorize_params(params)
    with pytest.raises(ValueError, match="exactly one"):
        factorize_params(params, rank=8, tol=0.1)


def test_factorize_params_tol_mode_stacked_meets_worst_slice():
    """Stacked leaves: the slice-0 probe can undershoot units with slower
    spectral decay — the vmapped pass must escalate the stack-wide rank
    until the WORST slice meets the tolerance, and report that worst
    error."""
    from repro.serve.lowrank import factorize_params

    # slice 0 decays fast (small probe rank); slice 1 is sharp (needs more)
    W = jnp.stack([
        make_test_matrix(192, 160, "fast", seed=20)[0],
        make_test_matrix(192, 160, "sharp", seed=21)[0],
    ])
    params = {"w_o": W}
    tol = 0.05
    fact, report = factorize_params(params, tol=tol)
    assert report["w_o"] <= tol, report
    A, B = fact["w_o"]["lr_a"], fact["w_o"]["lr_b"]
    for i in range(2):
        err = float(jnp.linalg.norm(W[i] - A[i] @ B[i]) / jnp.linalg.norm(W[i]))
        assert err <= tol, (i, err)
