"""Blocked/streaming + batched randomized SVD vs. the dense in-memory path,
all through the `repro.linalg` facade (HostOp / StackedOp / overrides).

Covers the DESIGN.md §"Blocked & batched execution" contracts:
  * panel streaming reproduces the dense result for dividing AND non-dividing
    block_rows (the acceptance case: 4096x512 at block_rows=256, <=1e-4);
  * the (1+eps) near-optimality guarantee survives blocking;
  * the batched vmap path equals a per-slice Python loop, in both the tall
    and the wide (orientation-swap) layouts;
  * the streamed sketch accumulation (panel-offset counter RNG) equals the
    monolithic sketch.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import linalg
from repro.core import RSVDConfig, low_rank_error, streamed_sketch, truncation_error
from repro.core.spectra import make_test_matrix
from repro.kernels import ref

BASE = RSVDConfig()  # the historical default variant, pinned on both paths


def _recon(U, S, Vt):
    return np.asarray((U * S[None, :]) @ Vt)


def _rel_fro(X, Y, A):
    return float(np.linalg.norm(X - Y) / np.linalg.norm(np.asarray(A)))


# ---------------------------------------------------------------------------
# (a) blocked == unblocked across block sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [100, 128, 512])  # 100 non-dividing
def test_blocked_matches_dense(block_rows):
    A, _ = make_test_matrix(512, 96, "fast", seed=1)
    k = 12
    U0, S0, Vt0 = linalg.svd(A, k, overrides=BASE)
    U1, S1, Vt1 = linalg.svd(A, k, overrides=RSVDConfig(block_rows=block_rows))
    assert U1.shape == (512, k) and S1.shape == (k,) and Vt1.shape == (k, 96)
    assert _rel_fro(_recon(U0, S0, Vt0), _recon(U1, S1, Vt1), A) <= 1e-4
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=1e-4)


def test_blocked_acceptance_4096x512():
    """The PR acceptance case: block_rows=256 on 4096x512, <=1e-4 rel Fro."""
    A, _ = make_test_matrix(4096, 512, "fast", seed=2)
    k = 16
    cfg = RSVDConfig(power_iters=1, qr_method="cqr2")  # same cfg on both paths
    U0, S0, Vt0 = linalg.svd(A, k, overrides=cfg)
    U1, S1, Vt1 = linalg.svd(
        A, k, overrides=RSVDConfig(power_iters=1, qr_method="cqr2", block_rows=256)
    )
    assert _rel_fro(_recon(U0, S0, Vt0), _recon(U1, S1, Vt1), A) <= 1e-4


def test_host_numpy_plans_streamed_execution():
    """Out-of-core shape: a host numpy array wrapped in HostOp plans the
    streamed path by default, and matches the pinned streaming preset."""
    A_host = np.asarray(make_test_matrix(256, 64, "fast", seed=3)[0])
    op = linalg.HostOp(A_host, block_rows=128)
    assert linalg.plan(op, 8).path == "streamed"
    U, S, Vt = linalg.svd(op, 8)
    U2, S2, Vt2 = linalg.svd(A_host, 8, overrides=RSVDConfig.streaming(block_rows=128))
    np.testing.assert_array_equal(np.asarray(S), np.asarray(S2))
    err = float(linalg.residual(op, (U, S, Vt)))
    assert err < 0.2


# ---------------------------------------------------------------------------
# (b) near-optimality on decaying spectra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fast", "sharp"])
def test_blocked_near_optimal_error(kind):
    A, sig = make_test_matrix(384, 96, kind, seed=4)
    k = 16
    cfg = RSVDConfig.streaming(block_rows=100)  # non-dividing on purpose
    U, S, Vt = linalg.svd(A, k, overrides=cfg)
    err = float(low_rank_error(A, U, S, Vt))
    opt = float(truncation_error(sig, k))
    assert err <= 1.10 * opt + 1e-6, (err, opt)


def test_blocked_wide_matrix_orientation_swap():
    """m < n streams the taller side of A^T; factors keep the A orientation."""
    A, _ = make_test_matrix(256, 64, "fast", seed=5)
    At = A.T  # 64 x 256 wide
    U, S, Vt = linalg.svd(At, 10, overrides=RSVDConfig(block_rows=96))
    assert U.shape == (64, 10) and Vt.shape == (10, 256)
    err = float(low_rank_error(At, U, S, Vt))
    S_dense = jnp.linalg.svd(At, compute_uv=False)
    assert err <= 1.10 * float(truncation_error(S_dense, 10)) + 1e-6


# ---------------------------------------------------------------------------
# (c) batched path == Python loop; wide batched
# ---------------------------------------------------------------------------

def _stack(B, m, n, kind="fast"):
    return jnp.stack([make_test_matrix(m, n, kind, seed=10 + i)[0] for i in range(B)])


def test_batched_matches_python_loop():
    A = _stack(4, 96, 48)
    k, seed = 8, 5
    Ub, Sb, Vtb = linalg.svd(A, k, overrides=BASE, seed=seed)
    for i in range(A.shape[0]):
        # slice i sketches with seed + i — the loop equivalent
        Ui, Si, Vti = linalg.svd(A[i], k, overrides=BASE, seed=seed + i)
        np.testing.assert_allclose(np.asarray(Sb[i]), np.asarray(Si), rtol=2e-5)
        np.testing.assert_allclose(
            _recon(Ub[i], Sb[i], Vtb[i]), _recon(Ui, Si, Vti), atol=2e-4
        )


def test_batched_wide_matches_loop():
    A = _stack(3, 40, 120)  # m < n: orientation swap inside the batch
    k = 6
    Ub, Sb, Vtb = linalg.svd(A, k, overrides=BASE, seed=2)
    assert Ub.shape == (3, 40, k) and Vtb.shape == (3, k, 120)
    for i in range(3):
        Ui, Si, Vti = linalg.svd(A[i], k, overrides=BASE, seed=2 + i)
        np.testing.assert_allclose(np.asarray(Sb[i]), np.asarray(Si), rtol=2e-5)
        np.testing.assert_allclose(
            _recon(Ub[i], Sb[i], Vtb[i]), _recon(Ui, Si, Vti), atol=2e-4
        )


def test_three_d_input_plans_batched_path():
    A = _stack(2, 64, 32)
    assert linalg.plan(A, 4, overrides=BASE).path == "batched"
    U3, S3, Vt3 = linalg.svd(A, 4, overrides=BASE, seed=9)       # facade
    from repro.core.blocked import svd_batched

    Ub, Sb, Vtb = svd_batched(A, 4, BASE, seed=9)                # direct
    np.testing.assert_array_equal(np.asarray(S3), np.asarray(Sb))
    np.testing.assert_array_equal(np.asarray(U3), np.asarray(Ub))


def test_batched_override_rejects_2d():
    with pytest.raises(ValueError):
        linalg.svd(jnp.zeros((8, 4)), 2, overrides=RSVDConfig(batched=True))


# ---------------------------------------------------------------------------
# (d) streamed sketch accumulation == monolithic sketch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
@pytest.mark.parametrize("fused", [False, True])
def test_streamed_sketch_matches_monolithic(kind, fused):
    A, _ = make_test_matrix(64, 96, "fast", seed=6)
    # block_cols=40 leaves a ragged 16-wide last panel on purpose
    got = streamed_sketch(A, 17, seed=3, kind=kind, block_cols=40, fused=fused)
    want = ref.sketch_matmul_ref(A, 17, seed=3, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)
