"""repro.serve.decomp: the decomposition service.

Pins the subsystem's contracts:

  * coalesced per-request results BIT-identical to standalone
    `decompose(StackedOp(x[None]), ...)` at the request's seed — whatever
    batch the coalescer formed, and under arrival-order permutation
    (property test);
  * compiled-executable cache: at most ONE trace per distinct plan across
    N same-plan requests (asserted on `blocked._TRACE_COUNTS`);
  * two-lane scheduling starvation bound: a 65536 x 4096 out-of-core job
    concurrent with >= 100 small requests never makes an admitted request
    wait more than K big-job slices;
  * per-request fault isolation: a poisoned request fails alone
    (`RequestError` carrying a HealthReport), its batch neighbors keep
    bit-identical results; injected `flaky_link` transfer faults on the
    big lane never touch small-lane traffic;
  * the LRU plan cache short-circuits repeat planning (no second
    `planner.plan` call);
  * `serve.lowrank.factorize_params(service=...)` routes same-shaped
    leaves through the coalescer bit-identically to a serial service;
  * `serve.engine.Engine.generate` rejects empty prompts up-front.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import linalg
from repro.core import blocked
from repro.linalg import planner as planner_mod
from repro.serve import lowrank
from repro.serve.decomp import (
    DecompositionService,
    RequestError,
    ServiceClosed,
    ServiceOverloaded,
    trace_count,
)


def _mats(n, shape=(32, 16), seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for _ in range(n)]


def _standalone(x, k, seed):
    """The service's bit-identity baseline: this request alone, batch of 1."""
    U, S, Vt = linalg.decompose(
        linalg.StackedOp(x[None]), linalg.Rank(k), seed=seed).factors
    return U[0], S[0], Vt[0]


def _identical(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Coalescing: bit-identity and batching
# ---------------------------------------------------------------------------

def test_coalesced_bit_identical_to_standalone():
    xs = _mats(6)
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        futs = [svc.submit(x, linalg.Rank(4), seed=i) for i, x in enumerate(xs)]
        svc.flush()
        decs = [f.result(timeout=120) for f in futs]
    assert any(d.plan.batch > 1 for d in decs)  # coalescing actually happened
    for i, (x, dec) in enumerate(zip(xs, decs)):
        assert _identical(dec.factors, _standalone(x, 4, i))


def test_mixed_shapes_bucket_separately():
    a = _mats(2, (32, 16), seed=1)
    b = _mats(2, (24, 24), seed=2)
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        futs = ([svc.submit(x, linalg.Rank(4), seed=i) for i, x in enumerate(a)]
                + [svc.submit(x, linalg.Rank(4), seed=10 + i)
                   for i, x in enumerate(b)])
        svc.flush()
        decs = [f.result(timeout=120) for f in futs]
    for dec, x, seed in zip(decs, a + b, [0, 1, 10, 11]):
        assert dec.factors[0].shape[0] == x.shape[0]
        assert _identical(dec.factors, _standalone(x, 4, seed))


def test_executable_cache_one_trace_per_plan():
    """N same-shape waves -> one executable-cache plan entry per batch
    shape, each traced at most once (the subsystem's compile contract)."""
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        for wave in range(3):
            xs = _mats(4, seed=wave)
            futs = [svc.submit(x, linalg.Rank(4), seed=100 * wave + i)
                    for i, x in enumerate(xs)]
            svc.flush()
            for f in futs:
                f.result(timeout=120)
        stats = svc.executable_cache.stats()
        plans = svc.executable_cache.plans()
    assert stats["hits"] >= 1  # waves 2..3 reused wave 1's executable
    for pl in plans:
        assert trace_count(pl) <= 1, f"plan traced more than once: {pl}"


def test_plan_cache_no_replan_on_repeat(monkeypatch):
    """Satellite: the LRU plan cache must short-circuit the second plan()."""
    calls = []
    real_plan = planner_mod.plan

    def counting_plan(*a, **kw):
        calls.append(1)
        return real_plan(*a, **kw)

    linalg.clear_plan_cache()
    monkeypatch.setattr(planner_mod, "plan", counting_plan)
    x = _mats(1, seed=5)[0]
    linalg.decompose(x, linalg.Rank(4), seed=0)
    n_first = len(calls)
    assert n_first >= 1
    linalg.decompose(x, linalg.Rank(4), seed=1)  # same planning inputs
    assert len(calls) == n_first, "repeat decompose() re-planned"
    stats = linalg.plan_cache_stats()
    assert stats["hits"] >= 1


# ---------------------------------------------------------------------------
# Arrival-order permutation: per-slice seed isolation
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(perm_seed=st.integers(0, 10_000))
def test_arrival_order_permutation_irrelevant(perm_seed):
    xs = _mats(5, seed=3)
    order = np.random.default_rng(perm_seed).permutation(len(xs))
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        futs = {}
        for j in order:
            futs[int(j)] = svc.submit(xs[j], linalg.Rank(4), seed=int(j))
        svc.flush()
        decs = {j: f.result(timeout=120) for j, f in futs.items()}
    for j, x in enumerate(xs):
        assert _identical(decs[j].factors, _standalone(x, 4, j))


def test_svd_batched_seed_vector_permutation():
    """Core-level seed isolation: permuting (stack, seeds) together permutes
    the results bit-exactly — no slice reads a neighbor's randomness."""
    rng = np.random.default_rng(7)
    A = jnp.asarray(rng.standard_normal((4, 32, 16)), jnp.float32)
    seeds = jnp.asarray([11, 22, 33, 44], jnp.uint32)
    cfg = blocked.batched_cfg(planner_mod.plan(
        linalg.StackedOp(A), linalg.Rank(4)).to_config())
    U, S, Vt = blocked.svd_batched(A, 4, cfg, seed=seeds)
    perm = jnp.asarray([2, 0, 3, 1])
    Up, Sp, Vtp = blocked.svd_batched(A[perm], 4, cfg, seed=seeds[perm])
    assert _identical((Up, Sp, Vtp), (U[perm], S[perm], Vt[perm]))


# ---------------------------------------------------------------------------
# Fault isolation
# ---------------------------------------------------------------------------

def test_poisoned_request_fails_alone():
    xs = _mats(3, seed=4)
    bad = xs[1].at[3, 3].set(jnp.nan)
    batch = [xs[0], bad, xs[2]]
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        futs = [svc.submit(x, linalg.Rank(4), seed=i)
                for i, x in enumerate(batch)]
        svc.flush()
        with pytest.raises(RequestError) as exc_info:
            futs[1].result(timeout=120)
        neighbors = [futs[0].result(timeout=120), futs[2].result(timeout=120)]
    health = exc_info.value.health
    assert health is not None and not health.ok
    for (i, x) in ((0, xs[0]), (2, xs[2])):
        dec = neighbors[0 if i == 0 else 1]
        assert _identical(dec.factors, _standalone(x, 4, i))


def test_flaky_link_on_big_lane_isolated_from_small():
    """An injected transfer fault on the streamed big job must not leak
    into concurrent small-lane requests; the big request's guard ladder
    absorbs the fault (retry) so its own future still resolves."""
    rng = np.random.default_rng(8)
    # full-rank host matrix (a broadcast rank-1 view would break every QR
    # rung on its own and mask the fault-injection outcome)
    big = rng.standard_normal((4096, 256)).astype(np.float32)
    xs = _mats(6, seed=9)
    overrides = linalg.RSVDConfig(oversample=4, power_iters=0)
    with DecompositionService(window_s=0.05, max_batch=4,
                              big_threshold_s=0.0) as svc:
        with linalg.faults.inject("flaky_link", times=1):
            big_fut = svc.submit(
                linalg.HostOp(big, block_rows=512), linalg.Rank(4),
                seed=0, overrides=overrides, guard="retry")
            futs = [svc.submit(x, linalg.Rank(4), seed=i)
                    for i, x in enumerate(xs)]
            svc.flush()
            decs = [f.result(timeout=240) for f in futs]
            big_dec = big_fut.result(timeout=240)
    for i, (x, dec) in enumerate(zip(xs, decs)):
        assert _identical(dec.factors, _standalone(x, 4, i))
    assert big_dec.rank == 4
    assert big_dec.health is not None and big_dec.health.ok


# ---------------------------------------------------------------------------
# Scheduling: the starvation bound
# ---------------------------------------------------------------------------

STARVATION_K = 3  # strict-drain bound is 1; +admission/measurement races


def test_starvation_bound_under_out_of_core_job():
    """One 65536 x 4096 out-of-core solve concurrent with >= 100 small
    requests: every small request starts within STARVATION_K big-job
    slices of its submission, and everything completes."""
    rng = np.random.default_rng(10)
    # 0-stride broadcast view: 1 GiB logical, ~16 KiB resident — panels
    # materialize one block_rows slab at a time through stream_host_panels
    big = np.broadcast_to(
        rng.standard_normal((1, 4096)).astype(np.float32), (65536, 4096))
    overrides = linalg.RSVDConfig(oversample=4, power_iters=0)
    xs = _mats(4, (32, 16), seed=11)
    with DecompositionService(window_s=0.002, max_batch=4,
                              big_threshold_s=0.0, panel_group=2) as svc:
        big_fut = svc.submit(
            linalg.HostOp(big, block_rows=4096), linalg.Rank(4),
            seed=0, overrides=overrides)
        deadline = time.monotonic() + 60
        while svc.gate.big_slices == 0 and time.monotonic() < deadline:
            time.sleep(0.002)  # wait until the big job is mid-flight
        assert svc.gate.big_slices > 0, "big job never started slicing"
        futs = []
        for i in range(100):
            futs.append(svc.submit(xs[i % 4], linalg.Rank(4), seed=i))
            if i % 10 == 9:
                svc.flush()
                time.sleep(0.001)  # spread arrivals across the big job
        svc.flush()
        for f in futs:
            f.result(timeout=240)
        big_fut.result(timeout=240)
        records = svc.metrics.records()
    small = [r for r in records if r.lane == "small"]
    assert len(small) == 100
    worst = max(r.big_slices_waited for r in small)
    assert worst <= STARVATION_K, (
        f"a small request waited {worst} big-job slices (bound {STARVATION_K})")
    assert svc.gate.big_slices >= 2  # the big job really ran in slices


# ---------------------------------------------------------------------------
# Admission control, lifecycle, metrics
# ---------------------------------------------------------------------------

def test_big_lane_overload_refused():
    svc = DecompositionService(big_threshold_s=0.0, big_capacity=0)
    big = np.broadcast_to(np.ones((1, 256), np.float32), (4096, 256))
    with pytest.raises(ServiceOverloaded):
        svc.submit(linalg.HostOp(big, block_rows=512), linalg.Rank(4))
    svc.close()


def test_submit_after_close_raises():
    svc = DecompositionService()
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit(_mats(1)[0], linalg.Rank(4))


def test_metrics_export_schema():
    xs = _mats(4, seed=12)
    with DecompositionService(window_s=0.05, max_batch=4) as svc:
        futs = [svc.submit(x, linalg.Rank(4), seed=i) for i, x in enumerate(xs)]
        svc.flush()
        for f in futs:
            f.result(timeout=120)
        m = svc.metrics.export()
    for key in ("requests", "failed", "coalescing_factor", "cache_hit_rate",
                "compiles", "compile_s_total", "queue_s_p50", "queue_s_p99",
                "latency_s_p50", "latency_s_p99", "execute_s_p50",
                "predicted_walltime_err_p50", "max_big_slices_waited"):
        assert key in m, key
    assert m["requests"] == 4
    assert m["failed"] == 0
    assert m["coalescing_factor"] >= 1.0
    assert m["latency_s_p99"] >= m["latency_s_p50"] >= 0.0


def test_concurrent_submitters_threads():
    """CI smoke shape: many threads submitting concurrently; every future
    resolves bit-identically to its standalone baseline."""
    xs = _mats(12, seed=13)
    results = {}
    lock = threading.Lock()
    with DecompositionService(window_s=0.01, max_batch=4) as svc:
        def worker(j):
            fut = svc.submit(xs[j], linalg.Rank(4), seed=j)
            with lock:
                results[j] = fut
        threads = [threading.Thread(target=worker, args=(j,))
                   for j in range(len(xs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        svc.flush()
        decs = {j: f.result(timeout=120) for j, f in results.items()}
    for j, x in enumerate(xs):
        assert _identical(decs[j].factors, _standalone(x, 4, j))


# ---------------------------------------------------------------------------
# Satellites: lowrank service routing, engine empty-prompt validation
# ---------------------------------------------------------------------------

def _toy_params(seed=14):
    rng = np.random.default_rng(seed)
    w = lambda s: jnp.asarray(rng.standard_normal(s), jnp.float32)  # noqa: E731
    return {
        "layer0": {"w_up": w((64, 32)), "w_down": w((32, 64))},
        "layer1": {"w_up": w((64, 32)), "w_down": w((32, 64))},
        "embed": w((128, 32)),  # not a target key: stays dense
    }


def test_factorize_params_service_matches_serial_service():
    params = _toy_params()
    with DecompositionService(window_s=0.2, max_batch=4) as svc:
        fac_c, rep_c = lowrank.factorize_params(params, rank=8, service=svc)
        coalesced = svc.metrics.export()["coalescing_factor"]
    with DecompositionService(window_s=0.2, max_batch=1) as svc1:
        fac_s, rep_s = lowrank.factorize_params(params, rank=8, service=svc1)
    assert rep_c == rep_s
    la, lb = jax.tree.leaves(fac_c), jax.tree.leaves(fac_s)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert coalesced >= 1.0
    assert isinstance(fac_c["layer0"]["w_up"], dict)  # actually factorized
    assert not isinstance(fac_c["embed"], dict)       # non-target untouched


def test_factorize_params_service_poisoned_leaf_stays_dense():
    params = _toy_params(seed=15)
    params["layer1"]["w_up"] = params["layer1"]["w_up"].at[0, 0].set(jnp.nan)
    with DecompositionService(window_s=0.2, max_batch=4) as svc:
        fac, rep = lowrank.factorize_params(params, rank=8, service=svc)
    assert np.isnan(rep["layer1/w_up"])
    assert not isinstance(fac["layer1"]["w_up"], dict)   # kept dense
    assert isinstance(fac["layer0"]["w_up"], dict)       # neighbor unharmed
    assert np.isfinite(rep["layer0/w_up"])


def test_engine_rejects_empty_prompt():
    from repro.serve.engine import EmptyPromptError, Engine, Request

    eng = Engine(None, None)  # validation fires before params/cfg are touched
    good = Request(prompt=np.array([1, 2, 3], np.int32))
    empty = Request(prompt=np.array([], np.int32))
    with pytest.raises(EmptyPromptError):
        eng.generate([good, empty])
