"""Elastic-scaling driver (8 placeholder devices, subprocess):

1. trains a tiny model on mesh A = (8 data,),
2. checkpoints,
3. restores onto mesh B = (2 data, 4 model) — reshard-on-load,
4. continues training on the new mesh and asserts the loss keeps improving.

This is the node-loss recovery path: lose hosts -> restart with a different
mesh shape -> restore the same checkpoint bytes under new shardings.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import synthetic_batch
from repro.launch import mesh as mesh_mod
from repro.models import init_model
from repro.optim import adamw
from repro.train.train_step import compute_loss, make_train_step

CFG = ModelConfig(
    name="tiny-elastic",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    dtype="float32",
    attn_chunk=64,
)
SHAPE = ShapeConfig("s", seq_len=64, global_batch=8, kind="train")


def train_some(params, opt_state, mesh, steps, step0=0):
    param_sh = mesh_mod.param_shardings(CFG, params, mesh)
    params = jax.device_put(params, param_sh)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step_fn = jax.jit(lambda p, o, b: make_train_step(CFG, ocfg)(p, o, b, None)[:3])
    with mesh:
        for i in range(steps):
            batch = synthetic_batch(CFG, SHAPE, step=step0 + i)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
    return params, opt_state, float(metrics["loss"])


def main():
    assert len(jax.devices()) == 8
    mesh_a = jax.make_mesh((8,), ("data",))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))

    params = init_model(CFG, jax.random.key(0))
    opt = adamw.init_state(params)
    params, opt, loss_a = train_some(params, opt, mesh_a, steps=6)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(6, (params, opt), blocking=True)

        # --- elastic restart onto a DIFFERENT mesh ------------------------
        like = (init_model(CFG, jax.random.key(0)), adamw.init_state(params))
        sh_b = (
            mesh_mod.param_shardings(CFG, like[0], mesh_b),
            adamw.AdamWState(
                step=NamedSharding(mesh_b, P()),
                m=mesh_mod.param_shardings(CFG, like[0], mesh_b),
                v=mesh_mod.param_shardings(CFG, like[0], mesh_b),
            ),
        )
        (params_b, opt_b), step = mgr.restore(like, shardings=sh_b)
        assert step == 6

    # bitwise identity of the restored values
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # new-mesh sharding actually applied
    some_leaf = params_b["units"][0]["attn"]["wq"]
    assert some_leaf.sharding.mesh.shape == {"data": 2, "model": 4}, some_leaf.sharding

    # training continues on the new mesh
    params_b, opt_b, loss_b = train_some(params_b, opt_b, mesh_b, steps=6, step0=6)
    print(f"ELASTIC_OK loss_a={loss_a:.4f} loss_b={loss_b:.4f}")


if __name__ == "__main__":
    main()
