"""Hypothesis import shim: the real package when installed, otherwise a tiny
deterministic example-based fallback so tier-1 collects and runs green in
containers without ``hypothesis``.

Usage in test modules::

    from _hypothesis_compat import given, settings, st

The fallback implements exactly the subset this suite uses:

  * ``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.sampled_from(seq)``
  * ``@settings(deadline=..., max_examples=N)`` (other kwargs ignored)
  * ``@given(name=strategy, ...)`` (keyword style only)

Fallback semantics: each ``@given`` test runs ``min(max_examples, 2)``
examples drawn from a numpy Generator seeded by the test's qualified name
(crc32 — stable across processes, unlike ``hash``).  No shrinking, no
example database — failures print the drawn kwargs instead.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as _np

    # Tier-1 budget: each example of the kernel sweeps recompiles an
    # interpret-mode Pallas program (seconds), so the fallback runs few,
    # fixed examples — breadth comes from the real-hypothesis CI lane.
    _FALLBACK_CAP = 2

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value, **_):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: lo + (hi - lo) * float(rng.random()))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _Strategies()

    def settings(*, max_examples=10, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_compat_max_examples", None)
                    or getattr(fn, "_compat_max_examples", 10),
                    _FALLBACK_CAP,
                )
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode())
                )
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"{fn.__qualname__} failed on fallback example "
                            f"{drawn}: {e}"
                        ) from e

            # pytest must not see the strategy params as fixtures: drop the
            # __wrapped__ breadcrumb functools.wraps leaves and pin an empty
            # signature (mirrors what real hypothesis does).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
