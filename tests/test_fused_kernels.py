"""Fused one-pass kernels (power step, sketch+gram, TRSM) vs pure-jnp
oracles, plus the end-to-end fused/backends equivalences on all three
execution scales (dense / blocked / distributed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import qr as qr_mod
from repro.core.sketch import sketch_matrix
from repro.kernels import ops, ref


def _rand(shape, seed, dtype=jnp.float32):
    flat = sketch_matrix(int(np.prod(shape[:-1])), shape[-1], seed)
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused two-sided power step: (Y, Z[, G]) = (A X, Aᵀ Y[, Yᵀ Y])
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,n,s", [(64, 48, 16), (128, 128, 32), (130, 100, 17), (100, 257, 20)]
)
@pytest.mark.parametrize("with_gram", [False, True])
def test_power_step_matches_oracle(m, n, s, with_gram):
    a = _rand((m, n), 0)
    x = _rand((n, s), 1)
    got = ops.power_step(a, x, with_gram=with_gram)
    want = ref.power_step_ref(a, x, with_gram=with_gram)
    for g, w in zip(got, want):
        # fp32 tiled accumulation reorders sums vs the oracle: relative tol
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=1e-4, rtol=2e-3
        )


def test_power_step_bf16_fp32_accum():
    """bf16 inputs accumulate in fp32 in-kernel: the result must track the
    fp32-accumulating oracle to bf16 output resolution."""
    a = _rand((100, 70), 2, jnp.bfloat16)
    x = _rand((70, 12), 3, jnp.bfloat16)
    y, z, g = ops.power_step(a, x, with_gram=True)
    yr, zr, gr = ref.power_step_ref(a, x, with_gram=True)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=2e-1, rtol=2e-2
    )
    np.testing.assert_allclose(
        np.asarray(z, np.float32), np.asarray(zr, np.float32), atol=2e0, rtol=2e-2
    )
    # G output is always fp32
    assert g.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=2e0, rtol=2e-2)


@settings(deadline=None, max_examples=8)
@given(m=st.integers(2, 150), n=st.integers(2, 120), s=st.integers(1, 32),
       seed=st.integers(0, 1000))
def test_power_step_property(m, n, s, seed):
    a = _rand((m, n), seed)
    x = _rand((n, s), seed + 1)
    y, z = ops.power_step(a, x)
    yr, zr = ref.power_step_ref(a, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# sketch + gram epilogue: (Y, G) in one pass over A
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,s", [(64, 64, 16), (100, 90, 17), (128, 256, 32)])
@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
def test_sketch_gram_matches_oracle(m, n, s, kind):
    a = _rand((m, n), 4)
    y, g = ops.sketch_gram(a, s, seed=7, kind=kind)
    yr, gr = ref.sketch_gram_ref(a, s, seed=7, kind=kind)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-2, rtol=1e-4)
    # G is exactly symmetric (single accumulator, no reconstruction)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g).T)


def test_sketch_gram_bf16_fp32_accum():
    a = _rand((96, 80), 5, jnp.bfloat16)
    y, g = ops.sketch_gram(a, 10, seed=3)
    yr, gr = ref.sketch_gram_ref(a, 10, seed=3)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=5e-1, rtol=2e-2
    )
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=5e0, rtol=3e-2)


@settings(deadline=None, max_examples=8)
@given(m=st.integers(2, 150), n=st.integers(2, 120), s=st.integers(1, 32),
       seed=st.integers(0, 1000))
def test_sketch_gram_property(m, n, s, seed):
    a = _rand((m, n), seed)
    y, g = ops.sketch_gram(a, s, seed=seed)
    yr, gr = ref.sketch_gram_ref(a, s, seed=seed)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-2, rtol=1e-3)


# ---------------------------------------------------------------------------
# sketch_power: (Y, W, G) = (A Ω, Aᵀ Y, Yᵀ Y) in one pass, Ω in VMEM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,s", [(64, 48, 16), (130, 100, 17), (128, 256, 32)])
@pytest.mark.parametrize("kind", ["gaussian", "rademacher"])
def test_sketch_power_matches_oracle(m, n, s, kind):
    a = _rand((m, n), 30)
    got = ops.sketch_power(a, s, seed=5, kind=kind)
    want = ref.sketch_power_ref(a, s, seed=5, kind=kind)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-3, rtol=2e-3)


@settings(deadline=None, max_examples=8)
@given(m=st.integers(2, 150), n=st.integers(2, 120), s=st.integers(1, 32),
       seed=st.integers(0, 1000))
def test_sketch_power_property(m, n, s, seed):
    a = _rand((m, n), seed)
    got = ops.sketch_power(a, s, seed=seed)
    want = ref.sketch_power_ref(a, s, seed=seed)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-3, rtol=2e-3)


def test_fused_power_vmem_guard_falls_back():
    """Shapes whose strip working set exceeds the VMEM budget must route to
    the unfused body (the kernel would not compile on real hardware)."""
    from repro.core import RSVDConfig
    from repro.core.rsvd import _use_fused_power
    from repro.kernels.power_step import VMEM_BUDGET_BYTES, fused_power_vmem_bytes

    cfg = RSVDConfig.fast()
    small = jnp.zeros((512, 256), jnp.float32)
    assert _use_fused_power(small, cfg, s=34)
    big = jax.ShapeDtypeStruct((8192, 8192), jnp.float32)
    assert fused_power_vmem_bytes(8192, 266) > VMEM_BUDGET_BYTES
    assert not _use_fused_power(big, cfg, s=266)


# ---------------------------------------------------------------------------
# TRSM kernel: Q = Y R⁻¹
# ---------------------------------------------------------------------------

def _spd_r(s, seed, dtype=jnp.float32):
    y = _rand((4 * s, s), seed)
    g = np.asarray(ref.gram_ref(y, jnp.float32)) + s * np.eye(s, dtype=np.float32)
    return jnp.asarray(np.linalg.cholesky(g).T).astype(dtype)


@pytest.mark.parametrize("m,s", [(64, 16), (130, 17), (256, 40), (100, 130)])
def test_trsm_matches_oracle(m, s):
    y = _rand((m, s), 6)
    r = _spd_r(s, 7)
    got = ops.tri_solve_right(y, r)
    want = ref.tri_solve_right_ref(y, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-3)


@settings(deadline=None, max_examples=8)
@given(m=st.integers(2, 150), s=st.integers(1, 48), seed=st.integers(0, 1000))
def test_trsm_property(m, s, seed):
    y = _rand((m, s), seed)
    r = _spd_r(s, seed + 1)
    np.testing.assert_allclose(
        np.asarray(ops.tri_solve_right(y, r)),
        np.asarray(ref.tri_solve_right_ref(y, r)),
        atol=2e-4, rtol=1e-3,
    )


# ---------------------------------------------------------------------------
# traced sketch seed: one compiled program across seeds / offsets / vmap
# ---------------------------------------------------------------------------

def test_sketch_seed_is_traced_no_recompile():
    a = _rand((64, 64), 8)
    before_any = ops.sketch_matmul(a, 9, seed=1)
    size0 = ops.sketch_matmul._cache_size()
    for seed in (2, 3, 4):
        got = ops.sketch_matmul(a, 9, seed=seed)
        want = ref.sketch_matmul_ref(a, 9, seed=seed)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)
    # seed sweeps reuse the compiled program (seed is an SMEM operand)
    assert ops.sketch_matmul._cache_size() == size0
    np.testing.assert_allclose(
        np.asarray(before_any), np.asarray(ref.sketch_matmul_ref(a, 9, seed=1)),
        atol=1e-4, rtol=1e-4,
    )


def test_sketch_vmap_over_seeds():
    """The batched path's contract: vmapping the fused sketch over per-slice
    seeds equals a per-slice loop of materialized sketches."""
    a = _rand((3, 48, 64), 9)
    seeds = jnp.asarray([5, 6, 7], jnp.uint32)
    got = jax.vmap(lambda x, sd: ops.sketch_matmul(x, 11, sd))(a, seeds)
    for i in range(3):
        want = ref.sketch_matmul_ref(a[i], 11, seed=5 + i)
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want), atol=1e-4, rtol=1e-4
        )


# ---------------------------------------------------------------------------
# end-to-end: fused one-pass range finder == unfused (dense path)
# ---------------------------------------------------------------------------

def _cfgs(**kw):
    from repro.core import RSVDConfig

    return RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                      small_svd="lapack", **kw)


def test_fused_power_matches_unfused_dense():
    # "fast" has distinct singular values, so A_k (hence the reconstruction)
    # is unique and comparable; "sharp" cuts inside a degenerate cluster
    # where any rotated basis is an equally valid answer.
    from repro import linalg
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(300, 200, "fast", seed=10)
    k = 16
    U0, S0, Vt0 = linalg.svd(A, k, overrides=_cfgs())
    U1, S1, Vt1 = linalg.svd(
        A, k,
        overrides=_cfgs(fused_sketch=True, fused_power=True, kernel_backend="pallas"),
    )
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=2e-4)
    r0 = np.asarray((U0 * S0[None, :]) @ Vt0)
    r1 = np.asarray((U1 * S1[None, :]) @ Vt1)
    assert np.linalg.norm(r1 - r0) / np.linalg.norm(np.asarray(A)) < 1e-4
    np.testing.assert_allclose(np.asarray(U1.T @ U1), np.eye(k), atol=5e-5)


def test_fused_power_plain_scheme_matches_unfused():
    """The ablation path: the plain GEMM chain through the fused kernel."""
    from repro import linalg
    from repro.core import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(200, 128, "sharp", seed=11)
    k = 10
    base = RSVDConfig(power_scheme="plain", power_iters=1, qr_method="cqr2",
                      small_svd="lapack")
    U0, S0, Vt0 = linalg.svd(A, k, overrides=base)
    U1, S1, Vt1 = linalg.svd(
        A, k,
        overrides=RSVDConfig(power_scheme="plain", power_iters=1, qr_method="cqr2",
                             small_svd="lapack", fused_power=True),
    )
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=2e-4)


def test_fused_power_zero_iters():
    """power_iters=0 must still work through the fused body (no W)."""
    from repro import linalg
    from repro.core import low_rank_error
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(128, 96, "fast", seed=12)
    cfg = _cfgs(power_iters=0, fused_sketch=True, fused_power=True,
                kernel_backend="pallas")
    U, S, Vt = linalg.svd(A, 8, overrides=cfg)
    assert float(low_rank_error(A, U, S, Vt)) < 0.5
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(8), atol=5e-5)


def test_fused_f64_falls_back_to_unfused():
    """float64 (the faithful setting) must silently bypass the fp32 kernels."""
    from repro import linalg
    from repro.compat import enable_x64
    from repro.core.spectra import make_test_matrix

    with enable_x64():
        A, _ = make_test_matrix(128, 96, "sharp", seed=13, dtype=jnp.float64)
        k = 8
        U0, S0, _ = linalg.svd(A, k, overrides=_cfgs())
        U1, S1, _ = linalg.svd(
            A, k,
            overrides=_cfgs(fused_sketch=True, fused_power=True, kernel_backend="pallas"),
        )
        assert S1.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=1e-12)


# ---------------------------------------------------------------------------
# kernel backend parity on all three execution scales
# ---------------------------------------------------------------------------

def test_backend_pallas_dense_matches_jnp():
    from repro import linalg
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(256, 96, "fast", seed=14)
    k = 10
    U0, S0, Vt0 = linalg.svd(A, k, overrides=_cfgs(kernel_backend="jnp"))
    U1, S1, Vt1 = linalg.svd(A, k, overrides=_cfgs(kernel_backend="pallas"))
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=2e-5)
    r0 = np.asarray((U0 * S0[None, :]) @ Vt0)
    r1 = np.asarray((U1 * S1[None, :]) @ Vt1)
    assert np.linalg.norm(r1 - r0) / np.linalg.norm(np.asarray(A)) < 1e-4


def test_backend_pallas_blocked_matches_jnp():
    from repro import linalg
    from repro.core import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A, _ = make_test_matrix(384, 96, "sharp", seed=15)
    k = 10
    cfg0 = RSVDConfig.streaming(block_rows=100)
    # pallas backend + fused whole-panel sketch: the sketch_gram epilogue
    # feeds the first blocked-CQR2 Gram (no re-read of the Y panels)
    cfg1 = RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                      small_svd="lapack", block_rows=100,
                      kernel_backend="pallas", fused_sketch=True)
    U0, S0, Vt0 = linalg.svd(A, k, overrides=cfg0, seed=0)
    U1, S1, Vt1 = linalg.svd(A, k, overrides=cfg1, seed=0)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(U1.T @ U1), np.eye(k), atol=5e-5)


def test_backend_pallas_distributed_matches_jnp():
    """shard_map CQR through the Pallas kernels == plain (multi-device CI)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (CI sets xla_force_host_platform_device_count)")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import linalg
    from repro.core import RSVDConfig
    from repro.core.spectra import make_test_matrix

    n_dev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    A, _ = make_test_matrix(32 * n_dev, 64, "sharp", seed=16)
    A_sharded = jax.device_put(A, NamedSharding(mesh, P("data", None)))
    k = 8
    op = linalg.ShardedOp(A_sharded, mesh, "data")
    cfg0 = RSVDConfig(power_iters=1, kernel_backend="jnp")
    cfg1 = RSVDConfig(power_iters=1, kernel_backend="pallas")
    _, S0, _ = linalg.svd(op, k, overrides=cfg0)
    U1, S1, Vt1 = linalg.svd(op, k, overrides=cfg1)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(jnp.asarray(U1).T @ jnp.asarray(U1)), np.eye(k), atol=5e-5
    )


def test_qr_gram_trsm_backend_parity():
    """The backend seam itself: qr.gram / qr.tri_solve_right under the
    pallas context == the jnp defaults."""
    y = _rand((200, 24), 17)
    g0 = qr_mod.gram(y)
    with qr_mod.kernel_backend("pallas"):
        g1 = qr_mod.gram(y)
        r = qr_mod.cholesky_r_from_gram(g1)
        q1 = qr_mod.tri_solve_right(y, r)
    q0 = ref.tri_solve_right_ref(y, r)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q0), atol=2e-4, rtol=1e-3)
    # context restored
    assert qr_mod.active_kernel_backend() == "jnp"


def test_blocked_fused_sketch_f64_falls_back():
    """Blocked streaming with fused_sketch on f64 input must stay on the jnp
    sketch (and in f64), like the dense path's guard."""
    from repro import linalg
    from repro.compat import enable_x64
    from repro.core import RSVDConfig
    from repro.core.spectra import make_test_matrix

    with enable_x64():
        A, _ = make_test_matrix(256, 64, "fast", seed=18, dtype=jnp.float64)
        cfg0 = RSVDConfig.streaming(block_rows=100)
        cfg1 = RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                          small_svd="lapack", block_rows=100, fused_sketch=True)
        U0, S0, _ = linalg.svd(A, 8, overrides=cfg0, seed=0)
        U1, S1, _ = linalg.svd(A, 8, overrides=cfg1, seed=0)
        assert S1.dtype == jnp.float64 and U1.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(S1), np.asarray(S0), rtol=1e-12)


def test_blocked_cholesky_qr_bf16_panels_keep_dtype():
    """The blocked CQR pass factors/solves at fp32 (LAPACK has no bf16
    Cholesky/TRSM) but must hand back Q panels in the panel dtype, whether
    the Gram came from the panels or from the fp32 sketch_gram epilogue."""
    from repro.core.blocked import _blocked_cholesky_qr

    panels = [_rand((64, 12), 40 + i, jnp.bfloat16) for i in range(3)]
    Q, R = _blocked_cholesky_qr(panels)
    assert all(q.dtype == jnp.bfloat16 for q in Q)
    g = sum(np.asarray(p, np.float32).T @ np.asarray(p, np.float32) for p in panels)
    Q2, _ = _blocked_cholesky_qr(panels, jnp.asarray(g))  # epilogue-style fp32 G
    assert all(q.dtype == jnp.bfloat16 for q in Q2)
    stacked = np.concatenate([np.asarray(q, np.float32) for q in Q2])
    np.testing.assert_allclose(stacked.T @ stacked, np.eye(12), atol=0.1)


# ---------------------------------------------------------------------------
# batched path with the fused sketch
# ---------------------------------------------------------------------------

def test_batched_fused_sketch_matches_loop():
    from repro import linalg
    from repro.core import RSVDConfig
    from repro.core.spectra import make_test_matrix

    A = jnp.stack([make_test_matrix(96, 48, "fast", seed=20 + i)[0] for i in range(3)])
    k, seed = 6, 11
    cfg = RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                     small_svd="lapack", fused_sketch=True)
    Ub, Sb, Vtb = linalg.svd(linalg.StackedOp(A), k, overrides=cfg, seed=seed)
    for i in range(3):
        Ui, Si, Vti = linalg.svd(A[i], k, overrides=cfg, seed=seed + i)
        np.testing.assert_allclose(np.asarray(Sb[i]), np.asarray(Si), rtol=2e-5)
        ri = np.asarray((Ui * Si[None, :]) @ Vti)
        rb = np.asarray((Ub[i] * Sb[i][None, :]) @ Vtb[i])
        np.testing.assert_allclose(rb, ri, atol=2e-4)
