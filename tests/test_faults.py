"""Fault injection: each kind's observable effect, jit-cache safety, and
the streaming pipeline's exception/retry discipline.

The contract under test (linalg/faults.py + pipeline.py):

  * every fault kind is detected by the probe that models its real-world
    counterpart — nan_panel by the per-panel finiteness probe,
    corrupt_transfer by the downstream Gram/breakdown probes, flaky_link
    by the bounded transfer retry (degrading to the synchronous walk when
    the link stays down), cholesky_breakdown by the factor-diagonal probe;
  * faults are inert outside a guarded run where the hook runs inside
    jit-traced code, and a fault that fired at trace time can never
    shadow a clean compile-cache entry (the fingerprint static arg);
  * a consumer that abandons or dies mid-stream always leaves the staging
    ring fenced (`finally` -> `_await_in_flight`), and the next stream
    over the same ring discipline is bit-identical.
"""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.linalg import faults, guard, pipeline


@functools.lru_cache(maxsize=None)
def _host(m=256, n=64, seed=1):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, n)).astype(np.float32)


def _stream_op():
    return linalg.HostOp(_host(), block_rows=64, pipeline_depth=2)


def _same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestRegistry:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            with faults.inject("gamma_ray"):
                pass

    def test_scoped_activation(self):
        assert not faults.any_active()
        with faults.inject("nan_panel", panel=1):
            assert faults.any_active()
            assert faults.fingerprint() == (("nan_panel", 1, None, 0),)
        assert not faults.any_active()
        assert faults.fingerprint() == ()

    def test_fingerprint_tracks_firing(self):
        # a times-limited fault that fired must change the fingerprint, so
        # a probed jit twin traced WITH the fault cannot be replayed for a
        # later call where the budget is spent
        with faults.inject("flaky_link", panel=0) as f:
            before = faults.fingerprint()
            with pytest.raises(faults.TransferError):
                faults.maybe_fail_transfer(0)
            assert faults.fingerprint() != before
            faults.maybe_fail_transfer(0)  # budget spent: no raise


class TestNanPanel:
    def test_flagged_by_finiteness_probe(self):
        with faults.inject("nan_panel", panel=2):
            d = linalg.decompose(_stream_op(), 8, seed=7, guard="report")
        assert not d.health.ok
        assert d.health.final.nonfinite_panels == (2,)

    def test_validate_catches_it_first(self):
        with faults.inject("nan_panel", panel=2):
            with pytest.raises(ValueError, match="panel 2"):
                linalg.svd(_stream_op(), 8, seed=7, validate=True)


class TestCorruptTransfer:
    def test_caught_by_breakdown_probe(self):
        # garbled bytes are FINITE (1e30 fill) — the finiteness probe stays
        # green and the f32 Gram overflow trips the breakdown probe instead
        with faults.inject("corrupt_transfer", panel=0):
            d = linalg.decompose(_stream_op(), 8, seed=7, guard="report")
        assert not d.health.ok
        assert d.health.final.nonfinite_panels == ()
        assert d.health.final.breakdown


class TestFlakyLink:
    def test_single_hiccup_retried_bit_identical(self):
        base = linalg.svd(_stream_op(), 8, seed=7)
        with faults.inject("flaky_link", panel=1):  # defaults times=1
            d = linalg.decompose(_stream_op(), 8, seed=7, guard="report")
        _same(base, d.factors)
        assert d.health.ok
        assert d.health.final.transfer_retries >= 1
        assert not d.health.final.degraded_to_sync

    def test_dead_link_degrades_to_sync_walk(self):
        base = linalg.svd(_stream_op(), 8, seed=7)
        with faults.inject("flaky_link", panel=1, times=10_000):
            d = linalg.decompose(_stream_op(), 8, seed=7, guard="report")
        _same(base, d.factors)  # same values, only overlap lost
        assert d.health.ok
        assert d.health.final.degraded_to_sync

    def test_stream_degrade_values_identical(self):
        A = _host()
        bounds = pipeline.panel_bounds(A.shape[0], 64)
        with faults.inject("flaky_link", panel=1, times=100):
            with guard.collecting() as sink:
                panels = list(pipeline.stream_host_panels(A, bounds, 2))
        assert sink.transfer_retries == pipeline.TRANSFER_RETRIES
        assert sink.degraded_to_sync
        for p, (lo, hi) in zip(panels, bounds):
            np.testing.assert_array_equal(np.asarray(p), A[lo:hi])


class TestCholeskyBreakdown:
    def test_gated_on_guard(self):
        # the poison hook runs inside jit-traced code, so it consults the
        # sink: with guard off the fault must be completely inert
        A = jnp.asarray(_host(96, 64, seed=0))
        base = linalg.svd(A, 8, seed=3)
        with faults.inject("cholesky_breakdown"):
            _same(base, linalg.svd(A, 8, seed=3))

    def test_fires_under_report(self):
        A = jnp.asarray(_host(96, 64, seed=0))
        with faults.inject("cholesky_breakdown"):
            d = linalg.decompose(A, 8, seed=3, guard="report")
        assert not d.health.ok and d.health.final.breakdown


class TestCacheSafety:
    def test_clean_run_after_faulted_run(self):
        # a faulted guarded run compiles a poisoned probed twin; the next
        # clean guarded run must NOT replay it (fingerprint static arg)
        A = jnp.asarray(_host(96, 64, seed=0))
        base = linalg.svd(A, 8, seed=3)
        with faults.inject("cholesky_breakdown", times=1):
            df = linalg.decompose(A, 8, seed=3, guard="report")
        assert not bool((np.asarray(df.factors[1]) == np.asarray(base[1])).all())
        dc = linalg.decompose(A, 8, seed=3, guard="report")
        _same(base, dc.factors)
        assert dc.health.ok
        _same(base, linalg.svd(A, 8, seed=3))  # unguarded cache untouched


class TestStreamExceptionSafety:
    """Satellite regression: a consumer abandoning or raising mid-stream
    leaves the staging ring fenced and reusable."""

    def _counting_fence(self, monkeypatch):
        calls = []
        orig = pipeline._await_in_flight

        def fence(in_flight):
            calls.append(1)
            orig(in_flight)

        monkeypatch.setattr(pipeline, "_await_in_flight", fence)
        return calls

    def test_close_mid_stream_fences(self, monkeypatch):
        calls = self._counting_fence(monkeypatch)
        A = _host()
        bounds = pipeline.panel_bounds(A.shape[0], 64)
        gen = pipeline.stream_host_panels(A, bounds, 2)
        next(gen), next(gen)
        gen.close()
        assert calls == [1]

    def test_raise_mid_consume_fences_then_reusable(self, monkeypatch):
        calls = self._counting_fence(monkeypatch)
        A = _host()
        bounds = pipeline.panel_bounds(A.shape[0], 64)

        with pytest.raises(RuntimeError, match="consumer died"):
            for i, _ in enumerate(pipeline.stream_host_panels(A, bounds, 2)):
                if i == 1:
                    raise RuntimeError("consumer died at panel 1")
        assert calls == [1]

        monkeypatch.undo()
        panels = list(pipeline.stream_host_panels(A, bounds, 2))
        for p, (lo, hi) in zip(panels, bounds):
            np.testing.assert_array_equal(np.asarray(p), A[lo:hi])

    def test_exhausted_stream_fences_once(self, monkeypatch):
        calls = self._counting_fence(monkeypatch)
        A = _host()
        bounds = pipeline.panel_bounds(A.shape[0], 64)
        list(pipeline.stream_host_panels(A, bounds, 2))
        assert calls == [1]


class TestInterruptionKinds:
    """``preempt`` / ``device_lost``: the transient-interruption kinds that
    fire at snapshot boundaries.  Negative coverage: panel targeting, the
    default one-shot ``times`` budget, scope exit, and the class split
    between the two errors (the guard absorbs both, nothing else)."""

    def test_preempt_panel_targeted_misses_never_fire(self):
        with faults.inject("preempt", panel=3):
            faults.maybe_interrupt(1)               # wrong boundary: inert
            faults.maybe_interrupt(2)
            with pytest.raises(faults.PreemptionError, match="boundary 3"):
                faults.maybe_interrupt(3)
            faults.maybe_interrupt(3)               # default times=1: spent

    def test_device_lost_times_budget_and_fingerprint(self):
        with faults.inject("device_lost", times=2):
            assert faults.fingerprint() == (("device_lost", None, 2, 0),)
            for idx in range(2):
                with pytest.raises(faults.DeviceLostError):
                    faults.maybe_interrupt(idx)
            faults.maybe_interrupt(5)               # budget exhausted: inert
            assert faults.fingerprint() == (("device_lost", None, 2, 2),)
        assert faults.fingerprint() == ()

    def test_inert_outside_inject_scope(self):
        faults.maybe_interrupt(0)                   # nothing active: no-op
        with faults.inject("preempt"):
            pass
        faults.maybe_interrupt(0)                   # scope exited: inert again

    def test_kinds_raise_their_own_error_class(self):
        # distinct errors, one shared transient class the guard restarts on
        with faults.inject("preempt"):
            with pytest.raises(faults.PreemptionError):
                faults.maybe_interrupt(0)
        with faults.inject("device_lost"):
            with pytest.raises(faults.DeviceLostError):
                faults.maybe_interrupt(0)
        assert set(faults.TRANSIENT_ERRORS) == {
            faults.PreemptionError, faults.DeviceLostError}
        assert not issubclass(faults.PreemptionError, faults.TransferError)

    def test_interruption_never_poisons_unfaulted_solve(self):
        # a spent preempt fault in scope leaves a following solve untouched
        A = jnp.asarray(_host(96, 64, seed=0))
        base = linalg.svd(A, 8, seed=3)
        with faults.inject("preempt", panel=0):
            with pytest.raises(faults.PreemptionError):
                faults.maybe_interrupt(0)
            _same(base, linalg.svd(A, 8, seed=3))
