"""Correctness of the randomized k-SVD against dense SVD and paper claims,
driven through the `repro.linalg` facade (the one public call-site pattern)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.compat import enable_x64
from repro.core import RSVDConfig, low_rank_error, truncation_error
from repro.core.spectra import make_test_matrix
from repro.core.lanczos import lanczos_svd

FAST = RSVDConfig(power_scheme="stabilized", qr_method="cqr2", small_svd="gram_jacobi")


@pytest.mark.parametrize("kind", ["fast", "sharp", "slow"])
def test_near_optimal_error_fast_path(kind):
    """(1+eps) low-rank approximation property (paper's core guarantee)."""
    A, sig = make_test_matrix(300, 200, kind, seed=1)
    k = 20
    U, S, Vt = linalg.svd(A, k, overrides=FAST)
    err = float(low_rank_error(A, U, S, Vt))
    opt = float(truncation_error(sig, k))
    # stabilized power iteration gets within a few percent of optimal
    assert err <= 1.10 * opt + 1e-6, (err, opt)


@pytest.mark.parametrize("kind", ["fast", "sharp"])
def test_faithful_path_f64(kind):
    """Paper's Algorithm 1 verbatim, in float64 as the paper's dgesvd setting;
    reproduces the <=1e-8 relative-error-vs-GESVD claim on decaying spectra."""
    with enable_x64():
        A, sig = make_test_matrix(300, 200, kind, seed=2, dtype=jnp.float64)
        k = 20
        # Paper §4: "we kept the relative error on the limit of at most 1e-8"
        # by choosing s = O(k/eps); the sketch-size/power-iteration pair below
        # is that tuning for these spectra (error ~ (sig_{s+1}/sig_k)^(2(2q+1))).
        cfg = RSVDConfig(oversample=2 * k, power_iters=3)
        U, S, Vt = linalg.svd(A, k, overrides=cfg)
        S_exact = jnp.linalg.svd(A, compute_uv=False)[:k]
        rel = float(jnp.max(jnp.abs(S - S_exact) / S_exact))
        assert rel < 1e-8, rel


def test_singular_values_match_dense():
    A, _ = make_test_matrix(256, 128, "fast", seed=3)
    S_rand = linalg.eigvals(A, 10, overrides=FAST)
    S_dense = jnp.linalg.svd(A, compute_uv=False)[:10]
    # fp32 Gram-squaring floor: sigma_10/sigma_1 = 1e-2 -> lambda ratio 1e-4,
    # so relative error ~ eps_f32 / 1e-4 ~ 1e-3 is the expected accuracy here.
    np.testing.assert_allclose(np.asarray(S_rand), np.asarray(S_dense), rtol=5e-3)


def test_factors_reconstruct():
    A, _ = make_test_matrix(200, 150, "sharp", seed=4)
    k = 30
    U, S, Vt = linalg.svd(A, k, overrides=FAST)
    assert U.shape == (200, k) and S.shape == (k,) and Vt.shape == (k, 150)
    # U, V orthonormal
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(k), atol=2e-5)
    np.testing.assert_allclose(np.asarray(Vt @ Vt.T), np.eye(k), atol=2e-5)
    # singular values sorted descending and positive
    s = np.asarray(S)
    assert (np.diff(s) <= 1e-7).all() and (s > 0).all()


def test_wide_matrix_transpose_path():
    """m < n takes the transposed route; factors must still be consistent."""
    A, _ = make_test_matrix(300, 80, "fast", seed=5)
    At = A.T  # 80 x 300 (wide)
    U, S, Vt = linalg.svd(At, 10, overrides=FAST)
    assert U.shape == (80, 10) and Vt.shape == (10, 300)
    err = float(low_rank_error(At, U, S, Vt))
    S_dense = jnp.linalg.svd(At, compute_uv=False)
    opt = float(truncation_error(S_dense, 10))
    assert err <= 1.10 * opt + 1e-6


def test_deterministic_given_seed():
    A, _ = make_test_matrix(128, 96, "fast", seed=6)
    U1, S1, _ = linalg.svd(A, 8, overrides=FAST, seed=7)
    U2, S2, _ = linalg.svd(A, 8, overrides=FAST, seed=7)
    np.testing.assert_array_equal(np.asarray(S1), np.asarray(S2))
    np.testing.assert_array_equal(np.asarray(U1), np.asarray(U2))


def test_lanczos_baseline_agrees():
    """The SVDS baseline must agree with dense SVD (fair comparison check)."""
    with enable_x64():
        A, _ = make_test_matrix(200, 120, "fast", seed=8, dtype=jnp.float64)
        U, S, Vt = lanczos_svd(A, 10, extra=20)
        S_dense = jnp.linalg.svd(A, compute_uv=False)[:10]
        np.testing.assert_allclose(np.asarray(S), np.asarray(S_dense), rtol=1e-8)
