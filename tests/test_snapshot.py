"""Resumable decompositions: panel-granular checkpoint/resume, deadlines,
cancellation, crash-safe service restart (linalg/snapshot.py and friends).

Pins the subsystem's contracts:

  * `Checkpointer` publishes atomically (tmp -> fsync -> rename -> parent
    fsync): `.tmp` debris and manifest-less directories are invisible to
    `steps()`/`latest()`, `keep_last` GC holds, the cadence (`every`) is
    honored and `save_now` ignores it;
  * a streamed solve interrupted at EVERY panel-group boundary (injected
    `preempt`) resumes to factors BIT-identical to the uninterrupted run
    at the same seed — same for the adaptive Tolerance solve under
    `device_lost`, including rank/rank_history;
  * a stale snapshot whose token mismatches (different seed/config) is
    silently ignored — the run is fresh, never poisoned;
  * checkpointing an UNINTERRUPTED run changes nothing: factors stay
    bit-identical with saves on (host-side writes only);
  * cancellation and deadlines are cooperative: observed at panel-group
    boundaries, raising `Cancelled`/`DeadlineExceeded` carrying the final
    snapshot path, and the parked solve resumes bit-identically;
  * the guard absorbs TRANSIENT_ERRORS by restarting the SAME rung (ambient
    checkpointer preserves progress, `RungReport.restarts` counts it); an
    exhausted restart budget raises (report mode) or climbs the ladder
    (retry mode);
  * the service honors `deadline_s` (queued lapse resolves without running)
    and `Future.cancel()` (queued AND running), restores write-ahead jobs
    after a crash bit-identically, and exports the resilience counters;
  * kill -9 subprocess drivers (tests/resume_driver.py, slow lane) prove
    all of the above against a real unhandled process death.
"""
import pathlib
import subprocess
import sys
import threading
import time
from concurrent.futures import CancelledError

import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core import blocked
from repro.core.rsvd import RSVDConfig
from repro.linalg import faults, guard
from repro.linalg import registry as registry_mod
from repro.linalg import snapshot as snap
from repro.serve.decomp import DecompositionService
from repro.serve.decomp.jobstore import JobStore
from repro.serve.decomp.metrics import MetricsRecorder

ROOT = pathlib.Path(__file__).resolve().parent.parent

import os  # noqa: E402  (os.environ for the subprocess drivers)


def _decay(m, n, seed=0):
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((m, n)))
    V, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.exp(-np.arange(n) / 6.0)
    return (U @ (s[:, None] * V.T)).astype(np.float32)


def _same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _capture():
    return {"x": np.arange(6.0)}, {"token": "tok", "cursor": 3}


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------

class TestCheckpointer:
    def test_atomic_layout_gc_and_latest(self, tmp_path):
        ck = snap.Checkpointer(tmp_path, every=1, keep_last=2)
        for s in (1, 2, 3, 4):
            ck.maybe_save(s, _capture)
        assert ck.steps() == [3, 4]          # keep_last GC
        assert ck.saves == 4
        assert ck.overhead_s > 0.0
        ref, arrays, meta = ck.latest("tok")
        assert (ref.step, ref.token) == (4, "tok")
        assert pathlib.Path(ref.path).name == "snap_00000004"
        np.testing.assert_array_equal(arrays["x"], np.arange(6.0))
        assert meta["cursor"] == 3
        assert ck.latest("other-token") is None   # stale plan -> fresh run

    def test_tmp_debris_and_manifestless_dirs_invisible(self, tmp_path):
        ck = snap.Checkpointer(tmp_path)
        ck.save_now(1, _capture)
        (tmp_path / "snap_00000009.tmp").mkdir()
        (tmp_path / "snap_00000009.tmp" / "state.npz").write_bytes(b"junk")
        (tmp_path / "snap_00000050").mkdir()      # renamed but manifest-less
        assert ck.steps() == [1]
        ref, _, _ = ck.latest("tok")
        assert ref.step == 1

    def test_cadence_and_save_now(self, tmp_path):
        ck = snap.Checkpointer(tmp_path, every=3, keep_last=10)
        for s in range(1, 7):
            ck.maybe_save(s, _capture)
        assert ck.steps() == [3, 6]               # every 3rd boundary
        ck.save_now(7, _capture)                  # cadence-exempt final save
        assert ck.steps() == [3, 6, 7]

    def test_bad_cadence_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="cadence"):
            snap.Checkpointer(tmp_path, every=0)

    def test_boundary_is_inert_without_scope(self):
        def explode():
            raise AssertionError("capture must not run with nothing in scope")
        snap.boundary(1, explode)                 # no control, no faults: no-op

    def test_boundary_cancel_and_deadline_save_final_snapshot(self, tmp_path):
        ev = threading.Event()
        ev.set()
        ck = snap.Checkpointer(tmp_path / "c")
        with snap.scope(snap.RunControl(checkpointer=ck, cancel_event=ev)):
            with pytest.raises(snap.Cancelled) as ei:
                snap.boundary(5, _capture)
        assert ei.value.snapshot_path.endswith("snap_00000005")
        assert pathlib.Path(ei.value.snapshot_path).is_dir()

        ctl = snap.RunControl(checkpointer=snap.Checkpointer(tmp_path / "d"),
                              deadline_t=time.monotonic() - 1.0)
        with snap.scope(ctl):
            with pytest.raises(snap.DeadlineExceeded) as ei:
                snap.boundary(2, _capture)
        assert ei.value.snapshot_path.endswith("snap_00000002")

        # without a checkpointer the verdicts still fire, path-less
        with snap.scope(snap.RunControl(cancel_event=ev)):
            with pytest.raises(snap.Cancelled) as ei:
                snap.boundary(1, _capture)
        assert ei.value.snapshot_path is None


# ---------------------------------------------------------------------------
# engine resume bit-identity (every boundary)
# ---------------------------------------------------------------------------

def _streamed_solve(A, ck=None):
    cfg = RSVDConfig(qr_method="cqr2", power_iters=2, block_rows=32)
    ctl = None if ck is None else snap.RunControl(checkpointer=ck)
    with snap.maybe_scope(ctl):
        return blocked.svd_streamed(A, 8, cfg, seed=7)


ADAPTIVE_SPEC = linalg.Tolerance(1e-3, panel=8, max_rank=48)


class TestResumeBitIdentity:
    def test_streamed_every_boundary(self, tmp_path):
        A = jnp.asarray(_decay(96, 40))
        ref = _streamed_solve(A)
        interrupted = 0
        for b in range(1, 100):
            ck = snap.Checkpointer(tmp_path / f"b{b:02d}")
            try:
                with faults.inject("preempt", panel=b):
                    _streamed_solve(A, ck)
            except faults.PreemptionError:
                interrupted += 1
                _same(ref, _streamed_solve(A, ck))
            else:
                break       # boundary b never fired: the solve has < b ticks
        # 3 panels x (sketch + 2x2 power passes + project) = 18 boundaries
        assert interrupted == 18

    def test_adaptive_every_boundary(self, tmp_path):
        A = jnp.asarray(_decay(120, 60, seed=1))
        ref = linalg.decompose(A, ADAPTIVE_SPEC, seed=3)
        interrupted = 0
        for b in range(1, 50):
            ckdir = str(tmp_path / f"b{b:02d}")
            try:
                with faults.inject("device_lost", panel=b):
                    linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ckdir)
            except faults.DeviceLostError:
                interrupted += 1
                out = linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ckdir)
                _same(ref.factors, out.factors)
                assert out.rank == ref.rank
                assert out.rank_history == ref.rank_history
                assert out.err_history == ref.err_history
            else:
                break
        assert interrupted >= 2   # >= 3 growth steps at this decay/tolerance

    def test_checkpointing_uninterrupted_run_changes_nothing(self, tmp_path):
        A = jnp.asarray(_decay(120, 60, seed=1))
        ref = linalg.decompose(A, ADAPTIVE_SPEC, seed=3)
        ck = snap.Checkpointer(tmp_path / "ck")
        out = linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ck)
        _same(ref.factors, out.factors)
        assert ck.saves > 0       # snapshots were actually written

    def test_stale_token_yields_fresh_run(self, tmp_path):
        A = jnp.asarray(_decay(120, 60, seed=1))
        ckdir = str(tmp_path / "ck")
        with pytest.raises(faults.DeviceLostError):
            with faults.inject("device_lost", panel=2):
                linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ckdir)
        # resume with a DIFFERENT seed: the surviving seed=3 snapshot's token
        # mismatches, so the run is fresh — identical to a never-interrupted
        # seed=4 solve, not a hybrid
        ref4 = linalg.decompose(A, ADAPTIVE_SPEC, seed=4)
        out4 = linalg.decompose(A, ADAPTIVE_SPEC, seed=4, checkpoint=ckdir)
        _same(ref4.factors, out4.factors)


# ---------------------------------------------------------------------------
# cooperative cancellation / deadlines at the linalg facade
# ---------------------------------------------------------------------------

class TestCancelAndDeadline:
    def test_cancel_mid_solve_parks_then_resumes(self, tmp_path):
        A = jnp.asarray(_decay(120, 60, seed=1))
        ref = linalg.decompose(A, ADAPTIVE_SPEC, seed=3)
        ev = threading.Event()
        ev.set()
        ctl = snap.RunControl(checkpointer=snap.Checkpointer(tmp_path / "c"),
                              cancel_event=ev)
        with pytest.raises(snap.Cancelled) as ei:
            linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ctl)
        assert pathlib.Path(ei.value.snapshot_path).is_dir()
        ev.clear()
        out = linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ctl)
        _same(ref.factors, out.factors)

    def test_deadline_mid_solve_parks_then_resumes(self, tmp_path):
        A = jnp.asarray(_decay(120, 60, seed=1))
        ref = linalg.decompose(A, ADAPTIVE_SPEC, seed=3)
        ctl = snap.RunControl(checkpointer=snap.Checkpointer(tmp_path / "d"),
                              deadline_t=time.monotonic() - 1.0)
        with pytest.raises(snap.DeadlineExceeded) as ei:
            linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ctl)
        assert pathlib.Path(ei.value.snapshot_path).is_dir()
        ctl.deadline_t = None
        out = linalg.decompose(A, ADAPTIVE_SPEC, seed=3, checkpoint=ctl)
        _same(ref.factors, out.factors)


# ---------------------------------------------------------------------------
# guard: transient restarts
# ---------------------------------------------------------------------------

def _host_op(seed=2):
    return linalg.HostOp(_decay(256, 64, seed=seed), block_rows=64)


class TestGuardRestarts:
    def test_transient_absorbed_same_rung_bit_identical(self, tmp_path):
        ref = linalg.decompose(_host_op(), linalg.Rank(8), seed=5, guard="retry")
        with faults.inject("preempt", panel=4):
            dec = linalg.decompose(_host_op(), linalg.Rank(8), seed=5,
                                   guard="retry", checkpoint=str(tmp_path / "g"))
        assert dec.health.ok
        assert sum(a.restarts for a in dec.health.attempts) == 1
        assert "restarts=1" in dec.health.describe()
        _same(ref.factors, dec.factors)

    def test_exhausted_budget_raises_in_report_mode(self, tmp_path):
        policy = guard.GuardPolicy(mode="report", max_restarts=1)
        with faults.inject("device_lost", panel=1, times=10):
            with pytest.raises(faults.DeviceLostError):
                linalg.decompose(_host_op(), linalg.Rank(8), seed=5,
                                 guard=policy, checkpoint=str(tmp_path / "g"))

    def test_exhausted_budget_climbs_ladder_in_retry_mode(self):
        policy = guard.GuardPolicy(mode="retry", max_restarts=0)
        with faults.inject("preempt", panel=1, times=1):
            dec = linalg.decompose(_host_op(), linalg.Rank(8), seed=5,
                                   guard=policy)
        assert dec.health.ok                       # the next rung succeeded
        assert not dec.health.attempts[0].healthy
        assert "PreemptionError" in dec.health.attempts[0].error

    def test_cancel_never_absorbed_by_guard(self, tmp_path):
        ev = threading.Event()
        ev.set()
        ctl = snap.RunControl(cancel_event=ev)
        with pytest.raises(snap.Cancelled):
            linalg.decompose(_host_op(), linalg.Rank(8), seed=5,
                             guard="retry", checkpoint=ctl)

    def test_policy_validates_restart_fields(self):
        with pytest.raises(ValueError):
            guard.GuardPolicy(max_restarts=-1)
        with pytest.raises(ValueError):
            guard.GuardPolicy(restart_backoff_s=-0.5)


# ---------------------------------------------------------------------------
# service: deadlines, cancellation, crash restore
# ---------------------------------------------------------------------------

class TestService:
    def test_deadline_lapsed_while_queued(self):
        with DecompositionService() as svc:
            fut = svc.submit(jnp.asarray(_decay(64, 32)), linalg.Rank(4),
                             deadline_s=0.0)
            svc.flush()
            with pytest.raises(linalg.DeadlineExceeded):
                fut.result(timeout=60)
            svc.drain(timeout=60)
            assert svc.metrics.export()["deadline_exceeded"] == 1

    def test_cancel_while_queued_neighbor_unaffected(self):
        arr = _decay(1024, 96, seed=3)
        with DecompositionService() as svc:
            f1 = svc.submit(linalg.HostOp(arr, block_rows=128),
                            linalg.Rank(8), seed=0)
            f2 = svc.submit(linalg.HostOp(arr, block_rows=128),
                            linalg.Rank(8), seed=1)
            f2.cancel()
            with pytest.raises((CancelledError, snap.Cancelled)):
                f2.result(timeout=120)
            assert f1.result(timeout=120).rank == 8    # neighbor unaffected
            svc.drain(timeout=120)
            assert svc.metrics.export()["cancelled"] == 1

    def test_running_cancel_is_cooperative(self, tmp_path):
        arr = _decay(4096, 256, seed=4)
        ckdir = tmp_path / "ck"
        with DecompositionService() as svc:
            fut = svc.submit(linalg.HostOp(arr, block_rows=256),
                             linalg.Rank(8), seed=2, checkpoint=str(ckdir))
            t0 = time.monotonic()
            while (not list(ckdir.glob("snap_*")) and not fut.done()
                   and time.monotonic() - t0 < 120):
                time.sleep(0.001)
            fut.cancel()
            try:
                fut.result(timeout=300)    # finished before the cancel: legal
            except CancelledError:
                pass                       # cancelled while still queued
            except snap.Cancelled as exc:  # the cooperative path under test
                assert pathlib.Path(exc.snapshot_path).is_dir()
        # whatever raced, the partial (or full) solve left durable snapshots
        assert [p for p in ckdir.glob("snap_*") if p.suffix != ".tmp"]

    def test_restore_reenqueues_interrupted_job_bit_identical(self, tmp_path):
        arr = _decay(512, 96, seed=6)
        spec, seed = linalg.Rank(8), 11
        store, ckdir = tmp_path / "store", tmp_path / "ck"
        op = linalg.as_linop(linalg.HostOp(arr, block_rows=128))
        pl = registry_mod.cached_plan(op, linalg.as_spec(spec), kind="svd",
                                      overrides=None,
                                      guard=guard.as_guard(None),
                                      validate=False)
        # crash simulation: the write-ahead record exists, the solve died
        # mid-panel with snapshots on disk, complete() never ran
        job_id = JobStore(store).record(
            op=op, spec=spec, kind="svd", seed=seed, guard_mode="off",
            validate=False, plan_fingerprint=pl.fingerprint(),
            checkpoint_dir=str(ckdir), deadline_s=None)
        assert job_id is not None
        with pytest.raises(faults.PreemptionError):
            with faults.inject("preempt", panel=5):
                linalg.decompose(linalg.HostOp(arr, block_rows=128), spec,
                                 seed=seed, checkpoint=str(ckdir))
        ref = linalg.decompose(linalg.HostOp(arr, block_rows=128), spec,
                               seed=seed)
        svc = DecompositionService.restore(str(store))
        try:
            dec = svc.restored_futures[job_id].result(timeout=300)
            assert svc.metrics.export()["resumed_jobs"] == 1
        finally:
            svc.close()
        _same(ref.factors, dec.factors)
        assert JobStore(store).pending() == []     # record retired on resolve

    def test_restore_plan_mismatch_runs_fresh(self, tmp_path):
        arr = _decay(256, 64, seed=7)
        store = tmp_path / "store"
        op = linalg.as_linop(linalg.HostOp(arr, block_rows=64))
        job_id = JobStore(store).record(
            op=op, spec=linalg.Rank(6), kind="svd", seed=2, guard_mode="off",
            validate=False, plan_fingerprint="stale|environment|changed",
            checkpoint_dir=str(tmp_path / "ck"), deadline_s=None)
        ref = linalg.decompose(linalg.HostOp(arr, block_rows=64),
                               linalg.Rank(6), seed=2)
        svc = DecompositionService.restore(str(store))
        try:
            dec = svc.restored_futures[job_id].result(timeout=300)
        finally:
            svc.close()
        _same(ref.factors, dec.factors)

    def test_jobstore_rejects_unpersistable_sources(self, tmp_path):
        class NoArray:
            shape = (8, 8)
        assert JobStore(tmp_path).record(
            op=NoArray(), spec=linalg.Rank(2), kind="svd", seed=0,
            guard_mode="off", validate=False, plan_fingerprint="x",
            checkpoint_dir=None, deadline_s=None) is None
        assert list(tmp_path.iterdir()) == []      # nothing was written

    def test_jobstore_sweeps_tmp_debris(self, tmp_path):
        (tmp_path / "job_deadbeef.tmp").mkdir(parents=True)
        store = JobStore(tmp_path)
        assert store.pending() == []
        assert not (tmp_path / "job_deadbeef.tmp").exists()

    def test_metrics_export_resilience_counters(self):
        ex = MetricsRecorder().export()
        for key in ("cancelled", "deadline_exceeded", "restarts",
                    "resumed_jobs", "checkpoint_overhead_s"):
            assert key in ex, key


# ---------------------------------------------------------------------------
# lint contract: the new state carriers are key dataclasses
# ---------------------------------------------------------------------------

def test_state_dataclasses_are_lint_keyed():
    from repro.analysis import rules
    assert "SnapshotRef" in rules.KEY_DATACLASSES
    assert "JobRecord" in rules.KEY_DATACLASSES


# ---------------------------------------------------------------------------
# kill -9 subprocess drivers (slow lane / CI resilience lane)
# ---------------------------------------------------------------------------

def _run_driver(mode, workdir):
    env = os.environ.copy()
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "resume_driver.py"),
         mode, str(workdir)],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, (
        f"resume driver {mode!r} failed rc={proc.returncode}\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


@pytest.mark.slow
def test_streamed_resume_survives_sigkill(tmp_path):
    assert "RESUME_STREAMED_OK" in _run_driver("streamed", tmp_path)


@pytest.mark.slow
def test_adaptive_resume_survives_sigkill(tmp_path):
    assert "RESUME_ADAPTIVE_OK" in _run_driver("adaptive", tmp_path)


@pytest.mark.slow
def test_service_restore_survives_sigkill(tmp_path):
    assert "SERVICE_RESTORE_OK" in _run_driver("service", tmp_path)


@pytest.mark.slow
def test_checkpoint_manager_crash_mid_save(tmp_path):
    assert "CKPT_CRASH_OK" in _run_driver("ckpt", tmp_path)
