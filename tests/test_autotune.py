"""Block-size autotuner: bucketing, cache hit/miss, JSON round-trip, and the
ops.py consultation path."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune as at
from repro.kernels import ops, ref
from repro.core.sketch import sketch_matrix


@pytest.fixture(autouse=True)
def _clean_table():
    at.clear()
    yield
    at.clear()


def test_shape_bucket_rounds_up_to_pow2():
    assert at.shape_bucket((100, 257, 1)) == (128, 512, 1)
    assert at.shape_bucket((128,)) == (128,)
    assert at.shape_bucket((129,)) == (256,)


def test_lookup_miss_then_hit():
    assert at.lookup("matmul", (300, 300, 300), "float32", "interpret") is None
    at.record("matmul", (300, 300, 300), "float32",
              at.BlockSizes(256, 128, 128), "interpret")
    got = at.lookup("matmul", (300, 300, 300), "float32", "interpret")
    assert got == at.BlockSizes(256, 128, 128)
    # same bucket (512^3), different concrete shape -> hit
    assert at.lookup("matmul", (400, 290, 500), "float32", "interpret") == got
    # different bucket / dtype / backend / kernel -> miss
    assert at.lookup("matmul", (600, 300, 300), "float32", "interpret") is None
    assert at.lookup("matmul", (300, 300, 300), "bfloat16", "interpret") is None
    assert at.lookup("matmul", (300, 300, 300), "float32", "tpu") is None
    assert at.lookup("gram", (300, 300, 300), "float32", "interpret") is None


def test_json_roundtrip(tmp_path, monkeypatch):
    at.record("gram", (128, 128, 1024), "float32",
              at.BlockSizes(128, 64, 256), "interpret", us=42.0)
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    assert at.save() == path
    payload = json.load(open(path))
    assert payload["interpret"]["gram"]
    at.clear()
    assert at.lookup("gram", (128, 128, 1024), "float32", "interpret") == \
        at.BlockSizes(128, 64, 256)  # lazily reloaded from $REPRO_AUTOTUNE_CACHE


def test_fresh_record_survives_lazy_file_load(tmp_path, monkeypatch):
    """A winner recorded THIS process must not be clobbered when the stale
    cache file is lazily loaded by a later lookup."""
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    json.dump({"interpret": {"matmul": {"512x512x512_float32":
              {"bm": 128, "bn": 128, "bk": 128}}}}, open(path, "w"))
    # fresh sweep records a new winner BEFORE any lookup touches the file
    at.record("matmul", (300, 300, 300), "float32",
              at.BlockSizes(256, 256, 256), "interpret")
    got = at.lookup("matmul", (300, 300, 300), "float32", "interpret")
    assert got == at.BlockSizes(256, 256, 256)  # in-memory wins over stale file


def test_save_merges_existing_file(tmp_path, monkeypatch):
    """Saving a sweep for one kernel must keep other kernels' persisted
    entries intact."""
    path = str(tmp_path / "cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    json.dump({"tpu": {"gram": {"256x256x256_float32":
              {"bm": 128, "bn": 128, "bk": 128}}}}, open(path, "w"))
    at.record("matmul", (64, 64, 64), "float32", at.BlockSizes(64, 64, 64), "interpret")
    at.save()
    payload = json.load(open(path))
    assert "gram" in payload["tpu"] and "matmul" in payload["interpret"]


def test_no_persistence_without_path(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    at.record("matmul", (64, 64, 64), "float32", at.BlockSizes(64, 64, 64), "interpret")
    assert at.save() is None


def test_autotune_sweep_records_winner():
    a = sketch_matrix(96, 64, 0)
    b = sketch_matrix(64, 32, 1)

    def run(blocks):
        # exercise the real kernel at the candidate tiling
        from repro.kernels.matmul import matmul_padded

        pad = lambda x, ms: jnp.pad(x, [(0, (-d) % m) for d, m in zip(x.shape, ms)])
        xp = pad(a, (blocks.bm, blocks.bk))
        yp = pad(b, (blocks.bk, blocks.bn))
        return matmul_padded(xp, yp, bm=blocks.bm, bn=blocks.bn, bk=blocks.bk,
                             interpret=True)

    cands = [(32, 32, 32), (64, 32, 64), (0, 0, 0)]  # last one must be skipped
    best = at.autotune("matmul", run, (96, 32, 64), "float32", "interpret",
                       candidates=cands)
    assert best.astuple() in cands[:2]
    assert at.lookup("matmul", (96, 32, 64), "float32", "interpret") == best


def test_autotune_all_candidates_fail():
    with pytest.raises(ValueError):
        at.autotune("matmul", lambda b: 1 / 0, (8, 8, 8), "float32", "interpret",
                    candidates=[(8, 8, 8)])


def test_ops_consults_tuned_blocks_and_stays_correct():
    """A tuned entry changes the tiling ops.py picks; results must still
    match the oracle (padding adapts to the tuned block)."""
    m, k, n = 200, 150, 70
    sel0 = ops._select_blocks("matmul", (m, n, k), jnp.float32)
    assert sel0 == (128, 128, 128)  # heuristic default
    at.record("matmul", (m, n, k), "float32", at.BlockSizes(256, 64, 32),
              ops._backend_name())
    sel1 = ops._select_blocks("matmul", (m, n, k), jnp.float32)
    assert sel1 == (256, 64, 32)
    x = sketch_matrix(m, k, 2)
    y = sketch_matrix(k, n, 3)
    np.testing.assert_allclose(
        np.asarray(ops.matmul(x, y)), np.asarray(ref.matmul_ref(x, y)),
        atol=1e-4, rtol=1e-3,
    )


def test_ops_clamps_tuned_blocks_to_small_dims():
    """A cache entry recorded at a big bucket must not produce an oversized
    block for a tiny dim (the _block clamp)."""
    at.record("matmul", (16, 16, 16), "float32", at.BlockSizes(256, 256, 256),
              ops._backend_name())
    bm, bn, bk = ops._select_blocks("matmul", (16, 16, 16), jnp.float32)
    assert (bm, bn, bk) == (16, 16, 16)


def test_backend_namespace_includes_device_kind():
    """The autotune bucket is keyed by execution mode AND device kind, so
    interpret-mode (CPU) sweeps can never shadow TPU winners."""
    name = ops._backend_name()
    mode, _, kind = name.partition(":")
    assert mode in ("tpu", "interpret") and kind, name
    # an entry recorded under a bare legacy namespace is invisible to ops
    at.record("matmul", (32, 32, 32), "float32", at.BlockSizes(8, 8, 8), mode)
    assert ops._select_blocks("matmul", (32, 32, 32), jnp.float32) == (32, 32, 32)
