"""Fixed-precision (adaptive-rank) QB: the stopping rule and its estimator.

Spectra with known decay (core/spectra.py) make the ORACLE rank computable:
the smallest j with `truncation_error(sig, j) <= eps`.  The adaptive engine
must land within one growth panel of it, meet the requested residual, and
run strictly fewer panels than the full-rank fallback whenever the spectrum
decays."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core import truncation_error
from repro.core.adaptive import adaptive_qb, fro_norm_sq
from repro.core.spectra import make_test_matrix


def _analytic_rank(sig, eps: float) -> int:
    """Smallest rank whose optimal truncation meets the tolerance."""
    for j in range(len(sig)):
        if float(truncation_error(sig, j)) <= eps:
            return j
    return len(sig)


# ---------------------------------------------------------------------------
# Rank selection: within +/- panel of the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,eps", [("fast", 1e-2), ("sharp", 1e-2)])
def test_tolerance_selects_rank_within_one_panel_of_oracle(kind, eps):
    panel = 8
    A, sig = make_test_matrix(224, 96, kind, seed=0)
    dec = linalg.decompose(A, linalg.Tolerance(eps, panel=panel), seed=1)
    oracle = _analytic_rank(sig, eps)
    # selected rank can never beat the oracle (randomized tail >= optimal
    # tail), and trimming removes all but the blocked-growth overshoot
    assert oracle <= dec.rank <= oracle + panel, (dec.rank, oracle)
    achieved = float(linalg.residual(A, dec.factors))
    assert achieved <= eps, (achieved, eps)


def test_adaptive_runs_strictly_fewer_panels_than_full_rank_fallback():
    """The acceptance property: on a decaying spectrum the tolerance is met
    with a strict prefix of the planned growth schedule, and the plan
    records that schedule."""
    A, _ = make_test_matrix(224, 96, "sharp", seed=2)
    dec = linalg.decompose(A, linalg.Tolerance(1e-2, panel=16), seed=0)
    assert dec.plan.path == "adaptive"
    assert dec.plan.rank_schedule[-1] == 96            # full-rank fallback cap
    assert len(dec.rank_history) < len(dec.plan.rank_schedule)
    assert dec.rank_history == dec.plan.rank_schedule[: len(dec.rank_history)]
    assert len(dec.plan.schedule_hbm_bytes) == len(dec.plan.rank_schedule)
    assert float(linalg.residual(A, dec.factors)) <= 1e-2


def test_unreachable_tolerance_falls_back_to_full_rank():
    """A slow (1/i^0.1) spectrum cannot reach 1% error below full rank: the
    engine must stop at the cap instead of looping."""
    A, sig = make_test_matrix(96, 48, "slow", seed=3)
    dec = linalg.decompose(A, linalg.Tolerance(1e-2, panel=16), seed=0)
    assert dec.rank_history[-1] == 48
    assert len(dec.rank_history) == len(dec.plan.rank_schedule)


def test_max_rank_caps_the_search():
    A, _ = make_test_matrix(96, 48, "slow", seed=4)
    dec = linalg.decompose(A, linalg.Tolerance(1e-3, panel=8, max_rank=24), seed=0)
    assert dec.rank <= 24 and dec.rank_history[-1] == 24


# ---------------------------------------------------------------------------
# Property: achieved residual <= requested tolerance across decays / dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,eps", [
    ("fast", 5e-2), ("fast", 1e-2), ("sharp", 1e-2), ("slow", 0.5),
])
@pytest.mark.parametrize("seed", [0, 7])
def test_achieved_residual_meets_tolerance_f32(kind, eps, seed):
    A, _ = make_test_matrix(192, 64, kind, seed=seed)
    dec = linalg.decompose(A, linalg.Tolerance(eps, panel=8), seed=seed + 1)
    achieved = float(linalg.residual(A, dec.factors))
    assert achieved <= eps, (kind, eps, achieved, dec.rank)
    # the posterior estimate agrees with the measured residual at the
    # stopping panel (exact identity up to fp32 roundoff, pre-trim)
    assert dec.err_history[-1] <= eps


def test_achieved_residual_meets_tolerance_f64():
    from repro.compat import enable_x64

    with enable_x64():
        A, _ = make_test_matrix(160, 64, "fast", seed=5, dtype=jnp.float64)
        dec = linalg.decompose(A, linalg.Tolerance(1e-3, panel=8), seed=2)
        assert dec.plan.kernel_backend == "jnp" and not dec.plan.fused_sketch
        achieved = float(linalg.residual(A, dec.factors))
        assert achieved <= 1e-3, achieved


def test_f64_certifies_below_the_f32_estimator_floor():
    """An f64 source keeps the f64 estimator floor: a 1e-6 tolerance (far
    below the ~3e-4 fp32 floor) is certified AND trimmed to the analytic
    rank on a true exponential spectrum."""
    from repro.compat import enable_x64
    from repro.core.spectra import random_orthogonal

    with enable_x64():
        n = 96
        sig = jnp.asarray(10.0 ** (-jnp.arange(n, dtype=jnp.float64) / 3.0))
        U = random_orthogonal(192, n, 1, dtype=jnp.float64)
        V = random_orthogonal(n, n, 2, dtype=jnp.float64)
        A = (U * sig[None, :]) @ V.T
        dec = linalg.decompose(A, linalg.Tolerance(1e-6, panel=8), seed=0)
        achieved = float(linalg.residual(A, dec.factors))
        tail = np.sqrt(np.cumsum(np.asarray(sig[::-1]) ** 2)[::-1]
                       / np.sum(np.asarray(sig) ** 2))
        analytic = int(np.nonzero(tail <= 1e-6)[0][0])
        assert achieved <= 1e-6, achieved
        assert analytic <= dec.rank <= analytic + 8, (dec.rank, analytic)


def test_column_means_keeps_f64_precision_under_x64():
    """column_means accumulates in promote_types(dtype, f32): an f64 source
    under x64 must keep full f64 precision through the panel walk — a
    silent f32 accumulator would lose ~8 digits on an offset of 1e8
    (CenteredOp/pca centers with exactly this mean)."""
    from repro.compat import enable_x64

    with enable_x64():
        rng = np.random.default_rng(40)
        X_np = (1e8 + rng.standard_normal((300, 12))).astype(np.float64)
        for src in (jnp.asarray(X_np), linalg.HostOp(X_np, block_rows=64)):
            mu = linalg.column_means(src)
            assert mu.dtype == jnp.float64
            np.testing.assert_allclose(np.asarray(mu), X_np.mean(axis=0),
                                       rtol=1e-13, atol=0.0)


def test_column_means_promotes_f32_over_a_long_panel_walk():
    """An f32 source still accumulates at f32-or-better per panel: the
    blocked sum over many panels stays within a few f32 ulps of the f64
    reference (no precision cliff from the panel loop)."""
    rng = np.random.default_rng(41)
    X_np = (100.0 + rng.standard_normal((2048, 8))).astype(np.float32)
    mu = linalg.column_means(linalg.HostOp(X_np, block_rows=128))
    ref = X_np.astype(np.float64).mean(axis=0)
    np.testing.assert_allclose(np.asarray(mu, np.float64), ref, rtol=2e-6)


@pytest.mark.parametrize("sketch", ["rademacher", "srht", "countsketch"])
@pytest.mark.parametrize("spectrum_kind", ["fast", "slow"])
def test_tolerance_met_for_every_sketch_kind(sketch, spectrum_kind):
    """The accuracy contract is sketch-independent: decompose(A,
    Tolerance(eps)) certifies eps for the structured kinds exactly as for
    gaussian (gaussian itself is pinned above), on both fast and slow
    spectral decay."""
    eps = 2e-2
    A, _ = make_test_matrix(192, 64, spectrum_kind, seed=17)
    dec = linalg.decompose(A, linalg.Tolerance(eps, panel=8, sketch=sketch),
                           seed=3)
    assert dec.plan.sketch_kind == sketch
    achieved = float(linalg.residual(A, dec.factors))
    assert achieved <= eps, (sketch, spectrum_kind, achieved, dec.rank)


def test_tolerance_streams_host_source():
    """Adaptive growth over a HostOp: only panel-sized state moves, and the
    stopping rule sees the same estimator."""
    A_np = np.asarray(make_test_matrix(256, 64, "fast", seed=6)[0])
    op = linalg.HostOp(A_np, block_rows=64)
    dec = linalg.decompose(op, linalg.Tolerance(2e-2, panel=8), seed=1)
    assert float(linalg.residual(op, dec.factors)) <= 2e-2
    assert dec.rank < 64


def test_wide_source_plan_records_executed_orientation():
    """The QB engine never transposes (qb/lu factor shapes are contract-
    bound): a wide source's adaptive plan must record the source dims as-is
    and the solve must still meet the tolerance."""
    A, _ = make_test_matrix(224, 96, "fast", seed=13)
    A_wide = A.T                                   # 96 x 224
    dec = linalg.decompose(A_wide, linalg.Tolerance(2e-2, panel=8), seed=2)
    assert (dec.plan.m, dec.plan.n) == (96, 224)
    U, S, Vt = dec.factors
    assert U.shape[0] == 96 and Vt.shape[1] == 224
    assert float(linalg.residual(A_wide, dec.factors)) <= 2e-2


def test_tolerance_on_composed_operator():
    """CenteredOp source: the estimator's ||A||_F^2 walk composes panel-wise
    (never materializing the centered matrix)."""
    X = make_test_matrix(192, 48, "fast", seed=8)[0] + 0.75
    op = linalg.CenteredOp(linalg.DenseOp(X))
    dec = linalg.decompose(op, linalg.Tolerance(5e-2, panel=8), seed=3)
    Xc = X - jnp.mean(X, axis=0)[None, :]
    U, S, Vt = dec.factors
    err = float(jnp.linalg.norm(Xc - (U * S[None, :]) @ Vt) / jnp.linalg.norm(Xc))
    assert err <= 5e-2 + 1e-5, err


# ---------------------------------------------------------------------------
# Energy spec
# ---------------------------------------------------------------------------

def test_energy_captures_requested_fraction():
    A, sig = make_test_matrix(192, 64, "fast", seed=9)
    p = 0.99
    dec = linalg.decompose(A, linalg.Energy(p, panel=4), seed=0)
    U, S, Vt = dec.factors
    captured = float(jnp.sum(S**2)) / float(jnp.sum(A.astype(jnp.float32) ** 2))
    assert captured >= p - 1e-4, (captured, p)
    # and the oracle comparison: smallest rank with cumulative energy >= p
    e = np.cumsum(np.asarray(sig, np.float64) ** 2)
    oracle = int(np.nonzero(e >= p * e[-1])[0][0]) + 1
    assert oracle <= dec.rank <= oracle + 4


# ---------------------------------------------------------------------------
# The engine itself: estimator identity + basis quality
# ---------------------------------------------------------------------------

def test_posterior_estimator_matches_true_residual():
    """remaining = ||A||^2 - ||B||^2 must equal the true ||A - Q Q^T A||^2
    (the Frobenius identity the stopping rule rests on)."""
    A, _ = make_test_matrix(128, 48, "sharp", seed=10)
    norm = fro_norm_sq(linalg.DenseOp(A))
    qb = adaptive_qb(linalg.DenseOp(A), panel=12, max_rank=36,
                     threshold_sq=None, norm_sq=norm, seed=4)
    R = A - qb.Q @ qb.B
    true_sq = float(jnp.sum(R.astype(jnp.float32) ** 2))
    assert math.isclose(qb.remaining_sq, true_sq, rel_tol=1e-3, abs_tol=1e-4 * norm)


def test_fixed_rank_qb_skips_the_estimator_pass():
    """Rank specs have no stopping rule: no ||A||_F^2 pass, no estimator
    fields (one fewer read of A on the fixed-rank qb/lu/eigh paths)."""
    A, _ = make_test_matrix(96, 32, "fast", seed=12)
    qb = adaptive_qb(linalg.DenseOp(A), panel=12, max_rank=12,
                     threshold_sq=None, seed=4)
    assert qb.norm_sq is None and qb.remaining_sq is None
    assert qb.err_history == () and qb.rank_history == (12,)
    dec = linalg.decompose(A, linalg.Rank(8), kind="qb", seed=1)
    assert dec.err_history == ()


def test_grown_basis_stays_orthonormal():
    """CGS2 against the accumulated basis: ||Q^T Q - I|| = O(eps) even after
    several growth panels."""
    # slow decay: every panel contributes, so the basis actually grows to 48
    A, _ = make_test_matrix(160, 64, "slow", seed=11)
    qb = adaptive_qb(linalg.DenseOp(A), panel=8, max_rank=48,
                     threshold_sq=None, seed=5)
    G = np.asarray(qb.Q.T @ qb.Q)
    assert np.max(np.abs(G - np.eye(G.shape[0]))) < 5e-5
    assert qb.rank_history == (8, 16, 24, 32, 40, 48)


def test_panel_seeds_decorrelate():
    """Different growth panels draw DIFFERENT sketches (per-panel seed
    offsets through the counter RNG) — a repeated sketch would stall the
    basis on slow-decay spectra."""
    from repro.core.sketch import sketch_matrix

    s0 = np.asarray(sketch_matrix(32, 8, jnp.uint32(3)))
    s1 = np.asarray(sketch_matrix(32, 8, jnp.uint32(4)))
    assert not np.allclose(s0, s1)
