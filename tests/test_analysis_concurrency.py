"""Concurrent-access regressions for the shared-mutable-state fixes the
RL002 lint surfaced: autotune table, fault registry, decomposition registry,
and the trace-count accounting — plus exact once-per-plan tracing when many
service threads hit the same plan simultaneously."""
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import pytest

from repro import linalg
from repro.core import blocked
from repro.kernels import autotune
from repro.linalg import faults, registry
from repro.serve.decomp import cache as serve_cache

pytestmark = pytest.mark.analysis

N_THREADS = 8


def _hammer(fn, iters=200):
    """Run fn(thread_idx, iter_idx) from N_THREADS threads; re-raise the
    first worker exception (silent worker death hides races)."""
    barrier = threading.Barrier(N_THREADS)
    errors = []

    def worker(t):
        try:
            barrier.wait()
            for i in range(iters):
                fn(t, i)
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(N_THREADS)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]


def test_autotune_concurrent_record_lookup():
    autotune.clear()
    blocks = autotune.BlockSizes(8, 128, 128)

    def fn(t, i):
        kernel = f"k{(t + i) % 3}"
        autotune.record(kernel, (256, 256), jnp.float32, blocks, "pallas",
                        us=float(i))
        got = autotune.lookup(kernel, (256, 256), jnp.float32, "pallas")
        assert got is None or got == blocks

    _hammer(fn)
    for kernel in ("k0", "k1", "k2"):
        assert autotune.lookup(kernel, (256, 256), jnp.float32,
                               "pallas") == blocks
    autotune.clear()


def test_fault_registry_concurrent_inject():
    def fn(t, i):
        with faults.inject("nan_panel", panel=t) as fault:
            faults.fingerprint()
            assert faults._fired[id(fault)] == 0

    _hammer(fn, iters=100)
    assert not faults.any_active()
    assert not faults._fired


def test_registry_concurrent_register_and_get():
    base_kinds = set(registry.kinds())

    def execute(op, spec, pl, seed):  # pragma: no cover - never called
        raise NotImplementedError

    def fn(t, i):
        registry.register(
            registry.DecompositionKind(f"_test_kind_{t}", execute))
        assert registry.get("svd").name == "svd"
        assert registry.get(f"_test_kind_{t}").name == f"_test_kind_{t}"

    try:
        _hammer(fn, iters=100)
        for t in range(N_THREADS):
            assert registry.get(f"_test_kind_{t}") is not None
    finally:
        with registry._registry_write_lock:
            for name in set(registry.kinds()) - base_kinds:
                registry._REGISTRY.pop(name, None)


def test_plan_cache_stats_exact_under_contention():
    # The cached_plan LRU was already lock-guarded (PR 8); this pins the
    # accounting: every call lands in exactly one of hits/misses/bypasses.
    registry.clear_plan_cache()
    ops = [linalg.DenseOp(jax.ShapeDtypeStruct((64 + 8 * j, 32), jnp.float32))
           for j in range(3)]
    before = registry.plan_cache_stats()
    iters = 100

    def fn(t, i):
        pl = registry.cached_plan(ops[(t + i) % 3], 4)
        assert pl.path == "dense"

    _hammer(fn, iters=iters)
    after = registry.plan_cache_stats()
    delta = sum(after[k] - before[k] for k in ("hits", "misses", "bypasses"))
    assert delta == N_THREADS * iters


def test_trace_counter_is_exact_under_contention():
    key = ("analysis-concurrency-probe", 0)
    before = blocked.trace_count(key)
    iters = 500

    def fn(t, i):
        blocked._note_trace(key)

    _hammer(fn, iters=iters)
    # An unlocked Counter drops increments under contention; the locked one
    # must account for every single trace.
    assert blocked.trace_count(key) - before == N_THREADS * iters
    with blocked._trace_counts_lock:
        blocked._TRACE_COUNTS.pop(key, None)


def test_service_traces_once_per_plan_under_thread_storm():
    # Shape chosen to collide with no other test's trace key.
    stack = ((jnp.arange(5 * 40 * 16, dtype=jnp.float32)
              .reshape(5, 40, 16) * 0.73) % 1.0 + 0.1)
    pl = linalg.plan(linalg.StackedOp(stack), 3)
    cache = serve_cache.ExecutableCache()
    seeds = blocked.slice_seeds(0, 5)
    before = serve_cache.trace_count(pl)
    results = []

    def request(_):
        solve, _hit = cache.get(pl)
        return jax.block_until_ready(solve(stack, seeds))

    # One warm-up request compiles the plan's program (exactly one trace)...
    request(0)
    assert serve_cache.trace_count(pl) - before == 1

    with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
        results = list(pool.map(request, range(N_THREADS * 2)))

    # ...and a thread storm on the warm plan must not re-trace at all —
    # the locked counter proves it exactly (an unlocked Counter could both
    # hide a stray re-trace and lose increments under contention).
    assert serve_cache.trace_count(pl) - before == 1
    u0, s0, v0 = results[0]
    for u, s, v in results[1:]:
        assert jnp.array_equal(s, s0)
