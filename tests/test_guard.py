"""Guarded execution: probes, the escalation ladder, and the validate= knob.

The contract under test (DESIGN.md §Guarded execution):

  * guard off is BIT-identical to a pre-guard solve at fixed seed — the
    probe call sites never run without an active sink, and the probed jit
    twins are separate cache entries;
  * report mode observes from byproducts only: factors stay bit-identical,
    the plan's predicted HBM traffic is unchanged (no extra pass over A),
    and a HealthReport rides on the Decomposition;
  * retry mode climbs cqr2 -> cqr3 -> householder -> f64+reseed (streamed
    plans skip householder) until the explicitly verified ||QtQ - I||_F
    meets the policy's ortho tolerance, recording every rung;
  * validate= screens the input for non-finite values, naming the offending
    panel on streamed sources, and is a bit-identical passthrough on clean
    input.

Rung pins are EMPIRICAL (this backend, these shapes): dense f32 stays on
cqr2 through kappa=1e6 and escalates once at 1e8; the f64 planner already
plans householder (single healthy rung); adaptive runs land on householder
under the default f32 tolerance because panel-accumulated CGS2 leaves
||QtQ - I||_F at a few 1e-5 — a relaxed ortho_tol pins them to cqr2.
"""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.compat import enable_x64
from repro.linalg import faults, guard


def _ill_np(m, n, kappa, seed=0):
    """Dense matrix with exactly log-spaced spectrum 1 .. 1/kappa (f64
    construction, cast by the caller)."""
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((m, min(m, n))))
    V, _ = np.linalg.qr(rng.standard_normal((n, min(m, n))))
    s = np.logspace(0.0, -np.log10(kappa), min(m, n))
    return (U * s) @ V.T


@functools.lru_cache(maxsize=None)
def _ill_f32(m, n, kappa, seed=0):
    return np.asarray(_ill_np(m, n, kappa, seed), dtype=np.float32)


@functools.lru_cache(maxsize=None)
def _gauss(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, n)).astype(np.float32)


def _same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# policy plumbing


class TestPolicy:
    def test_mode_validated(self):
        with pytest.raises(ValueError, match="guard mode"):
            guard.GuardPolicy(mode="paranoid")
        with pytest.raises(ValueError, match="max_retries"):
            guard.GuardPolicy(max_retries=-1)

    def test_as_guard_coercions(self):
        assert guard.as_guard(None).mode == "off"
        assert guard.as_guard("retry").mode == "retry"
        p = guard.GuardPolicy(mode="report")
        assert guard.as_guard(p) is p
        with pytest.raises(TypeError):
            guard.as_guard(42)

    def test_ortho_tol_defaults(self):
        p = guard.GuardPolicy(mode="retry")
        assert p.resolve_ortho_tol("float32") == pytest.approx(1e-5)
        assert p.resolve_ortho_tol("float64") == pytest.approx(1e-10)
        assert guard.GuardPolicy(ortho_tol=3e-4).resolve_ortho_tol(
            "float64") == pytest.approx(3e-4)

    def test_hashable_for_static_jit_args(self):
        # GuardPolicy rides on the frozen ExecutionPlan, which jitted
        # consumers (core/pca.py) take as a static argument
        assert hash(guard.GuardPolicy()) == hash(guard.GuardPolicy())
        assert guard.GuardPolicy() != guard.GuardPolicy(mode="retry")

    def test_escalation_methods(self):
        A = _gauss(96, 64)
        pl = linalg.plan(jnp.asarray(A), 8)
        assert guard._escalation_methods(pl) == ["cqr3", "householder"]
        pls = linalg.plan(linalg.HostOp(A, block_rows=32), 8)
        assert pls.path == "streamed"
        assert guard._escalation_methods(pls) == ["cqr3"]


class TestDescribe:
    def test_default_plan_has_no_guard_bits(self):
        d = linalg.plan(jnp.asarray(_gauss(96, 64)), 8).describe()
        assert "guard" not in d and "validate" not in d

    def test_non_default_bits_printed(self):
        d = linalg.plan(jnp.asarray(_gauss(96, 64)), 8,
                        guard="retry", validate=True).describe()
        assert "guard=retry" in d and "validate=on" in d


# ---------------------------------------------------------------------------
# report mode: zero-extra-read observation


class TestReportMode:
    def test_dense_bit_identical_with_health(self):
        A = jnp.asarray(_gauss(96, 64))
        base = linalg.svd(A, 8, seed=3)
        d = linalg.decompose(A, 8, seed=3, guard="report")
        _same(base, d.factors)
        assert d.health is not None and d.health.ok
        assert d.health.mode == "report"
        assert len(d.health.attempts) == 1
        assert d.health.final.first_pass_ortho is not None
        assert d.health.final.cond_proxy is not None

    def test_streamed_bit_identical_with_health(self):
        A = _gauss(256, 64, seed=1)
        base = linalg.svd(linalg.HostOp(A, block_rows=64), 8, seed=7)
        d = linalg.decompose(linalg.HostOp(A, block_rows=64), 8, seed=7,
                             guard="report")
        _same(base, d.factors)
        assert d.health.ok and d.health.rung_used == "cqr2"

    def test_predicted_hbm_unchanged(self):
        # the acceptance roofline assert: report probes add no reads of A
        A = jnp.asarray(_gauss(96, 64))
        off = linalg.plan(A, 8)
        rep = linalg.plan(A, 8, guard="report")
        assert rep.predicted_hbm_bytes == off.predicted_hbm_bytes

    def test_guard_off_no_health(self):
        d = linalg.decompose(jnp.asarray(_gauss(96, 64)), 8, seed=3)
        assert d.health is None

    def test_batched_source_reports(self):
        W = jnp.asarray(np.stack([_gauss(96, 64, seed=s) for s in range(3)]))
        base = linalg.svd(linalg.StackedOp(W), 8, seed=2)
        d = linalg.decompose(linalg.StackedOp(W), 8, seed=2, guard="report")
        _same(base, d.factors)
        assert d.health.ok
        assert d.health.final.first_pass_ortho is not None

    def test_report_does_not_escalate(self):
        A = jnp.asarray(_ill_f32(96, 64, 1e8))
        with faults.inject("cholesky_breakdown"):
            d = linalg.decompose(A, 8, seed=5, guard="report")
        assert not d.health.ok
        assert len(d.health.attempts) == 1
        assert d.health.final.breakdown


# ---------------------------------------------------------------------------
# retry mode: the escalation ladder (empirical rung pins)


class TestRetryLadder:
    @pytest.mark.parametrize("kappa,rungs", [
        (1e2, ("cqr2",)),
        (1e4, ("cqr2",)),
        (1e6, ("cqr2",)),
        (1e8, ("cqr2",)),
    ])
    def test_dense_f32_sweep(self, kappa, rungs):
        # at sketch width s=18 the top of a log-spaced spectrum spans only
        # ~kappa^(17/63), so kappa(Y) never crosses the CQR2 edge here and
        # every rung verifies on cqr2 — escalation under natural (unfaulted)
        # conditions is exercised by the adaptive tests below, and under
        # breakdown by TestAcceptance
        A = jnp.asarray(_ill_f32(96, 64, kappa))
        d = linalg.decompose(A, 8, seed=5, guard="retry")
        h = d.health
        assert h.ok
        assert tuple(a.rung for a in h.attempts) == rungs
        assert h.rung_used == rungs[-1]
        assert h.final.ortho_fro is not None and h.final.ortho_fro <= 1e-5

    def test_probe_fires_past_cqr2_edge(self):
        # a tighter probe_tol turns the edge-of-validity warning (probe
        # ~0.1 at kappa(Y) ~ eps^{-1/2}) into an escalation; the stronger
        # rung must then clear it
        A = jnp.asarray(_ill_f32(96, 64, 1e8))
        d = linalg.decompose(
            A, 8, seed=5, guard=linalg.GuardPolicy(mode="retry", probe_tol=0.01))
        h = d.health
        assert h.ok and h.rung_used == "cqr3"
        assert h.attempts[0].first_pass_ortho > 0.01
        assert h.attempts[1].first_pass_ortho <= 0.01

    @pytest.mark.parametrize("kappa", [1e2, 1e8])
    def test_streamed_f32_sweep(self, kappa):
        A = np.asarray(_ill_np(256, 64, kappa), dtype=np.float32)
        op = linalg.HostOp(A, block_rows=64, pipeline_depth=2)
        d = linalg.decompose(op, 8, seed=5, guard="retry")
        assert d.health.ok and d.health.rung_used == "cqr2"
        assert d.health.final.ortho_fro <= 1e-5

    @pytest.mark.parametrize("kappa", [1e2, 1e8])
    def test_adaptive_default_lands_on_householder(self, kappa):
        # panel-accumulated CGS2 leaves ||QtQ - I||_F at a few 1e-5 under
        # cqr2/cqr3, above the default f32 tolerance — the ladder tops out
        A = jnp.asarray(_ill_f32(96, 64, kappa))
        d = linalg.decompose(A, linalg.Tolerance(5e-2), seed=3, guard="retry")
        h = d.health
        assert h.ok and h.rung_used == "householder"
        assert tuple(a.rung for a in h.attempts) == (
            "cqr2", "cqr3", "householder")
        assert h.final.ortho_fro <= 1e-5

    def test_adaptive_relaxed_tol_stays_on_cqr2(self):
        A = jnp.asarray(_ill_f32(96, 64, 1e2))
        d = linalg.decompose(
            A, linalg.Tolerance(5e-2), seed=3,
            guard=linalg.GuardPolicy(mode="retry", ortho_tol=1e-3))
        assert d.health.ok
        assert tuple(a.rung for a in d.health.attempts) == ("cqr2",)

    @pytest.mark.parametrize("kappa", [1e4, 1e8])
    def test_dense_f64_planned_householder(self, kappa):
        # the planner already plans householder for f64 dense sources — the
        # first rung is healthy at the f64 tolerance, no escalation
        with enable_x64():
            A = jnp.asarray(_ill_np(96, 64, kappa))
            assert A.dtype == jnp.float64
            d = linalg.decompose(A, 8, seed=5, guard="retry")
        h = d.health
        assert h.ok and tuple(a.rung for a in h.attempts) == ("householder",)
        assert h.final.ortho_fro <= 1e-10

    def test_max_retries_bounds_the_ladder(self):
        A = jnp.asarray(_ill_f32(96, 64, 1e8))
        with faults.inject("cholesky_breakdown"):  # every cholesky rung dies
            d = linalg.decompose(
                A, 8, seed=5,
                guard=linalg.GuardPolicy(mode="retry", max_retries=1))
        assert not d.health.ok
        assert len(d.health.attempts) == 2  # first attempt + one escalation

    def test_ladder_exhausted_returns_last_flagged(self):
        A = _gauss(256, 64, seed=1)
        op = linalg.HostOp(A, block_rows=64, pipeline_depth=2)
        with faults.inject("cholesky_breakdown"):  # no householder rung to
            d = linalg.decompose(op, 8, seed=7, guard="retry")  # hide in
        assert not d.health.ok
        assert d.health.attempts[-1].breakdown

    def test_guarded_qb_eigh_pca_verify(self):
        A = _gauss(96, 64)
        for kind, src in (("qb", jnp.asarray(A)),
                          ("eigh", jnp.asarray(A.T @ A)),
                          ("pca", jnp.asarray(A))):
            d = linalg.decompose(src, 8, kind=kind, seed=2, guard="retry")
            assert d.health.ok, kind
            assert d.health.final.ortho_fro is not None, kind

    def test_guarded_lu_skips_verification(self):
        # lu has no orthonormal factor — probes still gate, verification is
        # skipped rather than failing on a triangular factor
        d = linalg.decompose(jnp.asarray(_gauss(96, 64)), 8, kind="lu",
                             seed=2, guard="retry")
        assert d.health.ok
        assert d.health.final.ortho_fro is None


class TestAcceptance:
    def test_breakdown_recovers_via_ladder(self):
        """The PR's acceptance scenario: an injected f32 Cholesky breakdown
        at kappa=1e8 forces the retry ladder through cqr2 and cqr3 (both
        poisoned) to householder, which recovers to a verified
        ||QtQ - I||_F <= 1e-5, and the report names the rung."""
        A = jnp.asarray(_ill_f32(96, 64, 1e8))
        with faults.inject("cholesky_breakdown"):
            d = linalg.decompose(A, 8, seed=5, guard="retry")
        h = d.health
        assert h.ok
        assert h.rung_used == "householder"
        assert tuple(a.rung for a in h.attempts) == (
            "cqr2", "cqr3", "householder")
        assert all(a.breakdown for a in h.attempts[:2])
        assert h.final.ortho_fro <= 1e-5
        assert "rung_used=householder" in h.describe()
        U, S, Vt = d.factors
        assert bool(jnp.isfinite(S).all())


# ---------------------------------------------------------------------------
# validate=


class TestValidate:
    def test_clean_passthrough_bit_identical(self):
        A = jnp.asarray(_gauss(96, 64))
        _same(linalg.svd(A, 8, seed=3), linalg.svd(A, 8, seed=3, validate=True))

    def test_dense_device_source_screened(self):
        A = np.array(_gauss(96, 64))
        A[10, 3] = np.inf
        with pytest.raises(ValueError, match="validate: non-finite"):
            linalg.svd(jnp.asarray(A), 8, seed=3, validate=True)

    def test_streamed_names_the_panel(self):
        A = np.array(_gauss(256, 64, seed=1))
        A[70, 3] = np.nan  # rows 64:128 -> panel 1 at block_rows=64
        op = linalg.HostOp(A, block_rows=64, pipeline_depth=2)
        with pytest.raises(ValueError, match=r"panel 1 \(rows 64:128\)"):
            linalg.svd(op, 8, seed=7, validate=True)

    def test_streamed_clean_bit_identical(self):
        A = _gauss(256, 64, seed=1)
        base = linalg.svd(linalg.HostOp(A, block_rows=64), 8, seed=7)
        val = linalg.svd(linalg.HostOp(A, block_rows=64), 8, seed=7,
                         validate=True)
        _same(base, val)

    def test_sparse_stored_values_screened(self):
        sp = pytest.importorskip("scipy.sparse")
        M = sp.random(96, 64, density=0.05, random_state=0, dtype=np.float32)
        M.data[0] = np.nan
        with pytest.raises(ValueError, match="sparse"):
            linalg.svd(linalg.SparseOp(M), 8, seed=3, validate=True)

    def test_validate_on_decompose_and_plan(self):
        A = np.array(_gauss(96, 64))
        A[0, 0] = np.nan
        pl = linalg.plan(jnp.asarray(A), 8, validate=True)
        assert pl.validate
        with pytest.raises(ValueError, match="validate"):
            linalg.decompose(jnp.asarray(A), 8, plan=pl, seed=3)
        # knob override on a pinned plan without the flag
        pl2 = linalg.plan(jnp.asarray(A), 8)
        with pytest.raises(ValueError, match="validate"):
            linalg.decompose(jnp.asarray(A), 8, plan=pl2, seed=3,
                             validate=True)


# ---------------------------------------------------------------------------
# serve-layer isolation (satellite: one bad leaf must not sink the tree)


class TestLowrankIsolation:
    def test_poisoned_leaf_stays_dense_others_compress(self):
        from repro.serve.lowrank import factorize_params

        good = _gauss(96, 64, seed=2)
        bad = np.array(_gauss(96, 64, seed=3))
        bad[5, 5] = np.nan
        params = {"a": {"w_up": jnp.asarray(good)},
                  "b": {"w_up": jnp.asarray(bad)}}
        out, report = factorize_params(params, rank=8)
        assert set(out["a"]["w_up"]) == {"lr_a", "lr_b"}  # factorized
        assert isinstance(out["b"]["w_up"], jnp.ndarray)  # kept dense
        assert np.isnan(report["b/w_up"])
        assert np.isfinite(report["a/w_up"])
