"""Roofline machinery unit tests: HLO collective parser, cost composition,
hardware-constant arithmetic."""
import json

import pytest

from repro.launch.dryrun import parse_collectives
from repro.roofline import hw
from repro.roofline.analysis import Roofline, _composed, analyze_record


def test_parse_collectives_ops_and_bytes():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[256,256]{1,0} all-reduce(%y), to_apply=%add
  %ars = f32[8]{0} all-reduce-start(%z), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%w), dimensions={0}
  %a2a = bf16[4,128]{1,0} all-to-all(%v), dimensions={0}
  %cp = s32[100]{0} collective-permute(%u), source_target_pairs={{0,1}}
  %not_a_coll = f32[9]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["count"] == 6
    assert out["per_op"]["all-gather"] == 16 * 1024 * 2
    assert out["per_op"]["all-reduce"] == 256 * 256 * 4 + 8 * 4
    assert out["per_op"]["reduce-scatter"] == 64 * 32 * 4
    assert out["per_op"]["all-to-all"] == 4 * 128 * 2
    assert out["per_op"]["collective-permute"] == 100 * 4
    assert out["total"] == sum(out["per_op"].values())


def test_composed_scan_correction():
    rec = {
        "full": {"cost": {"flops": 100.0}},
        "mini": {"cost": {"flops": 7.0}},
        "n_scan_units": 11,
    }
    assert _composed(rec, ("cost", "flops")) == 100.0 + 10 * 7.0


def test_composed_without_mini():
    rec = {"full": {"cost": {"flops": 42.0}}, "n_scan_units": 5}
    assert _composed(rec, ("cost", "flops")) == 42.0


def test_analyze_record_terms(tmp_path):
    rec = {
        "arch": "llama3.2-1b",
        "shape": "train_4k",
        "mesh": "single",
        "n_devices": 256,
        "n_scan_units": 16,
        "full": {
            "cost": {"flops": 1e14, "bytes_accessed": 1e11, "transcendentals": 0},
            "collectives": {"total": 1e9, "per_op": {}, "count": 3},
            "memory": {
                "argument_bytes": int(1e9), "output_bytes": 0,
                "temp_bytes": int(5e9), "alias_bytes": 0,
                "generated_code_bytes": 0,
            },
        },
        "analytic": {"params_total": 1.2e9, "params_active": 1.2e9,
                     "tokens": 1048576.0, "model_flops": 0},
    }
    r = analyze_record(rec)
    assert r.t_compute == pytest.approx(1e14 / hw.PEAK_FLOPS_BF16)
    assert r.t_memory == pytest.approx(1e11 / hw.HBM_BW)
    assert r.t_collective == pytest.approx(1e9 / hw.ICI_LINK_BW)
    assert r.bottleneck == "compute"
    assert 0 < r.roofline_fraction() <= 1.0
    assert r.memory_fit["hbm_gb"] == pytest.approx(hw.HBM_BYTES / 1e9)


def test_skip_record():
    r = analyze_record(
        {"arch": "phi3-mini-3.8b", "shape": "long_500k", "mesh": "single",
         "n_devices": 256, "skipped": "quadratic"}
    )
    assert r.skipped == "quadratic"
