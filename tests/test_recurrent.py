"""Recurrent-block math: chunkwise mLSTM == quadratic mLSTM, RG-LRU decode
== train-scan, hypothesis sweeps over chunk sizes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.sketch import sketch_matrix
from repro.models import recurrent as R


def _cfg():
    return get_config("xlstm-350m").reduced()


def _x(B, T, d, seed=5, scale=0.3):
    return sketch_matrix(B * T, d, seed).reshape(B, T, d) * scale


def test_mlstm_chunked_equals_quadratic():
    cfg = _cfg()
    params = R.mlstm_init(jax.random.key(0), cfg, jnp.float32)
    x = _x(2, 64, cfg.d_model)
    ref = R.mlstm_train(params, x, cfg)
    for chunk in (16, 32):
        got = R.mlstm_train_chunked(params, x, cfg, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_mlstm_chunked_state_matches_prefill_handoff():
    cfg = _cfg()
    params = R.mlstm_init(jax.random.key(1), cfg, jnp.float32)
    x = _x(2, 128, cfg.d_model, seed=7)
    _, st_ref = R.mlstm_train(params, x, cfg, return_state=True)
    _, st_chk = R.mlstm_train_chunked(params, x, cfg, chunk=32, return_state=True)
    np.testing.assert_allclose(np.asarray(st_ref.C), np.asarray(st_chk.C), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref.n), np.asarray(st_chk.n), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref.m), np.asarray(st_chk.m), atol=2e-5, rtol=1e-4)


@pytest.mark.slow
def test_rglru_decode_continues_train():
    """prefill state hand-off + decode steps == training scan on the longer seq."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = R.rglru_init(jax.random.key(2), cfg, jnp.float32)
    x = _x(2, 40, cfg.d_model, seed=9)
    full = R.rglru_train(params, x, cfg)

    out_pre, state = R.rglru_train(params, x[:, :36], cfg, return_state=True)
    outs = [out_pre]
    for t in range(36, 40):
        o, state = R.rglru_decode(params, x[:, t : t + 1], state, cfg)
        outs.append(o)
    stitched = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(full), atol=2e-5, rtol=1e-4)


@settings(deadline=None, max_examples=8)
@given(T=st.sampled_from([64]), chunk=st.sampled_from([16, 32]), seed=st.integers(0, 100))
def test_mlstm_chunk_invariance_property(T, chunk, seed):
    cfg = _cfg()
    params = R.mlstm_init(jax.random.key(3), cfg, jnp.float32)
    x = _x(1, T, cfg.d_model, seed=seed)
    ref = R.mlstm_train(params, x, cfg)
    if T % chunk:
        return
    got = R.mlstm_train_chunked(params, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=5e-5, rtol=5e-4)
