"""Execution-planner golden tests + plan/roofline/facade properties.

Golden table: (source, dtype, overrides) -> expected ExecutionPlan fields,
including the paper's benchmark shapes, where `plan()` must reproduce the
historical `fast()` / `streaming()` dispatch decisions (the VMEM gate that
un-fuses the power step at 8192x8192 included).  Plans are shape-only, so
the big shapes use jax.ShapeDtypeStruct — nothing is allocated.

Properties:
  * every plan's predicted HBM bytes equals the roofline model
    (repro/roofline/rsvd_model.py) evaluated at the plan's own fields;
  * `linalg.svd` on DenseOp / HostOp / StackedOp / ShardedOp returns
    BIT-identical factors to the pre-facade dense / blocked / batched /
    distributed implementations at fixed seed;
  * CenteredOp-based PCA equals `pca_exact` on small inputs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core.rsvd import RSVDConfig
from repro.core.spectra import make_test_matrix
from repro.roofline import rsvd_model


def _sds(m, n, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((m, n), dtype)


# ---------------------------------------------------------------------------
# Golden dispatch table
# ---------------------------------------------------------------------------

# (label, op-builder, overrides, expected plan fields)
GOLDEN = [
    # The paper's benchmark shapes under the fast() preset: fused everywhere
    # the VMEM budget allows, unfused where the n x s accumulators blow it.
    ("fast_2000", lambda: linalg.DenseOp(_sds(2000, 2000)), RSVDConfig.fast(), 90,
     dict(path="dense", fused_power=True, fused_sketch=True,
          kernel_backend="pallas", qr_method="cqr2", s=100, pipeline_depth=1)),
    ("fast_8192_vmem_gate", lambda: linalg.DenseOp(_sds(8192, 8192)), RSVDConfig.fast(), 246,
     dict(path="dense", fused_power=False, fused_sketch=True,
          kernel_backend="pallas", s=256)),
    ("fast_65536x4096", lambda: linalg.DenseOp(_sds(65536, 4096)), RSVDConfig.fast(), 118,
     dict(path="dense", fused_power=True, m=65536, n=4096, s=128)),
    # streaming() preset: panel-streamed, CQR2, no fusion of the power step
    # streamed plans double-buffer the panel prefetch by default (the
    # quarter-HBM budget fits 2 staging panels comfortably at this shape)
    ("streaming_65536x4096", lambda: linalg.DenseOp(_sds(65536, 4096)),
     RSVDConfig.streaming(), 118,
     dict(path="streamed", block_rows=4096, qr_method="cqr2",
          small_svd="lapack", fused_power=False, pipeline_depth=2)),
    # an explicit depth override is the starting point (still clamped by the
    # panel count AND the quarter-HBM budget rule; 3 x 64MB panels fit here)
    ("streaming_depth_override", lambda: linalg.DenseOp(_sds(65536, 4096)),
     dataclasses.replace(RSVDConfig.streaming(), pipeline_depth=3), 118,
     dict(path="streamed", pipeline_depth=3)),
    # f64 faithful: everything un-fused, jnp backend (paper's dgesvd setting)
    ("faithful_f64", lambda: linalg.DenseOp(_sds(300, 200, jnp.float64)),
     RSVDConfig.faithful(), 20,
     dict(path="dense", fused_power=False, fused_sketch=False,
          kernel_backend="jnp", qr_method="householder", dtype="float64")),
    # wide input: the plan records the post-orientation (tall) dims
    ("wide_orientation", lambda: linalg.DenseOp(_sds(128, 4096)), RSVDConfig(), 16,
     dict(path="dense", m=4096, n=128, s=26)),
    # 3-D stack -> batched, power fusion never applies under vmap
    ("stacked", lambda: linalg.StackedOp(jnp.zeros((4, 128, 64))), RSVDConfig.fast(), 8,
     dict(path="batched", batch=4, fused_power=False)),
    # explicit batched override on 2-D input still PLANS batched (execution
    # raises, matching the historical loud failure)
    ("batched_flag", lambda: linalg.DenseOp(_sds(128, 64)),
     RSVDConfig(batched=True), 8, dict(path="batched")),
]


@pytest.mark.parametrize("label,mk_op,overrides,k,expect",
                         GOLDEN, ids=[g[0] for g in GOLDEN])
def test_plan_golden(label, mk_op, overrides, k, expect):
    pl = linalg.plan(mk_op(), k, overrides=overrides)
    for field, want in expect.items():
        assert getattr(pl, field) == want, (label, field, getattr(pl, field), want)


def test_plan_streamed_default_panel_sized_on_oriented_rows():
    """Wide host sources stream A.T, so the default panel-shrink must size
    panels by the SHORT dim — a (1024 x 1e6) host array keeps the 4096
    default instead of over-shrinking to the 256 floor."""
    wide = linalg.DenseOp(_sds(1024, 1_000_000),
                          block_rows=linalg.HostOp.DEFAULT_BLOCK_ROWS)
    pl = linalg.plan(wide, 16)
    assert pl.path == "streamed" and pl.block_rows == linalg.HostOp.DEFAULT_BLOCK_ROWS


def test_plan_defaults_host_source_streams():
    A_host = np.zeros((512, 96), np.float32)
    pl = linalg.plan(linalg.HostOp(A_host, block_rows=128), 8)
    assert pl.path == "streamed" and pl.block_rows == 128
    # and without an explicit panel height the streaming default applies
    pl2 = linalg.plan(linalg.HostOp(A_host), 8)
    assert pl2.path == "streamed" and pl2.block_rows == linalg.HostOp.DEFAULT_BLOCK_ROWS


def test_plan_defaults_composed_source_is_matfree():
    op = linalg.CenteredOp(linalg.DenseOp(jnp.zeros((64, 16))))
    pl = linalg.plan(op, 4)
    assert pl.path == "matfree" and not pl.fused_power and not pl.fused_sketch


def test_plan_sharded_source():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pl = linalg.plan(linalg.ShardedOp(_sds(256, 64), mesh, "data"), 8)
    assert pl.path == "sharded"


def test_plan_sharded_records_what_the_shard_body_executes():
    """The shard_map body hardcodes CQR2 + LAPACK small SVD + materialized
    per-shard Omega; a fast() override must not make the plan claim
    gram_jacobi or a fused sketch that never runs."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    op = linalg.ShardedOp(_sds(256, 64), mesh, "data")
    pl = linalg.plan(op, 8, overrides=RSVDConfig.fast())
    assert pl.small_svd == "lapack" and pl.qr_method == "cqr2"
    assert not pl.fused_sketch and not pl.fused_power


def test_plan_f64_records_jnp_backend():
    """qr.py vetoes the fp32-accumulating Pallas primitives for float64, so
    an f64 plan must record kernel_backend='jnp' even under fast()."""
    pl = linalg.plan(linalg.DenseOp(_sds(300, 200, jnp.float64)), 20,
                     overrides=RSVDConfig.fast())
    assert pl.kernel_backend == "jnp" and not pl.fused_sketch


def test_plan_vmem_budget_is_honored():
    """Shrinking the budget must flip the 2000x2000 fast() plan to unfused —
    the same gate the dense body applies, parameterized by Budget."""
    op = linalg.DenseOp(_sds(2000, 2000))
    tight = linalg.Budget(vmem_bytes=1 << 20)
    assert linalg.plan(op, 90, overrides=RSVDConfig.fast()).fused_power
    assert not linalg.plan(op, 90, budget=tight, overrides=RSVDConfig.fast()).fused_power


def test_plan_vmem_budget_cannot_loosen_past_kernel_limit():
    """A LOOSER budget must not make the plan claim a fusion the dense
    body's compiled-in VMEM gate would refuse at trace time (the plan is a
    record of what executes, never a wish)."""
    op = linalg.DenseOp(_sds(8192, 8192))
    loose = linalg.Budget(vmem_bytes=1 << 30)
    pl = linalg.plan(op, 246, budget=loose, overrides=RSVDConfig.fast())
    assert not pl.fused_power


def test_protocol_only_source_runs_matfree_even_with_overrides():
    """A user-defined LinOp (no .array) must plan matfree whether or not
    overrides pin the numerical variant."""

    class GramOp(linalg.LinOp):
        def __init__(self, A):
            self._A = A

        @property
        def shape(self):
            return tuple(self._A.shape)

        @property
        def dtype(self):
            return self._A.dtype

        def matmat(self, X):
            return self._A @ X

        def rmatmat(self, Y):
            return self._A.T @ Y

    A, sig = make_test_matrix(200, 64, "fast", seed=9)
    op = GramOp(A)
    cfg = RSVDConfig(power_iters=1, qr_method="cqr2")
    assert linalg.plan(op, 8).path == "matfree"
    assert linalg.plan(op, 8, overrides=cfg).path == "matfree"
    U, S, Vt = linalg.svd(op, 8, overrides=cfg, seed=1)
    err = float(linalg.residual(A, (U, S, Vt)))
    from repro.core import truncation_error

    assert err <= 1.10 * float(truncation_error(sig, 8)) + 1e-6


def test_eigvals_matfree_sigma_only_matches_svd():
    op = linalg.CenteredOp(linalg.DenseOp(make_test_matrix(96, 32, "fast", seed=10)[0]))
    S_full = linalg.svd(op, 6, seed=2)[1]
    S_only = linalg.eigvals(op, 6, seed=2)
    np.testing.assert_array_equal(np.asarray(S_only), np.asarray(S_full))


def test_hostop_keeps_streaming_under_numerical_overrides():
    """Overrides that pin only the numerical variant (no block_rows) must
    not collapse an explicit HostOp onto the wholesale-dense path."""
    A_host = np.asarray(make_test_matrix(256, 48, "fast", seed=11)[0])
    op = linalg.HostOp(A_host, block_rows=64)
    cfg = RSVDConfig(power_iters=1, qr_method="cqr2")  # no execution switches
    pl = linalg.plan(op, 8, overrides=cfg)
    assert pl.path == "streamed" and pl.block_rows == 64
    U, S, Vt = linalg.svd(op, 8, overrides=cfg, seed=0)
    assert float(linalg.residual(op, (U, S, Vt))) < 0.2


def test_pca_dense_path_is_jitted_and_matches_eager():
    """Device-array PCA runs one compiled program (seed traced — sweeps
    don't recompile) and equals the eager CenteredOp pipeline."""
    from repro.linalg.api import _pca_centered_dense

    X = make_test_matrix(96, 32, "fast", seed=12)[0] + 0.25
    r0 = linalg.pca(X, 4, seed=0)
    size0 = _pca_centered_dense._cache_size()
    r1 = linalg.pca(X, 4, seed=1)
    assert _pca_centered_dense._cache_size() == size0  # traced seed, no recompile
    eager = linalg.svd(linalg.CenteredOp(linalg.DenseOp(X)), 4, seed=1)
    np.testing.assert_allclose(np.asarray(r1.singular_values), np.asarray(eager[1]),
                               rtol=1e-5)
    assert r0.components.shape == (4, 32)


def test_plan_matches_dense_body_gate():
    """plan().fused_power must agree with core.rsvd._use_fused_power (the
    dense body's trace-time gate) on a sweep of shapes."""
    from repro.core.rsvd import _use_fused_power

    cfg = RSVDConfig.fast()
    for m, n in [(256, 128), (2000, 2000), (8192, 8192), (4096, 512), (512, 4096)]:
        k = 16
        mt, nt = max(m, n), min(m, n)
        s = min(k + cfg.oversample, nt)
        pl = linalg.plan(linalg.DenseOp(_sds(m, n)), k, overrides=cfg)
        want = _use_fused_power(_sds(mt, nt), cfg, s)
        assert pl.fused_power == want, (m, n, pl.fused_power, want)


# ---------------------------------------------------------------------------
# Property: predicted HBM bytes == the roofline model at the plan's fields
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk_op,overrides,k", [
    (lambda: linalg.DenseOp(_sds(2000, 2000)), RSVDConfig.fast(), 90),
    (lambda: linalg.DenseOp(_sds(8192, 8192)), RSVDConfig.fast(), 246),
    (lambda: linalg.DenseOp(_sds(128, 4096)), RSVDConfig(), 16),
    (lambda: linalg.DenseOp(_sds(300, 200, jnp.float64)), RSVDConfig.faithful(), 20),
    (lambda: linalg.StackedOp(jnp.zeros((4, 128, 64))), RSVDConfig(), 8),
    (lambda: linalg.DenseOp(_sds(65536, 4096)), RSVDConfig.streaming(), 118),
])
def test_predicted_bytes_match_roofline_model(mk_op, overrides, k):
    pl = linalg.plan(mk_op(), k, overrides=overrides)
    want = rsvd_model.predicted_hbm_bytes(
        pl.m, pl.n, pl.s, pl.power_iters, pl.fused_power, pl.fused_sketch,
        dtype_bytes=jnp.dtype(pl.dtype).itemsize, batch=pl.batch,
    )
    assert pl.predicted_hbm_bytes == want
    # the walltime prediction comes from the SAME model, at the plan's own
    # fields: the overlap model for streamed plans, HBM bandwidth elsewhere
    if pl.path == "streamed":
        want_t = rsvd_model.streamed_walltime_s(
            pl.m, pl.n, pl.s, pl.block_rows, pl.power_iters, pl.pipeline_depth,
            dtype_bytes=jnp.dtype(pl.dtype).itemsize, fused_sketch=pl.fused_sketch,
        )
    else:
        want_t = rsvd_model.hbm_walltime_s(pl.predicted_hbm_bytes)
    assert pl.predicted_walltime_s == want_t
    # and the fused plan must predict strictly less traffic than unfused
    if pl.fused_power:
        unfused = rsvd_model.predicted_hbm_bytes(
            pl.m, pl.n, pl.s, pl.power_iters, False, False,
            dtype_bytes=jnp.dtype(pl.dtype).itemsize, batch=pl.batch)
        assert pl.predicted_hbm_bytes < unfused


# ---------------------------------------------------------------------------
# Acceptance: facade factors are BIT-identical to the pre-facade paths
# ---------------------------------------------------------------------------

def _assert_same(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_denseop_bit_identical_to_dense_path():
    from repro.core import rsvd as rsvd_mod

    A, _ = make_test_matrix(256, 96, "fast", seed=1)
    cfg = RSVDConfig(power_scheme="stabilized", qr_method="cqr2")
    got = linalg.svd(linalg.DenseOp(A), 10, overrides=cfg, seed=3)
    want = rsvd_mod._randomized_svd_dense(A, jnp.uint32(3), 10, cfg)
    _assert_same(got, want)


def test_hostop_bit_identical_to_blocked_path():
    from repro.core.blocked import svd_streamed

    A_host = np.asarray(make_test_matrix(300, 64, "fast", seed=2)[0])
    cfg = RSVDConfig.streaming(block_rows=100)
    got = linalg.svd(linalg.HostOp(A_host, block_rows=100), 8, overrides=cfg, seed=1)
    want = svd_streamed(A_host, 8, cfg, seed=1)
    _assert_same(got, want)


def test_stackedop_bit_identical_to_batched_path():
    from repro.core.blocked import svd_batched

    A = jnp.stack([make_test_matrix(96, 48, "fast", seed=3 + i)[0] for i in range(3)])
    cfg = RSVDConfig()
    got = linalg.svd(linalg.StackedOp(A), 6, overrides=cfg, seed=4)
    want = svd_batched(A, 6, cfg, seed=4)
    _assert_same(got, want)


def test_shardedop_bit_identical_to_distributed_path():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import svd_sharded

    n_dev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    A, _ = make_test_matrix(32 * n_dev, 64, "fast", seed=5)
    A_sharded = jax.device_put(A, NamedSharding(mesh, P("data", None)))
    cfg = RSVDConfig(power_iters=1)
    got = linalg.svd(linalg.ShardedOp(A_sharded, mesh, "data"), 8, overrides=cfg, seed=0)
    want = svd_sharded(A_sharded, 8, mesh, "data", cfg, seed=0)
    _assert_same(got, want)


# ---------------------------------------------------------------------------
# Property: CenteredOp-based PCA == pca_exact on small inputs
# ---------------------------------------------------------------------------

def test_centered_pca_matches_exact():
    from repro.core.pca import pca_exact

    X, _ = make_test_matrix(160, 40, "fast", seed=7)
    X = X + 0.5  # a nonzero mean so the centering actually matters
    k = 5
    res = linalg.pca(X, k)
    exact = pca_exact(X, k)
    np.testing.assert_allclose(np.asarray(res.mean), np.asarray(exact.mean), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(res.explained_variance), np.asarray(exact.explained_variance),
        rtol=2e-3,
    )
    # the spanned subspace agrees: compare the basis-invariant projectors
    P_got = np.asarray(res.components).T @ np.asarray(res.components)
    P_want = np.asarray(exact.components).T @ np.asarray(exact.components)
    np.testing.assert_allclose(P_got, P_want, atol=2e-3)


def test_centered_pca_streams_host_input():
    """The centered HOST source: mu and the factors come out right without
    the centered matrix (or X itself) ever being device-resident whole."""
    from repro.core.pca import pca_exact

    X = np.asarray(make_test_matrix(256, 32, "fast", seed=8)[0]) + 1.0
    res = linalg.pca(linalg.HostOp(X, block_rows=64), 4)
    exact = pca_exact(jnp.asarray(X), 4)
    np.testing.assert_allclose(np.asarray(res.mean), np.asarray(exact.mean), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(res.singular_values), np.asarray(exact.singular_values), rtol=5e-3
    )
