"""Overlapped out-of-core pipeline: bit-identity, depth semantics, donation.

The contract under test (DESIGN.md §Pipeline): prefetch reorders TRANSFERS,
never arithmetic —

  * panel walks / streamed SVD / adaptive QB at depths 1, 2, 3 are
    BIT-identical on HostOp and composed (CenteredOp) sources, dividing and
    odd-tail panel shapes alike;
  * depth 1 degrades to the pre-pipeline synchronous behavior;
  * adaptive QB early-stopping mid-stream abandons in-flight prefetch
    cleanly (same rank, same estimator trajectory at every depth);
  * the donated per-panel update steps (core/blocked.py, core/adaptive.py)
    really alias their accumulator in the compiled HLO — the peak-memory
    parity check;
  * the planner's depth selection follows the quarter-HBM budget rule and
    stamps a walltime from the overlap model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core.blocked import svd_streamed
from repro.core.rsvd import RSVDConfig
from repro.core.spectra import make_test_matrix
from repro.linalg import pipeline, prefetch_panels
from repro.roofline import rsvd_model


def _host(m, n, seed=0, kind="fast"):
    return np.asarray(make_test_matrix(m, n, kind, seed=seed)[0])


def _assert_bit_identical(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Panel-walk bit-identity (the primitive everything else rides)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,block", [(256, 64), (250, 64), (130, 32), (96, 96)])
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_prefetch_panels_bit_identical_hostop(m, block, depth):
    """Staged-ring panels == synchronous panels, odd tails included
    (250/64 and 130/32 leave ragged last panels the ring zero-pads)."""
    A = _host(m, 48, seed=1)
    op = linalg.HostOp(A, block_rows=block)
    sync = [np.asarray(p) for p in op.row_panels(block)]
    pre = [np.asarray(p) for p in prefetch_panels(op, block, depth)]
    assert len(sync) == len(pre)
    for s, p in zip(sync, pre):
        np.testing.assert_array_equal(s, p)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_prefetch_panels_bit_identical_composed(depth):
    """CenteredOp over a host source: the BASE transfer is what prefetches;
    the per-panel centering rides the already-staged device panel."""
    A = _host(200, 24, seed=2) + 1.0
    cop = linalg.CenteredOp(linalg.HostOp(A, block_rows=48))
    sync = [np.asarray(p) for p in cop.row_panels(48)]
    pre = [np.asarray(p) for p in prefetch_panels(cop, 48, depth)]
    for s, p in zip(sync, pre):
        np.testing.assert_array_equal(s, p)


def test_prefetch_ring_reuse_many_panels():
    """More panels than depth slots: every slot is reused multiple times and
    no panel is corrupted by a later occupant (the staging-ring guard)."""
    A = np.arange(512 * 8, dtype=np.float32).reshape(512, 8)
    got = list(prefetch_panels(linalg.HostOp(A, block_rows=32), 32, 2))
    assert len(got) == 16
    for i, p in enumerate(got):
        np.testing.assert_array_equal(np.asarray(p), A[i * 32 : (i + 1) * 32])


def test_depth_one_is_the_synchronous_walk():
    """Depth 1 must degrade to today's behavior: plain `jnp.asarray(slice)`
    per panel, no staging ring, no lookahead queue."""
    A = _host(128, 16, seed=3)
    bounds = pipeline.panel_bounds(128, 32)
    out = list(pipeline.stream_host_panels(A, bounds, 1))
    for (lo, hi), p in zip(bounds, out):
        np.testing.assert_array_equal(np.asarray(p), A[lo:hi])
    # lookahead(it, 1) is a pass-through of the same iterator items
    items = [object() for _ in range(5)]
    assert list(pipeline.lookahead(iter(items), 1)) == items


def test_default_depth_resolution():
    """Explicit depth > ambient scope > source attribute > backend-aware
    auto (host-resident sources double-buffer on real accelerators; on a
    CPU host there is no link to overlap, so auto stays 1)."""
    auto_host = 1 if jax.default_backend() == "cpu" else pipeline.DEFAULT_DEPTH
    assert pipeline.resolve_depth(3, host_resident=False) == 3
    assert pipeline.resolve_depth(None, host_resident=True) == auto_host
    assert pipeline.resolve_depth(None, host_resident=False) == 1
    with pipeline.default_depth(4):
        assert pipeline.resolve_depth(None, host_resident=False) == 4
        assert pipeline.resolve_depth(2, host_resident=False) == 2
        # the ambient (plan-decided, budget-clamped) depth outranks a
        # source's own pipeline_depth attribute
        assert pipeline.resolve_depth(None, source_default=3) == 4
    assert pipeline.resolve_depth(None, source_default=3) == 3
    assert pipeline.resolve_depth(None, host_resident=False) == 1


# ---------------------------------------------------------------------------
# Streamed SVD bit-identity across depths (HostOp end to end)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [100, 128])  # 100: odd tail panel
def test_svd_streamed_prefetch_bit_identical(block_rows):
    A = _host(300, 64, seed=4)
    cfg = RSVDConfig.streaming(block_rows=block_rows)
    base = svd_streamed(A, 8, cfg, seed=1, pipeline_depth=1)
    for depth in (2, 3):
        got = svd_streamed(A, 8, cfg, seed=1, pipeline_depth=depth)
        _assert_bit_identical(got, base)


def test_facade_streamed_plan_prefetch_bit_identical():
    """The planned (depth-2) facade solve == the forced-synchronous solve.
    The streaming preset pins depth 2 explicitly — the backend-aware
    default would stay synchronous on this CPU test host."""
    A = _host(300, 48, seed=5)
    op = linalg.HostOp(A, block_rows=64)
    pl = linalg.plan(op, 8, overrides=RSVDConfig.streaming(block_rows=64))
    assert pl.path == "streamed" and pl.pipeline_depth == 2
    got = linalg.svd(op, 8, plan=pl, seed=3)
    sync = linalg.svd(op, 8, seed=3,
                      overrides=dataclasses.replace(
                          RSVDConfig.streaming(block_rows=64), pipeline_depth=1))
    _assert_bit_identical(got, sync)


def test_centered_matfree_prefetch_bit_identical():
    """Composed-over-host matfree path: ambient depth changes nothing but
    transfer timing."""
    A = _host(256, 32, seed=6) + 0.5
    op = linalg.CenteredOp(linalg.HostOp(A, block_rows=64))
    runs = []
    for depth in (1, 2, 3):
        with pipeline.default_depth(depth):
            runs.append(linalg.svd(op, 6, seed=2))
    _assert_bit_identical(runs[0], runs[1])
    _assert_bit_identical(runs[0], runs[2])


# ---------------------------------------------------------------------------
# Adaptive QB: early stop mid-pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [2, 3])
def test_adaptive_early_stop_discards_inflight_prefetch(depth):
    """A Tolerance solve on a host source stops growing panels the moment
    the estimator clears eps — with prefetch in flight.  The abandoned
    transfers must not perturb ANYTHING: same executed rank, same estimator
    trajectory, same factors as the synchronous run."""
    A = _host(256, 96, seed=7, kind="sharp")
    spec = linalg.Tolerance(1e-2, panel=16)
    sync = linalg.decompose(linalg.HostOp(A, block_rows=64), spec, seed=0,
                            overrides=RSVDConfig(pipeline_depth=1))
    over = linalg.decompose(linalg.HostOp(A, block_rows=64), spec, seed=0,
                            overrides=RSVDConfig(pipeline_depth=depth))
    assert over.plan.pipeline_depth == depth
    # the solve stopped early (otherwise nothing was in flight to discard)
    assert len(over.rank_history) < len(over.plan.rank_schedule)
    assert over.rank == sync.rank
    assert over.rank_history == sync.rank_history
    assert over.err_history == sync.err_history
    _assert_bit_identical(over.factors, sync.factors)


# ---------------------------------------------------------------------------
# Donation: the compiled HLO really aliases the accumulator buffer
# ---------------------------------------------------------------------------

def _alias_bytes(jitted, *args):
    compiled = jitted.lower(*args).compile()
    return compiled.memory_analysis().alias_size_in_bytes


def test_donated_updates_alias_accumulator_buffer():
    """Peak-memory parity: each donated per-panel update step must reuse its
    accumulator's buffer (alias bytes == accumulator bytes), i.e. the
    compiled program allocates NO fresh output for the carry."""
    from repro.core import adaptive, blocked

    acc = jnp.zeros((64, 16), jnp.float32)
    x = jnp.ones((64, 16), jnp.float32)
    assert _alias_bytes(blocked._add_donated, acc, x) == acc.nbytes

    Z = jnp.zeros((48, 16), jnp.float32)
    Ap = jnp.ones((32, 48), jnp.float32)
    Qp = jnp.ones((32, 16), jnp.float32)
    assert _alias_bytes(blocked._accum_xty, Z, Ap, Qp) == Z.nbytes

    G = jnp.zeros((16, 16), jnp.float32)
    Yp = jnp.ones((32, 16), jnp.float32)
    compiled = blocked._gram_accum.lower(G, Yp, backend="jnp").compile()
    assert compiled.memory_analysis().alias_size_in_bytes == G.nbytes

    Y = jnp.zeros((64, 8), jnp.float32)
    Q = jnp.ones((64, 24), jnp.float32)
    assert _alias_bytes(adaptive._deflate_step, Y, Q) == Y.nbytes


def test_donated_update_matches_undonated():
    """Donation must not change a single bit of the update arithmetic."""
    from repro.core import blocked

    rng = np.random.RandomState(11)
    Z0 = jnp.asarray(rng.randn(24, 8).astype(np.float32))
    Ap = jnp.asarray(rng.randn(16, 24).astype(np.float32))
    Qp = jnp.asarray(rng.randn(16, 8).astype(np.float32))
    want = np.asarray(Z0 + Ap.T @ Qp)
    got = np.asarray(blocked._accum_xty(Z0, Ap, Qp))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Planner: depth selection + overlap-model walltime
# ---------------------------------------------------------------------------

def test_plan_depth_shrinks_under_tight_hbm_budget():
    """The quarter-HBM rule that sizes panels also caps the staging ring:
    a budget that fits one panel but not two forces depth 1 (synchronous)."""
    op = linalg.DenseOp(jax.ShapeDtypeStruct((65536, 4096), jnp.float32),
                        block_rows=4096)
    panel_bytes = 4096 * 4096 * 4
    tight = linalg.Budget(hbm_bytes=panel_bytes * 4)      # quarter = 1 panel
    roomy = linalg.Budget(hbm_bytes=panel_bytes * 8)      # quarter = 2 panels
    assert linalg.plan(op, 16, budget=tight,
                       overrides=RSVDConfig.streaming()).pipeline_depth == 1
    assert linalg.plan(op, 16, budget=roomy,
                       overrides=RSVDConfig.streaming()).pipeline_depth == 2


def test_plan_depth_clamped_to_panel_count():
    """A single-panel stream has nothing to prefetch: depth collapses to 1
    even when explicitly asked for more."""
    A = _host(64, 32, seed=8)
    pl = linalg.plan(linalg.HostOp(A, block_rows=128), 8,
                     overrides=dataclasses.replace(
                         RSVDConfig.streaming(block_rows=128), pipeline_depth=4))
    assert pl.pipeline_depth == 1


def test_overlap_walltime_model_shape():
    """The overlap model's structural properties: depth 2 is never slower
    than depth 1, is bounded below by both the pure-transfer and the
    pure-compute time, and equals the plan's stamped prediction."""
    m, n, s, block, q = 65536, 4096, 128, 4096, 2
    sync_t = rsvd_model.streamed_walltime_s(m, n, s, block, q, 1)
    over_t = rsvd_model.streamed_walltime_s(m, n, s, block, q, 2)
    assert over_t < sync_t
    from repro.roofline import hw
    passes = rsvd_model.streamed_pass_count(q)
    transfer_total = passes * m * n * 4 / hw.HOST_LINK_BW
    compute_total = rsvd_model.hbm_walltime_s(
        rsvd_model.predicted_hbm_bytes(m, n, s, q, False, False))
    assert over_t >= max(transfer_total, compute_total) * 0.99
    assert sync_t >= transfer_total + compute_total * 0.99
    pl = linalg.plan(linalg.DenseOp(jax.ShapeDtypeStruct((m, n), jnp.float32)),
                     118, overrides=RSVDConfig.streaming())
    assert pl.predicted_walltime_s == rsvd_model.streamed_walltime_s(
        pl.m, pl.n, pl.s, pl.block_rows, pl.power_iters, pl.pipeline_depth,
        dtype_bytes=4, fused_sketch=pl.fused_sketch)


def test_dense_lazy_row_panels_no_copy():
    """DenseOp.row_panels on a device array yields lazy slices — no
    re-wrap copy; HostOp keeps the host->device move per panel."""
    A = jnp.asarray(_host(128, 16, seed=9))
    op = linalg.DenseOp(A, block_rows=64)
    panels = list(op.row_panels(64))
    assert all(isinstance(p, jax.Array) for p in panels)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(panels)),
                                  np.asarray(A))
