"""Parallel-order Jacobi eigensolver vs LAPACK eigh, incl. hypothesis sweep."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.eigh_jacobi import jacobi_eigh, svd_via_gram
from repro.core.sketch import sketch_matrix


def _sym(n, seed, scale=1.0):
    G = np.asarray(sketch_matrix(n, n, seed))
    return jnp.asarray((G + G.T) / 2 * scale)


@pytest.mark.parametrize(
    "n", [2, 3, 8, 17, 32, pytest.param(64, marks=pytest.mark.slow)]
)
def test_matches_eigh(n):
    A = _sym(n, seed=n)
    w, V = jacobi_eigh(A)
    w_ref = np.linalg.eigvalsh(np.asarray(A))[::-1]
    np.testing.assert_allclose(np.asarray(w), w_ref, atol=1e-4 * max(1, n))
    # eigen-equation residual
    resid = np.asarray(A @ V - V * w[None, :])
    assert np.abs(resid).max() < 1e-3
    # orthonormal eigenvectors
    np.testing.assert_allclose(np.asarray(V.T @ V), np.eye(n), atol=1e-4)


def test_diagonal_matrix_is_fixed_point():
    d = jnp.asarray([5.0, 3.0, 1.0, -2.0])
    w, V = jacobi_eigh(jnp.diag(d))
    np.testing.assert_allclose(np.asarray(w), [5.0, 3.0, 1.0, -2.0], atol=1e-6)
    np.testing.assert_allclose(np.abs(np.asarray(V)), np.eye(4), atol=1e-6)


def test_svd_via_gram_matches_lapack():
    B = sketch_matrix(24, 100, seed=3)
    U, S, Vt = svd_via_gram(B, use_jacobi=True)
    S_ref = np.linalg.svd(np.asarray(B), compute_uv=False)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-3)
    recon = np.asarray((U * S[None, :]) @ Vt)
    np.testing.assert_allclose(recon, np.asarray(B), atol=2e-3)


@settings(deadline=None, max_examples=15)
@given(n=st.integers(2, 48), seed=st.integers(0, 2**16), scale=st.floats(0.01, 100.0))
def test_jacobi_eigensystem_property(n, seed, scale):
    A = _sym(n, seed, scale)
    w, V = jacobi_eigh(A)
    # trace and Frobenius norm are rotation invariants
    assert np.isclose(float(jnp.sum(w)), float(jnp.trace(A)), rtol=1e-3, atol=1e-3 * scale)
    assert np.isclose(
        float(jnp.sum(w**2)), float(jnp.sum(A * A)), rtol=1e-3, atol=1e-3 * scale**2
    )
