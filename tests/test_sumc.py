"""Subspace clustering (SuMC) reproduction: ARI=1.0 on paper-style data, and
the randomized solver must agree with the dense eigensolver."""
import numpy as np
import pytest

from repro.core.sumc import (
    adjusted_rand_index,
    eigh_solver,
    rsvd_solver,
    sumc,
    synthetic_subspace_data,
)


def test_ari_metric():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(a, a) == 1.0
    assert adjusted_rand_index(a, 1 - a % 2) < 1.0
    # permutation-invariant
    assert adjusted_rand_index(a, (a + 1) % 3) == 1.0


@pytest.mark.parametrize(
    "solver",
    [pytest.param(eigh_solver, marks=pytest.mark.slow), rsvd_solver],
    ids=["eigh", "rsvd"],  # rsvd (the paper's solver) stays tier-1
)
def test_sumc_recovers_subspaces(solver):
    """Scaled-down paper Table 1 'first' dataset: exact subspaces -> ARI 1.0."""
    X, y = synthetic_subspace_data(
        sizes=[120, 160, 200], dims=[5, 8, 11], ambient=64, seed=0
    )
    res = sumc(X, n_clusters=3, subspace_dims=[5, 8, 11], solver=solver, seed=1)
    ari = adjusted_rand_index(res.labels, y)
    assert ari == 1.0, ari
    assert res.solver_calls > 0


def test_solver_call_counting_and_convergence():
    X, y = synthetic_subspace_data(sizes=[80, 80], dims=[4, 6], ambient=32, seed=2)
    res = sumc(
        X, n_clusters=2, subspace_dims=[4, 6], solver=rsvd_solver, seed=3, n_init=5
    )
    # at most one solver call per cluster per iteration per restart
    assert 0 < res.solver_calls <= 2 * 50 * 5
    # monotone non-increasing cost after first refit (within the winning run)
    costs = res.cost_history
    assert all(b <= a * (1 + 1e-5) for a, b in zip(costs, costs[1:]))
