"""LinOp operator sources: algebra vs dense references, panel iteration,
composed operators (scaled / centered / low-rank update / deflation), and
the panel-wise residual."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core import low_rank_error
from repro.core.spectra import make_test_matrix


def _rand(m, n, seed):
    from repro.core.sketch import sketch_matrix

    return sketch_matrix(m, n, seed)


# ---------------------------------------------------------------------------
# sources: matmat / rmatmat / row_panels vs the dense array
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wrap", [
    lambda A: linalg.DenseOp(A),
    lambda A: linalg.HostOp(np.asarray(A), block_rows=40),
])
def test_source_products_match_dense(wrap):
    A = _rand(100, 36, 0)
    X = _rand(36, 7, 1)
    Y = _rand(100, 7, 2)
    op = wrap(A)
    assert op.shape == (100, 36) and jnp.dtype(op.dtype) == jnp.float32
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(A @ X),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(op.rmatmat(Y)), np.asarray(A.T @ Y),
                               atol=1e-5, rtol=1e-5)
    # panels tile the rows exactly
    stacked = jnp.concatenate(list(op.row_panels()), axis=0)
    np.testing.assert_array_equal(np.asarray(stacked), np.asarray(A))


def test_transpose_swaps_products():
    A = _rand(64, 24, 3)
    op = linalg.DenseOp(A).T
    assert op.shape == (24, 64)
    X = _rand(64, 5, 4)
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(A.T @ X),
                               atol=1e-5, rtol=1e-5)
    assert op.T is not op and op.T.shape == (64, 24)


def test_stacked_op_products():
    A = jnp.stack([_rand(32, 16, i) for i in range(3)])
    op = linalg.StackedOp(A)
    X = _rand(16, 4, 9)
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(A @ X),
                               atol=1e-5, rtol=1e-5)
    with pytest.raises(ValueError):
        linalg.StackedOp(_rand(8, 4, 0))


def test_as_linop_coercions():
    assert isinstance(linalg.as_linop(jnp.zeros((4, 3))), linalg.DenseOp)
    assert isinstance(linalg.as_linop(np.zeros((4, 3))), linalg.HostOp)
    assert isinstance(linalg.as_linop(jnp.zeros((2, 4, 3))), linalg.StackedOp)
    op = linalg.DenseOp(jnp.zeros((4, 3)))
    assert linalg.as_linop(op) is op
    # clear facade-level errors: bad rank -> ValueError, non-array -> TypeError
    with pytest.raises(ValueError, match="2-D .* or 3-D"):
        linalg.as_linop(jnp.zeros((4,)))
    with pytest.raises(TypeError, match="no .ndim"):
        linalg.as_linop(object())


# ---------------------------------------------------------------------------
# composed operators
# ---------------------------------------------------------------------------

def test_scaled_op():
    A = _rand(48, 20, 5)
    op = linalg.ScaledOp(linalg.DenseOp(A), -2.5)
    X = _rand(20, 3, 6)
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(-2.5 * (A @ X)),
                               atol=1e-5, rtol=1e-5)
    stacked = jnp.concatenate(list(op.row_panels(13)), axis=0)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(-2.5 * A),
                               atol=1e-6, rtol=1e-6)


def test_centered_op_equals_materialized_centering():
    A = _rand(80, 24, 7) + 3.0
    op = linalg.CenteredOp(linalg.DenseOp(A))
    Ac = A - jnp.mean(A, axis=0)[None, :]
    np.testing.assert_allclose(np.asarray(op.mu), np.asarray(jnp.mean(A, axis=0)),
                               atol=1e-5)
    X = _rand(24, 5, 8)
    Y = _rand(80, 5, 9)
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(Ac @ X),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(op.rmatmat(Y)), np.asarray(Ac.T @ Y),
                               atol=1e-3, rtol=1e-4)
    stacked = jnp.concatenate(list(op.row_panels(32)), axis=0)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(Ac), atol=1e-5)


def test_composed_op_rejects_3d_base():
    stack = jnp.zeros((3, 16, 8))
    with pytest.raises(ValueError, match="2-D base"):
        linalg.CenteredOp(linalg.StackedOp(stack))
    with pytest.raises(ValueError, match="2-D base"):
        linalg.pca(stack, 2)  # coerces to StackedOp -> CenteredOp must reject


def test_column_means_streams_host_panels():
    A = np.asarray(_rand(100, 12, 10)) + 1.5
    mu = linalg.column_means(linalg.HostOp(A, block_rows=30))
    np.testing.assert_allclose(np.asarray(mu), A.mean(axis=0), atol=1e-5)


def test_low_rank_update_op_and_deflation():
    A, sig = make_test_matrix(128, 48, "fast", seed=11)
    U = _rand(128, 4, 12)
    V = _rand(48, 4, 13)
    op = linalg.LowRankUpdateOp(linalg.DenseOp(A), U, V)
    dense = A + U @ V.T
    X = _rand(48, 6, 14)
    np.testing.assert_allclose(np.asarray(op.matmat(X)), np.asarray(dense @ X),
                               atol=1e-4, rtol=1e-4)
    stacked = jnp.concatenate(list(op.row_panels(50)), axis=0)
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(dense), atol=1e-5)
    with pytest.raises(ValueError):
        linalg.LowRankUpdateOp(linalg.DenseOp(A), U, _rand(47, 4, 15))

    # deflation: after peeling the top-k subspace, the next leading singular
    # value is sigma_{k+1} of the original
    k = 8
    Uk, Sk, Vtk = linalg.svd(A, k, seed=0)
    resid = linalg.deflated(linalg.DenseOp(A), Uk, Sk, Vtk)
    S_next = linalg.svd(resid, 3, seed=1)[1]
    np.testing.assert_allclose(float(S_next[0]), float(sig[k]), rtol=5e-3)


# ---------------------------------------------------------------------------
# panel-wise residual
# ---------------------------------------------------------------------------

def test_residual_matches_low_rank_error_dense():
    A, _ = make_test_matrix(200, 64, "fast", seed=16)
    res = linalg.svd(A, 10, seed=2)
    want = float(low_rank_error(A, *res))
    got = float(linalg.residual(A, res))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # panelized accumulation only reorders the fp32 sums
    got_panels = float(linalg.residual(A, res, block_rows=37))
    np.testing.assert_allclose(got_panels, want, rtol=1e-4)


def test_residual_streams_host_source():
    A_host = np.asarray(make_test_matrix(300, 48, "fast", seed=17)[0])
    op = linalg.HostOp(A_host, block_rows=64)
    res = linalg.svd(op, 8, seed=3)
    want = float(low_rank_error(jnp.asarray(A_host), *res))
    got = float(linalg.residual(op, res))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_residual_stacked_source():
    A = jnp.stack([make_test_matrix(64, 24, "fast", seed=20 + i)[0] for i in range(3)])
    res = linalg.svd(A, 5, seed=4)
    got = float(linalg.residual(A, res))
    # reference: per-slice errors combined into the stack-wide Frobenius ratio
    num = den = 0.0
    for i in range(3):
        e = float(low_rank_error(A[i], res[0][i], res[1][i], res[2][i]))
        w = float(jnp.sum(A[i] ** 2))
        num += (e ** 2) * w
        den += w
    np.testing.assert_allclose(got, np.sqrt(num / den), rtol=1e-5)


def test_residual_stacked_tolerates_zero_slice():
    """An all-zero slice (padded/ragged batch entry) must not NaN the
    stack-wide residual — the squared sums are combined BEFORE the divide."""
    A = jnp.stack([make_test_matrix(32, 12, "fast", seed=30)[0],
                   jnp.zeros((32, 12))])
    U = jnp.zeros((2, 32, 3))
    S = jnp.zeros((2, 3))
    Vt = jnp.zeros((2, 3, 12))
    got = float(linalg.residual(A, (U, S, Vt)))
    assert np.isfinite(got) and np.isclose(got, 1.0)  # zero factors -> err = 1
