"""Distributed (shard_map) RSVD == single-device RSVD, via 8-device subprocess."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # driver sets its own device count
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / name)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_distributed_rsvd_matches_reference():
    out = _run_driver("distributed_driver.py")
    assert "DISTRIBUTED_RSVD_OK" in out


def test_elastic_reshard_on_load():
    """Checkpoint on mesh (8,) -> restore + continue on mesh (2,4)."""
    out = _run_driver("elastic_driver.py")
    assert "ELASTIC_OK" in out


def test_straggler_watchdog_flags_slow_steps():
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.optim import adamw
    from repro.configs import get_config

    cfg = get_config("llama3.2-1b").reduced()
    tr = Trainer(cfg, adamw.AdamWConfig(), TrainerConfig(straggler_factor=3.0),
                 step_fn=lambda *a: a)
    # steady 100ms steps, then a 10x straggler
    flags = [tr._watchdog(0.1, s) for s in range(10)]
    assert not any(flags)
    assert tr._watchdog(1.0, 10) is True
    assert tr.straggler.flagged_steps == 1
    assert tr.straggler.worst_ratio > 5
