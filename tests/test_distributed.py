"""Distributed (shard_map) RSVD == single-device RSVD, via 8-device subprocess."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)  # driver sets its own device count
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / name)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_distributed_rsvd_matches_reference():
    out = _run_driver("distributed_driver.py")
    assert "DISTRIBUTED_RSVD_OK" in out


@pytest.mark.slow
def test_elastic_reshard_on_load():
    """Checkpoint on mesh (8,) -> restore + continue on mesh (2,4)."""
    out = _run_driver("elastic_driver.py")
    assert "ELASTIC_OK" in out


def test_distributed_rsvd_inprocess_multidevice():
    """shard_map RSVD == dense RSVD on the ambient devices (no subprocess).

    Runs whenever the interpreter already sees >1 CPU device — the CI tier-1
    job sets XLA_FLAGS=--xla_force_host_platform_device_count=4 precisely so
    this path is exercised on every push; single-device local runs skip it.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (CI sets xla_force_host_platform_device_count)")

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import linalg
    from repro.core import RSVDConfig, low_rank_error, truncation_error
    from repro.core.spectra import make_test_matrix

    n_dev = len(jax.devices())
    # jax.sharding.Mesh directly: jax.make_mesh does not exist on the older
    # jax lines repro.compat still supports.
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    A, sig = make_test_matrix(32 * n_dev, 64, "fast", seed=0)
    A_sharded = jax.device_put(A, NamedSharding(mesh, P("data", None)))

    k = 8
    op = linalg.ShardedOp(A_sharded, mesh, "data")
    assert linalg.plan(op, k).path == "sharded"
    U, S, Vt = linalg.svd(op, k, overrides=RSVDConfig(power_iters=1))
    err = float(low_rank_error(A, jnp.asarray(U), jnp.asarray(S), jnp.asarray(Vt)))
    opt = float(truncation_error(sig, k))
    assert err <= 1.10 * opt + 1e-6, (err, opt)
    S_dense = jnp.linalg.svd(A, compute_uv=False)[:k]
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_dense), rtol=5e-3)


def test_straggler_watchdog_flags_slow_steps():
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.optim import adamw
    from repro.configs import get_config

    cfg = get_config("llama3.2-1b").reduced()
    tr = Trainer(cfg, adamw.AdamWConfig(), TrainerConfig(straggler_factor=3.0),
                 step_fn=lambda *a: a)
    # steady 100ms steps, then a 10x straggler
    flags = [tr._watchdog(0.1, s) for s in range(10)]
    assert not any(flags)
    assert tr._watchdog(1.0, 10) is True
    assert tr.straggler.flagged_steps == 1
    assert tr.straggler.worst_ratio > 5
