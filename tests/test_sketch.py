"""Statistical and structural properties of the counter-based sketch RNG —
the paper's 'fast parallel RNG' pillar, TPU edition."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sketch import (
    hash_u32,
    normal_from_index,
    rademacher_from_index,
    sketch_matrix,
    uniform_from_index,
)


def test_gaussian_moments():
    """Mean/var/skew/kurtosis of the Box-Muller stream match N(0,1)."""
    n = 200_000
    z = np.asarray(normal_from_index(jnp.arange(n, dtype=jnp.uint32), 7))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert abs((z**3).mean()) < 0.03            # skewness
    assert abs((z**4).mean() - 3.0) < 0.1       # kurtosis


def test_uniform_coverage_and_range():
    n = 100_000
    u = np.asarray(uniform_from_index(jnp.arange(n, dtype=jnp.uint32), 3))
    assert (u > 0).all() and (u <= 1).all()      # (0, 1]: log-safe
    hist, _ = np.histogram(u, bins=20, range=(0, 1))
    assert hist.min() > 0.8 * n / 20             # no empty bins / heavy skew


def test_rademacher_balance():
    n = 100_000
    r = np.asarray(rademacher_from_index(jnp.arange(n, dtype=jnp.uint32), 11))
    assert set(np.unique(r)) == {-1.0, 1.0}
    assert abs(r.mean()) < 0.01


def test_stream_decorrelation_across_seeds():
    n = 50_000
    idx = jnp.arange(n, dtype=jnp.uint32)
    z1 = np.asarray(normal_from_index(idx, 0))
    z2 = np.asarray(normal_from_index(idx, 1))
    assert abs(np.corrcoef(z1, z2)[0, 1]) < 0.01


def test_row_offset_matches_full_matrix():
    """A row-sharded device generating ITS rows must reproduce the global
    sketch exactly — the property that makes the distributed RSVD
    collective-free at the sketch step."""
    full = np.asarray(sketch_matrix(64, 16, seed=5))
    top = np.asarray(sketch_matrix(32, 16, seed=5, row_offset=0))
    bot = np.asarray(sketch_matrix(32, 16, seed=5, row_offset=32))
    np.testing.assert_array_equal(np.vstack([top, bot]), full)


def test_sketch_is_near_isometry():
    """Johnson-Lindenstrauss sanity: Omega/sqrt(s) roughly preserves norms."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 4096)).astype(np.float32))
    omega = sketch_matrix(4096, 256, seed=9)
    y = np.asarray(x @ omega) / np.sqrt(256)
    ratios = np.linalg.norm(y, axis=1) / np.asarray(jnp.linalg.norm(x, axis=1))
    assert (np.abs(ratios - 1) < 0.15).all(), ratios


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**31), idx=st.integers(0, 2**31))
def test_hash_determinism_property(seed, idx):
    a = int(hash_u32(jnp.asarray([idx], jnp.uint32), seed)[0])
    b = int(hash_u32(jnp.asarray([idx], jnp.uint32), seed)[0])
    assert a == b
    # single-bit index flip decorrelates the output (avalanche, weak check)
    c = int(hash_u32(jnp.asarray([idx ^ 1], jnp.uint32), seed)[0])
    assert a != c or idx == idx ^ 1


# ---------------------------------------------------------------------------
# Structured sketches: SRHT and CountSketch (PR 6)
# ---------------------------------------------------------------------------

from repro.core.sketch import (  # noqa: E402
    apply_structured,
    countsketch_matrix,
    fwht,
    srht_matrix,
)


def test_fwht_matches_hadamard_matmul():
    """The butterfly transform equals x @ H for the normalized Hadamard H."""
    n = 32
    i = np.arange(n)
    H = ((-1.0) ** np.array([bin(r & c).count("1") for r in i for c in i])
         ).reshape(n, n) / np.sqrt(n)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, n)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fwht(x)), np.asarray(x) @ H,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ["srht", "countsketch"])
@pytest.mark.parametrize("n", [48, 64])  # non-pow2 exercises the padding
def test_structured_fast_apply_matches_materialized(kind, n):
    """apply_structured (FWHT / segment-sum) and A @ sketch_matrix compute
    the SAME linear map (different summation order — allclose, not equal)."""
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((37, n)).astype(np.float32))
    fast = np.asarray(apply_structured(A, 16, 5, kind))
    mat = np.asarray(A @ sketch_matrix(n, 16, 5, kind))
    np.testing.assert_allclose(fast, mat, rtol=1e-4, atol=1e-4)


def test_srht_columns_orthogonal():
    """Over the full n_pad rows, Omega's columns are orthogonal with squared
    norm n_pad / s exactly: distinct Hadamard columns under one sign flip."""
    Om = np.asarray(srht_matrix(64, 16, seed=3))
    np.testing.assert_allclose(Om.T @ Om, np.eye(16) * 64 / 16,
                               rtol=1e-4, atol=1e-4)
    # every entry is +-1/sqrt(s)
    np.testing.assert_allclose(np.abs(Om), 1 / np.sqrt(16), rtol=1e-5)


def test_countsketch_structure():
    """Each row holds exactly one +-1; the ranked bucket assignment is
    BALANCED (no empty sketch column — a raw hash % s would leave empty
    buckets at panel widths, handing the range finder a zero column)."""
    Om = np.asarray(countsketch_matrix(64, 16, seed=3))
    assert np.all(np.sum(Om != 0, axis=1) == 1)
    assert set(np.unique(Om[Om != 0])) == {-1.0, 1.0}
    counts = np.bincount(np.argmax(np.abs(Om), axis=1), minlength=16)
    assert counts.min() >= 1 and counts.max() - counts.min() <= 1, counts


@pytest.mark.parametrize("kind", ["srht", "countsketch"])
def test_structured_deterministic_in_seed(kind):
    a = np.asarray(sketch_matrix(40, 8, seed=7, kind=kind))
    b = np.asarray(sketch_matrix(40, 8, seed=7, kind=kind))
    c = np.asarray(sketch_matrix(40, 8, seed=8, kind=kind))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("kind", ["srht", "countsketch"])
def test_structured_rejects_row_offset(kind):
    """Structured draws are global (column sample / bucket assignment) —
    row-offset panel regeneration must fail loudly, not silently diverge."""
    with pytest.raises(ValueError, match="row-decomposable"):
        sketch_matrix(32, 8, seed=0, kind=kind, row_offset=16)


@pytest.mark.parametrize("kind", ["srht", "countsketch"])
def test_structured_sketch_preserves_column_space_rank(kind):
    """Subspace-embedding sanity: sketching a rank-r matrix with s >= 2r
    keeps rank r (the range finder's working requirement)."""
    rng = np.random.default_rng(2)
    L = rng.standard_normal((60, 6)).astype(np.float32)
    R = rng.standard_normal((6, 80)).astype(np.float32)
    A = jnp.asarray(L @ R)                      # rank 6
    Y = np.asarray(apply_structured(A, 16, 11, kind))
    assert np.linalg.matrix_rank(Y, tol=1e-4) == 6
