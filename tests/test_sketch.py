"""Statistical and structural properties of the counter-based sketch RNG —
the paper's 'fast parallel RNG' pillar, TPU edition."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sketch import (
    hash_u32,
    normal_from_index,
    rademacher_from_index,
    sketch_matrix,
    uniform_from_index,
)


def test_gaussian_moments():
    """Mean/var/skew/kurtosis of the Box-Muller stream match N(0,1)."""
    n = 200_000
    z = np.asarray(normal_from_index(jnp.arange(n, dtype=jnp.uint32), 7))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert abs((z**3).mean()) < 0.03            # skewness
    assert abs((z**4).mean() - 3.0) < 0.1       # kurtosis


def test_uniform_coverage_and_range():
    n = 100_000
    u = np.asarray(uniform_from_index(jnp.arange(n, dtype=jnp.uint32), 3))
    assert (u > 0).all() and (u <= 1).all()      # (0, 1]: log-safe
    hist, _ = np.histogram(u, bins=20, range=(0, 1))
    assert hist.min() > 0.8 * n / 20             # no empty bins / heavy skew


def test_rademacher_balance():
    n = 100_000
    r = np.asarray(rademacher_from_index(jnp.arange(n, dtype=jnp.uint32), 11))
    assert set(np.unique(r)) == {-1.0, 1.0}
    assert abs(r.mean()) < 0.01


def test_stream_decorrelation_across_seeds():
    n = 50_000
    idx = jnp.arange(n, dtype=jnp.uint32)
    z1 = np.asarray(normal_from_index(idx, 0))
    z2 = np.asarray(normal_from_index(idx, 1))
    assert abs(np.corrcoef(z1, z2)[0, 1]) < 0.01


def test_row_offset_matches_full_matrix():
    """A row-sharded device generating ITS rows must reproduce the global
    sketch exactly — the property that makes the distributed RSVD
    collective-free at the sketch step."""
    full = np.asarray(sketch_matrix(64, 16, seed=5))
    top = np.asarray(sketch_matrix(32, 16, seed=5, row_offset=0))
    bot = np.asarray(sketch_matrix(32, 16, seed=5, row_offset=32))
    np.testing.assert_array_equal(np.vstack([top, bot]), full)


def test_sketch_is_near_isometry():
    """Johnson-Lindenstrauss sanity: Omega/sqrt(s) roughly preserves norms."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 4096)).astype(np.float32))
    omega = sketch_matrix(4096, 256, seed=9)
    y = np.asarray(x @ omega) / np.sqrt(256)
    ratios = np.linalg.norm(y, axis=1) / np.asarray(jnp.linalg.norm(x, axis=1))
    assert (np.abs(ratios - 1) < 0.15).all(), ratios


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**31), idx=st.integers(0, 2**31))
def test_hash_determinism_property(seed, idx):
    a = int(hash_u32(jnp.asarray([idx], jnp.uint32), seed)[0])
    b = int(hash_u32(jnp.asarray([idx], jnp.uint32), seed)[0])
    assert a == b
    # single-bit index flip decorrelates the output (avalanche, weak check)
    c = int(hash_u32(jnp.asarray([idx ^ 1], jnp.uint32), seed)[0])
    assert a != c or idx == idx ^ 1
