"""`repro.linalg` public facade: one call-site pattern for every rSVD path.

    U, S, Vt = linalg.svd(source, k)                 # planner picks the path
    pl       = linalg.plan(source, k)                # inspect before running
    U, S, Vt = linalg.svd(source, k, plan=pl)        # execute a pinned plan
    err      = linalg.residual(source, (U, S, Vt))   # panel-wise, no m x n temp

`source` is anything `as_linop` accepts: a device array (DenseOp), a host
numpy array (HostOp, panel-streamed), a 3-D stack (StackedOp), a
`ShardedOp(A, mesh, axis)`, or a composed operator (CenteredOp, ScaledOp,
LowRankUpdateOp) — the last class runs the generic operator body, nothing
materialized.  Execution delegates to the SAME numerics as the historical
entry points (`core/rsvd.py`, `core/blocked.py`, `core/distributed.py`), so
fixed-seed results are bit-identical to the pre-facade paths.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig
from repro.linalg import planner as planner_mod
from repro.linalg.operators import LinOp, ShardedOp, as_linop
from repro.linalg.planner import Budget, ExecutionPlan

SVDResult = Tuple[jax.Array, jax.Array, jax.Array]


def plan(op, k: int, budget: Optional[Budget] = None,
         overrides: Optional[RSVDConfig] = None) -> ExecutionPlan:
    """See planner.plan — re-exported as part of the facade."""
    return planner_mod.plan(op, k, budget=budget, overrides=overrides)


def _dense_array(op: LinOp) -> jax.Array:
    """The device array a dense plan executes on (host numpy under a dense
    plan moves wholesale, matching the historical entry point)."""
    return op.array if isinstance(op.array, jax.Array) else jnp.asarray(op.array)


def svd(
    a,
    k: int,
    *,
    plan: Optional[ExecutionPlan] = None,
    overrides: Optional[RSVDConfig] = None,
    budget: Optional[Budget] = None,
    seed: int = 0,
) -> SVDResult:
    """Rank-k randomized SVD of any operator source.  Returns (U, S, Vt)
    with U: m x k, S: k, Vt: k x n (leading batch axis for StackedOp)."""
    op = as_linop(a)
    pl = plan if plan is not None else planner_mod.plan(op, k, budget=budget, overrides=overrides)
    cfg = pl.to_config()
    if pl.path == "dense":
        from repro.core import rsvd as rsvd_mod

        return rsvd_mod._randomized_svd_dense(
            _dense_array(op), jnp.asarray(seed, jnp.uint32), k, cfg
        )
    if pl.path == "streamed":
        from repro.core import blocked

        return blocked.svd_streamed(op.array, k, cfg, seed=seed)
    if pl.path == "batched":
        from repro.core import blocked

        return blocked.svd_batched(op.array, k, cfg, seed=seed)
    if pl.path == "sharded":
        from repro.core import distributed

        mesh, axis = op.sharding
        return distributed.svd_sharded(op.array, k, mesh, axis, cfg, seed=seed)
    if pl.path == "matfree":
        return _matfree_svd(op, k, pl, seed)
    raise ValueError(f"unknown execution path: {pl.path}")


def eigvals(
    a,
    k: int,
    *,
    plan: Optional[ExecutionPlan] = None,
    overrides: Optional[RSVDConfig] = None,
    budget: Optional[Budget] = None,
    seed: int = 0,
) -> jax.Array:
    """k largest singular values only (the paper's eigenvalue-benchmark
    mode: Algorithm 1 steps 1-5, Sigma only)."""
    op = as_linop(a)
    pl = plan if plan is not None else planner_mod.plan(op, k, budget=budget, overrides=overrides)
    cfg = pl.to_config()
    if pl.path == "dense":
        from repro.core import rsvd as rsvd_mod

        return rsvd_mod._randomized_eigvals_dense(
            _dense_array(op), jnp.asarray(seed, jnp.uint32), k, cfg
        )
    if pl.path == "streamed":
        from repro.core import blocked

        return blocked.eigvals_streamed(op.array, k, cfg, seed=seed)
    if pl.path == "matfree":
        return _matfree_svd(op, k, pl, seed, want_uv=False)
    # batched / sharded: Sigma rides the factor solve
    return svd(op, k, plan=pl, seed=seed)[1]


# ---------------------------------------------------------------------------
# Matrix-free body: Algorithm 1 over the LinOp protocol (composed operators)
# ---------------------------------------------------------------------------

def _matfree_svd(op: LinOp, k: int, pl: ExecutionPlan, seed, want_uv: bool = True):
    """Algorithm 1 phrased purely through matmat/rmatmat — serves any
    composed operator (centered, scaled, deflated) without materializing it.
    The range finder works on the taller orientation, like the dense path.
    ``want_uv=False`` is the Sigma-only mode: steps 1-5, skipping the
    step-6 U assembly (the m x s GEMM).

    NOTE: the stabilized loop below deliberately mirrors the unfused body
    in core/rsvd.py (`_stabilized_power` / `_rsvd_body`) with A@ / Aᵀ@
    replaced by the operator products — numerics fixes there must land
    here too (tests/test_planner.py pins the paths against each other
    through the CenteredOp == pca_exact property)."""
    m_raw, n_raw = op.shape
    if m_raw < n_raw:
        if not want_uv:
            return _matfree_svd(op.T, k, pl, seed, want_uv=False)
        V, S, Ut = _matfree_svd(op.T, k, pl, seed)
        return Ut.T, S, V.T
    with qr_mod.kernel_backend(pl.kernel_backend):
        m, n = op.shape
        s = min(k + pl.oversample, min(m, n))
        fdtype = jnp.promote_types(op.dtype, jnp.float32)
        omega = sketch_mod.sketch_matrix(
            n, s, jnp.asarray(seed, jnp.uint32), pl.sketch_kind, dtype=fdtype
        )
        Y = op.matmat(omega)
        for _ in range(pl.power_iters):
            if pl.power_scheme == "plain":
                Y = op.matmat(op.rmatmat(Y))
            else:
                Q = qr_mod.orthonormalize(Y, pl.qr_method)
                Z = op.rmatmat(Q)
                Qz = qr_mod.orthonormalize(Z, pl.qr_method)
                Y = op.matmat(Qz)
        Q = qr_mod.orthonormalize(Y, pl.qr_method)
        B = op.rmatmat(Q).T                      # (s, n) without forming A
        from repro.core.rsvd import _small_svd

        U_b, S, Vt = _small_svd(B, pl.small_svd)
        if not want_uv:
            return S[:k]
        U = Q @ U_b
        return U[:, :k], S[:k], Vt[:k, :]


# ---------------------------------------------------------------------------
# PCA on the centered OPERATOR (the m x n centered temporary is gone)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "pl"))
def _pca_centered_dense(X: jax.Array, seed: jax.Array, k: int, pl: ExecutionPlan):
    """Jitted PCA over the centered OPERATOR of a device-resident X: the
    whole pipeline (mean, sketch, power loop, small SVD) is one compiled
    program per (shape, plan) — the repeated-PCA hot path — while X - mu
    still never materializes (the CenteredOp matmat/rmatmat carry the
    correction).  ExecutionPlan is frozen/hashable, so it keys the cache;
    the seed is traced."""
    from repro.linalg.operators import CenteredOp, DenseOp

    mu = jnp.mean(X, axis=0)
    _, S, Vt = _matfree_svd(CenteredOp(DenseOp(X), mu), k, pl, seed)
    return mu, S, Vt


def pca(x, k: int, *, overrides: Optional[RSVDConfig] = None,
        budget: Optional[Budget] = None, seed: int = 0):
    """Top-k principal components of X (N x d) via the CenteredOp source.

    Returns a `repro.core.pca.PCAResult`.  Unlike the historical
    `core.pca.pca`, the centered matrix X - mu is never materialized: the
    range finder consumes `CenteredOp(X)` through matmat/rmatmat.  Device-
    resident X runs as one jitted program (cached per shape/plan); host
    numpy sources stream row panels eagerly."""
    from repro.core.pca import PCAResult
    from repro.linalg.operators import CenteredOp, DenseOp

    op = as_linop(x)
    n = op.shape[0]
    if type(op) is DenseOp:  # HostOp subclasses DenseOp — excluded by type()
        # Plan on shapes only (a dummy mu skips the eager column_means),
        # then run the compiled pipeline.
        shape_op = CenteredOp(op, mu=jnp.zeros((op.shape[1],), op.dtype))
        pl = planner_mod.plan(shape_op, k, budget=budget, overrides=overrides)
        mu, S, Vt = _pca_centered_dense(
            op.array, jnp.asarray(seed, jnp.uint32), k, pl
        )
    else:
        cop = CenteredOp(op)
        mu = cop.mu
        _, S, Vt = svd(cop, k, overrides=overrides, budget=budget, seed=seed)
    return PCAResult(
        components=Vt,
        explained_variance=S**2 / (n - 1),
        singular_values=S,
        mean=mu,
    )


# ---------------------------------------------------------------------------
# Panel-wise residual: relative Frobenius error without an m x n temporary
# ---------------------------------------------------------------------------

def residual(a, result: SVDResult, block_rows: Optional[int] = None) -> jax.Array:
    """||A - U S Vt||_F / ||A||_F accumulated one row panel at a time.

    The historical `core.rsvd.low_rank_error` materializes the full m x n
    reconstruction — fine in-core, impossible for a streamed/host source.
    This walks `op.row_panels()`: per panel only a (block_rows x n) residual
    exists, so HostOp sources report error at streaming residency.  3-D
    stacked sources reduce over every slice (flat Frobenius norm)."""
    U, S, Vt = result
    op = as_linop(a)
    if len(op.shape) == 3:
        # One vmapped pass collecting (||R_i||^2, ||A_i||^2) per slice —
        # summed before the divide, so an all-zero slice contributes 0/0-free
        # and the stack is read exactly once.
        A3 = jnp.asarray(op.array).astype(jnp.float32)

        def _slice_sq(Ai, Ui, Si, Vti):
            R = Ai - (Ui.astype(jnp.float32) * Si.astype(jnp.float32)[None, :]) \
                @ Vti.astype(jnp.float32)
            return jnp.sum(R * R), jnp.sum(Ai * Ai)

        nums, dens = jax.vmap(_slice_sq)(A3, U, S, Vt)
        return jnp.sqrt(jnp.sum(nums) / jnp.sum(dens))
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    lo = 0
    scaled_vt = (S[:, None] * Vt).astype(jnp.float32)          # (k, n), skinny
    for panel in op.row_panels(block_rows):
        hi = lo + panel.shape[0]
        P = panel.astype(jnp.float32)
        R = P - U[lo:hi].astype(jnp.float32) @ scaled_vt
        num = num + jnp.sum(R * R)
        den = den + jnp.sum(P * P)
        lo = hi
    if lo != op.shape[0]:
        raise ValueError(f"row_panels covered {lo} of {op.shape[0]} rows")
    return jnp.sqrt(num / den)
