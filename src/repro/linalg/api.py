"""`repro.linalg` public facade: one call-site pattern for every rSVD path.

    U, S, Vt = linalg.svd(source, k)                 # planner picks the path
    pl       = linalg.plan(source, k)                # inspect before running
    U, S, Vt = linalg.svd(source, k, plan=pl)        # execute a pinned plan
    err      = linalg.residual(source, (U, S, Vt))   # panel-wise, no m x n temp

Spec-driven decompositions (PR 4): call sites that know an ACCURACY rather
than a rank state it, and pick a factorization kind from the registry:

    dec = linalg.decompose(source, linalg.Tolerance(1e-2))        # adaptive rank
    dec = linalg.decompose(source, linalg.Energy(0.95), kind="pca")
    Q, B = linalg.decompose(source, linalg.Rank(64), kind="qb")
    w, V = linalg.decompose(psd, linalg.Tolerance(1e-3), kind="eigh")

`source` is anything `as_linop` accepts: a device array (DenseOp), a host
numpy array (HostOp, panel-streamed), a 3-D stack (StackedOp), a
`ShardedOp(A, mesh, axis)`, or a composed operator (CenteredOp, ScaledOp,
LowRankUpdateOp) — the last class runs the generic operator body, nothing
materialized.  Execution delegates to the SAME numerics as the historical
entry points (`core/rsvd.py`, `core/blocked.py`, `core/distributed.py`), so
fixed-seed results are bit-identical to the pre-facade paths; `svd`,
`eigvals`, and `pca` survive as thin Rank-spec wrappers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig
from repro.linalg import faults as faults_mod
from repro.linalg import guard as guard_mod
from repro.linalg import pipeline as pipeline_mod
from repro.linalg import planner as planner_mod
from repro.linalg import registry as registry_mod
from repro.linalg import snapshot as snapshot_mod
from repro.linalg.operators import LinOp, ShardedOp, as_linop, prefetch_panels
from repro.linalg.planner import Budget, ExecutionPlan
from repro.linalg.spec import Rank, Spec, as_spec

SVDResult = Tuple[jax.Array, jax.Array, jax.Array]


def plan(op, spec, budget: Optional[Budget] = None,
         overrides: Optional[RSVDConfig] = None, kind: str = "svd",
         nnz: Optional[int] = None, guard=None,
         validate: bool = False) -> ExecutionPlan:
    """See planner.plan — re-exported as part of the facade.

    Mirrors `decompose`'s source preparation (e.g. kind="pca" wraps in
    CenteredOp) so a plan built here describes the operator that will
    actually execute when pinned via `decompose(..., plan=pl)`.  `guard`
    ("off" | "report" | "retry" or a GuardPolicy) and `validate` set the
    guarded-execution fields — linalg/guard.py."""
    entry = registry_mod.get(kind)
    op = as_linop(op)
    if entry.prepare is not None:
        op = entry.prepare(op)
    return planner_mod.plan(op, spec, budget=budget, overrides=overrides,
                            kind=kind, nnz=nnz, guard=guard,
                            validate=validate)


# ---------------------------------------------------------------------------
# Spec-driven decompositions: the registry front door
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Decomposition:
    """What `decompose` returns: the factors plus the full decision/record.

    `factors` is kind-shaped — (U, S, Vt) for svd, (Q, B) for qb, (w, V)
    for eigh, (perm_rows, L, U, perm_cols) for lu, PCAResult field order
    for pca — and the object unpacks like that tuple.  `plan` carries the
    PLANNED rank schedule; `rank_history` is the prefix that actually ran
    (adaptive solves stop early), and `err_history` the posterior relative-
    error estimate after each growth panel.  `health` is the guard's
    HealthReport when the plan's GuardPolicy is "report" or "retry"
    (linalg/guard.py) and None under guard "off"."""

    kind: str
    spec: Spec
    plan: ExecutionPlan
    rank: int
    factors: tuple
    rank_history: Tuple[int, ...]
    err_history: Tuple[float, ...]
    health: Optional[guard_mod.HealthReport] = None

    def __iter__(self):
        return iter(self.factors)

    def __getitem__(self, i):
        return self.factors[i]

    def __len__(self):
        return len(self.factors)


def decompose(
    a,
    spec,
    kind: str = "svd",
    *,
    plan: Optional[ExecutionPlan] = None,
    overrides: Optional[RSVDConfig] = None,
    budget: Optional[Budget] = None,
    seed: int = 0,
    guard=None,
    validate: Optional[bool] = None,
    checkpoint=None,
) -> Decomposition:
    """Factorize `a` to the accuracy `spec` with the registry entry `kind`.

    `spec` is a rank (int / `Rank`) or an adaptive accuracy contract
    (`Tolerance`, `Energy`); `kind` is one of `registry.kinds()` —
    "svd" | "eigh" | "qb" | "lu" | "pca".  Rank-spec svd is bit-identical
    to `linalg.svd(a, k)` at fixed seed (same plan, same executors).

    `guard` / `validate` (linalg/guard.py): explicit arguments win over a
    pinned plan's fields; None inherits them.  Under guard "report" /
    "retry" the result's `health` carries the probe verdict (and the
    ladder trail for retry); `validate=True` screens non-finite input
    before factors can silently go NaN.

    `checkpoint` (linalg/snapshot.py) makes a streamed/adaptive solve
    resumable: a directory path (or `Checkpointer` / `RunControl`) where
    engine state is persisted at panel-group boundaries.  An interrupted
    call re-issued with the same arguments and checkpoint directory
    resumes from the last snapshot, bit-identical to an uninterrupted run;
    `None` (default) adds zero work and zero HBM traffic."""
    spec = as_spec(spec)
    entry = registry_mod.get(kind)
    op = as_linop(a)
    if entry.prepare is not None:
        op = entry.prepare(op)
    if plan is not None and (plan.kind != kind or plan.spec != spec):
        raise ValueError(
            f"pinned plan was built for kind={plan.kind!r} "
            f"spec={plan.spec.describe() if plan.spec else None}, which does "
            f"not match the requested kind={kind!r} spec={spec.describe()} — "
            "re-plan with linalg.plan(a, spec, kind=kind)"
        )
    pl = plan if plan is not None else registry_mod.cached_plan(
        op, spec, budget=budget, overrides=overrides, kind=kind,
        guard=guard, validate=bool(validate),
    )
    pl = _with_guard_overrides(pl, guard, validate, pinned=plan is not None)
    with snapshot_mod.maybe_scope(checkpoint), \
            guard_mod.validated(op, pl.validate):
        if pl.guard.mode != "off":
            ortho = None
            if entry.ortho_factor is not None:
                ortho = lambda res: entry.ortho_factor(res[0])  # noqa: E731
            result, health = guard_mod.run_guarded(
                lambda op_, pl_, seed_: entry.execute(op_, spec, pl_, seed_),
                op, pl, seed, ortho_factor=ortho,
            )
            factors, rank, rank_history, err_history = result
        else:
            health = None
            factors, rank, rank_history, err_history = entry.execute(
                op, spec, pl, seed)
    return Decomposition(
        kind=kind,
        spec=spec,
        plan=pl,
        rank=int(rank),
        factors=tuple(factors),
        rank_history=tuple(rank_history),
        err_history=tuple(err_history),
        health=health,
    )


def _with_guard_overrides(pl: ExecutionPlan, guard, validate,
                          pinned: bool) -> ExecutionPlan:
    """Apply explicit guard/validate arguments over a plan's fields.

    Only meaningful for PINNED plans (a fresh plan was already built with
    them); neither field changes a healthy solve's numerics, so replacing
    them on a pinned plan cannot invalidate its execution decisions."""
    if not pinned:
        return pl
    import dataclasses

    updates = {}
    if guard is not None:
        updates["guard"] = guard_mod.as_guard(guard)
    if validate is not None:
        updates["validate"] = bool(validate)
    return dataclasses.replace(pl, **updates) if updates else pl


def _dense_array(op: LinOp) -> jax.Array:
    """The device array a dense plan executes on (host numpy under a dense
    plan moves wholesale, matching the historical entry point)."""
    return op.array if isinstance(op.array, jax.Array) else jnp.asarray(op.array)


def svd(
    a,
    k: int,
    *,
    plan: Optional[ExecutionPlan] = None,
    overrides: Optional[RSVDConfig] = None,
    budget: Optional[Budget] = None,
    seed: int = 0,
    guard=None,
    validate: Optional[bool] = None,
) -> SVDResult:
    """Rank-k randomized SVD of any operator source.  Returns (U, S, Vt)
    with U: m x k, S: k, Vt: k x n (leading batch axis for StackedOp).

    This is the `Rank(k)`-spec thin wrapper: `decompose(a, Rank(k))` runs
    the SAME plan and executors, bit-identical at fixed seed.

    Guarded execution: `guard="retry"` (or a guard-carrying plan) recovers
    breakdowns through the escalation ladder but this wrapper returns the
    bare factor tuple — use `decompose(a, k, guard=...)` when you want the
    HealthReport itself."""
    k = _fixed_rank(k, "svd")
    op = as_linop(a)
    pl = plan if plan is not None else registry_mod.cached_plan(
        op, k, budget=budget, overrides=overrides, guard=guard,
        validate=bool(validate))
    pl = _with_guard_overrides(pl, guard, validate, pinned=plan is not None)
    with guard_mod.validated(op, pl.validate):
        if pl.guard.mode != "off":
            result, _health = guard_mod.run_guarded(
                lambda op_, pl_, seed_: _execute_svd_plan(op_, k, pl_, seed_),
                op, pl, seed,
                ortho_factor=lambda res: None if getattr(res[0], "ndim", 2) == 3 else res[0],
            )
            return result
        return _execute_svd_plan(op, k, pl, seed)


def _fixed_rank(k, entry: str) -> int:
    """The svd/eigvals wrappers are fixed-rank only: adaptive specs must go
    through `decompose` (which returns the selected rank and trajectory)."""
    spec = as_spec(k)
    if not isinstance(spec, Rank):
        raise ValueError(
            f"linalg.{entry} takes a rank; for adaptive specs like "
            f"{spec.describe()} use linalg.decompose(a, spec)"
        )
    return spec.k


def _execute_svd_plan(op: LinOp, k: int, pl: ExecutionPlan, seed) -> SVDResult:
    """Execute a fixed-rank plan through the historical per-path numerics
    (shared by `svd` and the registry's Rank-spec handler)."""
    if pl.path == "adaptive":
        raise ValueError(
            "an adaptive plan cannot execute through the fixed-rank svd "
            "wrapper; pass it to linalg.decompose(a, spec, plan=pl)"
        )
    cfg = pl.to_config()
    if pl.path == "dense":
        from repro.core import rsvd as rsvd_mod

        A = _dense_array(op)
        seed_arr = jnp.asarray(seed, jnp.uint32)
        if guard_mod.active_sink() is not None:
            # guarded run: the probed compiled twin returns the health
            # scalars as extra jit outputs (the unguarded program and its
            # cache entry are untouched — guard "off" stays bit-identical)
            out, probes = rsvd_mod._randomized_svd_dense_probed(
                A, seed_arr, k, cfg, faults_mod.fingerprint())
            guard_mod.absorb(probes)
            return out
        return rsvd_mod._randomized_svd_dense(A, seed_arr, k, cfg)
    if pl.path == "streamed":
        from repro.core import blocked

        return blocked.svd_streamed(op.array, k, cfg, seed=seed)
    if pl.path == "batched":
        from repro.core import blocked

        return blocked.svd_batched(op.array, k, cfg, seed=seed)
    if pl.path == "sharded":
        from repro.core import distributed

        mesh, axis = op.sharding
        return distributed.svd_sharded(op.array, k, mesh, axis, cfg, seed=seed)
    if pl.path == "matfree":
        # host-rooted composed sources stream underneath matmat/rmatmat;
        # the ambient scope hands them the plan's prefetch depth
        with pipeline_mod.default_depth(pl.pipeline_depth):
            return _matfree_svd(op, k, pl, seed)
    if pl.path == "sparse":
        # the sparse path is the operator body with SpMM products; when the
        # plan claims a fused sketch, _matfree_svd routes through the
        # source's `sketch` hook (SparseOp -> the Pallas SpMM kernel)
        return _matfree_svd(op, k, pl, seed)
    raise ValueError(f"unknown execution path: {pl.path}")


def eigvals(
    a,
    k: int,
    *,
    plan: Optional[ExecutionPlan] = None,
    overrides: Optional[RSVDConfig] = None,
    budget: Optional[Budget] = None,
    seed: int = 0,
) -> jax.Array:
    """k largest singular values only (the paper's eigenvalue-benchmark
    mode: Algorithm 1 steps 1-5, Sigma only)."""
    k = _fixed_rank(k, "eigvals")
    op = as_linop(a)
    pl = plan if plan is not None else registry_mod.cached_plan(
        op, k, budget=budget, overrides=overrides)
    cfg = pl.to_config()
    if pl.path == "dense":
        from repro.core import rsvd as rsvd_mod

        return rsvd_mod._randomized_eigvals_dense(
            _dense_array(op), jnp.asarray(seed, jnp.uint32), k, cfg
        )
    if pl.path == "streamed":
        from repro.core import blocked

        return blocked.eigvals_streamed(op.array, k, cfg, seed=seed)
    if pl.path in ("matfree", "sparse"):
        with pipeline_mod.default_depth(pl.pipeline_depth):
            return _matfree_svd(op, k, pl, seed, want_uv=False)
    # batched / sharded: Sigma rides the factor solve
    return svd(op, k, plan=pl, seed=seed)[1]


# ---------------------------------------------------------------------------
# Matrix-free body: Algorithm 1 over the LinOp protocol (composed operators)
# ---------------------------------------------------------------------------

def _matfree_svd(op: LinOp, k: int, pl: ExecutionPlan, seed, want_uv: bool = True):
    """Algorithm 1 phrased purely through matmat/rmatmat — serves any
    composed operator (centered, scaled, deflated) without materializing it.
    The range finder works on the taller orientation, like the dense path.
    ``want_uv=False`` is the Sigma-only mode: steps 1-5, skipping the
    step-6 U assembly (the m x s GEMM).

    NOTE: the stabilized loop below deliberately mirrors the unfused body
    in core/rsvd.py (`_stabilized_power` / `_rsvd_body`) with A@ / Aᵀ@
    replaced by the operator products — numerics fixes there must land
    here too (tests/test_planner.py pins the paths against each other
    through the CenteredOp == pca_exact property)."""
    m_raw, n_raw = op.shape
    if m_raw < n_raw:
        if not want_uv:
            return _matfree_svd(op.T, k, pl, seed, want_uv=False)
        V, S, Ut = _matfree_svd(op.T, k, pl, seed)
        return Ut.T, S, V.T
    with qr_mod.kernel_backend(pl.kernel_backend):
        m, n = op.shape
        s = min(k + pl.oversample, min(m, n))
        fdtype = jnp.promote_types(op.dtype, jnp.float32)
        sketcher = getattr(op, "sketch", None)
        if pl.fused_sketch and sketcher is not None:
            # source-fused sketch (SparseOp: block-ELL SpMM with Omega tiles
            # generated in VMEM — Omega never exists in HBM)
            Y = sketcher(s, jnp.asarray(seed, jnp.uint32), pl.sketch_kind).astype(fdtype)
        else:
            omega = sketch_mod.sketch_matrix(
                n, s, jnp.asarray(seed, jnp.uint32), pl.sketch_kind, dtype=fdtype
            )
            Y = op.matmat(omega)
        for _ in range(pl.power_iters):
            if pl.power_scheme == "plain":
                Y = op.matmat(op.rmatmat(Y))
            else:
                Q = qr_mod.orthonormalize(Y, pl.qr_method)
                Z = op.rmatmat(Q)
                Qz = qr_mod.orthonormalize(Z, pl.qr_method)
                Y = op.matmat(Qz)
        Q = qr_mod.orthonormalize(Y, pl.qr_method)
        B = op.rmatmat(Q).T                      # (s, n) without forming A
        from repro.core.rsvd import _small_svd

        U_b, S, Vt = _small_svd(B, pl.small_svd)
        if not want_uv:
            return S[:k]
        U = Q @ U_b
        return U[:, :k], S[:k], Vt[:k, :]


# ---------------------------------------------------------------------------
# PCA on the centered OPERATOR (the m x n centered temporary is gone)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "pl"))
def _pca_centered_dense(X: jax.Array, seed: jax.Array, k: int, pl: ExecutionPlan):
    """Jitted PCA over the centered OPERATOR of a device-resident X: the
    whole pipeline (mean, sketch, power loop, small SVD) is one compiled
    program per (shape, plan) — the repeated-PCA hot path — while X - mu
    still never materializes (the CenteredOp matmat/rmatmat carry the
    correction).  ExecutionPlan is frozen/hashable, so it keys the cache;
    the seed is traced."""
    from repro.linalg.operators import CenteredOp, DenseOp

    mu = jnp.mean(X, axis=0)
    _, S, Vt = _matfree_svd(CenteredOp(DenseOp(X), mu), k, pl, seed)
    return mu, S, Vt


def pca(x, k, *, overrides: Optional[RSVDConfig] = None,
        budget: Optional[Budget] = None, seed: int = 0):
    """Top-k principal components of X (N x d) via the CenteredOp source.

    `k` is a rank (int) or an accuracy spec: `Energy(p)` keeps the smallest
    rank explaining fraction p of the variance, `Tolerance(eps)` targets a
    relative reconstruction error (both run the adaptive QB engine over
    the centered operator — the spec-driven path of the registry).

    Returns a `repro.core.pca.PCAResult`.  Unlike the historical
    `core.pca.pca`, the centered matrix X - mu is never materialized: the
    range finder consumes `CenteredOp(X)` through matmat/rmatmat.  Device-
    resident X runs as one jitted program (cached per shape/plan); host
    numpy sources stream row panels eagerly."""
    from repro.core.pca import PCAResult
    from repro.linalg.operators import CenteredOp, DenseOp

    spec = as_spec(k)
    if not isinstance(spec, Rank):
        dec = decompose(x, spec, kind="pca", overrides=overrides,
                        budget=budget, seed=seed)
        components, expvar, svals, mu = dec.factors
        return PCAResult(components=components, explained_variance=expvar,
                         singular_values=svals, mean=mu)
    k = spec.k
    op = as_linop(x)
    n = op.shape[0]
    if type(op) is DenseOp:  # HostOp subclasses DenseOp — excluded by type()
        # Plan on shapes only (a dummy mu skips the eager column_means),
        # then run the compiled pipeline.
        shape_op = CenteredOp(op, mu=jnp.zeros((op.shape[1],), op.dtype))
        pl = planner_mod.plan(shape_op, k, budget=budget, overrides=overrides)
        mu, S, Vt = _pca_centered_dense(
            op.array, jnp.asarray(seed, jnp.uint32), k, pl
        )
    else:
        cop = CenteredOp(op)
        mu = cop.mu
        _, S, Vt = svd(cop, k, overrides=overrides, budget=budget, seed=seed)
    return PCAResult(
        components=Vt,
        explained_variance=S**2 / (n - 1),
        singular_values=S,
        mean=mu,
    )


# ---------------------------------------------------------------------------
# Panel-wise residual: relative Frobenius error without an m x n temporary
# ---------------------------------------------------------------------------

def residual(a, result: SVDResult, block_rows: Optional[int] = None) -> jax.Array:
    """||A - U S Vt||_F / ||A||_F accumulated one row panel at a time.

    The historical `core.rsvd.low_rank_error` materializes the full m x n
    reconstruction — fine in-core, impossible for a streamed/host source.
    This walks `op.row_panels()`: per panel only a (block_rows x n) residual
    exists, so HostOp sources report error at streaming residency.  3-D
    stacked sources reduce over every slice (flat Frobenius norm)."""
    U, S, Vt = result
    op = as_linop(a)
    if len(op.shape) == 3:
        # One vmapped pass collecting (||R_i||^2, ||A_i||^2) per slice —
        # summed before the divide, so an all-zero slice contributes 0/0-free
        # and the stack is read exactly once.
        A3 = jnp.asarray(op.array).astype(jnp.float32)

        def _slice_sq(Ai, Ui, Si, Vti):
            R = Ai - (Ui.astype(jnp.float32) * Si.astype(jnp.float32)[None, :]) \
                @ Vti.astype(jnp.float32)
            return jnp.sum(R * R), jnp.sum(Ai * Ai)

        nums, dens = jax.vmap(_slice_sq)(A3, U, S, Vt)
        return jnp.sqrt(jnp.sum(nums) / jnp.sum(dens))
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    lo = 0
    scaled_vt = (S[:, None] * Vt).astype(jnp.float32)          # (k, n), skinny
    # prefetched walk: host panel i+1 transfers while panel i's residual
    # GEMM runs — same panels, same order, same accumulation
    for panel in prefetch_panels(op, block_rows):
        hi = lo + panel.shape[0]
        P = panel.astype(jnp.float32)
        R = P - U[lo:hi].astype(jnp.float32) @ scaled_vt
        num = num + jnp.sum(R * R)
        den = den + jnp.sum(P * P)
        lo = hi
    if lo != op.shape[0]:
        raise ValueError(f"row_panels covered {lo} of {op.shape[0]} rows")
    return jnp.sqrt(num / den)
