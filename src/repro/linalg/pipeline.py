"""Overlapped out-of-core panel pipeline: double-buffered prefetch.

The streamed/adaptive paths consume A one row panel at a time.  Before this
module, every panel was moved host->device *synchronously* — the sketch /
power GEMMs sat idle during the transfer and the transfer engine sat idle
during the GEMMs, so out-of-core walltime was ``sum(transfer) + sum(compute)``.
Lu et al. (arXiv:1706.07191) show the out-of-core block rSVD bottleneck is
exactly this serialization: with the copy of panel *i+1* issued while panel
*i* computes, walltime drops to ``max(transfer, compute)`` per panel plus a
fill/drain term (the overlap model in roofline/rsvd_model.py).

Two primitives, composed by `operators.prefetch_panels`:

  stream_host_panels   host (numpy) slices staged through a ring of `depth`
                       reusable uniform staging buffers (CUDA pinned-buffer
                       discipline, jax edition).  The tail panel is ZERO-
                       PADDED so every transfer has the same (block, n)
                       shape — one transfer program, jit-stable consumers —
                       and sliced back to its true height on device, so
                       yielded values are bit-identical to the synchronous
                       `jnp.asarray(array[lo:hi])`.
  lookahead            generic depth-deep pull-ahead over any panel
                       iterator: jax dispatches asynchronously, so *pulling*
                       panel i+1 (its slice / transfer / per-panel compose)
                       enqueues its production while the consumer's compute
                       on panel i is still running.

Only transfer ORDER changes — never arithmetic: each yielded panel holds
exactly the bytes the synchronous path would have moved, so every consumer
(core/blocked.py, core/adaptive.py, linalg.residual, HostOp products) stays
bit-identical at fixed seed, prefetched or not (tests/test_pipeline.py).

Depth resolution: an explicit ``depth`` argument wins; else the ambient
`default_depth(...)` scope (how the execution planner's ``pipeline_depth``
reaches duck-typed consumers like core/adaptive.py without threading a
parameter through every layer); else DEFAULT_DEPTH for host-resident
sources and 1 (no prefetch — today's behavior) for device-resident ones.

Early stop is safe: a consumer that abandons the iterator (adaptive QB
meeting its tolerance mid-stream) or raises mid-stream closes the
generator, whose ``finally`` fences every in-flight transfer before the
staging ring is released (`_await_in_flight`) — no DMA is ever left
reading a buffer a later stream may rewrite, and no estimator state ever
saw the un-consumed panels.

Fault tolerance (PR 7): the host->device put of each staged panel runs
under bounded retry-with-backoff (`TRANSFER_RETRIES`); a link that stays
down degrades the REST of the walk to the synchronous per-panel path
(`jnp.asarray`) instead of failing the solve — values stay bit-identical,
only overlap is lost.  Each produced panel also passes `_panel_probe`:
the fault-injection hooks (linalg/faults.py), the guard's per-panel
finiteness probe, and the `validate=` screen (raising a ValueError that
names the offending panel) all live there, and all cost nothing when
inactive.
"""
from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.linalg import faults as faults_mod
from repro.linalg import guard as guard_mod

#: bounded retry of a failed staging transfer, with exponential backoff
TRANSFER_RETRIES = 3
TRANSFER_BACKOFF_S = 0.02

#: prefetch depth for host-resident sources when neither the caller nor the
#: ambient scope says otherwise: classic double buffering (panel i computes
#: while panel i+1 transfers; deeper rings only help jittery links)
DEFAULT_DEPTH = 2

# Thread-local (like the guard's sink stack): the decomposition service
# runs solves on several worker threads, and one thread's ambient depth
# must not reach another thread's concurrent panel walk.
_depth_state = threading.local()


@contextlib.contextmanager
def default_depth(depth: Optional[int]):
    """Ambient prefetch depth for every panel walk in the scope.

    The planner stamps `pipeline_depth` on the ExecutionPlan; executors wrap
    the solve in this scope so duck-typed panel consumers (core/adaptive.py,
    HostOp.matmat) honor the plan without a threaded parameter."""
    prev = getattr(_depth_state, "depth", None)
    _depth_state.depth = depth
    try:
        yield
    finally:
        _depth_state.depth = prev


# Per-thread per-panel callback.  The serve-layer scheduler hangs its
# cooperative yield gate here: a long out-of-core job's panel walk calls the
# hook once per produced panel, and the gate uses those calls to hand the
# device to waiting short requests between panel groups.  Every panel path
# funnels through `_panel_probe`, so the hook covers the staged ring, the
# depth-1 synchronous walk, and `lookahead` alike.  One getattr when unset.
_hook_state = threading.local()


@contextlib.contextmanager
def panel_hook(fn):
    """Ambient per-panel callback for the CURRENT thread's panel walks.

    ``fn(ordinal)`` runs after each panel is produced (post fault/guard/
    validate probes), on the consuming thread — it may block, which is
    exactly how the scheduler's yield gate pauses a big job mid-walk.

    Relationship to `snapshot.boundary` (PR 10): this hook fires on panel
    PRODUCTION (the staging/prefetch side — device-sharing granularity),
    while the engines call the snapshot boundary on panel CONSUMPTION,
    after the panel's contribution is folded into their accumulators —
    the only point where captured state is consistent.  The two funnels
    are deliberately separate: a job parked by the gate holds no snapshot
    lock, and a snapshot save never blocks the gate."""
    prev = getattr(_hook_state, "fn", None)
    _hook_state.fn = fn
    try:
        yield
    finally:
        _hook_state.fn = prev


def resolve_depth(depth: Optional[int] = None, host_resident: bool = False,
                  source_default: Optional[int] = None) -> int:
    """Explicit depth > ambient scope > source attribute > auto.

    The ambient scope outranks `source_default` (an operator's own
    `pipeline_depth` attribute) deliberately: the scope is how an
    ExecutionPlan's budget-clamped depth reaches nested walks, and a
    source preference must not override what the planner decided fits.

    Auto is DEFAULT_DEPTH for host-resident sources on a REAL accelerator
    and 1 everywhere else: on the CPU backend "device" memory is host
    memory — there is no link to overlap, and the staging ring's extra
    panel copies are pure overhead (measured ~1.6x slower end-to-end), so
    prefetch there must be an explicit opt-in (testing the machinery)."""
    if depth:
        return max(1, int(depth))
    override = getattr(_depth_state, "depth", None)
    if override:
        return max(1, int(override))
    if source_default:
        return max(1, int(source_default))
    if host_resident and jax.default_backend() != "cpu":
        return DEFAULT_DEPTH
    return 1


#: jitted identity copy — a fresh device buffer (non-donated jit inputs are
#: never aliased to outputs), used to sever CPU zero-copy device_put aliases
_device_copy = jax.jit(jnp.copy)


def panel_bounds(m: int, b: int) -> List[Tuple[int, int]]:
    """[(lo, hi), ...] covering [0, m) in strides of b (last panel ragged)."""
    if b <= 0:
        raise ValueError(f"panel size must be positive, got {b}")
    return [(lo, min(lo + b, m)) for lo in range(0, m, b)]


def _panel_probe(idx: int, panel, rows: Optional[Tuple[int, int]] = None):
    """Per-produced-panel hook: fault injection, guard finiteness probe,
    `validate=` screen.  One module-global check when everything is off —
    the panel passes through untouched and unread."""
    panel = faults_mod.poison_panel(idx, panel)
    sink = guard_mod.active_sink()
    validating = guard_mod.validation_active()
    if sink is not None or validating:
        # the panel is already device-resident — this is a reduction over
        # bytes the solve was about to read anyway, not an extra pass over A
        finite = jnp.isfinite(panel).all()
        if sink is not None:
            sink.record_panel(idx, finite)
        if validating and not bool(finite):
            where = f"rows {rows[0]}:{rows[1]}" if rows else f"ordinal {idx}"
            raise ValueError(
                f"validate: non-finite values in input panel {idx} ({where}) "
                "— clean the source or drop validate=")
    hook = getattr(_hook_state, "fn", None)
    if hook is not None:
        hook(idx)
    return panel


class _StagingFailed(Exception):
    """Internal: a staged transfer failed after TRANSFER_RETRIES retries —
    the stream degrades to the synchronous walk from this panel on."""

    def __init__(self, idx: int):
        super().__init__(f"staging transfer failed at panel {idx}")
        self.idx = idx


def _await_in_flight(in_flight: List[Optional[jax.Array]]) -> None:
    """Fence every in-flight staged transfer (slot-reuse + early-exit
    safety: called from the stream's ``finally`` so a consumer raising or
    abandoning mid-stream can never leave a DMA reading ring memory)."""
    for dev in in_flight:
        if dev is not None:
            dev.block_until_ready()


def _put_with_retry(buf, idx: int) -> jax.Array:
    """`jax.device_put` with bounded retry-with-backoff on transfer errors
    (injected `flaky_link` faults or real runtime transfer failures).
    Raises `_StagingFailed` once the budget is spent."""
    delay = TRANSFER_BACKOFF_S
    for attempt in range(TRANSFER_RETRIES + 1):
        try:
            faults_mod.maybe_fail_transfer(idx)
            return jax.device_put(buf)
        except (faults_mod.TransferError, RuntimeError):
            if attempt == TRANSFER_RETRIES:
                raise _StagingFailed(idx) from None
            guard_mod.note_transfer_retry()
            time.sleep(delay)
            delay *= 2
    raise _StagingFailed(idx)  # unreachable


def stream_host_panels(
    array,
    bounds: Sequence[Tuple[int, int]],
    depth: int,
) -> Iterator[jax.Array]:
    """Device panels ``array[lo:hi]`` with `depth`-deep staged prefetch.

    A ring of `depth` reusable host staging buffers, each sized to the
    LARGEST panel (the tail is zero-padded up to it, so every
    `jax.device_put` ships the same uniform shape).  When panel *i* is
    yielded, panels *i+1 .. i+depth-1* are already in flight — jax's async
    dispatch runs those copies while the consumer computes on panel *i*.

    Slot-reuse safety: before a staging buffer is overwritten for panel
    *i+depth*, the device array produced from its PREVIOUS occupant
    (panel *i*) is awaited — by then that transfer finished long ago (the
    consumer is `depth` panels ahead), so the wait is ~free, but it makes
    overwriting the source memory of an in-flight DMA impossible.  On the
    CPU backend `jax.device_put` may ZERO-COPY an aligned host buffer — the
    "transfer" is permanent aliasing, which no await can fence — so there
    each staged panel is chased with an explicit on-device copy
    (`_device_copy`) and the slot wait lands on the copy instead; real
    accelerators DMA host memory and skip the extra hop.

    Yields are bit-identical to ``jnp.asarray(array[lo:hi])``: the pad rows
    are sliced back off on device before the consumer ever sees them.
    """
    bounds = list(bounds)
    if not bounds:
        return
    depth = max(1, min(int(depth), len(bounds)))
    if depth == 1:
        for i, (lo, hi) in enumerate(bounds):
            yield _panel_probe(i, jnp.asarray(array[lo:hi]), rows=(lo, hi))
        return
    block = max(hi - lo for lo, hi in bounds)
    n = array.shape[1]
    ring = [np.empty((block, n), dtype=array.dtype) for _ in range(depth)]
    in_flight: List[Optional[jax.Array]] = [None] * depth

    # On CPU, device_put of an aligned numpy buffer can alias it outright
    # (no copy ever happens) — reusing the slot would then rewrite panels a
    # consumer still holds.  An explicit device-side copy severs the alias;
    # waiting on the COPY before slot reuse guarantees its read of the
    # (possibly aliased) staging memory is complete.
    chase_copy = jax.default_backend() == "cpu"

    def stage(idx: int) -> jax.Array:
        lo, hi = bounds[idx]
        rows = hi - lo
        slot = idx % depth
        prev = in_flight[slot]
        if prev is not None:
            prev.block_until_ready()  # DMA/copy out of this slot must be done
        buf = ring[slot]
        buf[:rows] = array[lo:hi]
        if rows < block:
            buf[rows:] = 0  # uniform transfer shape, jit-stable
        faults_mod.corrupt_staged(idx, buf[:rows])
        dev = _put_with_retry(buf, idx)
        if chase_copy:
            dev = _device_copy(dev)
        in_flight[slot] = dev
        panel = dev if rows == block else dev[:rows]
        return _panel_probe(idx, panel, rows=(lo, hi))

    fallback_from: Optional[int] = None
    pending: collections.deque = collections.deque()
    try:
        for i in range(depth):
            try:
                pending.append(stage(i))
            except _StagingFailed as fail:
                fallback_from = fail.idx
                break
        nxt = depth
        while pending:
            panel = pending.popleft()
            if fallback_from is None and nxt < len(bounds):
                # issue the NEXT transfer before handing back control, so it
                # overlaps the consumer's compute on this panel
                try:
                    pending.append(stage(nxt))
                except _StagingFailed as fail:
                    fallback_from = fail.idx
                nxt += 1
            yield panel
        if fallback_from is not None:
            # the link stayed down through the retry budget: finish the walk
            # synchronously (same values, no overlap) instead of failing
            guard_mod.note_transfer_degraded()
            for i in range(fallback_from, len(bounds)):
                lo, hi = bounds[i]
                yield _panel_probe(i, jnp.asarray(array[lo:hi]), rows=(lo, hi))
    finally:
        _await_in_flight(in_flight)


def lookahead(panels: Iterable, depth: int) -> Iterator:
    """Pull up to `depth - 1` panels ahead of the consumer.

    The generic prefetch for sources whose panels are PRODUCED rather than
    copied (device-resident slices, composed per-panel transforms over an
    already-prefetched base): pulling enqueues the producer's async work,
    which then overlaps the consumer's compute on earlier panels.  Depth 1
    degrades to plain iteration — exactly the pre-pipeline behavior.

    Each pulled panel passes `_panel_probe` at production (fault hooks,
    guard finiteness probe, `validate=` screen) — free when all three are
    inactive."""
    if depth <= 1:
        for i, panel in enumerate(panels):
            yield _panel_probe(i, panel)
        return
    queue: collections.deque = collections.deque()
    for i, panel in enumerate(panels):
        queue.append(_panel_probe(i, panel))
        if len(queue) >= depth:
            yield queue.popleft()
    while queue:
        yield queue.popleft()
