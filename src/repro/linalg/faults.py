"""Fault injection for guarded-execution testing.

A context-manager registry of injectable faults, modelling the failure
modes the guard layer (guard.py) must detect and recover from:

  - ``nan_panel``          a produced source panel carries a NaN (the
                           "poisoned upload" model) — caught by the
                           per-panel finiteness probe / ``validate=``.
  - ``corrupt_transfer``   a staged host->device buffer is garbled in
                           place before the DMA (wrong bytes moved) —
                           caught downstream by the Gram/breakdown probes.
  - ``flaky_link``         ``jax.device_put`` on the staging path raises
                           :class:`TransferError` — absorbed by the
                           pipeline's bounded retry-with-backoff, which
                           degrades to the synchronous walk when the link
                           stays down.
  - ``cholesky_breakdown`` the Gram matrix handed to
                           ``qr.cholesky_r_from_gram`` gets a non-finite
                           entry, which the floor shift cannot rescue, so
                           the Cholesky diagonal goes NaN — this is the
                           forced-breakdown trigger for the retry ladder.
  - ``preempt``            the worker is preempted at a panel-group
                           boundary (:class:`PreemptionError`, raised from
                           ``snapshot.boundary``) — the transient-
                           interruption model behind the guard's
                           ``max_restarts`` restart policy and the
                           checkpoint/resume tests.
  - ``device_lost``        the accelerator disappears at a panel-group
                           boundary (:class:`DeviceLostError`) — same
                           firing site and restart semantics as
                           ``preempt``, modelling a device reset rather
                           than a scheduler eviction.

Trace-time safety contract: hooks that run *inside* jit-traced code
(``poison_gram``) are consulted only while a guard probe sink is active,
and the guarded compiled twins take :func:`fingerprint` as a static jit
argument.  Unguarded jitted programs therefore never trace with a fault
baked in, and a faulted trace can never shadow a clean cache entry.
:func:`fingerprint` includes per-fault firing counts, so a ``times``-limited
fault that fired at trace time forces a re-trace (without the fault) on
the next call instead of silently replaying the poisoned program.

Only stdlib + jax/numpy imports here: ``core/qr.py`` and ``pipeline.py``
reach this module via ``sys.modules`` / lazy imports, and nothing in
``repro.linalg`` may be imported at the top level (cycle hazard).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import jax.numpy as jnp

KINDS = ("nan_panel", "corrupt_transfer", "flaky_link", "cholesky_breakdown",
         "preempt", "device_lost")


class TransferError(RuntimeError):
    """Injected host->device transfer failure (``flaky_link``)."""


class PreemptionError(RuntimeError):
    """Injected worker preemption at a panel-group boundary (``preempt``)."""


class DeviceLostError(RuntimeError):
    """Injected device loss at a panel-group boundary (``device_lost``)."""


#: the transient-interruption class the guard's restart policy absorbs
#: (same rung, progress preserved through the ambient checkpointer) —
#: distinct from numerical breakdowns, which escalate the ladder instead
TRANSIENT_ERRORS = (PreemptionError, DeviceLostError)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One active fault.

    ``panel`` targets a single panel ordinal (None = every panel; ignored
    by ``cholesky_breakdown``).  ``times`` bounds how often the fault
    fires (None = unlimited; ``flaky_link`` defaults to 1 so the retry
    path is exercised rather than the degrade path).
    """

    kind: str
    panel: Optional[int] = None
    times: Optional[int] = None


# The fault registry is process-global (a fault injected on the test thread
# must be visible to service workers streaming panels), so registration and
# firing-count updates hold _registry_mu; jnp-path readers take snapshots.
_registry_mu = threading.Lock()
_active: List[Fault] = []
_fired: Dict[int, int] = {}


@contextlib.contextmanager
def inject(kind: str, panel: Optional[int] = None,
           times: Optional[int] = None) -> Iterator[Fault]:
    """Activate one fault for the duration of the ``with`` block."""
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; expected one of {KINDS}")
    if times is None and kind in ("flaky_link", "preempt", "device_lost"):
        # one firing by default: a single interruption exercises the
        # retry/restart path rather than a permanently dead environment
        times = 1
    fault = Fault(kind, panel, times)
    with _registry_mu:
        _active.append(fault)
        _fired[id(fault)] = 0
    try:
        yield fault
    finally:
        with _registry_mu:
            _active.remove(fault)
            _fired.pop(id(fault), None)


def any_active() -> bool:
    return bool(_active)


def fingerprint() -> Tuple:
    """Hashable key of the active fault set, including firing counts.

    Passed as a static argument to the guarded ("probed") jit twins so
    fault state participates in the compile cache key (see module
    docstring for why the counts matter).
    """
    return tuple((f.kind, f.panel, f.times, _fired[id(f)]) for f in _active)


def _matches(fault: Fault, kind: str, idx: Optional[int] = None) -> bool:
    if fault.kind != kind:
        return False
    if fault.panel is not None and idx is not None and fault.panel != idx:
        return False
    if fault.times is not None and _fired[id(fault)] >= fault.times:
        return False
    return True


def _fire(fault: Fault) -> None:
    with _registry_mu:
        _fired[id(fault)] += 1


def poison_panel(idx: int, panel):
    """``nan_panel``: overwrite one element of a produced panel with NaN."""
    if not _active:
        return panel
    for fault in list(_active):
        if _matches(fault, "nan_panel", idx):
            _fire(fault)
            panel = jnp.asarray(panel)
            panel = panel.reshape(-1).at[0].set(jnp.nan).reshape(panel.shape)
    return panel


def corrupt_staged(idx: int, buf) -> None:
    """``corrupt_transfer``: garble the staged host buffer in place.

    Fills with a large finite value so an f32 Gram overflows to inf and
    the Cholesky breakdown probe (not the finiteness probe) catches it.
    """
    if not _active:
        return
    for fault in list(_active):
        if _matches(fault, "corrupt_transfer", idx):
            _fire(fault)
            if buf.dtype.kind == "f":
                buf[...] = 1.0e30


def maybe_fail_transfer(idx: int) -> None:
    """``flaky_link``: raise :class:`TransferError` before a device_put."""
    if not _active:
        return
    for fault in list(_active):
        if _matches(fault, "flaky_link", idx):
            _fire(fault)
            raise TransferError(
                f"injected flaky host->device link at panel {idx}")


def maybe_interrupt(idx: int) -> None:
    """``preempt`` / ``device_lost``: raise at panel-group boundary ``idx``
    (the `snapshot.boundary` funnel — panel-targeted and count-limited like
    ``nan_panel``, so tests can interrupt one specific boundary once)."""
    if not _active:
        return
    for fault in list(_active):
        if _matches(fault, "preempt", idx):
            _fire(fault)
            raise PreemptionError(
                f"injected preemption at panel-group boundary {idx}")
        if _matches(fault, "device_lost", idx):
            _fire(fault)
            raise DeviceLostError(
                f"injected device loss at panel-group boundary {idx}")


def poison_gram(G):
    """``cholesky_breakdown``: non-finite Gram entry (guarded runs only).

    Callers gate this on an active guard sink — see the trace-time safety
    contract in the module docstring.
    """
    if not _active:
        return G
    for fault in list(_active):
        if _matches(fault, "cholesky_breakdown"):
            _fire(fault)
            G = G.at[0, 0].set(jnp.nan)
    return G
