"""Accuracy specs: *what* a decomposition must achieve, not *what rank to run*.

The paper's Algorithm 1 takes a target rank `k`, but the applications it
serves (compression, PCA, low-rank serving) actually know an *accuracy*:
"2% Frobenius error" or "95% of the variance".  A `Spec` states that
contract; the planner and the adaptive QB engine (core/adaptive.py) turn it
into an execution:

  Rank(k)                  fixed rank — the historical entry points, exactly
  Tolerance(eps)           grow the basis until ||A - QB||_F <= eps ||A||_F
  Energy(p)                grow until the basis captures fraction p of
                           ||A||_F^2 (PCA's explained-variance contract)

`Tolerance`/`Energy` share one stopping machinery: the posterior estimator
``remaining = ||A||_F^2 - ||B||_F^2`` (exact for an orthonormal basis Q, see
core/adaptive.py), so both reduce to a threshold on the remaining energy —
`threshold_sq` below.  After the basis converges, `select_rank` trims the
revealed spectrum to the smallest rank that still meets the spec (the
±panel overshoot of blocked growth is removed).

Every spec also carries a ``sketch`` knob — "gaussian" (default, None),
"rademacher", "srht", or "countsketch" — naming the test-matrix family the
range finder draws.  The structured kinds (core/sketch.py) apply in
O(mn log n) / O(mn) instead of the O(mns) Gaussian GEMM; the planner
resolves the knob into the executed config (falling back to gaussian on
paths that can't stream a structured sketch) so the plan records what runs.

Rank-selection boundary semantics (pinned by tests/test_decompose.py):
`select_rank` returns the SMALLEST rank meeting the contract, with both
comparisons INCLUSIVE (residual <= target, captured >= p * total), clamped
to at least 1, and falling back to every revealed value when the contract
is unreachable.  Tolerance indexes residuals by "values kept" (resid[j] =
remaining + tail[j], so ok[0] IS the rank); Energy indexes cumulative
capture 0-based (rank = ok[0] + 1).  The two expressions differ but the
semantics are identical.

Specs are frozen/hashable: they ride inside `ExecutionPlan` (a jit static
argument) and serialize through `dataclasses.asdict` into BENCH_rsvd.json.

A spec states the ACCURACY contract only; numerical-health policy is the
separate `GuardPolicy` knob threaded the same way (`plan(..., guard=...)`,
linalg/guard.py) — the two compose on one plan without knowing each other.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def _validate_sketch(sketch: Optional[str]) -> None:
    if sketch is None:
        return
    from repro.core.sketch import SKETCH_KINDS

    if sketch not in SKETCH_KINDS:
        raise ValueError(
            f"unknown sketch kind {sketch!r} (choose from {SKETCH_KINDS})"
        )


@dataclass(frozen=True)
class Spec:
    """Base accuracy spec.  See `Rank`, `Tolerance`, `Energy`."""

    def describe(self) -> str:
        raise NotImplementedError

    def threshold_sq(self, norm_sq: float) -> Optional[float]:
        """Stop growing the basis once the estimated remaining energy
        ||A - QB||_F^2 drops to this value (None = fixed-rank, no stop)."""
        return None

    def select_rank(self, svals, remaining_sq: float, norm_sq: float) -> int:
        """Trim the revealed spectrum: smallest rank meeting the spec.

        ``svals`` are the singular values of B (== those of QB, Q
        orthonormal), descending; ``remaining_sq`` is the estimated energy
        outside range(Q).  Rank j leaves a squared residual of
        ``remaining_sq + sum(svals[j:]**2)``."""
        raise NotImplementedError


@dataclass(frozen=True)
class Rank(Spec):
    """Fixed target rank — the paper's original contract."""

    k: int
    sketch: Optional[str] = None

    def __post_init__(self):
        if not isinstance(self.k, (int, np.integer)) or isinstance(self.k, bool):
            raise ValueError(f"Rank takes an integer k, got {self.k!r}")
        _validate_sketch(self.sketch)

    def describe(self) -> str:
        base = f"rank(k={self.k}"
        return base + (f", sketch={self.sketch})" if self.sketch else ")")

    def select_rank(self, svals, remaining_sq, norm_sq) -> int:
        return min(self.k, len(svals))


def _tail_sq(svals) -> np.ndarray:
    """tail_sq[j] = sum(svals[j:]**2) for j = 0..len, in float64."""
    sq = np.asarray(svals, np.float64) ** 2
    return np.concatenate([np.cumsum(sq[::-1])[::-1], [0.0]])


@dataclass(frozen=True)
class Tolerance(Spec):
    """Relative-error target: ||A - A_r||_norm <= eps * ||A||_norm.

    Only ``norm="fro"`` is implemented (the posterior estimator is exact in
    the Frobenius norm; a spectral-norm stop would need power iteration on
    the residual operator).  ``panel`` overrides the autotune-sized growth
    panel; ``max_rank`` caps the search (default min(m, n) — the full-rank
    fallback when the tolerance is unreachable)."""

    eps: float
    norm: str = "fro"
    max_rank: Optional[int] = None
    panel: Optional[int] = None
    sketch: Optional[str] = None

    def __post_init__(self):
        if not (float(self.eps) > 0.0):
            raise ValueError(f"Tolerance eps must be positive, got {self.eps}")
        if self.norm != "fro":
            raise ValueError(
                f"Tolerance norm={self.norm!r} not supported (only 'fro' — the"
                " posterior energy estimator is a Frobenius identity)"
            )
        _validate_sketch(self.sketch)

    def describe(self) -> str:
        base = f"tol(eps={float(self.eps):g}"
        return base + (f", sketch={self.sketch})" if self.sketch else ")")

    def threshold_sq(self, norm_sq: float) -> float:
        return float(self.eps) ** 2 * norm_sq

    def select_rank(self, svals, remaining_sq, norm_sq) -> int:
        target = self.threshold_sq(norm_sq)
        resid = remaining_sq + _tail_sq(svals)          # resid[j]: keep j vals
        ok = np.nonzero(resid <= target)[0]
        return max(1, int(ok[0])) if ok.size else len(svals)


@dataclass(frozen=True)
class Energy(Spec):
    """Captured-energy target: keep the smallest rank whose components hold
    fraction ``p`` of ||A||_F^2 (PCA's explained-variance contract)."""

    p: float
    max_rank: Optional[int] = None
    panel: Optional[int] = None
    sketch: Optional[str] = None

    def __post_init__(self):
        if not (0.0 < float(self.p) <= 1.0):
            raise ValueError(f"Energy fraction p must be in (0, 1], got {self.p}")
        _validate_sketch(self.sketch)

    def describe(self) -> str:
        base = f"energy(p={float(self.p):g}"
        return base + (f", sketch={self.sketch})" if self.sketch else ")")

    def threshold_sq(self, norm_sq: float) -> float:
        # captured >= p * total  <=>  remaining <= (1 - p) * total
        return (1.0 - float(self.p)) * norm_sq

    def select_rank(self, svals, remaining_sq, norm_sq) -> int:
        captured = np.cumsum(np.asarray(svals, np.float64) ** 2)
        ok = np.nonzero(captured >= float(self.p) * norm_sq)[0]
        return int(ok[0]) + 1 if ok.size else len(svals)


def as_spec(x) -> Spec:
    """Coerce the facade's rank-or-spec argument: ints become `Rank`."""
    if isinstance(x, Spec):
        return x
    if isinstance(x, (int, np.integer)) and not isinstance(x, bool):
        return Rank(int(x))
    raise ValueError(
        f"expected a rank (int) or a Spec (Rank/Tolerance/Energy), got {x!r}"
    )
