"""Panel-granular snapshot/resume for the streamed and adaptive engines.

A long out-of-core solve (Lu et al.: A far beyond device memory, minutes of
panel streaming) is all-or-nothing without this module: any preemption,
worker crash or persistent fault re-runs the whole solve from panel 0.
Here every streamed path can persist its engine state at panel-group
boundaries and be restored into a solve **bit-identical to the
uninterrupted run**:

  capture    the engines (core/blocked.py stage machine, core/adaptive.py
             growth loop) expose their accumulated state — basis panels,
             B blocks, Gram/estimator accumulators, the panel cursor, the
             per-panel counter-RNG offsets (a step index: Omega slabs are
             regenerated from ``seed + step``, never stored) — as a flat
             dict of host arrays plus a JSON-able meta dict.
  persist    `Checkpointer` writes each snapshot with the atomic publish
             pattern of repro.checkpoint: write to ``snap_<N>.tmp``, fsync
             the payload and manifest, ``os.rename``, then fsync the
             PARENT directory (the rename itself is durable).  A crash
             mid-save can never corrupt the previous snapshot, and
             ``latest()`` skips ``.tmp`` debris.
  restore    the engines probe `resume(token)` at solve start; a snapshot
             whose ``token`` (the engine's own fingerprint of shapes,
             seed, config and panel schedule) matches is rehydrated and
             the solve continues from the saved cursor.  Everything the
             engines recompute on restore (CholeskyQR bases from saved
             Y/Gram panels, Omega slabs from counter-RNG offsets) is a
             deterministic function of saved bytes, so resumed factors
             are bit-identical to the uninterrupted run at fixed seed.

`boundary(step, capture)` is the single per-boundary funnel the engines
call (through ``sys.modules`` — repro.core never imports repro.linalg at
module level).  In order it:

  1. fires the ``preempt`` / ``device_lost`` injected faults
     (linalg/faults.py) — the transient-interruption model that drives the
     guard's restart policy and the resume tests;
  2. checks the ambient `RunControl` for cooperative cancellation and the
     request deadline, saving a final snapshot and raising `Cancelled` /
     `DeadlineExceeded` (each carrying the snapshot path) when tripped;
  3. saves a snapshot when one is due (``Checkpointer.every``).

With no control in scope and no faults active the whole call is two
dictionary probes — checkpoint-off execution stays byte-identical in
predicted HBM traffic (snapshot writes are host-side only; nothing here
ever reads A or touches device memory).

The control scope is THREAD-LOCAL (the `qr.kernel_backend` /
`pipeline.default_depth` pattern): the decomposition service runs solves
on concurrent worker threads, and one request's deadline or checkpoint
directory must never leak into another's solve.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.linalg import faults as faults_mod


class Cancelled(RuntimeError):
    """Cooperative cancellation observed at a panel-group boundary.

    ``snapshot_path`` is the final snapshot saved before raising (None when
    the run had no checkpointer) — resubmitting with the same checkpoint
    directory resumes from exactly this point."""

    def __init__(self, message: str, snapshot_path: Optional[str] = None):
        super().__init__(message)
        self.snapshot_path = snapshot_path


class DeadlineExceeded(TimeoutError):
    """The request deadline passed; checked at panel-group boundaries.
    Carries ``snapshot_path`` like `Cancelled` — the partial solve is not
    lost, it is parked."""

    def __init__(self, message: str, snapshot_path: Optional[str] = None):
        super().__init__(message)
        self.snapshot_path = snapshot_path


@dataclasses.dataclass(frozen=True)
class SnapshotRef:
    """Identity of one persisted snapshot — frozen/hashable (it rides in
    exceptions and job-store manifests as a key, linted by RL003)."""

    token: str
    step: int
    path: str


class Checkpointer:
    """Atomic snapshot persistence for one solve (or one resumable job).

    Layout:  <dir>/snap_<step:08d>/
               manifest.json   — token, step, meta (engine state scalars)
               state.npz       — the engine's array state, exact bytes

    ``every`` saves one snapshot per ``every`` boundaries (the panel-group
    granularity); `save_now` ignores the cadence (the cancel/deadline final
    snapshot).  All methods are called from the solving thread only; the
    instance keeps a lock anyway so a service can read `overhead_s` while
    a solve runs."""

    def __init__(self, directory, every: int = 1, keep_last: int = 2):
        if every < 1:
            raise ValueError(f"checkpoint cadence must be >= 1, got {every}")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.every = int(every)
        self.keep_last = max(1, int(keep_last))
        self._mu = threading.Lock()
        self._boundaries = 0
        self._saves = 0
        self._overhead_s = 0.0

    # ---------------- save -------------------------------------------------

    def maybe_save(self, step: int, capture: Callable) -> Optional[str]:
        """Save when a snapshot is due at this boundary (every-th call)."""
        self._boundaries += 1
        if self._boundaries % self.every:
            return None
        return self.save_now(step, capture)

    def save_now(self, step: int, capture: Callable) -> str:
        """Capture and persist unconditionally (atomic publish)."""
        t0 = time.perf_counter()
        arrays, meta = capture()
        tmp = self.dir / f"snap_{step:08d}.tmp"
        final = self.dir / f"snap_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        with open(tmp / "state.npz", "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        with open(tmp / "manifest.json", "w") as f:
            json.dump({"step": int(step), **meta}, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        fsync_dir(self.dir)  # make the rename itself durable
        self._gc()
        with self._mu:
            self._saves += 1
            self._overhead_s += time.perf_counter() - t0
        return str(final)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"snap_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------------------------------------

    def steps(self) -> list:
        out = []
        for p in self.dir.glob("snap_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue  # crash-mid-save debris is never picked up
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest(self, token: str):
        """Newest snapshot whose token matches, or None (a stale snapshot
        from a different plan/seed/shape silently yields a fresh run).
        Returns ``(SnapshotRef, arrays, meta)``."""
        for s in reversed(self.steps()):
            d = self.dir / f"snap_{s:08d}"
            meta = json.loads((d / "manifest.json").read_text())
            if meta.get("token") != token:
                continue
            with np.load(d / "state.npz") as data:
                arrays = {k: np.asarray(data[k]) for k in data.files}
            return SnapshotRef(token=token, step=s, path=str(d)), arrays, meta
        return None

    # ---------------- accounting -------------------------------------------

    @property
    def overhead_s(self) -> float:
        """Walltime spent capturing + persisting (host-side only)."""
        with self._mu:
            return self._overhead_s

    @property
    def saves(self) -> int:
        with self._mu:
            return self._saves


def fsync_dir(path) -> None:
    """fsync a DIRECTORY: after `os.rename(tmp, final)` the rename lives in
    the parent directory's metadata, which a power failure can still lose
    unless the directory itself is synced.  No-op on platforms that refuse
    to open directories."""
    try:
        fd = os.open(str(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# ---------------------------------------------------------------------------
# the ambient run control (thread-local scope)
# ---------------------------------------------------------------------------

class RunControl:
    """Everything a resumable run needs at its boundaries: the checkpointer
    (None = no persistence), the absolute monotonic deadline (None = no
    deadline) and the cooperative cancel event (None = not cancellable)."""

    def __init__(self, checkpointer: Optional[Checkpointer] = None,
                 deadline_t: Optional[float] = None,
                 cancel_event: Optional[threading.Event] = None):
        self.checkpointer = checkpointer
        self.deadline_t = deadline_t
        self.cancel_event = cancel_event


_control_state = threading.local()


@contextlib.contextmanager
def scope(control: RunControl) -> Iterator[RunControl]:
    """Make ``control`` ambient for the current thread's solves (stack
    discipline, like guard.collecting)."""
    prev = getattr(_control_state, "control", None)
    _control_state.control = control
    try:
        yield control
    finally:
        _control_state.control = prev


def active() -> Optional[RunControl]:
    return getattr(_control_state, "control", None)


def as_control(checkpoint) -> Optional[RunControl]:
    """Coerce the facade's ``checkpoint=`` argument: a directory path or a
    `Checkpointer` becomes a checkpoint-only control; a `RunControl` passes
    through (the service builds those, adding deadline/cancel); None is
    None."""
    if checkpoint is None:
        return None
    if isinstance(checkpoint, RunControl):
        return checkpoint
    if isinstance(checkpoint, Checkpointer):
        return RunControl(checkpointer=checkpoint)
    return RunControl(checkpointer=Checkpointer(checkpoint))


@contextlib.contextmanager
def maybe_scope(checkpoint) -> Iterator[Optional[RunControl]]:
    """`scope` that is a no-op for ``checkpoint=None`` (an outer control —
    e.g. the service's — stays visible instead of being shadowed)."""
    ctl = as_control(checkpoint)
    if ctl is None:
        yield None
        return
    with scope(ctl):
        yield ctl


# ---------------------------------------------------------------------------
# the per-boundary funnel
# ---------------------------------------------------------------------------

def boundary(step: int, capture: Callable[[], Tuple[Dict, Dict]]) -> None:
    """One panel-group boundary of a resumable engine.  ``capture`` is only
    called when a snapshot is actually written — with nothing in scope this
    costs two dict probes and moves zero bytes."""
    faults_mod.maybe_interrupt(step)
    ctl = active()
    if ctl is None:
        return
    ckpt = ctl.checkpointer
    if ctl.cancel_event is not None and ctl.cancel_event.is_set():
        path = ckpt.save_now(step, capture) if ckpt is not None else None
        raise Cancelled(
            f"cancelled at panel-group boundary {step}"
            + (f" (snapshot: {path})" if path else ""),
            snapshot_path=path)
    if ctl.deadline_t is not None and time.monotonic() >= ctl.deadline_t:
        path = ckpt.save_now(step, capture) if ckpt is not None else None
        raise DeadlineExceeded(
            f"deadline exceeded at panel-group boundary {step}"
            + (f" (snapshot: {path})" if path else ""),
            snapshot_path=path)
    if ckpt is not None:
        ckpt.maybe_save(step, capture)


def resume(token: str):
    """The engines' restore probe: the ambient checkpointer's newest
    token-matching snapshot as ``(SnapshotRef, arrays, meta)``, or None
    (no control, no checkpointer, or no compatible snapshot)."""
    ctl = active()
    if ctl is None or ctl.checkpointer is None:
        return None
    return ctl.checkpointer.latest(token)
