# repro.linalg — the operator-source + execution-planner facade over every
# randomized-SVD path in the repo (dense / streamed / batched / sharded /
# matrix-free).  See DESIGN.md §"API: operators and plans".
from repro.core.rsvd import RSVDConfig, low_rank_error, truncation_error  # noqa: F401
from repro.linalg.api import eigvals, pca, plan, residual, svd  # noqa: F401
from repro.linalg.operators import (  # noqa: F401
    CenteredOp,
    DenseOp,
    HostOp,
    LinOp,
    LowRankUpdateOp,
    ScaledOp,
    ShardedOp,
    StackedOp,
    as_linop,
    column_means,
    deflated,
)
from repro.linalg.planner import Budget, ExecutionPlan  # noqa: F401
