# repro.linalg — the operator-source + execution-planner facade over every
# randomized-SVD path in the repo (dense / streamed / batched / sharded /
# matrix-free / adaptive), plus the spec-driven decomposition registry
# (svd / eigh / qb / lu / pca).  See DESIGN.md §"API: operators and plans"
# and §"Specs and the decomposition registry".
from repro.core.rsvd import RSVDConfig, low_rank_error, truncation_error  # noqa: F401
from repro.linalg.api import (  # noqa: F401
    Decomposition,
    decompose,
    eigvals,
    pca,
    plan,
    residual,
    svd,
)
from repro.linalg.operators import (  # noqa: F401
    CenteredOp,
    DenseOp,
    HostOp,
    LinOp,
    LowRankUpdateOp,
    ScaledOp,
    ShardedOp,
    SparseOp,
    StackedOp,
    as_linop,
    column_means,
    deflated,
    prefetch_panels,
)
from repro.linalg import faults  # noqa: F401
from repro.linalg import guard  # noqa: F401
from repro.linalg import pipeline  # noqa: F401
from repro.linalg import snapshot  # noqa: F401
from repro.linalg.guard import GuardPolicy, HealthReport  # noqa: F401
from repro.linalg.snapshot import (  # noqa: F401
    Cancelled,
    Checkpointer,
    DeadlineExceeded,
    RunControl,
)
from repro.linalg.planner import Budget, ExecutionPlan  # noqa: F401
from repro.linalg.registry import (  # noqa: F401
    DecompositionKind,
    cached_plan,
    clear_plan_cache,
    kinds,
    plan_cache_stats,
    register,
)
from repro.linalg.spec import Energy, Rank, Spec, Tolerance, as_spec  # noqa: F401
