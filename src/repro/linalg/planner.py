"""Execution planner: `plan(op, k, budget) -> ExecutionPlan`.

Replaces the ad-hoc `if` ladder that used to live in `core.rsvd.randomized_svd`
plus the hand-tuned `RSVDConfig` execution switches (`fused_power`,
`kernel_backend`, `block_rows`, `batched`).  The planner inspects the
operator source (shape, dtype, residency, sharding), the device, the VMEM /
HBM budget, and the `kernels/autotune.py` block-size cache, and emits an
inspectable `ExecutionPlan` that `linalg.svd / eigvals / pca` execute.

`RSVDConfig` survives as a thin frozen view for explicit overrides: passing
`overrides=RSVDConfig...` reproduces the pre-planner dispatch decisions
bit-for-bit (the presets `faithful()` / `fast()` / `streaming()` map onto
plans 1:1), with the same VMEM gate the dense body applies — so a plan's
`fused_power` field is the EFFECTIVE decision, never an unhonored request.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rsvd import RSVDConfig
from repro.linalg import guard as guard_mod
from repro.linalg import operators as ops_mod
from repro.linalg import pipeline as pipeline_mod
from repro.linalg import spec as spec_mod
from repro.linalg.operators import LinOp, as_linop
from repro.linalg.spec import Rank, Spec
from repro.roofline import rsvd_model

#: execution paths the planner can choose
PATHS = ("dense", "streamed", "batched", "sharded", "matfree", "adaptive", "sparse")


@dataclass(frozen=True)
class Budget:
    """Hardware envelope the planner fits a solve into.

    Unset fields resolve to the single source of truth — the per-kernel
    VMEM working-set budget (kernels/power_step.py) and the TPU-v5e HBM
    size (roofline/hw.py) — so a partially-specified Budget can never
    freeze a stale copy of either constant.  `vmem_bytes` can only
    TIGHTEN the fusion gate: the fused body re-checks the compiled-in
    budget at trace time, so a plan claiming fusion past it would lie
    about what executes (see `_effective_fused_power`)."""

    vmem_bytes: Optional[int] = None
    hbm_bytes: Optional[int] = None

    def __post_init__(self):
        from repro.kernels.power_step import VMEM_BUDGET_BYTES
        from repro.roofline import hw

        if self.vmem_bytes is None:
            object.__setattr__(self, "vmem_bytes", VMEM_BUDGET_BYTES)
        if self.hbm_bytes is None:
            object.__setattr__(self, "hbm_bytes", hw.HBM_BYTES)

    @staticmethod
    def default() -> "Budget":
        return Budget()


@dataclass(frozen=True)
class ExecutionPlan:
    """The planner's full decision record — every field the executor reads,
    plus the roofline prediction, so a plan is inspectable and loggable
    (benchmarks/bench_rsvd.py persists executed plans to BENCH_rsvd.json)."""

    path: str                      # dense | streamed | batched | sharded | matfree | adaptive | sparse
    m: int                         # post-orientation tall dim (m >= n); adaptive
    n: int                         # plans record the EXECUTED (source) orientation
    k: int
    s: int                         # sketch width = min(k + oversample, n)
    batch: int                     # leading batch dim (1 unless path=batched)
    dtype: str
    # numerical variant (Algorithm 1 switches)
    oversample: int
    power_iters: int
    power_scheme: str
    qr_method: str
    small_svd: str
    sketch_kind: str
    # execution switches (all EFFECTIVE — gates already applied)
    fused_sketch: bool
    fused_power: bool
    kernel_backend: str
    block_rows: Optional[int]
    block_cols: Optional[int]
    blocks: Tuple[int, int, int]   # (bm, bn, bk) the kernels will tile with
    predicted_hbm_bytes: int       # roofline/rsvd_model.py whole-solve bytes
    # spec-driven decomposition fields (PR 4): what the caller asked for and,
    # for adaptive (fixed-precision) plans, the planned rank growth.  For
    # Rank specs, k above IS the target; for Tolerance/Energy, k records the
    # max-rank cap (the full-rank fallback) and s the growth-panel sketch
    # width.
    kind: str = "svd"                           # registry entry to execute
    spec: Optional[Spec] = None                 # the accuracy contract
    panel: Optional[int] = None                 # adaptive growth-panel width
    rank_schedule: Tuple[int, ...] = ()         # planned cumulative basis sizes
    schedule_hbm_bytes: Tuple[int, ...] = ()    # roofline bytes per growth step
    # out-of-core pipeline fields (PR 5): how deep the panel prefetch runs
    # (1 = fully synchronous — the pre-pipeline behavior) and the overlap-
    # aware walltime prediction (rsvd_model.streamed_walltime_s for streamed
    # plans, plain HBM-bandwidth time elsewhere).
    pipeline_depth: int = 1
    predicted_walltime_s: float = 0.0
    # sparse-source fields (PR 6): stored nonzeros and density of the solve's
    # base operator.  Set whenever the source (possibly under a composition)
    # is a SparseOp — the traffic prediction then prices every read of A at
    # nnz * (value + index) bytes (rsvd_model.sparse_* functions).
    nnz: Optional[int] = None
    density: Optional[float] = None
    # guarded-execution fields (PR 7): how the executor watches / recovers
    # this solve (linalg/guard.py) and whether input is screened for
    # non-finite values up front.  Neither changes the numerics of a
    # healthy solve: guard "off" and validate=False are the pre-guard
    # behavior bit-for-bit, and "report" only adds probe reductions on
    # byproducts (no extra reads of A — predicted_hbm_bytes is unchanged).
    guard: guard_mod.GuardPolicy = guard_mod.GuardPolicy()
    validate: bool = False

    def fingerprint(self) -> str:
        """Stable string identity of the numerics this plan executes — the
        fields that determine the op sequence at fixed seed (prediction
        fields and guard/validate knobs excluded: they never change a
        healthy solve's bytes).  Job-store manifests persist this so a
        restored service only resumes a job whose re-planned execution is
        the one the snapshot came from."""
        return "|".join(str(x) for x in (
            self.path, self.m, self.n, self.k, self.s, self.batch,
            self.dtype, self.oversample, self.power_iters, self.power_scheme,
            self.qr_method, self.small_svd, self.sketch_kind,
            self.fused_sketch, self.fused_power, self.kernel_backend,
            self.block_rows, self.block_cols, self.kind, self.panel,
            self.pipeline_depth, self.nnz))

    def to_config(self) -> RSVDConfig:
        """The thin frozen RSVDConfig view the core numerics execute."""
        return RSVDConfig(
            oversample=self.oversample,
            power_iters=self.power_iters,
            power_scheme=self.power_scheme,
            qr_method=self.qr_method,
            small_svd=self.small_svd,
            sketch_kind=self.sketch_kind,
            fused_sketch=self.fused_sketch,
            fused_power=self.fused_power,
            kernel_backend=self.kernel_backend,
            block_rows=self.block_rows if self.path == "streamed" else None,
            block_cols=self.block_cols,
            batched=self.path == "batched",
            pipeline_depth=self.pipeline_depth if self.path == "streamed" else None,
        )

    def describe(self) -> str:
        """One-line human summary (examples/quickstart.py prints this)."""
        shape = f"{self.batch}x{self.m}x{self.n}" if self.batch > 1 else f"{self.m}x{self.n}"
        spec_str = self.spec.describe() if self.spec is not None else f"rank(k={self.k})"
        bits = [
            f"path={self.path}", f"shape={shape}", f"k={self.k}", f"s={self.s}",
            f"kind={self.kind}", f"spec={spec_str}",
            f"qr={self.qr_method}", f"backend={self.kernel_backend}",
            f"fused_sketch={self.fused_sketch}", f"fused_power={self.fused_power}",
            f"pipeline_depth={self.pipeline_depth}",
        ]
        if self.guard.mode != "off":
            bits.append(f"guard={self.guard.mode}")
        if self.validate:
            bits.append("validate=on")
        if self.block_rows:
            bits.append(f"block_rows={self.block_rows}")
        if self.path == "adaptive":
            bits.append(f"panel={self.panel}")
            bits.append(f"steps={len(self.rank_schedule)}")
        if self.nnz is not None:
            bits.append(f"nnz={self.nnz}")
            bits.append(f"density={self.density:.4g}")
        bits.append(f"pred_hbm={self.predicted_hbm_bytes / 1e6:.1f}MB")
        return " ".join(bits)


def _is_f64(dtype) -> bool:
    return jnp.dtype(dtype) == jnp.float64


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_path(op: LinOp, cfg: Optional[RSVDConfig]) -> str:
    """The dispatch ladder, now in one inspectable place.

    With explicit overrides this reproduces the historical
    `core.rsvd.randomized_svd` dispatch exactly: 3-D / `batched` -> batched,
    `block_rows` -> streamed, everything else dense (host numpy included —
    the old entry point moved it to device wholesale).  Without overrides
    the operator's residency decides: host-resident sources stream."""
    if isinstance(op, (ops_mod.ComposedOp, ops_mod._TransposedOp)):
        return "matfree"
    if op.sharding is not None:
        return "sharded"
    if len(op.shape) == 3:
        return "batched"
    if isinstance(op, ops_mod.SparseOp):
        # the sparse path IS the matfree operator body, named so the plan
        # (and its SpMM traffic pricing) is distinguishable and loggable
        return "sparse"
    if not isinstance(op, ops_mod.DenseOp):
        # protocol-only sources have no .array to hand the dense/streamed
        # executors — they run the generic operator body, overrides or not
        return "matfree"
    if cfg is not None:
        if cfg.batched:
            return "batched"
        if cfg.block_rows:
            return "streamed"
        # An explicitly constructed HostOp (or a block_rows-carrying source)
        # expresses out-of-core intent that numerical overrides must not
        # discard — moving the whole host array to device would defeat the
        # residency contract.  The deprecation shim wraps raw arrays in
        # DenseOp, so the historical wholesale-dense dispatch is unaffected.
        if isinstance(op, ops_mod.HostOp) or op.block_rows:
            return "streamed"
        return "dense"
    if isinstance(op, ops_mod.HostOp) or op.block_rows:
        return "streamed"
    return "dense"


def _default_config(op: LinOp, path: str, budget: Budget) -> RSVDConfig:
    """Planner defaults when the caller gives no overrides: device- and
    dtype-aware versions of the faithful/fast/streaming presets."""
    f64 = _is_f64(op.dtype)
    if path == "streamed":
        block = op.block_rows or ops_mod.HostOp.DEFAULT_BLOCK_ROWS
        # Shrink the panel until one panel + sketch-width state fits a
        # quarter of the HBM budget (leave room for Y/Q/U and the caller).
        # Panels are block_rows x n AFTER orientation (the streamed body
        # factors the taller side), so the row length is the SHORT dim.
        n = min(op.shape[-2], op.shape[-1])
        itemsize = jnp.dtype(op.dtype).itemsize
        while block > 256 and block * n * itemsize > budget.hbm_bytes // 4:
            block //= 2
        return dataclasses.replace(RSVDConfig.streaming(block_rows=block),
                                   fused_sketch=_on_tpu() and not f64,
                                   kernel_backend="pallas" if _on_tpu() and not f64 else "jnp")
    if f64:
        if path == "adaptive":
            # the adaptive body is CholeskyQR-shaped (deflation + CGS2); the
            # jnp backend keeps the faithful f64 precision end to end
            return RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                              small_svd="lapack")
        return RSVDConfig.faithful()  # the paper's dgesvd setting: jnp, no fusion
    if _on_tpu():
        if path == "dense":
            return RSVDConfig.fast()
        # batched / sharded / matfree: the CQR Gram+TRSM primitives route
        # through the Pallas kernels on every path that honors the backend
        return RSVDConfig(power_scheme="stabilized", qr_method="cqr2",
                          small_svd="lapack", fused_sketch=True,
                          kernel_backend="pallas")
    # CPU / interpret-mode hosts: the Pallas kernels are a correctness
    # harness there, not a perf mode — stay on the XLA GEMMs.
    return RSVDConfig(power_scheme="stabilized", qr_method="cqr2")


def _effective_fused_power(m: int, n: int, s: int, dtype, cfg: RSVDConfig,
                           path: str, budget: Budget) -> bool:
    """The dense body's fusion gate, evaluated at plan time.  Delegates to
    the SAME predicate the dense body uses (core.rsvd._use_fused_power,
    parameterized by the plan's VMEM budget) so plan and execution can
    never drift apart.  The budget is clamped to the kernel's compiled-in
    VMEM_BUDGET_BYTES: the body re-checks that constant at trace time, so
    a looser Budget must not make the plan claim a fusion that would not
    actually execute."""
    if path != "dense":
        return False  # vmap (batched) and panel/shard bodies never fuse power
    from repro.core.rsvd import _use_fused_power
    from repro.kernels.power_step import VMEM_BUDGET_BYTES

    shape = jax.ShapeDtypeStruct((m, n), dtype)
    vmem = min(budget.vmem_bytes, VMEM_BUDGET_BYTES)
    return _use_fused_power(shape, cfg, s, vmem_budget=vmem)


def _host_rooted(op: LinOp) -> bool:
    """Does the solve ultimately stream a HOST-resident array?  Composed /
    transposed operators are peeled down to their base: the transfers a
    CenteredOp-over-HostOp pays are the HostOp's."""
    while isinstance(op, (ops_mod.ComposedOp, ops_mod._TransposedOp)):
        op = op.base if isinstance(op, ops_mod.ComposedOp) else op._op
    return isinstance(getattr(op, "array", None), np.ndarray)


def _sparse_nnz(op: LinOp) -> Optional[int]:
    """Stored nonzeros of the solve's BASE operator, or None for dense
    sources.  Composed / transposed operators are peeled (a CenteredOp over
    a SparseOp still pays SpMM traffic for every read of A — the rank-one
    correction is O(s) extra, which the byte model drops)."""
    while isinstance(op, (ops_mod.ComposedOp, ops_mod._TransposedOp)):
        op = op.base if isinstance(op, ops_mod.ComposedOp) else op._op
    return op.nnz if isinstance(op, ops_mod.SparseOp) else None


def _apply_sketch_knobs(cfg: RSVDConfig, spec: Spec, path: str) -> RSVDConfig:
    """Resolve the sketch kind the solve will RUN: a spec-level `sketch=`
    knob overrides the config default, and structured kinds fall back to
    gaussian on the paths that regenerate row-offset sketch panels
    (streamed / sharded) — SRHT's column sample and CountSketch's buckets
    are global draws, not row-decomposable, so those bodies cannot stream
    them.  The returned config records what actually executes."""
    requested = getattr(spec, "sketch", None)
    if requested:
        cfg = dataclasses.replace(cfg, sketch_kind=requested)
    from repro.core import sketch as sketch_mod

    if cfg.sketch_kind in sketch_mod.STRUCTURED_KINDS and path in ("streamed", "sharded"):
        cfg = dataclasses.replace(cfg, sketch_kind="gaussian")
    return cfg


def _pick_pipeline_depth(cfg: Optional[RSVDConfig], m: int, n: int,
                         block_rows: int, itemsize: int,
                         budget: Budget,
                         source_depth: Optional[int] = None) -> int:
    """Prefetch depth for a panel-streaming plan, from the same quarter-HBM
    budget rule that sizes the panels: `depth` staging panels must be
    co-resident, so depth shrinks (down to 1 — synchronous) whenever
    depth * panel_bytes overflows the quarter budget a single panel was
    sized into.  An explicit cfg.pipeline_depth — else the source's own
    preference, mirroring the block_rows precedence — is the starting point
    (still budget- and panel-count-clamped: a plan must be executable);
    otherwise the default is double-buffered on real accelerators and 1 on
    the CPU backend, where no host link exists to overlap."""
    n_panels = -(-m // block_rows)  # ceil
    requested = (cfg.pipeline_depth if cfg is not None else None) or source_depth
    if requested:
        depth = min(requested, n_panels)
    elif jax.default_backend() == "cpu":
        return 1
    else:
        depth = min(pipeline_mod.DEFAULT_DEPTH, n_panels)
    panel_bytes = block_rows * n * itemsize
    while depth > 1 and depth * panel_bytes > budget.hbm_bytes // 4:
        depth -= 1
    return max(depth, 1)


def _validate(op: LinOp, spec: Spec, kind: str) -> None:
    """Facade-level input validation: bad ranks and unknown kinds fail HERE
    with a clear ValueError instead of deep inside the numerics."""
    from repro.linalg import registry

    registry.get(kind)  # unknown kinds raise registry's ValueError
    shape = op.shape
    rmax = min(shape[-2], shape[-1])
    if rmax == 0:
        raise ValueError(f"source has an empty dimension: shape {tuple(shape)}")
    if isinstance(spec, Rank):
        if spec.k <= 0:
            raise ValueError(f"rank k must be positive, got k={spec.k}")
        if spec.k > rmax:
            raise ValueError(
                f"rank k={spec.k} exceeds min(m, n)={rmax} for source shape "
                f"{tuple(shape)}"
            )
    elif len(shape) == 3:
        raise ValueError(
            f"adaptive spec {spec.describe()} needs a 2-D source, got shape "
            f"{tuple(shape)} (per-slice ranks would be ragged — solve slices "
            "individually or use a Rank spec)"
        )
    if kind == "eigh" and shape[-2] != shape[-1]:
        raise ValueError(
            f"kind='eigh' needs a square (PSD) source, got shape {tuple(shape)}"
        )
    if kind in _QB_KINDS and len(shape) == 3:
        raise ValueError(
            f"kind={kind!r} needs a 2-D source, got shape {tuple(shape)}"
        )


#: kinds that always execute through the QB engine (core/adaptive.py), even
#: under a Rank spec — their plan records the QB growth, not a dense solve
_QB_KINDS = ("qb", "eigh", "lu")


def _plan_adaptive(op: LinOp, spec: Spec, kind: str, budget: Budget,
                   overrides: Optional[RSVDConfig],
                   nnz: Optional[int] = None,
                   guard: guard_mod.GuardPolicy = guard_mod.GuardPolicy(),
                   validate: bool = False) -> ExecutionPlan:
    """Fixed-precision (Tolerance/Energy) plan: the rank is unknown, so the
    plan records the GROWTH SCHEDULE — cumulative basis sizes in autotune-
    sized panels up to the max-rank cap — and the roofline bytes of each
    step.  Execution (registry -> core/adaptive.py) stops early once the
    posterior estimator meets the spec; the executed prefix of the schedule
    is what actually runs.

    Unlike the fixed-rank paths, the QB engine does NOT transpose wide
    sources (qb/lu factor shapes are part of the caller's contract, and the
    basis approximates range(A), which is orientation-specific), so the
    plan records the EXECUTED orientation — m/n are the source dims as-is,
    and the roofline schedule (whose deflation/reorth terms scale with the
    basis length m) models the solve that actually runs."""
    from repro.kernels.ops import _block, _select_blocks

    shape = op.shape
    m, n = shape[-2], shape[-1]
    rmax = min(m, n)
    f64 = _is_f64(op.dtype)
    cfg = overrides if overrides is not None else _default_config(op, "adaptive", budget)
    cfg = _apply_sketch_knobs(cfg, spec, "adaptive")
    if nnz is None:
        nnz = _sparse_nnz(op)

    if isinstance(spec, Rank):
        # a _QB_KINDS entry at fixed rank: ONE oversampled panel, trimmed
        # back to k by the rank reveal
        cap = min(spec.k + cfg.oversample, rmax)
        panel = cap
    else:
        cap = min(getattr(spec, "max_rank", None) or rmax, rmax)
        panel = getattr(spec, "panel", None)
        if not panel:
            # autotune-sized growth panel: the sketch kernel's preferred s-tile
            panel = _select_blocks("sketch_matmul", (m, 128, n), op.dtype)[1]
        panel = max(1, min(panel, cap))

    from repro.core import sketch as sketch_mod

    # the fused in-VMEM sketch serves device-resident dense sources only
    # (HostOp subclasses DenseOp but streams from host — excluded by type);
    # structured kinds apply by transform, so there is no RNG tile to fuse
    fused_sketch = (
        bool(cfg.fused_sketch) and not f64 and type(op) is ops_mod.DenseOp
        and cfg.sketch_kind not in sketch_mod.STRUCTURED_KINDS
    )
    backend = "jnp" if f64 else cfg.kernel_backend

    steps = -(-cap // panel)  # ceil
    rank_schedule = tuple(min((i + 1) * panel, cap) for i in range(steps))
    dtype_bytes = jnp.dtype(op.dtype).itemsize
    schedule_bytes = rsvd_model.adaptive_schedule_bytes(
        m, n, rank_schedule, cfg.power_iters,
        dtype_bytes=dtype_bytes, fused_sketch=fused_sketch, nnz=nnz,
    )
    if fused_sketch:
        bm_, bn_, bk_ = _select_blocks("sketch_matmul", (m, panel, n), op.dtype)
        blocks = (bm_, min(bn_, _block(panel)), bk_)
    else:
        blocks = _select_blocks("matmul", (m, n, panel), op.dtype)

    # Host-rooted sources stream their matmat/rmatmat (and the ||A||_F^2
    # walk) through the prefetch pipeline at this depth — the registry sets
    # it as the ambient pipeline.default_depth around the growth loop.
    pipeline_depth = 1
    if _host_rooted(op):
        stream_block = op.block_rows or ops_mod.HostOp.DEFAULT_BLOCK_ROWS
        pipeline_depth = _pick_pipeline_depth(
            overrides, m, n, stream_block, dtype_bytes, budget,
            source_depth=op.pipeline_depth,
        )

    return ExecutionPlan(
        path="adaptive",
        m=m, n=n, k=cap, s=panel, batch=1,
        dtype=jnp.dtype(op.dtype).name,
        oversample=cfg.oversample,
        power_iters=cfg.power_iters,
        power_scheme=cfg.power_scheme,
        qr_method=cfg.qr_method,
        small_svd=cfg.small_svd,
        sketch_kind=cfg.sketch_kind,
        fused_sketch=fused_sketch,
        fused_power=False,          # the growth loop never fuses the power step
        kernel_backend=backend,
        block_rows=None,
        block_cols=cfg.block_cols,
        blocks=tuple(blocks),
        predicted_hbm_bytes=sum(schedule_bytes),
        kind=kind,
        spec=spec,
        panel=panel,
        rank_schedule=rank_schedule,
        schedule_hbm_bytes=schedule_bytes,
        pipeline_depth=pipeline_depth,
        predicted_walltime_s=rsvd_model.hbm_walltime_s(sum(schedule_bytes)),
        nnz=nnz,
        density=None if nnz is None else nnz / float(m * n),
        guard=guard,
        validate=validate,
    )


def plan(
    op,
    spec,
    budget: Optional[Budget] = None,
    overrides: Optional[RSVDConfig] = None,
    kind: str = "svd",
    nnz: Optional[int] = None,
    guard=None,
    validate: bool = False,
) -> ExecutionPlan:
    """Build the execution plan for a solve over `op`.

    `spec` is a rank (int, the historical signature) or an accuracy `Spec`
    (`Rank`/`Tolerance`/`Energy`).  Shape-only: `op` may wrap a
    `jax.ShapeDtypeStruct` — nothing is computed or moved here.  `overrides`
    pins the numerical variant and the historical dispatch; otherwise the
    planner picks device-appropriate defaults per source kind.  `kind`
    names the decomposition-registry entry the plan targets (svd, eigh, qb,
    lu, pca).  `nnz` declares the source's stored-nonzero count for the
    SpMM traffic pricing — it defaults from the operator itself (SparseOp,
    possibly under a composition), and the explicit argument serves
    shape-only planning where no data exists to count.  `guard` (a mode
    string or GuardPolicy) and `validate` set the guarded-execution fields
    — see linalg/guard.py; both default to the unguarded pre-guard
    behavior."""
    op = as_linop(op)
    budget = budget or Budget.default()
    spec = spec_mod.as_spec(spec)
    guard = guard_mod.as_guard(guard)
    _validate(op, spec, kind)
    if nnz is None:
        nnz = _sparse_nnz(op)
    if not isinstance(spec, Rank) or kind in _QB_KINDS:
        return _plan_adaptive(op, spec, kind, budget, overrides, nnz=nnz,
                              guard=guard, validate=validate)
    k = spec.k
    path = _pick_path(op, overrides)
    cfg = overrides if overrides is not None else _default_config(op, path, budget)
    cfg = _apply_sketch_knobs(cfg, spec, path)

    shape = op.shape
    batch = shape[0] if len(shape) == 3 else 1
    m_raw, n_raw = shape[-2], shape[-1]
    m, n = (m_raw, n_raw) if m_raw >= n_raw else (n_raw, m_raw)  # tall orientation
    s = min(k + cfg.oversample, n)

    from repro.core import sketch as sketch_mod

    fused_power = _effective_fused_power(m, n, s, op.dtype, cfg, path, budget)
    fused_sketch = (
        bool(cfg.fused_sketch)
        and not _is_f64(op.dtype)
        and path not in ("matfree", "sharded")  # shard body materializes Omega
        # structured kinds apply by transform — no RNG tile to generate
        and cfg.sketch_kind not in sketch_mod.STRUCTURED_KINDS
    )
    # float64 always takes the jnp primitives (qr._use_pallas vetoes the
    # fp32-accumulating kernels) — record the backend that actually runs.
    backend = "jnp" if _is_f64(op.dtype) else cfg.kernel_backend
    power_scheme, qr_method, small_svd = cfg.power_scheme, cfg.qr_method, cfg.small_svd
    if path == "sharded":
        # The shard_map body hardcodes its variant — a CQR2 stabilized loop,
        # replicated LAPACK small SVD, per-shard regenerated Omega
        # (core/distributed.py); the plan records THAT, not the overrides'
        # wishes, so BENCH rows and describe() never misreport execution.
        power_scheme, qr_method, small_svd = "stabilized", "cqr2", "lapack"

    from repro.kernels.ops import _block, _select_blocks

    # Mirror the EXACT (kernel, shape-order, clamp) lookups the wrappers
    # perform (ops.power_step uses (m, n, s); ops.sketch_matmul uses
    # (m, s, n) and clamps bn to the sketch width) so the recorded tiles
    # are the ones that will actually run.
    if path == "sparse" and fused_sketch:
        # the SpMM-sketch kernel's tiling — the (bm, bk) pair also keys the
        # block-ELL pack SparseOp caches (ops.spmm_blocks does this lookup)
        blocks = _select_blocks("spmm_sketch", (m, s, n), op.dtype)
    elif fused_power:
        blocks = _select_blocks("power_step", (m, n, s), op.dtype)
    elif fused_sketch:
        bm_, bn_, bk_ = _select_blocks("sketch_matmul", (m, s, n), op.dtype)
        blocks = (bm_, min(bn_, _block(s)), bk_)
    else:
        blocks = _select_blocks("matmul", (m, n, s), op.dtype)

    if nnz is not None and path in ("sparse", "matfree"):
        # every read of A is an SpMM at nnz * (value + index) bytes — the
        # solve the matfree operator body actually runs over a sparse base
        predicted = rsvd_model.sparse_predicted_hbm_bytes(
            m, n, s,
            power_iters=cfg.power_iters,
            nnz=nnz,
            fused_sketch=fused_sketch,
            dtype_bytes=jnp.dtype(op.dtype).itemsize,
        )
    else:
        predicted = rsvd_model.predicted_hbm_bytes(
            m, n, s,
            power_iters=cfg.power_iters,
            fused_power=fused_power,
            fused_sketch=fused_sketch,
            dtype_bytes=jnp.dtype(op.dtype).itemsize,
            batch=batch,
        )

    block_rows = None
    pipeline_depth = 1
    if path == "streamed":
        # cfg's explicit panel height wins; else the source's; else the
        # streaming default (so a streamed plan is always executable).
        block_rows = cfg.block_rows or op.block_rows or ops_mod.HostOp.DEFAULT_BLOCK_ROWS
        pipeline_depth = _pick_pipeline_depth(
            cfg, m, n, block_rows, jnp.dtype(op.dtype).itemsize, budget,
            source_depth=op.pipeline_depth,
        )
        predicted_walltime = rsvd_model.streamed_walltime_s(
            m, n, s, block_rows, cfg.power_iters, pipeline_depth,
            dtype_bytes=jnp.dtype(op.dtype).itemsize, fused_sketch=fused_sketch,
        )
    elif path == "matfree" and _host_rooted(op):
        # composed-over-host sources stream underneath the operator products;
        # record the depth their prefetched base walk resolves to
        pipeline_depth = _pick_pipeline_depth(
            cfg, m, n, op.block_rows or ops_mod.HostOp.DEFAULT_BLOCK_ROWS,
            jnp.dtype(op.dtype).itemsize, budget,
            source_depth=op.pipeline_depth,
        )
        predicted_walltime = rsvd_model.hbm_walltime_s(predicted)
    else:
        predicted_walltime = rsvd_model.hbm_walltime_s(predicted)

    return ExecutionPlan(
        path=path,
        m=m, n=n, k=k, s=s, batch=batch,
        dtype=jnp.dtype(op.dtype).name,
        oversample=cfg.oversample,
        power_iters=cfg.power_iters,
        power_scheme=power_scheme,
        qr_method=qr_method,
        small_svd=small_svd,
        sketch_kind=cfg.sketch_kind,
        fused_sketch=fused_sketch,
        fused_power=fused_power,
        kernel_backend=backend,
        block_rows=block_rows,
        block_cols=cfg.block_cols,
        blocks=tuple(blocks),
        predicted_hbm_bytes=predicted,
        kind=kind,
        spec=spec,
        rank_schedule=(k,),
        pipeline_depth=pipeline_depth,
        predicted_walltime_s=predicted_walltime,
        nnz=nnz,
        density=None if nnz is None else nnz / float(m * n),
        guard=guard,
        validate=validate,
    )
