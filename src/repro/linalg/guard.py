"""Guarded execution: numerical-health probes + the breakdown retry ladder.

The paper's speed story rests on CholeskyQR-family orthonormalization,
which is exactly the piece that breaks on real traffic: CQR2 loses
orthogonality past kappa(Y) ~ eps^{-1/2} (~4e3 in f32), and the floor
shift in ``qr.cholesky_r_from_gram`` silently rescues the factorization
with a garbage R.  This module makes that failure *observable* (report
mode) and *recoverable* (retry mode) without touching the fast path:

``GuardPolicy`` (off | report | retry) rides on ``ExecutionPlan``:

- ``off``     nothing is probed; execution is bit-identical to a plan
              without a guard (the probes literally never run — probe
              call sites check for an active sink first).
- ``report``  health probes are collected from byproducts already
              resident — the CQR2 second Gram, the Cholesky factor's
              diagonal, streamed panels already on device — so no extra
              pass over A is made, and a ``HealthReport`` rides on the
              ``Decomposition`` result.
- ``retry``   on unhealthy probes, a driver-level (outside-jit)
              escalation ladder re-executes the solve under a stronger
              orthonormalizer, each rung recorded:

                cqr2 -> shifted cqr3 -> householder -> f64 + re-seeded sketch

              (streamed plans stop at cqr3 — a panel-split Y has no
              Householder form — and go straight to the f64 recompute;
              sharded plans hardcode their CQR2 variant in the shard body,
              so their only rung is a re-seeded retry.)  Retry mode also
              *verifies* each attempt explicitly (||QtQ - I||_F on the
              k-column factor — O(m k^2) flops, zero reads of A), because
              the probes measure the FIRST Cholesky pass, not the final
              output.

Probe semantics (see DESIGN.md §Guarded execution for the math):

- ``breakdown``      any Cholesky factor diagonal non-finite or <= 0.
                     With the floor shift this fires only for non-finite
                     Grams (poisoned input, overflow, injected fault) —
                     a merely ill-conditioned Gram is rescued *finitely*,
                     which is why the next probe exists.
- ``first_pass_ortho``  ||G2 - I||_F where G2 = Q1ᵀQ1 is CQR2's second
                     Gram (already computed by the algorithm).  Scales
                     like kappa(Y)^2 * eps: ~1e-3 for a healthy f32
                     solve, ~0.1 AT the CQR2 validity edge (kappa(Y) ~
                     eps^{-1/2}), order 1+ beyond it.  The health
                     threshold is ``GuardPolicy.probe_tol`` (0.5 — the
                     classical one-refinement radius ||Q1'Q1 - I|| <= 1/2
                     inside which the second pass still restores O(eps)
                     orthogonality), NOT the output tolerance.
- ``cond_proxy``     max(diag R)^2 / min(diag R)^2 — a lower bound on
                     kappa(G) = kappa(Y)^2, free from the factor already
                     computed.  Informational, never gated.
- ``nonfinite_panels``  streamed-source panels that failed the (device-
                     resident, reduction-only) finiteness check.

The sink is a trace-time THREAD-LOCAL stack (same pattern as
``qr.kernel_backend`` / ``pipeline.default_depth`` — per-thread so the
decomposition service's concurrent worker threads cannot leak probes or
probed-twin routing into each other's solves): eager bodies record
concrete device scalars; jitted bodies get "probed" compiled twins that
open a sink inside the trace and return the probe dict as extra jit
outputs, which the driver folds back via :func:`absorb`.  Unprobed jits
never trace with a sink active, so guard ``off`` shares their cache
entries untouched.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.linalg import faults as faults_mod
from repro.linalg import snapshot as snapshot_mod

#: seed offset of the re-seeded (f64 / sharded) recompute rung — a fresh
#: sketch decorrelates the retry from a sketch-direction near-degeneracy
RESEED_OFFSET = 7919

_QR_ORDER = ("cqr", "cqr2", "cqr3", "householder")

_DEFAULT_ORTHO_TOL = {"float64": 1.0e-10}
_DEFAULT_ORTHO_TOL_F32 = 1.0e-5


def _policy_mode(mode: str) -> str:
    if mode not in ("off", "report", "retry"):
        raise ValueError(
            f"unknown guard mode {mode!r}; expected 'off', 'report' or 'retry'")
    return mode


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """How a plan's execution is guarded.  Hashable (it rides on the frozen
    ``ExecutionPlan``, which jitted consumers take as a static argument).

    ``probe_tol`` gates the FIRST-PASS orthogonality probe (||G2 - I||_F,
    kappa^2*eps-scaled; 0.5 is the classical radius inside which CQR2's
    second pass still restores O(eps) orthogonality — see module
    docstring); ``ortho_tol`` gates the explicit output verification in
    retry mode and defaults per dtype (1e-5 f32 / 1e-10 f64) when None.

    ``max_restarts`` / ``restart_backoff_s`` govern TRANSIENT interruptions
    (preemption, device loss — `faults.TRANSIENT_ERRORS`), which are not
    numerical breakdowns: the same rung is restarted in place, up to
    ``max_restarts`` times per rung with exponential backoff, and an
    ambient snapshot scope (linalg/snapshot.py) lets the restart resume
    from the last panel-group boundary instead of panel 0.  Only when a
    rung's restarts are exhausted does the ladder treat the interruption
    like any other failed attempt and escalate (applies in every mode —
    restarts are environment recovery, not numerical-health policy)."""

    mode: str = "off"
    max_retries: int = 3
    ortho_tol: Optional[float] = None
    probe_tol: float = 0.5
    max_restarts: int = 2
    restart_backoff_s: float = 0.0

    def __post_init__(self):
        _policy_mode(self.mode)
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        if self.restart_backoff_s < 0:
            raise ValueError("restart_backoff_s must be >= 0")

    def resolve_ortho_tol(self, dtype_name: str) -> float:
        if self.ortho_tol is not None:
            return self.ortho_tol
        return _DEFAULT_ORTHO_TOL.get(dtype_name, _DEFAULT_ORTHO_TOL_F32)


def as_guard(g) -> GuardPolicy:
    """Coerce ``None`` / a mode string / a GuardPolicy to a GuardPolicy."""
    if g is None:
        return GuardPolicy()
    if isinstance(g, GuardPolicy):
        return g
    if isinstance(g, str):
        return GuardPolicy(mode=g)
    raise TypeError(f"guard must be a mode string or GuardPolicy, got {type(g).__name__}")


# ---------------------------------------------------------------------------
# probe sink (trace-time module-global stack)

class ProbeSink:
    """Accumulates probe values — device scalars (or tracers, inside a
    probed jit twin) — for one execution attempt."""

    def __init__(self):
        self.breakdown = None      # bool scalar: any Cholesky diag bad
        self.ortho_sq = None       # max ||G2 - I||_F^2 over recorded Grams
        self.cond = None           # max (diag-ratio)^2 condition proxy
        self.panel_flags: List[Tuple[int, object]] = []  # (ordinal, finite?)
        self.transfer_retries = 0  # host->device puts that needed a retry
        self.degraded_to_sync = False  # staging gave up -> synchronous walk

    def record_breakdown(self, flag) -> None:
        self.breakdown = flag if self.breakdown is None else jnp.logical_or(self.breakdown, flag)

    def record_ortho_sq(self, value) -> None:
        self.ortho_sq = value if self.ortho_sq is None else jnp.maximum(self.ortho_sq, value)

    def record_cond(self, value) -> None:
        self.cond = value if self.cond is None else jnp.maximum(self.cond, value)

    def record_panel(self, idx: int, finite) -> None:
        self.panel_flags.append((int(idx), finite))

    def traced(self) -> dict:
        """The scalar probes as a dict of tracers — the extra jit outputs
        of a probed compiled twin (panel/transfer probes never occur inside
        jit; the pipeline is eager)."""
        out = {}
        if self.breakdown is not None:
            out["breakdown"] = self.breakdown
        if self.ortho_sq is not None:
            out["ortho_sq"] = self.ortho_sq
        if self.cond is not None:
            out["cond"] = self.cond
        return out


# The sink stack is THREAD-LOCAL: the decomposition service runs solves
# from several worker threads at once, and a guard sink opened by one
# thread's guarded run must never capture probes (or reroute jits to their
# probed twins) in another thread's concurrent solve.
_sink_state = threading.local()


def _sink_stack() -> List[ProbeSink]:
    stack = getattr(_sink_state, "stack", None)
    if stack is None:
        stack = _sink_state.stack = []
    return stack


def active_sink() -> Optional[ProbeSink]:
    stack = getattr(_sink_state, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def collecting():
    """Open a probe sink for the duration of the block (stack discipline —
    probed jit twins open a nested sink inside their trace)."""
    sink = ProbeSink()
    stack = _sink_stack()
    stack.append(sink)
    try:
        yield sink
    finally:
        stack.remove(sink)


def absorb(probes: dict) -> None:
    """Fold a probed jit twin's output dict into the active sink, reducing
    possibly batched (vmapped) probe arrays to scalars."""
    sink = active_sink()
    if sink is None or not probes:
        return
    if "breakdown" in probes:
        sink.record_breakdown(jnp.any(probes["breakdown"]))
    if "ortho_sq" in probes:
        sink.record_ortho_sq(jnp.max(probes["ortho_sq"]))
    if "cond" in probes:
        sink.record_cond(jnp.max(probes["cond"]))


def note_transfer_retry() -> None:
    sink = active_sink()
    if sink is not None:
        sink.transfer_retries += 1


def note_transfer_degraded() -> None:
    sink = active_sink()
    if sink is not None:
        sink.degraded_to_sync = True


# ---------------------------------------------------------------------------
# input validation (the `validate=` knob)

_validation_state = threading.local()  # per-thread, like the sink stack


def validation_active() -> bool:
    return getattr(_validation_state, "depth", 0) > 0


@contextlib.contextmanager
def _validation_scope():
    _validation_state.depth = getattr(_validation_state, "depth", 0) + 1
    try:
        yield
    finally:
        _validation_state.depth -= 1


def _peel(op):
    """Follow composed wrappers to the base source (planner._host_rooted's
    peel, minus the host check)."""
    seen = 0
    while hasattr(op, "base") and seen < 32:
        op = op.base
        seen += 1
    return op


@contextlib.contextmanager
def validated(op, enabled: bool):
    """Screen the source for non-finite input around one solve.

    Dense / device-resident sources: ONE fused ``isfinite().all()``
    reduction up front (no extra pass beyond that single read).  Host-
    streamed sources: zero extra passes — the validation scope makes the
    solve's own panel walk raise a ``ValueError`` naming the first
    offending panel (pipeline._panel_probe).  Sparse sources check the
    stored values.  Composed sources are screened at their base."""
    if not enabled:
        yield
        return
    base = _peel(op)
    arr = getattr(base, "array", None)
    if arr is not None and not isinstance(arr, np.ndarray):
        if not bool(jnp.isfinite(arr).all()):
            raise ValueError(
                "validate: non-finite values in input (device source, shape "
                f"{tuple(arr.shape)}) — clean the source or drop validate=")
        yield
        return
    bcoo = getattr(base, "bcoo", None)
    if bcoo is not None:
        if not bool(jnp.isfinite(bcoo.data).all()):
            raise ValueError(
                "validate: non-finite stored values in sparse input (shape "
                f"{tuple(bcoo.shape)}, nnz={int(bcoo.nse)})")
        yield
        return
    # host numpy (streamed) or protocol-only source: validate inline on the
    # solve's own panel walk
    with _validation_scope():
        yield


# ---------------------------------------------------------------------------
# health reports

@dataclasses.dataclass(frozen=True)
class RungReport:
    """One execution attempt (one rung of the ladder)."""

    rung: str                              # as-planned qr method, or the
                                           # escalation name (cqr3 /
                                           # householder / f64_reseed / reseed)
    healthy: bool
    breakdown: bool = False
    first_pass_ortho: Optional[float] = None   # ||G2 - I||_F (probe)
    cond_proxy: Optional[float] = None
    nonfinite_panels: Tuple[int, ...] = ()
    factors_finite: bool = True
    ortho_fro: Optional[float] = None          # verified ||QtQ - I||_F (retry)
    transfer_retries: int = 0
    degraded_to_sync: bool = False
    restarts: int = 0                          # transient-interruption restarts
    error: Optional[str] = None                # escalation rung that raised

    def describe(self) -> str:
        bits = [f"rung={self.rung}", "ok" if self.healthy else "UNHEALTHY"]
        if self.breakdown:
            bits.append("breakdown")
        if self.first_pass_ortho is not None:
            bits.append(f"probe_ortho={self.first_pass_ortho:.3g}")
        if self.cond_proxy is not None:
            bits.append(f"cond_proxy={self.cond_proxy:.3g}")
        if self.ortho_fro is not None:
            bits.append(f"ortho={self.ortho_fro:.3g}")
        if self.nonfinite_panels:
            bits.append(f"nonfinite_panels={list(self.nonfinite_panels)}")
        if not self.factors_finite:
            bits.append("nonfinite_factors")
        if self.transfer_retries:
            bits.append(f"transfer_retries={self.transfer_retries}")
        if self.degraded_to_sync:
            bits.append("degraded_to_sync")
        if self.restarts:
            bits.append(f"restarts={self.restarts}")
        if self.error:
            bits.append(f"error={self.error!r}")
        return " ".join(bits)


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """The guard's verdict on one solve — rides on ``Decomposition.health``."""

    mode: str
    ok: bool
    rung_used: str                 # rung whose result was returned
    attempts: Tuple[RungReport, ...]

    @property
    def final(self) -> RungReport:
        return self.attempts[-1]

    def describe(self) -> str:
        head = f"guard={self.mode} {'ok' if self.ok else 'UNHEALTHY'} rung_used={self.rung_used}"
        return "\n".join([head] + ["  " + a.describe() for a in self.attempts])

    def __str__(self) -> str:
        return self.describe()


# ---------------------------------------------------------------------------
# the escalation ladder (retry mode) — driver level, outside every jit

def _ortho_residual(Q) -> jax.Array:
    """||QᵀQ - I||_F in the factor's compute precision (promoted to f32)."""
    Qf = Q.astype(jnp.promote_types(Q.dtype, jnp.float32))
    G = Qf.T @ Qf
    D = G - jnp.eye(G.shape[0], dtype=G.dtype)
    return jnp.sqrt(jnp.sum(D * D))


def _result_arrays(result):
    return [
        leaf for leaf in jax.tree_util.tree_leaves(result)
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape")
        and jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)
    ]


def _summarize(name: str, sink: ProbeSink, result, policy: GuardPolicy,
               dtype_name: str, ortho_factor: Optional[Callable],
               verify: bool, ortho_gates: bool = True) -> RungReport:
    """Concretize one attempt's sink into a RungReport (a handful of device
    syncs — panel flags stacked into ONE).  ``ortho_gates=False`` keeps the
    first-pass probe informational without letting it fail the attempt: the
    adaptive engine deliberately orthonormalizes deflated panels that are
    near cancellation noise (then discards them at the overlap floor), so
    on that path a large G2 residual is expected behavior, not ill health —
    breakdown/finiteness/verification still gate."""
    breakdown = bool(sink.breakdown) if sink.breakdown is not None else False
    ortho1 = float(jnp.sqrt(sink.ortho_sq)) if sink.ortho_sq is not None else None
    cond = float(sink.cond) if sink.cond is not None else None
    bad_panels: Tuple[int, ...] = ()
    if sink.panel_flags:
        flags = np.asarray(jnp.stack([jnp.asarray(f) for _, f in sink.panel_flags]))
        bad_panels = tuple(sorted({
            i for (i, _), ok in zip(sink.panel_flags, flags) if not ok}))
    finite = all(bool(jnp.isfinite(x).all()) for x in _result_arrays(result))
    verified = None
    if verify and finite and ortho_factor is not None:
        Q = ortho_factor(result)
        if Q is not None:
            verified = float(_ortho_residual(Q))
    tol = policy.resolve_ortho_tol(dtype_name)
    healthy = (
        finite
        and not breakdown
        and not bad_panels
        and (not ortho_gates or ortho1 is None or ortho1 <= policy.probe_tol)
        and (verified is None or verified <= tol)
    )
    return RungReport(
        rung=name, healthy=healthy, breakdown=breakdown,
        first_pass_ortho=ortho1, cond_proxy=cond,
        nonfinite_panels=bad_panels, factors_finite=finite,
        ortho_fro=verified, transfer_retries=sink.transfer_retries,
        degraded_to_sync=sink.degraded_to_sync,
    )


def _escalation_methods(pl) -> List[str]:
    """QR methods stronger than the plan's, in ladder order."""
    if pl.path == "sharded":
        return []  # the shard body hardcodes its CQR2 variant
    methods = list(_QR_ORDER)
    if pl.path == "streamed":
        methods.remove("householder")  # panel-split Y has no Householder form
    if pl.qr_method in methods:
        return methods[methods.index(pl.qr_method) + 1:]
    return [m for m in methods if m != pl.qr_method]


def _f64_rung_thunk(run, op, pl, seed):
    """The last rung: recompute in float64 with a re-seeded sketch.

    Serves array-rooted sources (Dense/Host/Stacked); protocol-only,
    sparse, composed and sharded sources have no safe wholesale cast, so
    the rung is skipped for them (None).  The cast, the re-plan and the
    solve all run under ``compat.enable_x64()``."""
    if pl.dtype == "float64" or pl.path == "sharded":
        return None
    arr = getattr(op, "array", None)
    if arr is None:
        return None

    def thunk():
        from repro import compat
        from repro.linalg import operators as ops_mod
        from repro.linalg import planner as planner_mod

        with compat.enable_x64():
            if isinstance(op, ops_mod.HostOp):
                op64 = ops_mod.HostOp(np.asarray(arr, np.float64),
                                      block_rows=op.block_rows,
                                      pipeline_depth=op.pipeline_depth)
            elif isinstance(arr, np.ndarray):
                op64 = ops_mod.as_linop(np.asarray(arr, np.float64))
            else:
                op64 = ops_mod.as_linop(jnp.asarray(arr, jnp.float64))
            spec = pl.spec if pl.spec is not None else pl.k
            pl64 = planner_mod.plan(op64, spec, kind=pl.kind)
            return run(op64, pl64, seed + RESEED_OFFSET)

    return thunk


def run_guarded(run, op, pl, seed: int, *,
                ortho_factor: Optional[Callable] = None):
    """Execute ``run(op, pl, seed)`` under ``pl.guard``.

    ``run`` is the raw executor for the plan's kind; ``ortho_factor``
    maps its result to the matrix whose columns retry mode verifies
    (None for kinds without an orthonormal factor, e.g. lu).

    Returns ``(result, HealthReport)``.  Report mode runs once and only
    observes; retry mode climbs the ladder until an attempt is healthy or
    ``max_retries`` escalations are spent, returning the LAST attempt's
    result (flagged unhealthy if the ladder was exhausted)."""
    policy = pl.guard
    verify = policy.mode == "retry"
    # the adaptive engine self-corrects past its conditioning edge (CGS2 +
    # overlap floor), so its internal first-pass probes inform but don't gate
    ortho_gates = pl.path != "adaptive"

    rungs: List[Tuple[str, Callable]] = [
        (pl.qr_method, lambda: run(op, pl, seed))]
    if verify:
        for method in _escalation_methods(pl):
            pl_r = dataclasses.replace(pl, qr_method=method, fused_power=False)
            rungs.append((method, lambda pl_r=pl_r: run(op, pl_r, seed)))
        f64 = _f64_rung_thunk(run, op, pl, seed)
        if f64 is not None:
            rungs.append(("f64_reseed", f64))
        elif pl.path == "sharded":
            rungs.append(("reseed", lambda: run(op, pl, seed + RESEED_OFFSET)))

    attempts: List[RungReport] = []
    result = None
    rung_used = rungs[0][0]
    for i, (name, thunk) in enumerate(rungs):
        restarts = 0
        try:
            while True:
                try:
                    with collecting() as sink:
                        res = thunk()
                    break
                except faults_mod.TRANSIENT_ERRORS:
                    # preemption / device loss: restart the SAME rung — with
                    # an ambient snapshot scope the re-run resumes from the
                    # last panel-group boundary, so progress is preserved
                    if restarts >= policy.max_restarts:
                        raise
                    if policy.restart_backoff_s:
                        time.sleep(policy.restart_backoff_s * (2 ** restarts))
                    restarts += 1
        except (snapshot_mod.Cancelled, snapshot_mod.DeadlineExceeded):
            # cooperative cancellation / deadline are caller verdicts on the
            # whole request, not rung failures — never absorbed by the ladder
            raise
        except faults_mod.TRANSIENT_ERRORS as exc:
            # restarts exhausted: the environment keeps interrupting this
            # rung — record it and (retry mode) climb; a stronger rung may
            # be cheap enough to finish between interruptions
            if not verify:
                raise
            attempts.append(RungReport(
                rung=name, healthy=False, factors_finite=False,
                restarts=restarts,
                error=f"{type(exc).__name__}: {exc}"))
            continue
        except faults_mod.TransferError as exc:
            # the staging pipeline already degraded and still failed —
            # record the dead rung; first-attempt failures keep climbing
            if not verify:
                raise
            attempts.append(RungReport(rung=name, healthy=False,
                                       factors_finite=False, error=str(exc)))
            continue
        except Exception as exc:
            if i == 0:
                raise  # structural errors (validate, bad spec) are not retried
            attempts.append(RungReport(
                rung=name, healthy=False, factors_finite=False,
                error=f"{type(exc).__name__}: {exc}"))
            continue
        report = _summarize(name, sink, res, policy, pl.dtype,
                            ortho_factor, verify, ortho_gates=ortho_gates)
        if restarts:
            report = dataclasses.replace(report, restarts=restarts)
        attempts.append(report)
        result = res
        rung_used = name
        if report.healthy or not verify:
            break
        if len(attempts) - 1 >= policy.max_retries:
            break
    if result is None:
        # every rung raised (e.g. a permanently dead host link even after
        # the synchronous fallback) — there is no result to flag, so fail
        health = HealthReport(mode=policy.mode, ok=False, rung_used=rung_used,
                              attempts=tuple(attempts))
        raise RuntimeError(f"guarded execution failed on every rung:\n{health}")
    ok = bool(attempts) and attempts[-1].healthy
    health = HealthReport(mode=policy.mode, ok=ok, rung_used=rung_used,
                          attempts=tuple(attempts))
    return result, health
