"""Operator sources for the randomized-SVD facade (`repro.linalg`).

The paper's core claim is that randomized SVD becomes hardware-fast when
every step is phrased as BLAS-3 over *whatever form the data arrives in*.
`LinOp` is that form-contract: an operator exposes its shape/dtype, the two
products the range finder needs (``matmat`` = A @ X, ``rmatmat`` = Aᵀ @ Y),
and optionally a ``row_panels()`` iterator (out-of-core streaming, panel-wise
residuals) and a ``sharding`` spec (mesh execution).  The execution planner
(planner.py) dispatches on the source; the algorithm never sees anything but
this protocol.

Concrete sources:
  DenseOp    device-resident 2-D array             -> dense in-memory path
  HostOp     host (numpy) 2-D array, panel-streamed -> blocked/streaming path
  StackedOp  3-D batch [B, m, n]                   -> batched vmap path
  ShardedOp  row-sharded array on a device mesh    -> shard_map path

Composed operators (the new workload class — nothing is materialized):
  ScaledOp          alpha * A
  CenteredOp        A - 1 muᵀ    (PCA without forming the centered matrix)
  LowRankUpdateOp   A + U Vᵀ     (deflation: A - U_k S_k V_kᵀ as an operator)

`prefetch_panels(op, block_rows, depth)` is the overlapped edition of
`row_panels`: host->device movement of panel i+1 is issued while panel i
computes (linalg/pipeline.py), bit-identical values in the same order.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

try:  # scipy ships with the jax toolchain, but SparseOp must not require it
    import scipy.sparse as _scipy_sparse
except ImportError:  # pragma: no cover - scipy is present in the image
    _scipy_sparse = None

from repro.linalg import pipeline as pipeline_mod


class LinOp:
    """Operator-source protocol.  Subclasses must provide `shape`, `dtype`,
    `matmat`, and `rmatmat`; `row_panels` / `sharding` are optional extras
    the planner and panel-wise consumers (linalg.residual) exploit."""

    #: (mesh, axis) for mesh-resident operators, else None.
    sharding: Optional[Tuple[jax.sharding.Mesh, str]] = None
    #: preferred row-panel height for streamed execution, else None.
    block_rows: Optional[int] = None
    #: preferred prefetch depth for panel walks, else None (auto: the
    #: pipeline default for host-resident sources, 1 otherwise).
    pipeline_depth: Optional[int] = None

    @property
    def shape(self) -> Tuple[int, ...]:
        raise NotImplementedError

    @property
    def dtype(self):
        raise NotImplementedError

    def matmat(self, X: jax.Array) -> jax.Array:
        """A @ X  (X is n x s, sketch-width)."""
        raise NotImplementedError

    def rmatmat(self, Y: jax.Array) -> jax.Array:
        """Aᵀ @ Y  (Y is m x s, sketch-width)."""
        raise NotImplementedError

    def row_panels(self, block_rows: Optional[int] = None) -> Iterator[jax.Array]:
        """Device-resident row panels covering A top-to-bottom.

        The default materializes panel slices of the dense form; sources
        with a cheaper panel story (HostOp: host slices moved one at a
        time) override it.  Composed operators compose panel-wise, so a
        CenteredOp over a HostOp still never forms the full matrix."""
        m = self.shape[0]
        b = block_rows or self.block_rows or m
        eye_dtype = jnp.promote_types(self.dtype, jnp.float32)
        for lo in range(0, m, b):
            hi = min(lo + b, m)
            # A[lo:hi] = (E_panelᵀ A)ᵀ through rmatmat.  E is the sliced
            # standard basis e_lo..e_{hi-1} — an offset-diagonal eye (iota
            # comparison), NOT an m-sized scatter per panel; entries are
            # exact 0/1 either way so the panel values are bit-identical.
            e = jnp.eye(m, hi - lo, -lo, dtype=eye_dtype)
            yield self.rmatmat(e).T.astype(self.dtype)

    def prefetch_panels(
        self, block_rows: Optional[int] = None, depth: Optional[int] = None
    ) -> Iterator[jax.Array]:
        """`row_panels` with depth-deep prefetch — see module-level
        `prefetch_panels` (this method exists so duck-typed consumers like
        core/adaptive.py can reach the pipeline without importing it)."""
        return prefetch_panels(self, block_rows, depth)

    @property
    def T(self) -> "LinOp":
        """The transposed operator (matmat/rmatmat swapped)."""
        return _TransposedOp(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}(shape={self.shape}, dtype={jnp.dtype(self.dtype).name})"


class _TransposedOp(LinOp):
    def __init__(self, op: LinOp):
        self._op = op

    @property
    def shape(self):
        s = self._op.shape
        return s[:-2] + (s[-1], s[-2])

    @property
    def dtype(self):
        return self._op.dtype

    def matmat(self, X):
        return self._op.rmatmat(X)

    def rmatmat(self, Y):
        return self._op.matmat(Y)

    @property
    def T(self) -> LinOp:
        return self._op


class DenseOp(LinOp):
    """Device-resident 2-D array (the paper's in-core case)."""

    def __init__(self, array, block_rows: Optional[int] = None,
                 pipeline_depth: Optional[int] = None):
        if getattr(array, "ndim", None) != 2:
            raise ValueError(f"DenseOp expects a 2-D array, got shape {getattr(array, 'shape', None)}")
        self.array = array
        self.block_rows = block_rows
        self.pipeline_depth = pipeline_depth

    @property
    def shape(self):
        return tuple(self.array.shape)

    @property
    def dtype(self):
        return self.array.dtype

    def matmat(self, X):
        return self.array @ X

    def rmatmat(self, Y):
        return self.array.T @ Y

    def row_panels(self, block_rows: Optional[int] = None):
        m = self.shape[0]
        b = block_rows or self.block_rows or m
        device_resident = isinstance(self.array, jax.Array)
        for lo in range(0, m, b):
            panel = self.array[lo : min(lo + b, m)]
            # Device-resident arrays slice lazily — re-wrapping the slice in
            # jnp.asarray forced a per-panel copy of data that never left
            # HBM.  Host (numpy) slices keep the explicit host->device move
            # (the HostOp contract; prefetch_panels overlaps it).
            yield panel if device_resident else jnp.asarray(panel)


class HostOp(DenseOp):
    """Host (numpy) 2-D array, possibly larger than device memory.

    Only one `block_rows x n` panel is device-resident at a time (the
    out-of-core contract of core/blocked.py); `matmat`/`rmatmat` stream the
    panels so even composed operators over a HostOp never move A wholesale.
    """

    DEFAULT_BLOCK_ROWS = 4096

    def __init__(self, array, block_rows: Optional[int] = None,
                 pipeline_depth: Optional[int] = None):
        array = np.asarray(array)
        super().__init__(array, block_rows or self.DEFAULT_BLOCK_ROWS,
                         pipeline_depth)

    def matmat(self, X):
        # prefetch_panels: panel p+1 transfers while panel p multiplies —
        # same values, same summation order as the synchronous walk.
        parts = [panel @ X for panel in self.prefetch_panels()]
        return jnp.concatenate(parts, axis=0)

    def rmatmat(self, Y):
        m, _ = self.shape
        out = None
        lo = 0
        for panel in self.prefetch_panels():
            hi = lo + panel.shape[0]
            contrib = panel.T @ Y[lo:hi]
            out = contrib if out is None else out + contrib
            lo = hi
        return out


class StackedOp(LinOp):
    """3-D batch [B, m, n]: a fleet of small SVDs under one vmap."""

    def __init__(self, array):
        if getattr(array, "ndim", None) != 3:
            raise ValueError(f"StackedOp expects [B, m, n], got shape {getattr(array, 'shape', None)}")
        self.array = jnp.asarray(array) if isinstance(array, np.ndarray) else array

    @property
    def shape(self):
        return tuple(self.array.shape)

    @property
    def dtype(self):
        return self.array.dtype

    def matmat(self, X):
        return self.array @ X      # batched matmul, X: [B, n, s] or [n, s]

    def rmatmat(self, Y):
        return jnp.swapaxes(self.array, -1, -2) @ Y


class ShardedOp(LinOp):
    """Row-sharded 2-D array on a device mesh (core/distributed.py path)."""

    def __init__(self, array, mesh: jax.sharding.Mesh, axis: str = "data"):
        if getattr(array, "ndim", None) != 2:
            raise ValueError(f"ShardedOp expects a 2-D array, got shape {getattr(array, 'shape', None)}")
        self.array = array
        self.mesh = mesh
        self.axis = axis
        self.sharding = (mesh, axis)

    @property
    def shape(self):
        return tuple(self.array.shape)

    @property
    def dtype(self):
        return self.array.dtype

    def matmat(self, X):
        return self.array @ X

    def rmatmat(self, Y):
        return self.array.T @ Y

    def row_panels(self, block_rows: Optional[int] = None):
        yield jnp.asarray(self.array)


class SparseOp(LinOp):
    """Sparse 2-D source (jax BCOO; scipy CSR/CSC/COO accepted).

    The recommender/graph/text workload class: the sketch Y = A @ Omega is an
    SpMM costing O(nnz * s) instead of O(m n s), so rSVD's dominant pass
    scales with the data that EXISTS.  `matmat`/`rmatmat` are BCOO SpMMs
    (A is never densified); `sketch` takes the fused path — a Pallas kernel
    (kernels/spmm_sketch.py) that streams block-ELL tiles of A and generates
    the matching Omega tiles in VMEM from the counter RNG, so Omega never
    touches HBM.  Off-TPU (interpret mode aside) or for structured sketch
    kinds it falls back to a materialized-Omega SpMM.

    `row_panels` inherits the basis-slice fallback — each panel is one
    nnz-proportional `rmatmat`, so panel walks (residuals, column means)
    stay sparse too; `block_rows` defaults bounded so those walks never
    materialize more than a panel of the dense form."""

    DEFAULT_BLOCK_ROWS = 4096

    #: fused-path guard: if block-ELL zero-padding would inflate the stored
    #: tiles past this fraction of the dense footprint, the structure is not
    #: sparse enough for the tiled kernel to win — use the BCOO SpMM.
    MAX_PACK_FILL = 0.5

    def __init__(self, a, block_rows: Optional[int] = None):
        if _scipy_sparse is not None and _scipy_sparse.issparse(a):
            a = jsparse.BCOO.from_scipy_sparse(a.tocoo())
        if isinstance(a, jsparse.JAXSparse) and not isinstance(a, jsparse.BCOO):
            to_bcoo = getattr(a, "to_bcoo", None)
            if to_bcoo is None:
                raise TypeError(
                    f"SparseOp cannot convert {type(a).__name__} to BCOO"
                )
            a = to_bcoo()
        if not isinstance(a, jsparse.BCOO):
            raise TypeError(
                "SparseOp expects a jax BCOO or a scipy sparse matrix, got "
                f"{type(a).__name__}"
            )
        if a.ndim != 2:
            raise ValueError(f"SparseOp expects a 2-D matrix, got shape {a.shape}")
        self.bcoo = a
        self.block_rows = block_rows or self.DEFAULT_BLOCK_ROWS
        self._t = None          # cached transposed BCOO for rmatmat
        self._packed = {}       # (bm, bk) -> block-ELL pack, or None if too dense

    @property
    def shape(self):
        return tuple(self.bcoo.shape)

    @property
    def dtype(self):
        return self.bcoo.dtype

    @property
    def nnz(self) -> int:
        """Stored nonzeros (the planner's traffic-model input)."""
        return int(self.bcoo.nse)

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / float(m * n)

    def matmat(self, X):
        return self.bcoo @ X

    def rmatmat(self, Y):
        if self._t is None:
            self._t = self.bcoo.T
        return self._t @ Y

    def sketch(self, s: int, seed: int, kind: str = "gaussian") -> jax.Array:
        """Y = A @ Omega without materializing Omega in HBM when possible.

        The fused path packs A into block-ELL tiles once (cached per tile
        shape) and runs the Pallas SpMM-sketch kernel; structured kinds and
        matrices whose padded tiles would exceed `MAX_PACK_FILL` of the
        dense footprint fall back to `matmat` on a materialized Omega —
        same map, different summation order."""
        from repro.core import sketch as sketch_mod
        from repro.kernels import ops as kernel_ops

        m, n = self.shape
        omega_dtype = jnp.promote_types(self.dtype, jnp.float32)
        packed = None
        # kernel accumulates fp32 — f64 sources keep the materialized path
        if kind not in sketch_mod.STRUCTURED_KINDS and self.dtype != jnp.float64:
            packed = self._block_ell(kernel_ops.spmm_blocks(self.shape, s, self.dtype))
        if packed is None:
            return self.matmat(sketch_mod.sketch_matrix(n, s, seed, kind, omega_dtype))
        data, tilecols = packed
        return kernel_ops.spmm_sketch(data, tilecols, s, seed=seed, kind=kind, m=m)

    def _block_ell(self, blocks):
        bm, bk = blocks
        key = (bm, bk)
        if key not in self._packed:
            from repro.kernels import spmm_sketch as spmm_mod

            self._packed[key] = spmm_mod.pack_block_ell(
                self.bcoo, bm, bk, max_fill=self.MAX_PACK_FILL
            )
        return self._packed[key]


# ---------------------------------------------------------------------------
# Composed operators — the matrix is never materialized
# ---------------------------------------------------------------------------

class ComposedOp(LinOp):
    """Base for operators derived from another operator.

    Subclasses implement `_panel_map(panel, lo, hi)` — the per-panel form of
    the composition — and get `row_panels` for free; `prefetch_panels`
    recurses into the BASE, so the host->device transfer under a composed
    operator is the thing that overlaps, with the panel transform riding the
    already-prefetched device panel."""

    def __init__(self, base: LinOp):
        self.base = as_linop(base)
        if len(self.base.shape) != 2:
            raise ValueError(
                f"composed operators require a 2-D base, got shape {self.base.shape}"
                " (stacked sources: compose per slice, or use core.pca.batched_pca"
                " for per-channel PCA)"
            )
        self.block_rows = self.base.block_rows
        self.pipeline_depth = self.base.pipeline_depth  # like block_rows: the
        # base is what streams, so its prefetch preference rides along

    @property
    def shape(self):
        return self.base.shape

    @property
    def dtype(self):
        return self.base.dtype

    def _panel_map(self, panel: jax.Array, lo: int, hi: int) -> jax.Array:
        """The composition applied to base rows [lo, hi) (device-resident)."""
        raise NotImplementedError

    def row_panels(self, block_rows: Optional[int] = None):
        lo = 0
        for panel in self.base.row_panels(block_rows):
            hi = lo + panel.shape[0]
            yield self._panel_map(panel, lo, hi)
            lo = hi


class ScaledOp(ComposedOp):
    """alpha * A."""

    def __init__(self, base: LinOp, alpha: float):
        super().__init__(base)
        self.alpha = alpha

    def matmat(self, X):
        return self.alpha * self.base.matmat(X)

    def rmatmat(self, Y):
        return self.alpha * self.base.rmatmat(Y)

    def _panel_map(self, panel, lo, hi):
        return (self.alpha * panel).astype(panel.dtype)


class CenteredOp(ComposedOp):
    """A - 1 muᵀ: the PCA operator.  mu defaults to A's column means,
    computed LAZILY with one panel-streamed pass (so shape-only planning
    over a ShapeDtypeStruct source never touches data) — the centered
    matrix itself is never formed (the m x n temporary the old `pca`
    materialized)."""

    def __init__(self, base: LinOp, mu: Optional[jax.Array] = None):
        super().__init__(base)
        self._mu = None if mu is None else jnp.asarray(mu)

    @property
    def mu(self) -> jax.Array:
        if self._mu is None:
            self._mu = column_means(self.base)
        return self._mu

    def matmat(self, X):
        correction = self.mu @ X                       # (s,)
        return self.base.matmat(X) - correction[None, :]

    def rmatmat(self, Y):
        colsum = jnp.sum(Y, axis=0)                    # (s,)
        return self.base.rmatmat(Y) - jnp.outer(self.mu, colsum)

    def _panel_map(self, panel, lo, hi):
        return (panel - self.mu[None, :]).astype(panel.dtype)


class LowRankUpdateOp(ComposedOp):
    """A + U Vᵀ with skinny U (m x r), V (n x r).

    Deflation — peeling off an already-computed leading subspace so the
    next solve targets the residual spectrum — is
    ``LowRankUpdateOp(op, -(U * S), Vt.T)``, i.e. A - U S Vᵀ as an operator.
    """

    def __init__(self, base: LinOp, U: jax.Array, V: jax.Array):
        super().__init__(base)
        m, n = self.base.shape
        if U.shape[0] != m or V.shape[0] != n or U.shape[1] != V.shape[1]:
            raise ValueError(
                f"update factors U {U.shape} / V {V.shape} do not match operator {self.base.shape}"
            )
        self.U = U
        self.V = V

    def matmat(self, X):
        return self.base.matmat(X) + self.U @ (self.V.T @ X)

    def rmatmat(self, Y):
        return self.base.rmatmat(Y) + self.V @ (self.U.T @ Y)

    def _panel_map(self, panel, lo, hi):
        return (panel + self.U[lo:hi] @ self.V.T).astype(panel.dtype)


def deflated(base: LinOp, U: jax.Array, S: jax.Array, Vt: jax.Array) -> LowRankUpdateOp:
    """A - U S Vᵀ as an operator (the deflation workload)."""
    return LowRankUpdateOp(base, -(U * S[None, :]), Vt.T)


def prefetch_panels(
    op, block_rows: Optional[int] = None, depth: Optional[int] = None
) -> Iterator[jax.Array]:
    """`op.row_panels(block_rows)` with depth-deep prefetch: the production
    of panel i+1 (host->device copy, lazy slice, composed transform) is
    issued while the consumer computes on panel i.

    Panel VALUES and order are identical to the synchronous walk — only
    transfer timing changes — so any row_panels consumer can switch over
    without a numerics diff (tests/test_pipeline.py pins bit-identity).

    Depth: explicit arg > the `pipeline.default_depth(...)` ambient scope
    (how an ExecutionPlan's `pipeline_depth` reaches nested walks) > the
    source's own `pipeline_depth` attribute > auto (DEFAULT_DEPTH for
    host-resident sources, 1 — plain iteration — otherwise).

    Routing: host numpy sources with plain-slice panels (HostOp) take the
    staged ring (`pipeline.stream_host_panels`: uniform zero-padded staging
    buffers, bounded at `depth` in flight); composed operators recurse into
    their BASE so the transfer underneath is what overlaps; everything else
    gets the generic `pipeline.lookahead` pull-ahead."""
    op = as_linop(op)
    b = block_rows or op.block_rows or op.shape[0]
    if isinstance(op, ComposedOp):
        def _mapped():
            lo = 0
            for panel in prefetch_panels(op.base, b, depth):
                hi = lo + panel.shape[0]
                yield op._panel_map(panel, lo, hi)
                lo = hi
        return _mapped()
    arr = getattr(op, "array", None)
    host = isinstance(arr, np.ndarray)
    d = pipeline_mod.resolve_depth(depth, host_resident=host,
                                   source_default=op.pipeline_depth)
    # the staged ring replicates DenseOp's plain-slice panels exactly; a
    # subclass with its own row_panels semantics must keep them
    if host and d > 1 and type(op).row_panels is DenseOp.row_panels:
        return pipeline_mod.stream_host_panels(
            arr, pipeline_mod.panel_bounds(op.shape[0], b), d
        )
    return pipeline_mod.lookahead(op.row_panels(b), d)


def column_means(op: LinOp) -> jax.Array:
    """muᵀ = 1ᵀA / m, accumulated one row panel at a time (bounded default
    panel height — the fp32 per-panel cast must stay panel-sized even for
    sources without a block_rows of their own).

    Accumulation runs in ``promote_types(panel.dtype, float32)`` — f32 at
    minimum, and f64 for an f64-under-x64 source, where the closing
    ``astype(op.dtype)`` is the identity (tests/test_adaptive.py pins that
    the promoted precision survives end-to-end for CenteredOp/pca)."""
    op = as_linop(op)
    m = op.shape[0]
    b = op.block_rows or HostOp.DEFAULT_BLOCK_ROWS
    total = None
    for panel in prefetch_panels(op, b):
        contrib = jnp.sum(panel.astype(jnp.promote_types(panel.dtype, jnp.float32)), axis=0)
        total = contrib if total is None else total + contrib
    return (total / m).astype(op.dtype)


def as_linop(a) -> LinOp:
    """Coerce an array (or LinOp) to an operator source.

    2-D device arrays -> DenseOp, 2-D host numpy -> HostOp (streamed),
    3-D -> StackedOp, sparse (jax BCOO / scipy) -> SparseOp.  Already-sharded
    arrays are NOT auto-detected — wrap them in ShardedOp(mesh, axis)
    explicitly (the mesh axis is a caller decision, not an array property
    the tracer can see)."""
    if isinstance(a, LinOp):
        return a
    # sparse first: a BCOO *has* ndim == 2, and falling through would wrap
    # it in DenseOp and densify on the first matmat
    if isinstance(a, jsparse.JAXSparse):
        return SparseOp(a)
    if _scipy_sparse is not None and _scipy_sparse.issparse(a):
        return SparseOp(a)
    ndim = getattr(a, "ndim", None)
    if ndim == 3:
        return StackedOp(a)
    if ndim == 2:
        if isinstance(a, np.ndarray):
            return HostOp(a)
        return DenseOp(a)
    if ndim is None:
        raise TypeError(
            f"cannot interpret {type(a).__name__} as a LinOp (no .ndim — pass"
            " an array or a LinOp source)"
        )
    raise ValueError(
        f"operator sources must be 2-D (matrix) or 3-D (stacked batch), got "
        f"ndim={ndim} with shape {getattr(a, 'shape', None)}"
    )
