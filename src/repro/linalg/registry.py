"""The decomposition registry: `kind -> how to finish a solve`.

`linalg.decompose(source, spec, kind=...)` looks the kind up here.  Every
entry shares one engine — the (possibly adaptive) QB factorization from
core/adaptive.py, spec-driven — and differs only in how the revealed
factors are finished:

  svd    U = Q U_b, S, Vt              (Rank specs on array sources keep the
                                        historical fixed-rank executors —
                                        bit-identical to `linalg.svd`)
  qb     Q' = Q U_b[:, :r], B' = S Vt  (rank-revealed orthonormal basis)
  eigh   Nystrom for PSD sources:      A ~= F F^T,  F = (A Q) R^{-1},
                                        R^T R = Q^T A Q (floor-shifted
                                        Cholesky), eigpairs from svd(F)
  lu     randomized LU (Shabat et al. 2013 via the QB core):
                                        A[pr][:, pc] ~= L @ U with L m x r
                                        lower-trapezoidal, U r x n upper-
                                        trapezoidal, from pivoted LUs of Q
                                        and of the r x n middle factor
  pca    svd over the CenteredOp       (components / explained variance;
                                        Energy(p) is the explained-variance
                                        contract)

A handler returns ``(factors, rank, rank_history, err_history)``; the
facade wraps that in a `Decomposition`.  Third parties can add kinds with
`register(DecompositionKind(...))` — the planner validates requested kinds
against `kinds()` at plan time.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qr as qr_mod
from repro.core.rsvd import _small_svd
from repro.linalg.spec import Rank, Spec

#: (factors, rank, rank_history, err_history)
HandlerResult = Tuple[tuple, int, Tuple[int, ...], Tuple[float, ...]]


@dataclass(frozen=True)
class DecompositionKind:
    """One registry entry.  `execute` finishes the solve; `prepare` (optional)
    transforms the source BEFORE planning (pca wraps in CenteredOp here, so
    the plan sees the operator that actually runs).  `ortho_factor`
    (optional) maps a handler's `factors` tuple to the matrix whose columns
    should be orthonormal — the guard's retry ladder verifies
    ||QᵀQ - I||_F on it (linalg/guard.py); None skips verification (lu,
    third-party kinds without an orthonormal factor)."""

    name: str
    execute: Callable  # (op, spec, plan, seed) -> HandlerResult
    prepare: Optional[Callable] = None  # (op) -> op
    description: str = ""
    ortho_factor: Optional[Callable] = None  # (factors) -> matrix | None


# Mutated by register() only (import time + third-party extensions), but
# extensions may register while service workers read — hence the lock.
_REGISTRY: Dict[str, DecompositionKind] = {}
_registry_write_lock = threading.Lock()


def register(entry: DecompositionKind) -> DecompositionKind:
    """Add (or replace) a decomposition kind.  Thread-safe: a service worker
    resolving kinds mid-`register` sees either the old or the new entry,
    never a torn table."""
    with _registry_write_lock:
        _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> DecompositionKind:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown decomposition kind {name!r}; registered kinds: "
            f"{', '.join(_REGISTRY)}"
        ) from None


def kinds() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# LRU plan cache
#
# Planning is pure given (source fingerprint, spec, kind, budget, overrides,
# guard, validate, backend) — everything the planner reads off a source is
# shape/dtype/residency metadata, never data — yet `decompose()` re-planned
# on every call.  The fingerprints below are hashable, so identical repeat
# calls (the serving hot path: same layer shapes, same spec, thousands of
# requests) reuse the frozen ExecutionPlan instead of re-walking the
# autotune tables and the roofline model.  Sources whose planning inputs
# cannot be fingerprinted safely (sharded meshes, protocol-only operators)
# BYPASS the cache — correctness first, the cache is an optimization.
# ---------------------------------------------------------------------------

PLAN_CACHE_SIZE = 256

_plan_cache: "collections.OrderedDict" = collections.OrderedDict()
_plan_cache_lock = threading.Lock()  # decompose() is called from service threads
_plan_cache_stats = {"hits": 0, "misses": 0, "bypasses": 0}


def _op_fingerprint(op):
    """Hashable token covering every source attribute the planner reads, or
    None when this source kind can't be fingerprinted safely.

    Composed/transposed wrappers contribute their typenames (the planner
    only dispatches on them and peels to the base); the base contributes
    shape, dtype, residency (host numpy vs device — `_host_rooted` and the
    dense/streamed split read it), block_rows, pipeline_depth, and nnz."""
    from repro.linalg import operators as ops_mod

    parts = []
    depth = 0
    while isinstance(op, (ops_mod.ComposedOp, ops_mod._TransposedOp)):
        parts.append(type(op).__name__)
        op = op.base if isinstance(op, ops_mod.ComposedOp) else op._op
        depth += 1
        if depth > 32:
            return None
    if op.sharding is not None:
        return None  # mesh identity is not worth fingerprinting
    if type(op) not in (ops_mod.DenseOp, ops_mod.HostOp, ops_mod.StackedOp,
                        ops_mod.SparseOp):
        return None  # protocol-only / third-party sources: bypass
    arr = getattr(op, "array", None)
    parts.append((
        type(op).__name__,
        tuple(op.shape),
        jnp.dtype(op.dtype).name,
        isinstance(arr, np.ndarray),          # residency drives path choice
        op.block_rows,
        op.pipeline_depth,
        getattr(op, "nnz", None) if type(op) is ops_mod.SparseOp else None,
    ))
    return tuple(parts)


def cached_plan(op, spec, budget=None, overrides=None, kind: str = "svd",
                nnz=None, guard=None, validate: bool = False):
    """`planner.plan` behind a size-bounded LRU keyed on the already-hashable
    inputs.  Semantically transparent: a hit returns the SAME frozen
    ExecutionPlan a fresh plan() call would build (plans carry no data), and
    un-fingerprintable sources fall through to planner.plan untouched."""
    from repro.linalg import guard as guard_mod
    from repro.linalg import planner as planner_mod
    from repro.linalg import spec as spec_mod

    spec = spec_mod.as_spec(spec)
    guard = guard_mod.as_guard(guard)
    token = _op_fingerprint(op)
    if token is None:
        with _plan_cache_lock:
            _plan_cache_stats["bypasses"] += 1
        return planner_mod.plan(op, spec, budget=budget, overrides=overrides,
                                kind=kind, nnz=nnz, guard=guard,
                                validate=validate)
    key = (token, spec, kind, budget, overrides, nnz, guard, bool(validate),
           jax.default_backend())
    with _plan_cache_lock:
        pl = _plan_cache.get(key)
        if pl is not None:
            _plan_cache.move_to_end(key)
            _plan_cache_stats["hits"] += 1
            return pl
        _plan_cache_stats["misses"] += 1
    pl = planner_mod.plan(op, spec, budget=budget, overrides=overrides,
                          kind=kind, nnz=nnz, guard=guard, validate=validate)
    with _plan_cache_lock:
        _plan_cache[key] = pl
        while len(_plan_cache) > PLAN_CACHE_SIZE:
            _plan_cache.popitem(last=False)
    return pl


def plan_cache_stats() -> Dict[str, int]:
    with _plan_cache_lock:
        return dict(_plan_cache_stats, size=len(_plan_cache))


def clear_plan_cache() -> None:
    with _plan_cache_lock:
        _plan_cache.clear()
        for k in _plan_cache_stats:
            _plan_cache_stats[k] = 0


# ---------------------------------------------------------------------------
# Shared engine: spec-driven QB + rank reveal
# ---------------------------------------------------------------------------

def _qb_core(op, spec: Spec, pl, seed):
    """Run the (adaptive) QB engine under the plan's switches.  The plan's
    `panel` / `k` carry the growth schedule (single `s`-wide panel for Rank
    specs); `threshold_sq` comes from the spec's stopping contract.  Rank
    specs need no stopping estimator, so they skip the ||A||_F^2 pass —
    one fewer read of A on the fixed-rank qb/lu/eigh paths.

    The whole growth runs under the plan's `pipeline_depth` as the ambient
    prefetch scope: host-rooted sources double-buffer every touch of A
    (matmat / rmatmat / the norm walk) without core/adaptive.py knowing the
    pipeline exists, and an early stop abandons in-flight prefetch cleanly."""
    from repro.core import adaptive
    from repro.linalg import pipeline

    with pipeline.default_depth(pl.pipeline_depth):
        norm_sq = threshold_sq = None
        if not isinstance(spec, Rank):
            norm_sq = adaptive.fro_norm_sq(op)
            threshold_sq = spec.threshold_sq(norm_sq)
        return adaptive.adaptive_qb(
            op,
            panel=pl.panel or pl.s,
            max_rank=pl.k,
            threshold_sq=threshold_sq,
            seed=seed,
            power_iters=pl.power_iters,
            qr_method=pl.qr_method,
            sketch_kind=pl.sketch_kind,
            fused_sketch=pl.fused_sketch,
            kernel_backend=pl.kernel_backend,
            norm_sq=norm_sq,
        )


def _reveal(qb, spec: Spec, pl):
    """Small SVD of B reveals the spectrum; the spec trims the rank (the
    ±panel overshoot of blocked growth, or the oversampling of a Rank
    spec's single panel)."""
    U_b, S, Vt = _small_svd(qb.B, pl.small_svd)
    keep = spec.select_rank(np.asarray(S), qb.remaining_sq or 0.0,
                            qb.norm_sq or 0.0)
    return U_b, S, Vt, int(keep)


# ---------------------------------------------------------------------------
# Handlers
# ---------------------------------------------------------------------------

def _execute_svd(op, spec, pl, seed) -> HandlerResult:
    if pl.path != "adaptive":
        # Rank spec on an array source: the historical fixed-rank executors,
        # bit-identical to pre-spec `linalg.svd` at fixed seed.
        from repro.linalg import api

        factors = api._execute_svd_plan(op, spec.k, pl, seed)
        return tuple(factors), spec.k, (spec.k,), ()
    qb = _qb_core(op, spec, pl, seed)
    U_b, S, Vt, keep = _reveal(qb, spec, pl)
    U = qb.Q @ U_b[:, :keep]
    return (U, S[:keep], Vt[:keep, :]), keep, qb.rank_history, qb.err_history


def _execute_qb(op, spec, pl, seed) -> HandlerResult:
    qb = _qb_core(op, spec, pl, seed)
    U_b, S, Vt, keep = _reveal(qb, spec, pl)
    Qk = qb.Q @ U_b[:, :keep]
    Bk = S[:keep, None] * Vt[:keep, :]
    return (Qk, Bk), keep, qb.rank_history, qb.err_history


def _execute_eigh(op, spec, pl, seed) -> HandlerResult:
    """Nystrom eigendecomposition for a PSD source: one extra pass over A
    (C = A Q) beyond the QB growth, everything else sketch-width."""
    qb = _qb_core(op, spec, pl, seed)
    U_b, S, Vt, keep = _reveal(qb, spec, pl)
    fdtype = jnp.promote_types(op.dtype, jnp.float32)
    Qk = (qb.Q @ U_b[:, :keep]).astype(fdtype)
    with qr_mod.kernel_backend(pl.kernel_backend):
        C = op.matmat(Qk).astype(fdtype)        # A Q, n x keep
        T = Qk.T @ C                            # Q^T A Q, keep x keep
        T = 0.5 * (T + T.T)
        # floor-shifted Cholesky (qr.cholesky_r_from_gram): indefinite noise
        # from a nearly-PSD source perturbs R at the eps level only
        R = qr_mod.cholesky_r_from_gram(T)
        F = qr_mod.tri_solve_right(C, R)        # A_nys = F F^T
    Uf, sf, _ = jnp.linalg.svd(F, full_matrices=False)
    w = sf**2                                   # descending eigenvalues
    return (w, Uf), keep, qb.rank_history, qb.err_history


def _execute_lu(op, spec, pl, seed) -> HandlerResult:
    """Randomized LU via the QB core: pivoted LU of the revealed basis Q,
    then of the r x n middle factor, composed so that

        A[perm_rows][:, perm_cols] ~= L @ U

    with L (m x r) lower-trapezoidal and U (r x n) unit-upper-trapezoidal —
    the two-sided permutation structure of Shabat et al. 2013, with the
    sketch stage replaced by the spec-driven (adaptive) basis."""
    from jax.lax import linalg as lax_linalg

    qb = _qb_core(op, spec, pl, seed)
    U_b, S, Vt, keep = _reveal(qb, spec, pl)
    fdtype = jnp.promote_types(op.dtype, jnp.float32)
    m, n = op.shape
    r = keep
    Qk = (qb.Q @ U_b[:, :r]).astype(fdtype)            # m x r, orthonormal
    Bk = (S[:r, None] * Vt[:r, :]).astype(fdtype)      # r x n
    lu1, _, perm_rows = lax_linalg.lu(Qk)              # Qk[perm] = L1 U1
    L1 = jnp.tril(lu1, -1) + jnp.eye(m, r, dtype=fdtype)
    U1 = jnp.triu(lu1[:r, :])
    mid = U1 @ Bk                                      # r x n
    lu2, _, perm_cols = lax_linalg.lu(mid.T)           # mid.T[perm] = L2 U2
    L2 = jnp.tril(lu2, -1) + jnp.eye(n, r, dtype=fdtype)
    U2 = jnp.triu(lu2[:r, :])
    # A[pr] ~= L1 (U1 Bk) = L1 mid;  mid[:, pc] = U2^T L2^T
    L = L1 @ U2.T                                      # lower-trapezoidal
    U = L2.T                                           # unit-upper-trapezoidal
    return (perm_rows, L, U, perm_cols), keep, qb.rank_history, qb.err_history


def _prepare_pca(op):
    from repro.linalg.operators import CenteredOp

    return op if isinstance(op, CenteredOp) else CenteredOp(op)


def _execute_pca(op, spec, pl, seed) -> HandlerResult:
    """PCA = svd of the CenteredOp (`prepare` wrapped it).  Factors follow
    `core.pca.PCAResult` field order: (components, explained_variance,
    singular_values, mean)."""
    (U, S, Vt), keep, rank_hist, err_hist = _execute_svd(op, spec, pl, seed)
    n = op.shape[0]
    return (Vt, S**2 / (n - 1), S, op.mu), keep, rank_hist, err_hist


def _batched_safe(factor):
    """Guard verification targets a single 2-D factor; batched (3-D)
    factors are skipped (the probes still cover them — every vmapped slice
    reports through the probed twin)."""
    return None if getattr(factor, "ndim", 2) == 3 else factor


register(DecompositionKind(
    "svd", _execute_svd,
    description="U S Vt; Rank specs keep the historical fixed-rank paths",
    ortho_factor=lambda f: _batched_safe(f[0])))          # U: m x k
register(DecompositionKind(
    "qb", _execute_qb,
    description="rank-revealed orthonormal basis: A ~= Q B",
    ortho_factor=lambda f: f[0]))                         # Q: m x r
register(DecompositionKind(
    "eigh", _execute_eigh,
    description="Nystrom eigendecomposition of a PSD source: A ~= V diag(w) V^T",
    ortho_factor=lambda f: f[1]))                         # V: n x r
register(DecompositionKind(
    "lu", _execute_lu,
    description="randomized LU: A[pr][:, pc] ~= L U (Shabat et al. 2013)"))
register(DecompositionKind(
    "pca", _execute_pca, prepare=_prepare_pca,
    description="PCA over the centered operator; Energy(p) = explained variance",
    ortho_factor=lambda f: f[0].T))                       # componentsᵀ: d x r
