"""Version-compatibility shims for the jax API surface this repo uses.

The codebase is written against the modern jax API (``jax.shard_map`` with
``axis_names=`` / ``check_vma=``, ``jax.sharding.get_abstract_mesh``,
``jax.enable_x64``).  Older jax releases (e.g. the 0.4.x line pinned in some
containers) expose the same functionality under ``jax.experimental`` with
different keyword names.  Every call site imports from here so the rest of
the code reads as modern jax and upgrades are a one-file change.
"""
from __future__ import annotations

import contextlib
from typing import Iterable, Optional

import jax

_HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(
    f,
    mesh,
    in_specs,
    out_specs,
    axis_names: Optional[Iterable[str]] = None,
    check_vma: Optional[bool] = None,
):
    """``jax.shard_map`` on new jax; ``jax.experimental.shard_map`` on old.

    ``axis_names`` is the set of MANUAL axes (modern spelling); on old jax it
    is translated to the complementary ``auto=`` frozenset.  ``check_vma``
    maps to old ``check_rep``.
    """
    check = True if check_vma is None else check_vma
    if _HAS_TOP_LEVEL_SHARD_MAP:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check, auto=auto,
    )


@contextlib.contextmanager
def enable_x64():
    """``with jax.enable_x64(True)`` / ``jax.experimental.enable_x64()``."""
    if hasattr(jax, "enable_x64"):
        with jax.enable_x64(True):
            yield
        return
    from jax.experimental import enable_x64 as _e64

    with _e64():
        yield


def manual_axis_names() -> set:
    """Mesh axis names currently bound as Manual (inside a shard_map body).

    with_sharding_constraint specs must not mention these.  New jax exposes
    them on the abstract mesh; old jax binds them in the axis environment.
    """
    am = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
    if am is not None and getattr(am, "axis_types", None):
        return {
            n
            for n, t in zip(am.axis_names, am.axis_types)
            if t == jax.sharding.AxisType.Manual
        }
    try:
        from jax._src import core as _core

        return set(_core.unsafe_get_axis_names())
    except Exception:  # pragma: no cover - last-resort fallback
        return set()
