"""GaLore-style low-rank optimizer built on the paper's randomized SVD.

For each 2-D weight (m x n, m <= n wlog) the Adam moments live in an r-dim
projected space: g_proj = P^T g with P (m x r) the top-r left singular
subspace of the gradient, recomputed every `update_every` steps with
*our* randomized SVD (core/rsvd.py — the paper's Algorithm 1).  Optimizer
memory per weight drops from 2mn to 2rn + mr.

This is the paper's "large-scale PCA inside the ML pipeline" vision made
concrete: the eigensolver sits inside the training step, so its speed (the
paper's contribution) directly bounds the projection-refresh overhead.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rsvd import RSVDConfig
from repro.optim import adamw

Params = Any

# Seeds are traced through the counter RNG, so per-refresh/per-unit seeds
# reuse one compiled program on every path.  fused_sketch now vmaps too
# (traced SMEM seed) and is worth enabling on real TPUs; the default stays
# off because off-TPU it runs in Pallas interpret mode (~18x slower than
# the XLA GEMM for zero HBM benefit).
_RSVD_CFG = RSVDConfig(oversample=8, power_iters=1, qr_method="cqr2", small_svd="gram")


class GaLoreLeaf(NamedTuple):
    p: jax.Array       # projection (m x r)
    m: jax.Array       # Adam m in projected space (r x n)
    v: jax.Array       # Adam v in projected space (r x n)


class GaLoreState(NamedTuple):
    step: jax.Array
    leaves: Params      # GaLoreLeaf per projected 2-D weight, None elsewhere
    dense: adamw.AdamWState  # classic Adam for non-projected leaves


def _mT(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)


def _projectable(leaf: jax.Array, rank: int) -> bool:
    # 2-D weights, or scan-stacked [units, m, n] weights (batched projection)
    return leaf.ndim in (2, 3) and min(leaf.shape[-2:]) > 2 * rank


def _masked(params: Params, rank: int, keep_projected: bool) -> Params:
    """Zero-shaped stand-ins so the dense Adam state skips projected leaves."""
    def f(p):
        if _projectable(p, rank) == keep_projected:
            return p
        return jnp.zeros((1,), p.dtype)  # placeholder leaf (negligible memory)

    return jax.tree.map(f, params)


def init_state(params: Params, rank: int, seed: int = 23) -> GaLoreState:
    def mk(p):
        if not _projectable(p, rank):
            return None
        units = p.shape[:-2]  # () for 2-D, (n_units,) for scan-stacked
        m, n = p.shape[-2:]
        if m <= n:
            proj = jnp.broadcast_to(jnp.eye(m, rank, dtype=jnp.float32), units + (m, rank))
            mom = jnp.zeros(units + (rank, n), jnp.float32)
        else:
            proj = jnp.broadcast_to(jnp.eye(n, rank, dtype=jnp.float32), units + (n, rank))
            mom = jnp.zeros(units + (m, rank), jnp.float32)
        return GaLoreLeaf(proj, mom, jnp.zeros_like(mom))

    dense = adamw.init_state(_masked(params, rank, keep_projected=False))
    return GaLoreState(
        step=jnp.zeros((), jnp.int32),
        leaves=jax.tree.map(mk, params),
        dense=dense,
    )


def _refresh_projection(g: jax.Array, rank: int) -> jax.Array:
    """Top-r singular subspace of the gradient via the paper's RSVD
    (the `repro.linalg` facade; `_RSVD_CFG` pins the numerical variant).

    Scan-stacked [units, m, n] gradients refresh every unit's projection in
    ONE vmapped solve (the StackedOp execution path) — the projection-
    refresh overhead is a single kernel launch regardless of layer count."""
    from repro import linalg

    m, n = g.shape[-2:]
    if g.ndim == 3:
        if m <= n:
            u, _, _ = linalg.svd(linalg.StackedOp(g), rank, overrides=_RSVD_CFG)
            return u                  # (units, m, r)
        _, _, vt = linalg.svd(linalg.StackedOp(g), rank, overrides=_RSVD_CFG)
        return _mT(vt)                # (units, n, r)
    gf = g.astype(jnp.float32)
    if m <= n:
        u, _, _ = linalg.svd(gf, rank, overrides=_RSVD_CFG)
        return u                      # (m, r)
    _, _, vt = linalg.svd(gf, rank, overrides=_RSVD_CFG)
    return vt.T                       # (n, r)


def apply_updates(
    params: Params,
    grads: Params,
    state: GaLoreState,
    opt_cfg: adamw.AdamWConfig,
    rank: int,
    update_every: int = 200,
) -> Tuple[Params, GaLoreState, Dict[str, jax.Array]]:
    step = state.step
    refresh = (step % update_every) == 0
    lr = adamw.schedule(opt_cfg, step)
    b1c = 1 - opt_cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - opt_cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, leaf):
        gf = g.astype(jnp.float32)
        m_, n_ = gf.shape[-2:]
        left = m_ <= n_
        proj = jax.lax.cond(
            refresh,
            lambda: _refresh_projection(gf, rank),
            lambda: leaf.p,
        )
        # matmul broadcasts over the optional leading units axis
        g_proj = _mT(proj) @ gf if left else gf @ proj         # (..,r,n)/(..,m,r)
        m_new = opt_cfg.b1 * leaf.m + (1 - opt_cfg.b1) * g_proj
        v_new = opt_cfg.b2 * leaf.v + (1 - opt_cfg.b2) * g_proj * g_proj
        delta_proj = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + opt_cfg.eps)
        delta = proj @ delta_proj if left else delta_proj @ _mT(proj)
        delta = delta + opt_cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, GaLoreLeaf(proj, m_new, v_new)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_leaf = treedef.flatten_up_to(state.leaves)
    out_p, out_leaf = [], []
    for p, g, leaf in zip(flat_p, flat_g, flat_leaf):
        if leaf is None:
            out_p.append(p)  # handled by the dense Adam branch below
            out_leaf.append(None)
        else:
            np_, nl = upd(p, g, leaf)
            out_p.append(np_)
            out_leaf.append(nl)
    new_params_proj = jax.tree.unflatten(treedef, out_p)
    new_leaves = jax.tree.unflatten(treedef, out_leaf)

    # dense Adam on the remaining (non-projected) leaves
    masked_params = _masked(params, rank, keep_projected=False)
    masked_grads = _masked(grads, rank, keep_projected=False)
    dense_params, dense_state, _ = adamw.apply_updates(
        masked_params, masked_grads, state.dense, opt_cfg
    )

    def merge(p, proj_p, dense_p):
        return proj_p if _projectable(p, rank) else dense_p

    new_params = jax.tree.map(merge, params, new_params_proj, dense_params)
    metrics = {"galore_refresh": refresh.astype(jnp.float32), "lr": lr}
    return new_params, GaLoreState(step + 1, new_leaves, dense_state), metrics


def memory_savings(params: Params, rank: int) -> Tuple[int, int]:
    """(dense Adam floats, GaLore floats) across projected leaves."""
    dense = 0
    lowrank = 0
    for p in jax.tree.leaves(params):
        if _projectable(p, rank):
            units = int(np.prod(p.shape[:-2])) if p.ndim > 2 else 1
            m, n = p.shape[-2:]
            dense += units * 2 * m * n
            r = rank
            lowrank += units * ((min(m, n) * r) + 2 * r * max(m, n))
    return dense, lowrank
