"""AdamW from scratch (no optax), with warmup-cosine schedule and global-norm
clipping.  Optimizer state is a pytree congruent with params, so it inherits
the params' sharding (including FSDP) under pjit.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def init_state(params: Params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def apply_updates(
    params: Params, grads: Params, state: AdamWState, cfg: AdamWConfig
) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    # flatten once: params may contain structural tuples (scanned units), so
    # per-leaf zipping is safer than tuple-valued tree.map results
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    triples = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in triples])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in triples])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in triples])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
