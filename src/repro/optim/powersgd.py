"""PowerSGD-style rank-k gradient compression built on the paper's primitives.

Each 2-D gradient M (m x n) is approximated as M_hat = P_hat Q^T where
  P = (M + E) Q_prev          (GEMM — the paper's BLAS-3 building block)
  P_hat = CholeskyQR2(P)      (the paper's orthonormalizer, DESIGN.md §2)
  Q = (M + E)^T P_hat         (GEMM)
with error feedback E <- (M + E) - M_hat carried across steps (Vogels et al.
2019).  This is exactly one step of the paper's randomized range finder with
a warm-started sketch.

Deployment modes:
  * in-graph (`compress_tree_grads`) — models the numerics under plain pjit;
  * cross-pod (`powersgd_psum`) — inside shard_map over the 'pod' axis the
    all-reduce moves P (m x k) + Q (n x k) instead of M (m x n): the
    collective-bytes ratio is k(m+n)/(mn) (e.g. 3072x8192 at k=32 -> 1.4%).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod
from repro.core.sketch import sketch_matrix

Params = Any


class PowerSGDState(NamedTuple):
    q: Params  # per-leaf Q (n x k) or None
    e: Params  # per-leaf error feedback (m x n) or None


def _compressible(leaf: jax.Array, rank: int) -> bool:
    # 2-D weights, or scan-stacked [units, m, n] weights (vmapped compression)
    return leaf.ndim in (2, 3) and min(leaf.shape[-2:]) > 4 * rank


def init_state(params: Params, rank: int, seed: int = 17) -> PowerSGDState:
    def mk_q(p):
        if _compressible(p, rank):
            q = sketch_matrix(p.shape[-1], rank, seed, dtype=jnp.float32)
            if p.ndim == 3:
                q = jnp.broadcast_to(q[None], (p.shape[0],) + q.shape).copy()
            return q
        return None

    def mk_e(p):
        if _compressible(p, rank):
            return jnp.zeros(p.shape, jnp.float32)
        return None

    return PowerSGDState(
        q=jax.tree.map(mk_q, params),
        e=jax.tree.map(mk_e, params),
    )


def _compress_one(g: jax.Array, q: jax.Array, e: jax.Array, psum_axes=()):
    gf = g.astype(jnp.float32) + e
    p = gf @ q                                   # (m, k) GEMM
    if psum_axes:
        p = jax.lax.pmean(p, psum_axes)          # the only cross-pod traffic
    p_hat, _ = qr_mod.cholesky_qr2(p)            # paper's BLAS-3 orthonormalizer
    q_new = gf.T @ p_hat                         # (n, k) GEMM
    if psum_axes:
        q_new = jax.lax.pmean(q_new, psum_axes)
    g_hat = p_hat @ q_new.T
    e_new = gf - g_hat
    return g_hat.astype(g.dtype), q_new, e_new


def compress_tree_grads(
    grads: Params, state: PowerSGDState, rank: int, psum_axes=()
) -> Tuple[Params, PowerSGDState, Dict[str, jax.Array]]:
    """Apply rank-k compression with error feedback to every 2-D leaf."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_q = treedef.flatten_up_to(state.q)
    flat_e = treedef.flatten_up_to(state.e)

    out_g, out_q, out_e = [], [], []
    err_num = jnp.zeros((), jnp.float32)
    err_den = jnp.zeros((), jnp.float32)
    for g, q, e in zip(flat_g, flat_q, flat_e):
        if q is None:
            out_g.append(g)  # small/1-D leaves pass through uncompressed
            out_q.append(None)
            out_e.append(None)
            continue
        if g.ndim == 3:  # scan-stacked: compress each unit's slice
            g_hat, q_new, e_new = jax.vmap(
                lambda gg, qq, ee: _compress_one(gg, qq, ee, psum_axes)
            )(g, q, e)
        else:
            g_hat, q_new, e_new = _compress_one(g, q, e, psum_axes)
        out_g.append(g_hat)
        out_q.append(q_new)
        out_e.append(e_new)
        err_num = err_num + jnp.sum(e_new**2)
        err_den = err_den + jnp.sum(g.astype(jnp.float32) ** 2)

    metrics = {"psgd_rel_err": jnp.sqrt(err_num / jnp.maximum(err_den, 1e-20))}
    return (
        jax.tree.unflatten(treedef, out_g),
        PowerSGDState(jax.tree.unflatten(treedef, out_q), jax.tree.unflatten(treedef, out_e)),
        metrics,
    )


def collective_bytes(shape: Tuple[int, int], rank: int, dtype_bytes: int = 4) -> Tuple[int, int]:
    """(full all-reduce bytes, PowerSGD bytes) for one matrix — roofline input."""
    m, n = shape
    return m * n * dtype_bytes, rank * (m + n) * dtype_bytes
