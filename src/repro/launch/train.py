"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--steps N] [--powersgd] [--galore]

On a real TPU fleet this runs under `jax.distributed.initialize()` with one
process per host; on this CPU container use --smoke to run the reduced
config end-to-end (the mesh path is exercised by repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", help="reduced config on CPU")
    ap.add_argument("--powersgd", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host fleet)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import get_config
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.data.synthetic import data_iterator
    from repro.models import init_model
    from repro.optim import adamw
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        shape = ShapeConfig("smoke", 64, 4, "train")
    else:
        shape = SHAPES[args.shape]
    if args.powersgd and cfg.powersgd_rank == 0:
        cfg = dataclasses.replace(cfg, powersgd_rank=32)

    params = init_model(cfg, jax.random.key(0))
    ocfg = adamw.AdamWConfig(total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=max(args.steps // 4, 1),
        checkpoint_dir=args.ckpt_dir,
    )
    trainer = Trainer(cfg, ocfg, tcfg)
    host = jax.process_index()
    n_hosts = jax.process_count()
    data = data_iterator(cfg, shape, host_index=host, host_count=n_hosts)
    params, _, metrics = trainer.run(params, data, resume=True)
    print(f"done: loss={float(metrics['loss']):.4f} "
          f"straggler_flags={trainer.straggler.flagged_steps}")


if __name__ == "__main__":
    main()
