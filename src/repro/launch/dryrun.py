import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may import jax.

import argparse
import dataclasses
import json
import pathlib
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES, ShapeConfig
from repro.launch import mesh as mesh_mod
from repro.launch.input_specs import abstract_caches, applicable, input_specs
from repro.models import abstract_params
from repro.models import transformer as T
from repro.optim import adamw
from repro.serve import serve_step
from repro.train.train_step import make_train_step

ART_DIR = pathlib.Path(os.environ.get("REPRO_ART_DIR", "artifacts/dryrun"))

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\("
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def parse_collectives(hlo_text: str):
    """Sum result-operand sizes of every collective op (per device)."""
    out = []
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        out.append({"op": op, "bytes": size * _DTYPE_BYTES[dtype]})
    totals: Dict[str, int] = {}
    for c in out:
        totals[c["op"]] = totals.get(c["op"], 0) + c["bytes"]
    return {"per_op": totals, "total": sum(totals.values()), "count": len(out)}


def analyze(compiled, lowered) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    return {
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost": {
            "flops": float(ca.get("flops", -1)),
            "transcendentals": float(ca.get("transcendentals", -1)),
            "bytes_accessed": float(ca.get("bytes accessed", -1)),
        },
        "collectives": parse_collectives(txt),
    }


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def _opt_shardings(param_sh):
    return adamw.AdamWState(
        step=NamedSharding(list(jax.tree.leaves(param_sh))[0].mesh, P()),
        m=param_sh,
        v=param_sh,
    )


def build_train(cfg, shape, mesh):
    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(adamw.init_state, params_abs)
    batch_abs = input_specs(cfg, shape)["batch"]

    param_sh = mesh_mod.param_shardings(cfg, params_abs, mesh)
    opt_sh = _opt_shardings(param_sh)
    batch_sh = mesh_mod.batch_shardings(cfg, batch_abs, mesh, shape.global_batch)

    bx = mesh_mod.batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in bx]))
    bdim = bx if shape.global_batch % n_dp == 0 else None
    vshard = "model" if cfg.padded_vocab_() % mesh.shape["model"] == 0 else None
    logits_sh = NamedSharding(mesh, P(bdim, None, vshard))

    ocfg = adamw.AdamWConfig()
    raw = make_train_step(cfg, ocfg, logits_sharding=logits_sh)

    def step(params, opt_state, batch):
        p, o, metrics, _ = raw(params, opt_state, batch, None)
        return p, o, metrics

    fn = jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1),
    )
    return fn, (params_abs, opt_abs, batch_abs)


def build_prefill(cfg, shape, mesh):
    params_abs = jax.eval_shape(
        lambda: jax.tree.map(
            lambda l: jnp.zeros(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else jnp.zeros(l.shape, l.dtype),
            abstract_params(cfg),
        )
    )
    spec = input_specs(cfg, shape)
    param_sh = mesh_mod.param_shardings(cfg, params_abs, mesh)
    tok_sh = NamedSharding(mesh, P(mesh_mod.batch_axes(mesh), None))
    cache_sh = mesh_mod.cache_shardings(cfg, spec["caches"], mesh, shape.global_batch)
    extras_sh = mesh_mod.batch_shardings(cfg, spec["extras"], mesh, shape.global_batch)

    def step(params, tokens, caches, extras):
        logits, caches, enc = serve_step.prefill_step(params, tokens, cfg, caches, extras=extras)
        return logits, caches

    fn = jax.jit(
        step,
        in_shardings=(param_sh, tok_sh, cache_sh, extras_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    return fn, (params_abs, spec["tokens"], spec["caches"], spec["extras"])


def build_decode(cfg, shape, mesh):
    params_abs = jax.eval_shape(
        lambda: jax.tree.map(
            lambda l: jnp.zeros(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else jnp.zeros(l.shape, l.dtype),
            abstract_params(cfg),
        )
    )
    spec = input_specs(cfg, shape)
    param_sh = mesh_mod.param_shardings(cfg, params_abs, mesh)
    bx = mesh_mod.batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in bx]))
    bdim = bx if shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp else None
    tok_sh = NamedSharding(mesh, P(bdim, None))
    pos_sh = NamedSharding(mesh, P())
    cache_sh = mesh_mod.cache_shardings(cfg, spec["caches"], mesh, shape.global_batch)
    args = [params_abs, spec["token"], spec["position"], spec["caches"]]
    in_sh = [param_sh, tok_sh, pos_sh, cache_sh]
    if cfg.is_encoder_decoder:
        args.append(spec["encoder_out"])
        in_sh.append(NamedSharding(mesh, P(bdim, None, None)))

        def step(params, token, position, caches, enc):
            return serve_step.decode_step(
                params, token, position, cfg, caches, encoder_out=enc
            )
    else:

        def step(params, token, position, caches):
            return serve_step.decode_step(params, token, position, cfg, caches)

    fn = jax.jit(
        step,
        in_shardings=tuple(in_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(3,),
    )
    return fn, tuple(args)


def build_train_f32(cfg, shape, mesh):
    """Paired baseline for the podsgd hillclimb: the standard train step with
    f32 params (XLA:CPU's bf16 emulation crashes inside manual-axis shard_map
    — 'Invalid binary instruction opcode copy' — so the podsgd comparison is
    run f32-vs-f32; on TPU bf16 is native and unaffected)."""
    return build_train(dataclasses.replace(cfg, dtype="float32"), shape, mesh)


def build_train_podsgd(cfg, shape, mesh):
    """Hillclimb variant: cross-pod PowerSGD gradient sync (train/podsgd.py)."""
    from repro.train.podsgd import init_podsgd_state, make_podsgd_train_step

    cfg = dataclasses.replace(cfg, dtype="float32")  # see build_train_f32

    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(adamw.init_state, params_abs)
    batch_abs = input_specs(cfg, shape)["batch"]
    n_pods = mesh.shape.get("pod", 1)
    psgd_abs = jax.eval_shape(
        lambda: init_podsgd_state(
            jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), params_abs),
            cfg.powersgd_rank, n_pods,
        )
    )

    param_sh = mesh_mod.param_shardings(cfg, params_abs, mesh)
    opt_sh = _opt_shardings(param_sh)
    batch_sh = mesh_mod.batch_shardings(cfg, batch_abs, mesh, shape.global_batch)
    flat_psh, pdef = jax.tree.flatten(
        jax.tree_util.tree_map_with_path(
            lambda path, l: mesh_mod.param_spec(path, l, cfg, mesh), params_abs
        )
    )
    e_abs_flat = pdef.flatten_up_to(psgd_abs[0])
    e_sh = jax.tree.unflatten(
        pdef,
        [
            None if e is None else NamedSharding(mesh, P(*(("pod",) + tuple(spec))))
            for e, spec in zip(e_abs_flat, flat_psh)
        ],
    )
    q_sh = jax.tree.map(lambda q: NamedSharding(mesh, P()), psgd_abs[1])

    vshard = "model" if cfg.padded_vocab_() % mesh.shape["model"] == 0 else None
    # inside the pod-manual shard_map, sharding constraints may only mention
    # the Auto axes ('data'/'model')
    logits_sh = NamedSharding(mesh, P("data", None, vshard))
    step = make_podsgd_train_step(cfg, adamw.AdamWConfig(), mesh, logits_sh)
    # NOTE: no donation here — donate_argnums + manual-axis shard_map trips an
    # XLA:CPU SPMD crash ("Invalid binary instruction opcode copy"); the real
    # deployment donates on TPU where the pass is exercised routinely.
    fn = jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, batch_sh, e_sh, q_sh),
        out_shardings=(param_sh, opt_sh, None, e_sh, q_sh),
    )
    return fn, (params_abs, opt_abs, batch_abs, psgd_abs[0], psgd_abs[1])


def build_train_no_seqshard(cfg, shape, mesh):
    """Ablation: sequence-sharded residual stream OFF (collective vs memory)."""
    return build_train(
        dataclasses.replace(cfg, dtype="float32", seq_shard=False), shape, mesh
    )


VARIANT_BUILDERS = {
    "podsgd": build_train_podsgd,
    "baseline_f32": build_train_f32,
    "no_seqshard": build_train_no_seqshard,
}


# ---------------------------------------------------------------------------
# Mini (single-unit) lowering for scan trip-count cost correction
# ---------------------------------------------------------------------------

def build_mini(cfg, shape, mesh):
    """Lower EXACTLY one scanned unit (same shardings) so the roofline can
    compose: total = full + (n_scan - 1) * mini.  Returns None when the arch
    has no scanned units."""
    params_abs = abstract_params(cfg)
    if "units" not in params_abs:
        return None, None
    dtype = cfg.param_dtype() if shape.kind == "train" else jnp.bfloat16
    units_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            (1,) + l.shape[1:],
            dtype if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype,
        ),
        params_abs["units"],
    )
    full_param_sh = mesh_mod.param_shardings(cfg, params_abs, mesh)
    units_sh = full_param_sh["units"]
    B, Tlen = shape.global_batch, shape.seq_len
    bx = mesh_mod.batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in bx]))
    bdim = bx if B % n_dp == 0 and B >= n_dp else None
    # the residual-stream dtype follows params in training (f32 ablations)
    act_dtype = cfg.param_dtype() if shape.kind == "train" else jnp.bfloat16

    if shape.kind == "train":
        Tq = Tlen + (cfg.vision_tokens if cfg.vision_stub else 0)
        x_abs = jax.ShapeDtypeStruct((B, Tq, cfg.d_model), act_dtype)
        x_sh = NamedSharding(mesh, P(bdim, None, None))
        pos = jnp.arange(Tq, dtype=jnp.int32)

        def loss(units, x):
            (h, aux), _ = T.scan_units(units, x, cfg, positions=pos, mode="train")
            l = jnp.sum(h.astype(jnp.float32))
            if aux:
                l = l + aux.get("moe_lb_loss", 0.0)
            return l

        def mini(units, x):
            return jax.grad(loss, argnums=(0, 1))(units, x)

        fn = jax.jit(mini, in_shardings=(units_sh, x_sh), out_shardings=(units_sh, x_sh))
        return fn, (units_abs, x_abs)

    # serve: one unit forward (prefill or decode shape)
    caches_abs_full = abstract_caches(cfg, shape)
    unit_caches_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((1,) + l.shape[1:], l.dtype),
        caches_abs_full["units"],
    )
    cache_sh_full = mesh_mod.cache_shardings(cfg, caches_abs_full, mesh, B)
    unit_cache_sh = cache_sh_full["units"]
    Tq = 1 if shape.kind == "decode" else Tlen
    x_abs = jax.ShapeDtypeStruct((B, Tq, cfg.d_model), act_dtype)
    x_sh = NamedSharding(mesh, P(bdim, None, None))
    mode = "decode" if shape.kind == "decode" else "prefill"
    pos_abs = jax.ShapeDtypeStruct((1,) if mode == "decode" else (Tq,), jnp.int32)

    def mini(units, x, ucaches, pos):
        (h, _), ncaches = T.scan_units(
            units, x, cfg, positions=pos, unit_caches=ucaches, mode=mode
        )
        return h, ncaches

    fn = jax.jit(
        mini,
        in_shardings=(units_sh, x_sh, unit_cache_sh, NamedSharding(mesh, P(None))),
        out_shardings=(x_sh, unit_cache_sh),
    )
    return fn, (units_abs, x_abs, unit_caches_abs, pos_abs)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def n_scan_units(cfg) -> int:
    n_units, _ = cfg.num_units_()
    return n_units - cfg.first_k_dense // max(len(cfg.block_pattern), 1)


def analytic_flops(cfg, shape) -> Dict[str, float]:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens."""
    import math

    params_abs = abstract_params(cfg)
    total = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(params_abs) if hasattr(l, "shape")
    )
    n_active = total
    if cfg.num_experts > 0:
        # subtract inactive expert fraction
        expert = 0
        for path, l in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
            names = [str(getattr(p, "key", "")) for p in path]
            if "ffn" in names and hasattr(l, "shape") and l.ndim >= 3 and cfg.num_experts in l.shape:
                expert += int(np.prod(l.shape))
        n_active = total - expert + expert * cfg.num_experts_per_tok // cfg.num_experts
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return {
        "params_total": float(total),
        "params_active": float(n_active),
        "tokens": float(tokens),
        "model_flops": float(mult * n_active * tokens),
    }


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
    variant: str | None = None,
) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    stem = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        stem += f"__{variant}"
    out_path = out_dir / f"{stem}.json"
    if out_path.exists():
        return json.loads(out_path.read_text())

    ok, reason = applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    if variant:
        rec["variant"] = variant
    if not ok:
        rec["skipped"] = reason
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    builders = {"train": build_train, "prefill": build_prefill, "decode": build_decode}
    t0 = time.time()
    with mesh:
        if variant:
            fn, args = VARIANT_BUILDERS[variant](cfg, shape, mesh)
        else:
            fn, args = builders[shape.kind](cfg, shape, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        rec["full"] = analyze(compiled, lowered)
        rec["compile_s"] = round(time.time() - t0, 1)
        print(f"[{arch} {shape_name} {mesh_name}] full compile {rec['compile_s']}s "
              f"flops={rec['full']['cost']['flops']:.3e} "
              f"coll={rec['full']['collectives']['total']:.3e}B")

        t1 = time.time()
        mini_fn, mini_args = build_mini(cfg, shape, mesh)
        if mini_fn is not None:
            mlow = mini_fn.lower(*mini_args)
            mcomp = mlow.compile()
            rec["mini"] = analyze(mcomp, mlow)
            rec["mini"]["compile_s"] = round(time.time() - t1, 1)
        rec["n_scan_units"] = n_scan_units(cfg)

    rec["analytic"] = analytic_flops(cfg, shape)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--variant", default=None, choices=[None, *VARIANT_BUILDERS])
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, out_dir, variant=args.variant)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
                    print(f"FAILED: {arch} {shape} multi={mp}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("ALL CELLS OK")


if __name__ == "__main__":
    main()
