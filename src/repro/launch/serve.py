"""Serving launcher: batched generation with optional RSVD weight compression.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      [--lowrank-rank 64] [--requests 8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--lowrank-rank", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve.engine import Engine, Request
    from repro.serve.lowrank import factorize_params, memory_report

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    params = init_model(cfg, jax.random.key(0))
    if args.lowrank_rank:
        params, report = factorize_params(params, rank=args.lowrank_rank)
        worst = max(report.values()) if report else 0.0
        print(f"low-rank factorized {len(report)} weight groups, worst rel-err {worst:.3f}")

    rng = np.random.default_rng(0)  # repro: noqa[RL004]: synthetic traffic prompts, launch script not library code
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(8, 32)).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]
    engine = Engine(params, cfg, max_batch=4, max_len=128)
    t0 = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(c.tokens) for c in outs)
    print(f"{len(outs)} completions, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
