"""Production mesh + sharding rules.

Mesh axes:
  pod    — inter-pod (DCN) axis: carries ONLY the data-parallel gradient
           reduction (PowerSGD-compressible).
  data   — intra-pod data parallelism / FSDP axis.
  model  — tensor/expert parallelism (heads, d_ff, vocab, experts).

IMPORTANT: functions only — importing this module must not touch jax device
state (the dry-run sets XLA_FLAGS before first jax use).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-pattern based, MaxText-style logical rules)
# ---------------------------------------------------------------------------

# weight-name classes
_IN_MODEL_OUT = {  # (d_in, d_out-sharded): activations enter replicated
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_x", "w_y", "w_z",
    "w_dkv", "w_uk", "w_uv", "router", "w_i", "w_f", "w_og", "w_ig", "w_rg",
}
_MODEL_IN_OUT = {"wo", "w_down", "w_out", "w_o"}  # (d_in-sharded, d_out)


def _leaf_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_spec(path, leaf, cfg, mesh) -> P:
    """PartitionSpec for one parameter leaf (handles scan-stacked leading dim)."""
    names = _leaf_names(path)
    shape = leaf.shape
    model_size = mesh.shape.get("model", 0)  # 0 = mesh has no model axis
    data_size = mesh.shape.get("data", 0)
    fsdp = cfg.fsdp
    # scanned units carry a leading stack axis
    stacked = "units" in names
    core = shape[1:] if stacked else shape
    name = names[-1]
    if name in ("w", "b"):  # conv
        name = "conv_" + name

    def out(*spec):
        return P(*(((None,) + spec) if stacked else spec))

    if len(core) == 0:
        return out()

    # vectors: replicate (cheap) unless large and divisible
    if len(core) == 1:
        return out(None)

    # expert-stacked weights [E, d_in, d_out]: EP over model
    if len(core) == 3 and "ffn" in names and core[0] == cfg.num_experts:
        if name in ("w_gate", "w_up"):
            return out("model", "data" if fsdp and _divides(core[1], data_size) else None, None)
        return out("model", None, "data" if fsdp and _divides(core[2], data_size) else None)

    # slstm per-head recurrent mixing [H, Dh, Dh]
    if len(core) == 3:
        last = "model" if _divides(core[2], model_size) else None
        return out(None, None, last)

    if name == "embed":
        if _divides(core[0], model_size):
            return out("model", "data" if fsdp and _divides(core[1], data_size) else None)
        if _divides(core[1], model_size):
            return out(None, "model")
        return out(None, None)
    if name == "head":
        d0 = "data" if fsdp and _divides(core[0], data_size) else None
        return out(d0, "model" if _divides(core[1], model_size) else None)

    if name in _IN_MODEL_OUT and len(core) == 2:
        m = "model" if _divides(core[1], model_size) else None
        d = "data" if fsdp and _divides(core[0], data_size) and m == "model" else None
        return out(d, m)
    if name in _MODEL_IN_OUT and len(core) == 2:
        m = "model" if _divides(core[0], model_size) else None
        d = "data" if fsdp and _divides(core[1], data_size) and m == "model" else None
        return out(m, d)
    if name == "conv_w":
        return out(None, "model" if _divides(core[1], model_size) else None)

    # fallback: replicate
    return out(*([None] * len(core)))


def param_shardings(cfg, params_abstract, mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, cfg, mesh)),
        params_abstract,
    )


# ---------------------------------------------------------------------------
# Data / cache shardings
# ---------------------------------------------------------------------------

def data_spec(mesh: Mesh, batch_divisible: bool = True) -> P:
    return P(batch_axes(mesh) if batch_divisible else None, None)


def batch_shardings(cfg, batch_abstract, mesh, global_batch: int) -> Any:
    bx = batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in bx]))
    bdim = bx if global_batch % n_dp == 0 and global_batch >= n_dp else None

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(bdim, *([None] * (nd - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch_abstract)


def cache_shardings(cfg, caches_abstract, mesh, global_batch: int) -> Any:
    """KV caches: batch over data axes; heads or head_dim over model,
    whichever divides.  Scan-stacked leaves get a leading None."""
    bx = batch_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in bx]))
    model_size = mesh.shape["model"]
    bdim = bx if global_batch % n_dp == 0 and global_batch >= n_dp else None

    def spec(path, leaf):
        names = _leaf_names(path)
        shape = leaf.shape
        stacked = "units" in names
        core = shape[1:] if stacked else shape

        def out(*s):
            return NamedSharding(mesh, P(*(((None,) + s) if stacked else s)))

        if len(core) == 0:
            return out()
        if len(core) == 1:
            return out(None)
        # [B, Hkv, T, Dh] KV / [B, H, Dh, Dh] mLSTM / [B, T, lora] MLA / [B, R]
        rest = list(core[1:])
        specs: list = [None] * len(rest)
        # choose the LAST divisible non-time axis for model sharding
        for i in range(len(rest) - 1, -1, -1):
            # axis 'T' in KV caches is core[2] == index 1 of rest for 4-D;
            # sharding time would break decode updates, so skip axis whose
            # size equals a plausible cache length (>= 1024) unless nothing
            # else divides.
            if rest[i] >= 1024 and i != len(rest) - 1:
                continue
            if _divides(rest[i], model_size):
                specs[i] = "model"
                break
        return out(bdim, *specs)

    return jax.tree_util.tree_map_with_path(spec, caches_abstract)
