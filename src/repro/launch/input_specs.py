"""ShapeDtypeStruct stand-ins for every (arch x shape) cell — no allocation.

input_specs(cfg, shape) returns the abstract inputs for the step the shape
kind lowers (train_step / prefill_step / decode_step), and the matching
sharding builders live in launch/mesh.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.serve import kvcache

S = jax.ShapeDtypeStruct


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {
        "tokens": S((B, T), jnp.int32),
        "labels": S((B, T), jnp.int32),
        "loss_mask": S((B, T), jnp.float32),
    }
    if cfg.vision_stub:
        batch["vision_embeds"] = S((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["audio_features"] = S((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


def cache_len_policy(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Cache buffer length: full seq for quadratic archs, window-bounded for
    sub-quadratic long-context decode (the structural reason long_500k runs
    only on SSM/hybrid archs).  VLM prompts carry vision_tokens extra
    positions in front of the text."""
    extra = cfg.vision_tokens if cfg.vision_stub else 0
    if shape.kind == "decode" and cfg.is_subquadratic_():
        w = cfg.window_size or 0
        return min(shape.seq_len + extra, max(w, 128))
    return shape.seq_len + extra


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    max_len = cache_len_policy(cfg, shape)
    return jax.eval_shape(
        lambda: kvcache.init_caches(cfg, shape.global_batch, max_len, dtype=jnp.bfloat16)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs keyed by step-function argument name."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _batch_specs(cfg, shape)}

    if shape.kind == "prefill":
        spec: Dict[str, Any] = {
            "tokens": S((B, T), jnp.int32),
            "caches": abstract_caches(cfg, shape),
        }
        extras = {}
        if cfg.vision_stub:
            extras["vision_embeds"] = S((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            extras["audio_features"] = S((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        spec["extras"] = extras
        return spec

    # decode: one new token against a seq_len-deep cache
    spec = {
        "token": S((B, 1), jnp.int32),
        "position": S((), jnp.int32),
        "caches": abstract_caches(cfg, shape),
    }
    if cfg.is_encoder_decoder:
        spec["encoder_out"] = S((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return spec


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — the DESIGN.md §5 skip matrix."""
    if shape.name == "long_500k" and not cfg.is_subquadratic_():
        return False, "full-attention arch: 500k decode is quadratic (skip per assignment)"
    return True, ""
