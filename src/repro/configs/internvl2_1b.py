"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT (stub) + Qwen2-0.5B LM backbone.
[arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, 256, 896] prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    vision_stub=True,
    vision_tokens=256,
    rope_theta=1_000_000.0,
    block_pattern=("global",),
    tie_embeddings=True,
    logits_pad_to=128,
    act="silu",
    galore_rank=64,
    powersgd_rank=16,
)
