"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,       # kv=32: full multi-head attention
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    block_pattern=("global",),
    tie_embeddings=False,
    act="silu",
    # paper-technique integration defaults
    galore_rank=128,
    powersgd_rank=32,
    lowrank_serve_rank=0,
)
