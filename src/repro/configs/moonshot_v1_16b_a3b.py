"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Moonlight follows the DeepSeek-V3 recipe: 2 shared experts, first layer
dense (d_ff=11264 a la moonlight), 64 routed experts top-6 with
renormalized gates; attention is plain MHA (kv=16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,            # dense (first-layer) FFN width
    moe_d_ff=1408,         # per-expert FFN width (the assigned d_ff)
    vocab_size=163840,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_k_dense=1,
    rope_theta=50000.0,
    block_pattern=("global",),
    tie_embeddings=False,
    act="silu",
    fsdp=True,             # 16B params: shard optimizer state over data too
    galore_rank=0,         # GaLore off for MoE (expert grads are sparse)
    powersgd_rank=32,      # compress dense (non-expert) grads only
)
