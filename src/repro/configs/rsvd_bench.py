"""The paper's own experiment configurations (§4), as selectable configs.

These drive benchmarks/bench_spectra.py, bench_pca.py and bench_sumc.py;
kept here so every experiment in EXPERIMENTS.md §Paper-repro maps to a
config object, same as the LM architectures.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class SpectraBench:
    """Figs 2-4: A in R^{2000 x n}, k = frac * n largest singular values."""

    m: int = 2000
    n_values: Tuple[int, ...] = (512, 1024, 2000)
    fracs: Tuple[float, ...] = (0.01, 0.03, 0.05, 0.10)
    kinds: Tuple[str, ...] = ("fast", "sharp", "slow")
    beta: float = 50.0             # sharp-decay breakout point
    target_rel_err: float = 1e-8   # the paper's accuracy budget (f64)


@dataclass(frozen=True)
class PCABench:
    """Fig 1: flattened RGB images, resolutions 8x8 ... 52x52."""

    resolutions: Tuple[int, ...] = (8, 12, 16, 24, 32, 40, 52)
    n_images: int = 2048
    component_fracs: Tuple[float, ...] = (0.01, 0.03, 0.05, 0.10, 0.20, 0.30)


@dataclass(frozen=True)
class SuMCBench:
    """Table 1: union-of-subspaces synthetic datasets."""

    first: Tuple[Tuple[int, ...], Tuple[int, ...], int] = (
        (500, 1000, 2000), (30, 50, 70), 1000
    )  # sizes, dims, ambient
    second: Tuple[Tuple[int, ...], Tuple[int, ...], int] = (
        (5000, 10000, 20000), (30, 50, 70), 1000
    )


SPECTRA = SpectraBench()
PCA = PCABench()
SUMC = SuMCBench()
