"""Architecture registry: every assigned config selectable via --arch <id>."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from repro.configs.qwen3_4b import CONFIG as qwen3_4b
from repro.configs.gemma2_2b import CONFIG as gemma2_2b
from repro.configs.llama3_2_1b import CONFIG as llama3_2_1b
from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.internvl2_1b import CONFIG as internvl2_1b
from repro.configs.xlstm_350m import CONFIG as xlstm_350m

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        phi3_mini_3_8b,
        qwen3_4b,
        gemma2_2b,
        llama3_2_1b,
        moonshot_v1_16b_a3b,
        deepseek_v2_lite_16b,
        whisper_base,
        recurrentgemma_9b,
        internvl2_1b,
        xlstm_350m,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(ARCHS)}")
    return ARCHS[name]
