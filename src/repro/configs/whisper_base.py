"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

6 encoder + 6 decoder layers, LayerNorm + GELU, absolute sinusoidal
positions (no RoPE).  The mel/conv frontend is a stub: input_specs()
provides precomputed frame embeddings [B, 1500, 512].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq_len=1500,
    use_rope=False,
    norm_kind="layer",
    act="gelu",
    block_pattern=("global",),
    tie_embeddings=True,
    logits_pad_to=128,
    galore_rank=64,
    powersgd_rank=16,
)
