"""Config system: model architecture + input shapes + parallelism policy.

Every assigned architecture is a ModelConfig constant in its own module;
`reduced()` derives the CPU smoke-test version (same family, tiny sizes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The assigned LM shape set (per-arch applicability handled in dryrun).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | hybrid | vlm | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention variants
    qk_norm: bool = False
    attn_softcap: Optional[float] = None     # gemma2 attention-logit cap
    final_softcap: Optional[float] = None    # gemma2 final-logit cap
    rope_theta: float = 10000.0
    use_rope: bool = True                    # whisper uses absolute positions
    window_size: Optional[int] = None        # local-attention window
    attn_chunk: int = 1024                   # online-softmax chunk length
    use_post_norm: bool = False              # gemma2 sandwich norms
    embed_scale: bool = False                # gemma multiplies embed by sqrt(d)

    # layer pattern: repeating unit; remainder unrolled at the top of stack
    block_pattern: Tuple[str, ...] = ("global",)

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    first_k_dense: int = 0
    moe_renormalize: bool = True
    capacity_factor: float = 1.25

    # MLA
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = True          # decode-time weight absorption (DeepSeek)

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500

    # vlm
    vision_stub: bool = False
    vision_tokens: int = 256

    # hybrid / ssm
    lru_width: Optional[int] = None
    conv1d_width: int = 4
    mlstm_proj_factor: int = 2       # xLSTM up-projection around the mLSTM cell
    mlstm_chunk: int = 2048          # chunkwise-parallel mLSTM chunk length

    # misc
    norm_eps: float = 1e-6
    norm_kind: str = "rms"           # 'rms' | 'layer'
    tie_embeddings: bool = True
    act: str = "silu"
    dtype: str = "bfloat16"
    logits_pad_to: int = 1           # pad logits V so the vocab axis shards
                                     # (padded ids get -1e9: softmax/argmax-inert)

    # --- paper-technique integration (RSVD) -----------------------------
    galore_rank: int = 0             # >0: RSVD low-rank optimizer states
    galore_update_every: int = 200
    powersgd_rank: int = 0           # >0: rank-k DP gradient compression
    lowrank_serve_rank: int = 0      # >0: serve-side factorized weights

    # --- runtime policy ---------------------------------------------------
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False               # shard params/opt over data axis too
    seq_shard: bool = True           # sequence-parallel residual stream (train)

    # ------------------------------------------------------------------
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def attn_scale_(self) -> float:
        return 1.0 / float(self.head_dim_()) ** 0.5

    def lru_width_(self) -> int:
        return self.lru_width if self.lru_width is not None else self.d_model

    def moe_d_ff_(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def param_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def num_units_(self) -> Tuple[int, Tuple[str, ...]]:
        """(scanned unit count, remainder pattern)."""
        u = len(self.block_pattern)
        return self.num_layers // u, self.block_pattern[: self.num_layers % u]

    def is_subquadratic_(self) -> bool:
        """True when no layer kind does unwindowed full attention."""
        kinds = set(self.block_pattern)
        quad = {"global"}
        return not (kinds & quad) and not self.is_encoder_decoder

    def has_decoder_(self) -> bool:
        return True  # every assigned arch decodes (whisper via its decoder)

    def trained_len_(self) -> int:
        """Max absolute-position table length (sinusoidal archs)."""
        return 4096

    def padded_vocab_(self) -> int:
        p = self.logits_pad_to
        return self.vocab_size + (-self.vocab_size) % p

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/kinds, tiny everything."""
        u = len(self.block_pattern)
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(u, 2 if u == 1 else u),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff > 0 else 0,
            vocab_size=512,
            num_experts=8 if self.num_experts else 0,
            num_experts_per_tok=2 if self.num_experts else 0,
            moe_d_ff=64 if self.num_experts else None,
            kv_lora_rank=32 if self.use_mla else 0,
            qk_rope_head_dim=16 if self.use_mla else 0,
            qk_nope_head_dim=32 if self.use_mla else 0,
            v_head_dim=32 if self.use_mla else 0,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=64 if self.is_encoder_decoder else 0,
            vision_tokens=8 if self.vision_stub else 0,
            lru_width=128 if self.lru_width is not None or "rglru" in self.block_pattern else None,
            window_size=min(self.window_size, 32) if self.window_size else None,
            attn_chunk=64,
            dtype="float32",
        )
