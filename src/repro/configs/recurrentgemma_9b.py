"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, pattern 2 recurrent : 1 attention.
[arXiv:2402.19427; unverified]

38 layers = 12 scanned (rglru, rglru, local) triples + 2 remainder rglru.
Sub-quadratic end-to-end (recurrence + 2048-token windowed attention), so
this arch RUNS the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,          # MQA on the attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    lru_width=4096,
    conv1d_width=4,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu_tanh",
    fsdp=True,
    galore_rank=128,
    powersgd_rank=32,
)
