"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6 — MLA kv_lora=512, 2 shared + routed top-6.
[arXiv:2405.04434; hf]

MLA dims per the DeepSeek-V2 paper: q heads carry 128 'nope' + 64 rope
dims; kv compressed to a 512-dim latent (the decode cache stores ONLY the
latent + one shared 64-dim rope key — itself a low-rank KV factorization,
cf. DESIGN.md §5).  First layer dense (d_ff=10944).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,            # dense (first-layer) FFN width
    moe_d_ff=1408,         # per-expert width (the assigned d_ff)
    vocab_size=102400,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    block_pattern=("global",),
    tie_embeddings=False,
    act="silu",
    fsdp=True,
    galore_rank=0,
    powersgd_rank=32,
)
