"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks have no separate FFN.  Mix ratio 3 mLSTM : 1 sLSTM
(the xLSTM paper's [7:1]-style majority-mLSTM stacks, rounded to a
4-layer repeating unit -> 6 scanned units).  Linear recurrence end-to-end,
so this arch RUNS the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    # chunk=4096: train_4k takes the (cheaper-at-short-T) quadratic path,
    # prefill_32k runs 8 chunks, long-context decode is O(1) regardless —
    # measured trade-off in EXPERIMENTS.md §Perf iteration 5b.
    mlstm_chunk=4096,
    tie_embeddings=True,
    act="silu",
    galore_rank=64,
    powersgd_rank=16,
)
