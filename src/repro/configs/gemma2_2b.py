"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— local+global alternating, logit softcap.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    rope_theta=10000.0,
    block_pattern=("local", "global"),   # alternating; 13 scanned pairs
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    use_post_norm=True,                  # gemma2 sandwich norms
    embed_scale=True,
    tie_embeddings=True,
    act="gelu_tanh",
    galore_rank=128,
    powersgd_rank=32,
)
