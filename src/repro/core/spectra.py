"""Synthetic test matrices with controlled spectra (paper §4, Figs 2-4).

The paper constructs A = U Sigma V^T with random orthogonal U, V and three
spectral profiles:

  (i)   fast decay:   sigma_i = 1 / i^2
  (ii)  sharp decay:  sigma_i = 1e-4 + 1 / (1 + exp(i + 1 - beta))
  (iii) slow decay:   sigma_i = 1 / i^0.1
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.sketch import sketch_matrix

DecayKind = Literal["fast", "sharp", "slow"]


def spectrum(n: int, kind: DecayKind, beta: float = 50.0, dtype=jnp.float32) -> jax.Array:
    i = jnp.arange(1, n + 1, dtype=dtype)
    if kind == "fast":
        return 1.0 / i**2
    if kind == "sharp":
        return 1e-4 + 1.0 / (1.0 + jnp.exp(i + 1.0 - beta))
    if kind == "slow":
        return 1.0 / i**0.1
    raise ValueError(f"unknown decay kind: {kind}")


def random_orthogonal(n: int, cols: int, seed: int, dtype=jnp.float32) -> jax.Array:
    """n x cols matrix with orthonormal columns (QR of a Gaussian)."""
    G = sketch_matrix(n, cols, seed, dtype=dtype)
    Q, R = jnp.linalg.qr(G, mode="reduced")  # repro: noqa[RL006]: test-matrix synthesis, not a solve path
    # Fix signs for determinism across backends.
    return Q * jnp.sign(jnp.diag(R))[None, :]


@functools.partial(jax.jit, static_argnames=("m", "n", "kind", "seed", "dtype"))
def make_test_matrix(
    m: int, n: int, kind: DecayKind, seed: int = 0, beta: float = 50.0, dtype=jnp.float32
) -> tuple[jax.Array, jax.Array]:
    """A = U diag(sigma) V^T (m >= n). Returns (A, sigma)."""
    r = min(m, n)
    sig = spectrum(r, kind, beta, dtype)
    U = random_orthogonal(m, r, seed, dtype)
    V = random_orthogonal(n, r, seed + 1, dtype)
    A = (U * sig[None, :]) @ V.T
    return A, sig
