"""SuMC — lossy-compression subspace clustering (paper experiment 3).

Reimplementation of the algorithmic core of Struski, Tabor, Spurek,
"Lossy compression approach to subspace clustering" (Inf. Sciences 2018),
which the paper accelerates by swapping its eigensolver for the randomized
GPU SVD.  The reproducible claims (paper Table 1):

  * the solver (eigendecomposition of cluster scatter) is called hundreds of
    thousands of times -> solver speed dominates end-to-end time;
  * swapping the dense eigensolver for randomized SVD preserves ARI = 1.0 on
    synthetic union-of-subspaces data while cutting wall time ~28x.

We therefore expose the solver as a pluggable callable and *count calls*,
mirroring the paper's "Solver calls" column.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rsvd import RSVDConfig


# ---------------------------------------------------------------------------
# Solvers: given centered cluster data (n_i x D) return an orthonormal basis
# of the dominant q-dimensional subspace.
# ---------------------------------------------------------------------------

def eigh_solver(Xc: jax.Array, q: int) -> jax.Array:
    """Dense baseline ('CPU' row of paper Table 1): full eigendecomposition
    of the D x D scatter matrix."""
    C = Xc.T @ Xc
    _, V = jnp.linalg.eigh(C)  # repro: noqa[RL006]: the paper's dense baseline (D x D scatter), benchmarked against
    return V[:, ::-1][:, :q]  # top-q columns


def rsvd_solver(Xc: jax.Array, q: int, cfg: RSVDConfig = RSVDConfig()) -> jax.Array:
    """Randomized solver ('GPU' row): top-q right singular vectors via the
    paper's Algorithm 1.

    Cluster sizes change every Lloyd iteration; jit would recompile per
    shape.  Zero-row padding to the next power of two preserves the column
    space (zero rows contribute nothing to X^T X) and caps the number of
    compilations at log2(n_max) — the production fix for ragged solver
    batches."""
    from repro import linalg

    n = Xc.shape[0]
    n_pad = 1 << max(int(np.ceil(np.log2(max(n, 2)))), 1)
    if n_pad != n:
        Xc = jnp.pad(Xc, ((0, n_pad - n), (0, 0)))
    _, _, Vt = linalg.svd(Xc, q, overrides=cfg)
    return Vt.T


# ---------------------------------------------------------------------------
# SuMC clustering
# ---------------------------------------------------------------------------

@dataclass
class SuMCResult:
    labels: np.ndarray
    bases: List[np.ndarray]
    means: List[np.ndarray]
    solver_calls: int
    iterations: int
    cost_history: List[float] = field(default_factory=list)


def _residual_cost(X: np.ndarray, mean: np.ndarray, W: np.ndarray) -> np.ndarray:
    """Squared distance of each row of X to the affine subspace (mean, W)."""
    Xc = X - mean[None, :]
    proj = Xc @ W  # (n, q)
    return np.sum(Xc * Xc, axis=1) - np.sum(proj * proj, axis=1)


def sumc(
    X: np.ndarray,
    n_clusters: int,
    subspace_dims: List[int] | int,
    solver: Callable[[jax.Array, int], jax.Array] = rsvd_solver,
    max_iters: int = 50,
    seed: int = 0,
    n_init: int = 5,
) -> SuMCResult:
    """SuMC with multi-restart (Lloyd alternation is non-convex; the paper
    fixes one initialization across solver variants — we additionally restart
    and keep the lowest-cost run, accumulating solver calls across restarts)."""
    best: SuMCResult | None = None
    total_calls = 0
    for trial in range(n_init):
        res = _sumc_single(X, n_clusters, subspace_dims, solver, max_iters, seed + trial)
        total_calls += res.solver_calls
        if best is None or (res.cost_history and best.cost_history and res.cost_history[-1] < best.cost_history[-1]):
            best = res
        if best.cost_history and best.cost_history[-1] < 1e-8 * X.size:
            break  # exact fit found — no need for more restarts
    assert best is not None
    best.solver_calls = total_calls
    return best


def _sumc_single(
    X: np.ndarray,
    n_clusters: int,
    subspace_dims: List[int] | int,
    solver: Callable[[jax.Array, int], jax.Array],
    max_iters: int,
    seed: int,
) -> SuMCResult:
    rng = np.random.default_rng(seed)  # repro: noqa[RL004]: host-side k-means-style label init, not a solve path
    n, D = X.shape
    dims = (
        [subspace_dims] * n_clusters if isinstance(subspace_dims, int) else list(subspace_dims)
    )
    labels = rng.integers(0, n_clusters, size=n)
    solver_calls = 0
    cost_history: List[float] = []

    means = [np.zeros(D, X.dtype) for _ in range(n_clusters)]
    bases = [np.eye(D, dims[c]).astype(X.dtype) for c in range(n_clusters)]

    for it in range(max_iters):
        # M-step: refit subspaces.
        for c in range(n_clusters):
            pts = X[labels == c]
            if len(pts) <= dims[c]:
                continue  # degenerate cluster keeps its old basis
            mu = pts.mean(axis=0)
            W = solver(jnp.asarray(pts - mu[None, :]), dims[c])
            solver_calls += 1
            means[c] = mu
            bases[c] = np.asarray(W)

        # E-step: reassign.
        costs = np.stack(
            [_residual_cost(X, means[c], bases[c]) for c in range(n_clusters)], axis=1
        )
        new_labels = np.argmin(costs, axis=1)
        total = float(costs[np.arange(n), new_labels].sum())
        cost_history.append(total)
        if np.array_equal(new_labels, labels):
            labels = new_labels
            break
        labels = new_labels

    return SuMCResult(labels, bases, means, solver_calls, it + 1, cost_history)


# ---------------------------------------------------------------------------
# Synthetic union-of-subspaces data (paper's Table 1 datasets) + ARI metric
# ---------------------------------------------------------------------------

def synthetic_subspace_data(
    sizes: List[int], dims: List[int], ambient: int = 1000, seed: int = 0, noise: float = 0.0
) -> Tuple[np.ndarray, np.ndarray]:
    """Points drawn from random linear subspaces of [0,1]^ambient.

    Paper 'first' dataset: sizes=[500,1000,2000], dims=[30,50,70], ambient=1000.
    Paper 'second':        sizes=[5000,10000,20000], same dims.
    """
    rng = np.random.default_rng(seed)  # repro: noqa[RL004]: synthetic-dataset generation (paper's SuMC data)
    xs, ys = [], []
    for c, (sz, d) in enumerate(zip(sizes, dims)):
        basis, _ = np.linalg.qr(rng.standard_normal((ambient, d)))  # repro: noqa[RL006]: synthetic subspace basis, host-side data gen
        coeff = rng.uniform(0, 1, size=(sz, d))
        pts = coeff @ basis.T
        if noise:
            pts = pts + noise * rng.standard_normal(pts.shape)
        xs.append(pts.astype(np.float32))
        ys.append(np.full(sz, c))
    X = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(X))
    return X[perm], y[perm]


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """ARI without sklearn (paper's clustering quality metric)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n = len(a)
    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    cont = np.zeros((len(ua), len(ub)), dtype=np.int64)
    np.add.at(cont, (ia, ib), 1)

    def comb2(x):
        return x * (x - 1) / 2.0

    sum_ij = comb2(cont).sum()
    sum_a = comb2(cont.sum(axis=1)).sum()
    sum_b = comb2(cont.sum(axis=0)).sum()
    expected = sum_a * sum_b / comb2(n)
    max_idx = 0.5 * (sum_a + sum_b)
    if max_idx == expected:
        return 1.0
    return float((sum_ij - expected) / (max_idx - expected))
