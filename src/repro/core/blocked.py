"""Blocked/streaming + batched randomized SVD (beyond-paper subsystem).

Two execution shapes the paper's single-GPU Algorithm 1 cannot serve:

1. **Panel streaming** (`blocked_randomized_svd`): A (m x n, tall) is consumed
   in row panels of `block_rows` — A may live in host memory (a numpy array)
   with only one panel on device at a time.  The trick is the same one that
   makes the *distributed* RSVD collective-cheap (core/distributed.py): every
   reduction in Algorithm 1 factors through a small accumulated state,

     sketch    Y_p = A_p @ Omega          per-panel GEMM, counter-RNG Omega
                                          (optionally itself streamed over
                                          column panels: Y_p += A_pj @ Omega_j
                                          via the panel-offset sketch kernel)
     CholeskyQR2  G = sum_p Y_p^T Y_p     s x s accumulator  -> R; Q_p = Y_p R^-1
     power     Z = sum_p A_p^T Q_p        n x s accumulator  -> orthonormalize
     project   B = sum_p Q_p^T A_p        s x n accumulator
     small SVD of B, U_p = Q_p @ U_b      per-panel GEMM

   where the panel sum plays the role of the all-reduce (`jax.lax.psum`) in
   the distributed path — both call the same `qr.cholesky_r_from_gram`.
   Device-resident working set: the m x n input A never is (one
   block_rows x n panel at a time), but the SKETCH-WIDTH panels Y/Q (m x s
   total) and the assembled U (m x k) are kept on device — an n/s (~20-50x)
   reduction vs. dense, not full independence from m.  Every per-panel op is
   local, so a caller needing true O(1)-in-m residency can spill Y_p/Q_p to
   host between passes; this implementation keeps them resident for speed.

2. **Batched** (`batched_randomized_svd`): a fleet of small SVDs [B, m, n]
   under one vmap — per-channel PCA, per-layer GaLore projection refresh,
   scan-stacked weight factorization (serve/lowrank.py).  Sketch seeds are
   decorrelated per slice through the counter RNG (seed + batch index), which
   is why `core.sketch` accepts traced seeds.

Dispatch now lives in the execution planner (`repro.linalg.plan`): HostOp /
`block_rows` plans execute `svd_streamed`, StackedOp (3-D) plans execute
`svd_batched`; the deprecated `core.rsvd.randomized_svd` shim routes here
through the same planner.  See DESIGN.md §"Blocked & batched execution" and
§"API: operators and plans".

Out-of-core transfers are OVERLAPPED: every pass over A's panels goes
through the prefetch pipeline (linalg/pipeline.py — the plan's
`pipeline_depth`, double-buffered by default for host numpy sources), and
the per-panel accumulator updates (Gram, Z, B, streamed-sketch Y) are
donated jitted steps so each accumulator occupies ONE device buffer for the
whole pass.  Neither changes a single arithmetic operation — results stay
bit-identical to the synchronous, undonated walk (DESIGN.md §Pipeline).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig, _rsvd_body, _small_svd


def _panel_bounds(m: int, b: int) -> List[Tuple[int, int]]:
    """[(lo, hi), ...] covering [0, m) in strides of b (last panel ragged).
    One source of truth — linalg/pipeline.py — shared with the staging ring
    and the bench, so panel coverage can never desynchronize."""
    from repro.linalg.pipeline import panel_bounds  # lazy: core stays cycle-free

    return panel_bounds(m, b)


def _device(panel) -> jax.Array:
    """Move one panel to device (no-op for arrays already there)."""
    return jnp.asarray(panel)


def _panel_stream(A, bounds, depth):
    """Factory of device-panel passes over A's row slices, prefetched.

    Each call starts one pass; with resolved depth > 1 the host->device copy
    of panel i+1 is issued while panel i computes (linalg/pipeline.py) —
    host numpy sources take the staged ring, device arrays degrade to the
    plain lazy-slice walk.  Values and order are bit-identical to the
    synchronous walk either way.

    ``start`` begins the pass at panel ordinal ``start`` (a resumed solve
    re-walks only the panels its restored cursor has not consumed; probe /
    hook ordinals restart at 0 for the shortened pass)."""
    from repro.linalg import pipeline as pipe  # lazy: core stays cycle-free

    host = isinstance(A, np.ndarray)
    d = pipe.resolve_depth(depth, host_resident=host)
    if host and d > 1:
        return lambda start=0: pipe.stream_host_panels(A, bounds[start:], d)
    return lambda start=0: pipe.lookahead(
        (_device(A[lo:hi]) for lo, hi in bounds[start:]), d)


# ---------------------------------------------------------------------------
# Donated per-panel update steps: the accumulator carries (Gram, Z, B, the
# streamed-sketch Y) are rebound every panel — donate_argnums lets XLA write
# the update into the SAME HBM buffer instead of reallocating per panel
# (the launch/dryrun.py train/serve-step pattern; like there, donation stays
# OUT of shard_map bodies — donate_argnums + manual-axis shard_map trips the
# XLA:CPU "Invalid binary instruction opcode copy" crash, so the distributed
# path in core/distributed.py keeps its undonated psum form).
# tests/test_pipeline.py asserts the input/output aliasing on compiled HLO.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _add_donated(acc, x):
    """acc + x, with acc's buffer reused for the result."""
    return acc + x


@functools.partial(jax.jit, donate_argnums=(0,))
def _accum_xty(acc, X, Y):
    """acc + Xᵀ Y — the power-loop Z and projection-B panel updates."""
    return acc + X.T @ Y


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("backend",))
def _gram_accum(G, Yp, *, backend):
    """G + YpᵀYp through the named kernel backend (static: the backend is a
    trace-time switch, so it must key the jit cache — the ambient context
    at call time may differ from the one a cached trace was built under)."""
    with qr_mod.kernel_backend(backend):
        return G + qr_mod.gram(Yp)


def _accum_panels(terms):
    """Left-associated sum of an iterable of equally-shaped terms, donating
    the running accumulator (same order as functools.reduce(jnp.add, ...),
    so results are bit-identical to the undonated form)."""
    acc = None
    for t in terms:
        acc = t if acc is None else _add_donated(acc, t)
    return acc


# ---------------------------------------------------------------------------
# Streamed sketch: Y += A_panel @ Omega_panel, Omega never materialized whole
# ---------------------------------------------------------------------------

def streamed_sketch(
    A,
    s: int,
    seed: int,
    kind: sketch_mod.SketchKind = "gaussian",
    block_cols: int | None = None,
    fused: bool = False,
) -> jax.Array:
    """Y = A @ Omega(n, s; seed) accumulated over column panels of A.

    Panel j multiplies rows [j*b, (j+1)*b) of the *logical* Omega, regenerated
    in place from the counter RNG (`row_offset`), so at most one
    (block_cols x s) panel of Omega ever exists — and with ``fused`` not even
    that (the Pallas kernel generates Omega tiles in VMEM).  Bit-wise the
    panels are the monolithic Omega; only the fp32 summation order differs.
    """
    m, n = A.shape
    b = block_cols or n
    Y = jnp.zeros((m, s), jnp.float32)
    for lo, hi in _panel_bounds(n, b):
        panel = _device(A[:, lo:hi])
        if fused:
            from repro.kernels.ops import sketch_matmul

            Y = _add_donated(Y, sketch_matmul(
                panel, s, seed, kind=kind, out_dtype=jnp.float32, row_offset=lo
            ))
        else:
            omega = sketch_mod.sketch_matrix(
                hi - lo, s, seed, kind, dtype=jnp.float32, row_offset=lo
            )
            Y = _add_donated(Y, panel.astype(jnp.float32) @ omega)
    return Y.astype(jnp.asarray(A[:1, :1]).dtype)


# ---------------------------------------------------------------------------
# Blocked CholeskyQR2 — the panel-sum twin of the distributed Gram all-reduce
# ---------------------------------------------------------------------------

def _blocked_gram(Y_panels: Sequence[jax.Array], G: jax.Array | None = None):
    """The panel-summed Gram G = Σ YpᵀYp (reusing a caller-supplied one)."""
    if G is not None:
        return G
    backend = qr_mod.active_kernel_backend()
    for Yp in Y_panels:
        G = qr_mod.gram(Yp) if G is None else _gram_accum(G, Yp, backend=backend)
    return G


def _blocked_cholesky_qr(Y_panels: Sequence[jax.Array], G: jax.Array | None = None,
                         shift=0.0, record_ortho: bool = False):
    """One CholeskyQR pass over a row-panel-split Y. Returns (Q_panels, R).

    The per-panel Gram and the R⁻¹ application go through the active kernel
    backend (qr.kernel_backend): "pallas" routes them to the SYRK and TRSM
    kernels, exactly as the dense and distributed paths do.  ``G`` lets the
    caller pass an already-reduced Gram (the sketch_gram epilogue) so the
    first pass skips re-reading every panel.  ``record_ortho`` feeds the
    accumulated Gram to the guard's orthogonality probe (set on a CQR2
    second pass, where G *is* ||Q1ᵀQ1 - I|| + I — a free byproduct)."""
    dtype = Y_panels[0].dtype
    G = _blocked_gram(Y_panels, G)
    # Factor and solve at >= fp32 (LAPACK has no bf16 Cholesky/TRSM), then
    # cast Q back so the panel dtype — and the assembled U — is preserved.
    fdtype = jnp.promote_types(dtype, jnp.float32)
    Gf = G.astype(fdtype)
    if record_ortho:
        qr_mod.record_ortho_gram(Gf)
    R = qr_mod.cholesky_r_from_gram(Gf, shift)
    Q_panels = [
        qr_mod.tri_solve_right(Yp.astype(fdtype), R).astype(dtype) for Yp in Y_panels
    ]
    return Q_panels, R


def _blocked_cholesky_qr2(Y_panels: Sequence[jax.Array], G1: jax.Array | None = None):
    """CholeskyQR2 on panels: O(eps) orthogonality for kappa(Y) <~ eps^-1/2,
    touching each panel twice and reducing only s x s Grams."""
    Q1, R1 = _blocked_cholesky_qr(Y_panels, G1)
    Q, R2 = _blocked_cholesky_qr(Q1, record_ortho=True)
    return Q, R2 @ R1


def _blocked_cholesky_qr3(Y_panels: Sequence[jax.Array], G1: jax.Array | None = None):
    """Shifted CholeskyQR3 on panels — the streamed twin of
    `qr.shifted_cholesky_qr3` (kappa(Y) up to ~1/eps), which the guard's
    retry ladder escalates to when a streamed CQR2 pass breaks down.

    The Fukaya et al. 2020 shift needs only ||Y||_F^2 = trace(G) — free
    from the Gram the first pass accumulates anyway, so the shifted pass
    still touches each panel exactly once."""
    m = sum(int(Yp.shape[0]) for Yp in Y_panels)
    G1 = _blocked_gram(Y_panels, G1)
    s = G1.shape[0]
    fdtype = jnp.promote_types(Y_panels[0].dtype, jnp.float32)
    eps = jnp.finfo(fdtype).eps
    shift = 11.0 * (m * s + s * (s + 1)) * eps * jnp.trace(G1.astype(fdtype))
    Q0, R0 = _blocked_cholesky_qr(Y_panels, G1, shift=shift)
    Q, R21 = _blocked_cholesky_qr2(Q0)
    return Q, R21 @ R0


def _panel_orthonormalizer(cfg: RSVDConfig):
    """The panel-split orthonormalizer for this config: CQR2 unless the
    plan (or the guard ladder, via a replaced plan) asks for the shifted
    CQR3.  Householder has no row-panel-split form — the ladder skips it
    for streamed plans and goes straight to the f64 recompute."""
    return _blocked_cholesky_qr3 if cfg.qr_method == "cqr3" else _blocked_cholesky_qr2


# ---------------------------------------------------------------------------
# Panel-streaming randomized SVD
# ---------------------------------------------------------------------------

def svd_streamed(
    A,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
    block_rows: int | None = None,
    pipeline_depth: int | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of A streamed in row panels of the tall side.

    Accepts a jax array OR a host numpy array (the out-of-core case: only
    `block_rows x n` of A is device-resident at a time; the s-column panels
    Y/Q — m x s in total — stay on device, see the module docstring).
    Host panels move through the prefetch pipeline: at `pipeline_depth`
    (arg > cfg.pipeline_depth > auto: double-buffered for numpy sources)
    panel i+1 transfers while panel i computes, every pass over A, with
    results bit-identical to the depth-1 synchronous walk.
    Returns (U, S, Vt) with the same contract as `linalg.svd`; U is
    assembled from per-panel GEMMs, so for a truly out-of-core caller the
    per-panel `Q_p @ U_b` products could be written back to host storage
    panel-by-panel instead.
    """
    m, n = A.shape
    if m < n:
        # Orientation swap: stream the taller side of A^T.  For numpy inputs
        # .T is a view — no host copy is made.
        V, S, Ut = svd_streamed(A.T, k, cfg, seed=seed, block_rows=block_rows,
                                pipeline_depth=pipeline_depth)
        return Ut.T, S, V.T

    b = block_rows or cfg.block_rows
    if not b:
        raise ValueError("svd_streamed needs block_rows (arg or cfg)")
    s = min(k + cfg.oversample, n)
    bounds = _panel_bounds(m, b)
    depth = pipeline_depth if pipeline_depth is not None else cfg.pipeline_depth
    panels = _panel_stream(A, bounds, depth)

    dtype = _device(A[:1, :1]).dtype
    token = _stream_token(m, n, k, s, cfg, seed, dtype, nb=len(bounds))

    with qr_mod.kernel_backend(cfg.kernel_backend):
        return _blocked_body(panels, k, s, cfg, seed, dtype, token=token)


# ---------------------------------------------------------------------------
# The streamed solve as a resumable stage machine.  Stages walk A's panels
# with an explicit cursor and call `_stream_boundary` after each consumed
# panel, so linalg/snapshot.py can capture the accumulated state (Y panels,
# Gram, Z/B accumulators, cursor) at any panel-group boundary and a restored
# run continues the walk from `bounds[cursor:]`.  Everything NOT saved (the
# CholeskyQR bases Q/Qz, Omega slabs) is recomputed on restore from saved
# bytes through the same ops — resumed factors are bit-identical to the
# uninterrupted run.  With no snapshot scope active each boundary is one
# sys.modules probe; the arithmetic and its order are EXACTLY the
# pre-machine body's (tests/test_blocked.py pins fixed-seed bytes).
# ---------------------------------------------------------------------------

class _StreamState:
    """Mutable stage-machine state of one streamed solve.

    ``stage`` walks sketch -> (power_z -> power_y) x power_iters -> project;
    ``cursor`` counts panels consumed in the CURRENT pass, ``ticks`` counts
    boundaries ever crossed (monotonic across restarts — the snapshot step
    key), ``piter`` the current power iteration.  ``Y`` holds the current
    pass's basis panels (the NEW panels while power_y rebuilds them)."""

    __slots__ = ("stage", "piter", "cursor", "ticks", "Y", "G1", "Z", "B",
                 "token")

    def __init__(self, token: str):
        self.stage = "sketch"
        self.piter = 0
        self.cursor = 0
        self.ticks = 0
        self.Y = []
        self.G1 = None
        self.Z = None
        self.B = None
        self.token = token

    def capture(self):
        """(arrays, meta) for snapshot.Checkpointer — exact host bytes."""
        arrays = {f"Y{i:04d}": np.asarray(y) for i, y in enumerate(self.Y)}
        for name in ("G1", "Z", "B"):
            v = getattr(self, name)
            if v is not None:
                arrays[name] = np.asarray(v)
        meta = {"token": self.token, "engine": "streamed", "stage": self.stage,
                "piter": self.piter, "cursor": self.cursor,
                "ticks": self.ticks, "n_y": len(self.Y)}
        return arrays, meta

    @classmethod
    def restore(cls, snap, token: str) -> "_StreamState":
        _ref, arrays, meta = snap
        st = cls(token)
        st.stage = meta["stage"]
        st.piter = int(meta["piter"])
        st.cursor = int(meta["cursor"])
        st.ticks = int(meta["ticks"])
        st.Y = [jnp.asarray(arrays[f"Y{i:04d}"]) for i in range(meta["n_y"])]
        for name in ("G1", "Z", "B"):
            if name in arrays:
                setattr(st, name, jnp.asarray(arrays[name]))
        return st


def _stream_token(m: int, n: int, k: int, s: int, cfg: RSVDConfig, seed,
                  dtype, nb: int) -> str:
    """Fingerprint of everything the streamed numerics depend on: a snapshot
    resumes only a solve that would replay the identical op sequence."""
    return "|".join(str(x) for x in (
        "streamed", m, n, k, s, int(seed), jnp.dtype(dtype).name, nb,
        cfg.power_iters, cfg.power_scheme, cfg.qr_method, cfg.sketch_kind,
        bool(cfg.fused_sketch), cfg.block_cols, cfg.kernel_backend,
        cfg.small_svd, cfg.oversample))


def _stream_boundary(st: _StreamState) -> None:
    """Advance one panel and cross a snapshot boundary.  sys.modules probe:
    core stays import-cycle-free (the `_record_step_finite` pattern); the
    snapshot module is in sys.modules whenever repro.linalg is."""
    import sys

    st.cursor += 1
    st.ticks += 1
    snap = sys.modules.get("repro.linalg.snapshot")
    if snap is not None:
        snap.boundary(st.ticks, st.capture)


def _stream_resume(token: str) -> "_StreamState | None":
    import sys

    snap = sys.modules.get("repro.linalg.snapshot")
    if snap is None:
        return None
    found = snap.resume(token)
    return None if found is None else _StreamState.restore(found, token)


def _blocked_body(panels, k: int, s: int, cfg: RSVDConfig, seed, dtype,
                  token: "str | None" = None):
    """Steps 1-6 over the panel generator, under the active kernel backend.

    ``token`` (from `_stream_token`) enables snapshot/resume; None (direct
    callers, tests of the raw body) runs the fresh stage machine with
    boundaries still crossed — identical arithmetic either way."""
    st = (_stream_resume(token) if token is not None else None) \
        or _StreamState(token or "")
    _panel_orth = _panel_orthonormalizer(cfg)

    # Step 1-2a: per-panel sketch.  Omega is n x s regenerated per panel from
    # the counter RNG — identical for every panel, no broadcast state.  The
    # fused whole-panel sketch rides the Gram epilogue: each panel's
    # contribution to G = YᵀY is accumulated while Y_p is produced, so the
    # first CQR2 pass below never re-reads Y.  (Column-paneled sketches
    # accumulate Y_p across block_cols calls, so no per-call Gram exists;
    # f64 — the faithful enable_x64 setting — stays on the jnp sketch, like
    # the dense path's guard.)
    if st.stage == "sketch":
        if cfg.fused_sketch and not cfg.block_cols and dtype != jnp.float64:
            from repro.kernels.ops import sketch_gram

            for Ap in panels(st.cursor):
                y, g = sketch_gram(Ap, s, seed, kind=cfg.sketch_kind)
                st.Y.append(y)
                st.G1 = g if st.G1 is None else _add_donated(st.G1, g)
                _stream_boundary(st)
        else:
            for Ap in panels(st.cursor):
                st.Y.append(streamed_sketch(
                    Ap, s, seed, cfg.sketch_kind,
                    block_cols=cfg.block_cols,
                    fused=cfg.fused_sketch and dtype != jnp.float64,
                ))
                _stream_boundary(st)
        st.stage = "power_z" if cfg.power_iters else "project"
        st.cursor = 0

    # Step 2: power iteration through the n x s accumulator Z.  The Z / B
    # accumulators below are donated per panel (_accum_xty): one n x s (or
    # s x n) HBM buffer carries the whole pass instead of a fresh
    # allocation per panel, and the summation order is unchanged.
    while st.stage in ("power_z", "power_y"):
        if st.stage == "power_z":
            if cfg.power_scheme == "plain":
                src = st.Y
            else:
                # recomputed (not snapshotted) on resume: a deterministic
                # function of the saved Y panels + Gram, same ops
                src, _ = _panel_orth(st.Y, st.G1)
            for Ap, Xp in zip(panels(st.cursor), src[st.cursor:]):
                st.Z = Ap.T @ Xp if st.Z is None else _accum_xty(st.Z, Ap, Xp)
                _stream_boundary(st)
            st.stage, st.cursor, st.Y = "power_y", 0, []
        else:  # power_y: rebuild Y from the completed Z accumulator
            if cfg.power_scheme == "plain":
                mult = st.Z
            else:
                mult = qr_mod.orthonormalize(st.Z, cfg.qr_method)  # n x s, fits
            for Ap in panels(st.cursor):
                st.Y.append(Ap @ mult)
                _stream_boundary(st)
            st.G1 = None  # Y was replaced; the sketch-pass Gram is stale
            st.Z = None
            st.piter += 1
            st.stage = "power_z" if st.piter < cfg.power_iters else "project"
            st.cursor = 0

    # Steps 3-4: orthonormal range basis (panel-split; recomputed from the
    # saved Y/G1 on resume), then B = Q^T A through the s x n accumulator.
    Q, _ = _panel_orth(st.Y, st.G1)
    for Ap, Qp in zip(panels(st.cursor), Q[st.cursor:]):
        st.B = Qp.T @ Ap if st.B is None else _accum_xty(st.B, Qp, Ap)
        _stream_boundary(st)

    # Steps 5-6: small SVD (s x n, in-memory) and per-panel U assembly.
    U_b, S, Vt = _small_svd(st.B, cfg.small_svd)
    U = jnp.concatenate([Qp @ U_b[:, :k] for Qp in Q], axis=0)
    return U, S[:k], Vt[:k, :]


def eigvals_streamed(
    A, k: int, cfg: RSVDConfig = RSVDConfig(), seed: int = 0,
    block_rows: int | None = None, pipeline_depth: int | None = None,
) -> jax.Array:
    """k largest singular values, streaming — Sigma-only mode of the above."""
    _, S, _ = svd_streamed(A, k, cfg, seed=seed, block_rows=block_rows,
                           pipeline_depth=pipeline_depth)
    return S


# ---------------------------------------------------------------------------
# Batched (vmap) randomized SVD
# ---------------------------------------------------------------------------

#: trace-time tally: (shape, dtype, k, cfg) -> how many times the batched
#: body was TRACED (not executed).  Incrementing inside the function body
#: runs at trace time only, so a jit cache hit leaves the count untouched —
#: the serve-layer executable cache asserts steady-state re-trace-freedom
#: (at most one trace per distinct plan) against this.  Service workers
#: trace concurrently, so every touch goes through _trace_counts_lock
#: (`_note_trace` / `trace_count`), keeping the tally exact under threads.
_TRACE_COUNTS: collections.Counter = collections.Counter()
_trace_counts_lock = threading.Lock()


def _trace_key(shape, dtype, k: int, cfg: RSVDConfig):
    return (tuple(shape), jnp.dtype(dtype).name, int(k), cfg)


def _note_trace(key) -> None:
    with _trace_counts_lock:
        _TRACE_COUNTS[key] += 1


def trace_count(key) -> int:
    """Exact number of traces recorded for a `_trace_key` (thread-safe)."""
    with _trace_counts_lock:
        return _TRACE_COUNTS.get(key, 0)


def _batched_tall_body(A: jax.Array, seeds: jax.Array, k: int, cfg: RSVDConfig):
    _note_trace(_trace_key(A.shape, A.dtype, k, cfg))
    with qr_mod.kernel_backend(cfg.kernel_backend):
        return jax.vmap(lambda a, sd: _rsvd_body(a, k, cfg, sd))(A, seeds)


_batched_tall = jax.jit(_batched_tall_body, static_argnames=("k", "cfg"))


@functools.partial(jax.jit, static_argnames=("k", "cfg", "fault_key"))
def _batched_tall_probed(A: jax.Array, seeds: jax.Array, k: int,
                         cfg: RSVDConfig, fault_key=()):
    """Guarded twin of `_batched_tall`: same body traced under an open
    guard sink; the per-slice probe scalars come back batched as extra jit
    outputs and the driver max/any-reduces them (guard.absorb).  See
    rsvd._randomized_svd_dense_probed for the fault_key cache contract."""
    del fault_key
    from repro.linalg import guard as guard_mod

    def one(a, sd):
        with guard_mod.collecting() as sink:
            out = _rsvd_body(a, k, cfg, sd)
        return out, sink.traced()

    with qr_mod.kernel_backend(cfg.kernel_backend):
        return jax.vmap(one)(A, seeds)


def batched_cfg(cfg: RSVDConfig) -> RSVDConfig:
    """The config the batched body actually traces with: fused power and the
    streaming fields are normalized away (meaningless under vmap — they
    would only fragment the jit cache key).  The serve-layer executable
    cache applies the SAME normalization when predicting a plan's trace
    key, so cache bookkeeping and execution can never drift apart."""
    if cfg.fused_power or cfg.block_rows or cfg.pipeline_depth:
        return dataclasses.replace(cfg, fused_power=False, block_rows=None,
                                   pipeline_depth=None)
    return cfg


def slice_seeds(seed, B: int) -> jax.Array:
    """Per-slice sketch seeds for a [B, m, n] batch.

    A scalar keeps the historical contract — slice i sketches with
    seed + i, a disjoint logical stream of the counter RNG.  A (B,)-shaped
    array pins each slice's seed EXPLICITLY: the request-coalescing service
    stacks unrelated requests into one batch, so slice seeds must follow
    the requests they came from (permuting arrival order permutes seeds
    with the slices, leaving every per-request result bit-identical)."""
    if np.ndim(seed) == 0:
        return jnp.uint32(seed) + jnp.arange(B, dtype=jnp.uint32)
    seeds = jnp.asarray(seed, jnp.uint32)
    if seeds.shape != (B,):
        raise ValueError(
            f"per-slice seeds must have shape ({B},) to match the batch, "
            f"got {tuple(seeds.shape)}")
    return seeds


def svd_batched(
    A: jax.Array,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of every slice of A: [B, m, n] -> (U [B, m, k],
    S [B, k], Vt [B, k, n]).

    One vmapped program instead of B kernel launches — the fleet-of-small-
    matrices workload (per-channel PCA, per-layer gradient compression).
    Slice i sketches with seed + i (or with ``seed[i]`` when ``seed`` is a
    (B,)-shaped array — see `slice_seeds`): the counter RNG makes that a
    disjoint logical stream, so batching changes nothing statistically vs.
    a Python loop with per-matrix seeds.

    The fused-sketch kernel takes its seed as a traced SMEM scalar, so the
    per-slice seeds vmap straight through it — the batched path uses the
    in-VMEM Omega generation like the dense path does.  The fused POWER
    path is disabled under vmap (its n x s VMEM accumulators would be
    per-slice); at batched (small-matrix) sizes power GEMMs are cheap.
    """
    if A.ndim != 3:
        raise ValueError(f"batched path expects [B, m, n], got shape {A.shape}")
    _, m, n = A.shape
    if m < n:
        V, S, Ut = svd_batched(jnp.swapaxes(A, -1, -2), k, cfg, seed=seed)
        return jnp.swapaxes(Ut, -1, -2), S, jnp.swapaxes(V, -1, -2)
    cfg = batched_cfg(cfg)
    seeds = slice_seeds(seed, A.shape[0])
    from repro.linalg import faults as faults_mod, guard as guard_mod

    if guard_mod.active_sink() is not None:
        out, probes = _batched_tall_probed(A, seeds, k, cfg,
                                           faults_mod.fingerprint())
        guard_mod.absorb(probes)
        return out
    return _batched_tall(A, seeds, k, cfg)


# Pre-facade names, kept importable for downstream code; the repo itself
# calls the new names (or, preferably, `repro.linalg.svd`).
blocked_randomized_svd = svd_streamed
blocked_randomized_eigvals = eigvals_streamed
batched_randomized_svd = svd_batched
