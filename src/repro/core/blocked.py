"""Blocked/streaming + batched randomized SVD (beyond-paper subsystem).

Two execution shapes the paper's single-GPU Algorithm 1 cannot serve:

1. **Panel streaming** (`blocked_randomized_svd`): A (m x n, tall) is consumed
   in row panels of `block_rows` — A may live in host memory (a numpy array)
   with only one panel on device at a time.  The trick is the same one that
   makes the *distributed* RSVD collective-cheap (core/distributed.py): every
   reduction in Algorithm 1 factors through a small accumulated state,

     sketch    Y_p = A_p @ Omega          per-panel GEMM, counter-RNG Omega
                                          (optionally itself streamed over
                                          column panels: Y_p += A_pj @ Omega_j
                                          via the panel-offset sketch kernel)
     CholeskyQR2  G = sum_p Y_p^T Y_p     s x s accumulator  -> R; Q_p = Y_p R^-1
     power     Z = sum_p A_p^T Q_p        n x s accumulator  -> orthonormalize
     project   B = sum_p Q_p^T A_p        s x n accumulator
     small SVD of B, U_p = Q_p @ U_b      per-panel GEMM

   where the panel sum plays the role of the all-reduce (`jax.lax.psum`) in
   the distributed path — both call the same `qr.cholesky_r_from_gram`.
   Device-resident working set: the m x n input A never is (one
   block_rows x n panel at a time), but the SKETCH-WIDTH panels Y/Q (m x s
   total) and the assembled U (m x k) are kept on device — an n/s (~20-50x)
   reduction vs. dense, not full independence from m.  Every per-panel op is
   local, so a caller needing true O(1)-in-m residency can spill Y_p/Q_p to
   host between passes; this implementation keeps them resident for speed.

2. **Batched** (`batched_randomized_svd`): a fleet of small SVDs [B, m, n]
   under one vmap — per-channel PCA, per-layer GaLore projection refresh,
   scan-stacked weight factorization (serve/lowrank.py).  Sketch seeds are
   decorrelated per slice through the counter RNG (seed + batch index), which
   is why `core.sketch` accepts traced seeds.

Dispatch now lives in the execution planner (`repro.linalg.plan`): HostOp /
`block_rows` plans execute `svd_streamed`, StackedOp (3-D) plans execute
`svd_batched`; the deprecated `core.rsvd.randomized_svd` shim routes here
through the same planner.  See DESIGN.md §"Blocked & batched execution" and
§"API: operators and plans".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig, _rsvd_body, _small_svd


def _panel_bounds(m: int, b: int) -> List[Tuple[int, int]]:
    """[(lo, hi), ...] covering [0, m) in strides of b (last panel ragged)."""
    if b <= 0:
        raise ValueError(f"panel size must be positive, got {b}")
    return [(lo, min(lo + b, m)) for lo in range(0, m, b)]


def _device(panel) -> jax.Array:
    """Move one panel to device (no-op for arrays already there)."""
    return jnp.asarray(panel)


# ---------------------------------------------------------------------------
# Streamed sketch: Y += A_panel @ Omega_panel, Omega never materialized whole
# ---------------------------------------------------------------------------

def streamed_sketch(
    A,
    s: int,
    seed: int,
    kind: sketch_mod.SketchKind = "gaussian",
    block_cols: int | None = None,
    fused: bool = False,
) -> jax.Array:
    """Y = A @ Omega(n, s; seed) accumulated over column panels of A.

    Panel j multiplies rows [j*b, (j+1)*b) of the *logical* Omega, regenerated
    in place from the counter RNG (`row_offset`), so at most one
    (block_cols x s) panel of Omega ever exists — and with ``fused`` not even
    that (the Pallas kernel generates Omega tiles in VMEM).  Bit-wise the
    panels are the monolithic Omega; only the fp32 summation order differs.
    """
    m, n = A.shape
    b = block_cols or n
    Y = jnp.zeros((m, s), jnp.float32)
    for lo, hi in _panel_bounds(n, b):
        panel = _device(A[:, lo:hi])
        if fused:
            from repro.kernels.ops import sketch_matmul

            Y = Y + sketch_matmul(
                panel, s, seed, kind=kind, out_dtype=jnp.float32, row_offset=lo
            )
        else:
            omega = sketch_mod.sketch_matrix(
                hi - lo, s, seed, kind, dtype=jnp.float32, row_offset=lo
            )
            Y = Y + panel.astype(jnp.float32) @ omega
    return Y.astype(jnp.asarray(A[:1, :1]).dtype)


# ---------------------------------------------------------------------------
# Blocked CholeskyQR2 — the panel-sum twin of the distributed Gram all-reduce
# ---------------------------------------------------------------------------

def _blocked_cholesky_qr(Y_panels: Sequence[jax.Array], G: jax.Array | None = None):
    """One CholeskyQR pass over a row-panel-split Y. Returns (Q_panels, R).

    The per-panel Gram and the R⁻¹ application go through the active kernel
    backend (qr.kernel_backend): "pallas" routes them to the SYRK and TRSM
    kernels, exactly as the dense and distributed paths do.  ``G`` lets the
    caller pass an already-reduced Gram (the sketch_gram epilogue) so the
    first pass skips re-reading every panel."""
    dtype = Y_panels[0].dtype
    if G is None:
        G = functools.reduce(jnp.add, [qr_mod.gram(Yp) for Yp in Y_panels])
    # Factor and solve at >= fp32 (LAPACK has no bf16 Cholesky/TRSM), then
    # cast Q back so the panel dtype — and the assembled U — is preserved.
    fdtype = jnp.promote_types(dtype, jnp.float32)
    R = qr_mod.cholesky_r_from_gram(G.astype(fdtype))
    Q_panels = [
        qr_mod.tri_solve_right(Yp.astype(fdtype), R).astype(dtype) for Yp in Y_panels
    ]
    return Q_panels, R


def _blocked_cholesky_qr2(Y_panels: Sequence[jax.Array], G1: jax.Array | None = None):
    """CholeskyQR2 on panels: O(eps) orthogonality for kappa(Y) <~ eps^-1/2,
    touching each panel twice and reducing only s x s Grams."""
    Q1, R1 = _blocked_cholesky_qr(Y_panels, G1)
    Q, R2 = _blocked_cholesky_qr(Q1)
    return Q, R2 @ R1


# ---------------------------------------------------------------------------
# Panel-streaming randomized SVD
# ---------------------------------------------------------------------------

def svd_streamed(
    A,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
    block_rows: int | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of A streamed in row panels of the tall side.

    Accepts a jax array OR a host numpy array (the out-of-core case: only
    `block_rows x n` of A is device-resident at a time; the s-column panels
    Y/Q — m x s in total — stay on device, see the module docstring).
    Returns (U, S, Vt) with the same contract as `linalg.svd`; U is
    assembled from per-panel GEMMs, so for a truly out-of-core caller the
    per-panel `Q_p @ U_b` products could be written back to host storage
    panel-by-panel instead.
    """
    m, n = A.shape
    if m < n:
        # Orientation swap: stream the taller side of A^T.  For numpy inputs
        # .T is a view — no host copy is made.
        V, S, Ut = svd_streamed(A.T, k, cfg, seed=seed, block_rows=block_rows)
        return Ut.T, S, V.T

    b = block_rows or cfg.block_rows
    if not b:
        raise ValueError("svd_streamed needs block_rows (arg or cfg)")
    s = min(k + cfg.oversample, n)
    bounds = _panel_bounds(m, b)
    panels = lambda: (_device(A[lo:hi]) for lo, hi in bounds)

    with qr_mod.kernel_backend(cfg.kernel_backend):
        return _blocked_body(panels, k, s, cfg, seed, _device(A[:1, :1]).dtype)


def _blocked_body(panels, k: int, s: int, cfg: RSVDConfig, seed, dtype):
    """Steps 1-6 over the panel generator, under the active kernel backend."""
    # Step 1-2a: per-panel sketch.  Omega is n x s regenerated per panel from
    # the counter RNG — identical for every panel, no broadcast state.  The
    # fused whole-panel sketch rides the Gram epilogue: each panel's
    # contribution to G = YᵀY is accumulated while Y_p is produced, so the
    # first CQR2 pass below never re-reads Y.  (Column-paneled sketches
    # accumulate Y_p across block_cols calls, so no per-call Gram exists;
    # f64 — the faithful enable_x64 setting — stays on the jnp sketch, like
    # the dense path's guard.)
    G1 = None
    if cfg.fused_sketch and not cfg.block_cols and dtype != jnp.float64:
        from repro.kernels.ops import sketch_gram

        pairs = [sketch_gram(Ap, s, seed, kind=cfg.sketch_kind) for Ap in panels()]
        Y = [y for y, _ in pairs]
        G1 = functools.reduce(jnp.add, [g for _, g in pairs])
    else:
        Y = [
            streamed_sketch(
                Ap, s, seed, cfg.sketch_kind,
                block_cols=cfg.block_cols,
                fused=cfg.fused_sketch and dtype != jnp.float64,
            )
            for Ap in panels()
        ]

    # Step 2: power iteration through the n x s accumulator Z.
    for _ in range(cfg.power_iters):
        if cfg.power_scheme == "plain":
            Z = functools.reduce(
                jnp.add, [Ap.T @ Yp for Ap, Yp in zip(panels(), Y)]
            )
            Y = [Ap @ Z for Ap in panels()]
        else:
            Q, _ = _blocked_cholesky_qr2(Y, G1)
            Z = functools.reduce(
                jnp.add, [Ap.T @ Qp for Ap, Qp in zip(panels(), Q)]
            )
            Qz = qr_mod.orthonormalize(Z, cfg.qr_method)  # n x s, fits
            Y = [Ap @ Qz for Ap in panels()]
        G1 = None  # Y was replaced; the sketch-pass Gram no longer matches

    # Step 3: orthonormal range basis, panel-split.
    Q, _ = _blocked_cholesky_qr2(Y, G1)

    # Step 4: B = Q^T A through the s x n accumulator.
    B = functools.reduce(jnp.add, [Qp.T @ Ap for Ap, Qp in zip(panels(), Q)])

    # Steps 5-6: small SVD (s x n, in-memory) and per-panel U assembly.
    U_b, S, Vt = _small_svd(B, cfg.small_svd)
    U = jnp.concatenate([Qp @ U_b[:, :k] for Qp in Q], axis=0)
    return U, S[:k], Vt[:k, :]


def eigvals_streamed(
    A, k: int, cfg: RSVDConfig = RSVDConfig(), seed: int = 0,
    block_rows: int | None = None,
) -> jax.Array:
    """k largest singular values, streaming — Sigma-only mode of the above."""
    _, S, _ = svd_streamed(A, k, cfg, seed=seed, block_rows=block_rows)
    return S


# ---------------------------------------------------------------------------
# Batched (vmap) randomized SVD
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def _batched_tall(A: jax.Array, seeds: jax.Array, k: int, cfg: RSVDConfig):
    with qr_mod.kernel_backend(cfg.kernel_backend):
        return jax.vmap(lambda a, sd: _rsvd_body(a, k, cfg, sd))(A, seeds)


def svd_batched(
    A: jax.Array,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of every slice of A: [B, m, n] -> (U [B, m, k],
    S [B, k], Vt [B, k, n]).

    One vmapped program instead of B kernel launches — the fleet-of-small-
    matrices workload (per-channel PCA, per-layer gradient compression).
    Slice i sketches with seed + i: the counter RNG makes that a disjoint
    logical stream, so batching changes nothing statistically vs. a Python
    loop with per-matrix seeds.

    The fused-sketch kernel takes its seed as a traced SMEM scalar, so the
    per-slice seeds vmap straight through it — the batched path uses the
    in-VMEM Omega generation like the dense path does.  The fused POWER
    path is disabled under vmap (its n x s VMEM accumulators would be
    per-slice); at batched (small-matrix) sizes power GEMMs are cheap.
    """
    if A.ndim != 3:
        raise ValueError(f"batched path expects [B, m, n], got shape {A.shape}")
    _, m, n = A.shape
    if m < n:
        V, S, Ut = svd_batched(jnp.swapaxes(A, -1, -2), k, cfg, seed=seed)
        return jnp.swapaxes(Ut, -1, -2), S, jnp.swapaxes(V, -1, -2)
    if cfg.fused_power or cfg.block_rows:
        cfg = dataclasses.replace(cfg, fused_power=False, block_rows=None)
    seeds = jnp.uint32(seed) + jnp.arange(A.shape[0], dtype=jnp.uint32)
    return _batched_tall(A, seeds, k, cfg)


# Pre-facade names, kept importable for downstream code; the repo itself
# calls the new names (or, preferably, `repro.linalg.svd`).
blocked_randomized_svd = svd_streamed
blocked_randomized_eigvals = eigvals_streamed
batched_randomized_svd = svd_batched
