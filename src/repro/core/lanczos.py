"""Golub-Kahan Lanczos bidiagonalization with full reorthogonalization.

This is the baseline the paper labels "SVDS" (RSpectra / PROPACK-style
partial SVD).  It is intentionally the *contrast* algorithm: each step is a
matrix-vector product (BLAS-2) plus reorthogonalization — exactly the memory-
bound, serial access pattern the paper's BLAS-3 reformulation avoids.  Kept
numerically honest (full reorthogonalization) so accuracy comparisons are
fair.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import sketch_matrix


@functools.partial(jax.jit, static_argnames=("k", "extra", "seed"))
def lanczos_svd(
    A: jax.Array, k: int, extra: int = 10, seed: int = 0
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial SVD via t = k + extra steps of Golub-Kahan bidiagonalization.

    Returns (U, S, Vt) of rank k.  O(t) matvecs with A and A^T; O(m t^2)
    reorthogonalization flops.
    """
    m, n = A.shape
    t = min(k + extra, min(m, n))
    dt = A.dtype

    u0 = sketch_matrix(m, 1, seed, dtype=dt)[:, 0]
    u0 = u0 / jnp.linalg.norm(u0)

    U = jnp.zeros((m, t), dt)
    V = jnp.zeros((n, t), dt)
    alphas = jnp.zeros((t,), dt)
    betas = jnp.zeros((t,), dt)  # betas[j] couples step j to j+1

    def body(j, carry):
        U, V, alphas, betas, u = carry
        r = A.T @ u
        # full reorthogonalization against V[:, :j]  (masked — V is zero beyond j)
        r = r - V @ (V.T @ r)
        r = r - V @ (V.T @ r)  # twice is enough (Kahan)
        alpha = jnp.linalg.norm(r)
        v = r / jnp.maximum(alpha, jnp.finfo(dt).tiny)
        p = A @ v - alpha * u
        p = p - U @ (U.T @ p)
        p = p - U @ (U.T @ p)
        beta = jnp.linalg.norm(p)
        u_next = p / jnp.maximum(beta, jnp.finfo(dt).tiny)
        U = U.at[:, j].set(u)
        V = V.at[:, j].set(v)
        alphas = alphas.at[j].set(alpha)
        betas = betas.at[j].set(beta)
        return (U, V, alphas, betas, u_next)

    U, V, alphas, betas, _ = jax.lax.fori_loop(
        0, t, body, (U, V, alphas, betas, u0)
    )

    # Bidiagonal B: diag(alphas) + superdiag(betas[:-1])
    B = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)
    Ub, S, Vbt = jnp.linalg.svd(B, full_matrices=False)  # repro: noqa[RL006]: bidiagonal B is rank x rank
    Uk = U @ Ub[:, :k]
    Vk = V @ Vbt[:k, :].T
    return Uk, S[:k], Vk.T


@functools.partial(jax.jit, static_argnames=("k", "extra", "seed"))
def lanczos_singular_values(A: jax.Array, k: int, extra: int = 10, seed: int = 0):
    _, S, _ = lanczos_svd(A, k, extra, seed)
    return S
