# The paper's primary contribution — randomized k-SVD reformulated as
# BLAS-3 + fast counter-based RNG — plus its applications (PCA, subspace
# clustering) and the multi-device distribution layer.
#
# The public call-site pattern is the `repro.linalg` facade (operator
# sources + execution plans); `randomized_svd` / `randomized_eigvals` are
# deprecated shims kept for pre-facade callers.
from repro.core.rsvd import (  # noqa: F401
    RSVDConfig,
    low_rank_error,
    randomized_eigvals,
    randomized_svd,
    truncation_error,
)
from repro.core.blocked import (  # noqa: F401
    batched_randomized_svd,
    blocked_randomized_eigvals,
    blocked_randomized_svd,
    eigvals_streamed,
    streamed_sketch,
    svd_batched,
    svd_streamed,
)
from repro.core.qr import (  # noqa: F401
    cholesky_qr,
    cholesky_qr2,
    cholesky_r_from_gram,
    orthonormalize,
    shifted_cholesky_qr3,
)
from repro.core.sketch import sketch_matrix  # noqa: F401
