# The paper's primary contribution — randomized k-SVD reformulated as
# BLAS-3 + fast counter-based RNG — plus its applications (PCA, subspace
# clustering) and the multi-device distribution layer.
from repro.core.rsvd import (  # noqa: F401
    RSVDConfig,
    low_rank_error,
    randomized_eigvals,
    randomized_svd,
    truncation_error,
)
from repro.core.blocked import (  # noqa: F401
    batched_randomized_svd,
    blocked_randomized_eigvals,
    blocked_randomized_svd,
    streamed_sketch,
)
from repro.core.qr import (  # noqa: F401
    cholesky_qr,
    cholesky_qr2,
    cholesky_r_from_gram,
    orthonormalize,
    shifted_cholesky_qr3,
)
from repro.core.sketch import sketch_matrix  # noqa: F401
