"""Fixed-precision randomized QB: incremental blocked range growth.

The paper's Algorithm 1 assumes the caller knows the rank `k`.  This module
implements the *fixed-precision* counterpart (Heavner et al. 2021 blocked
rank-revealing style): grow an orthonormal basis Q panel by panel until the
estimated residual meets the requested accuracy, never materializing any
m x n temporary.  Per panel of width b:

  sketch     Y = A @ Omega_p        Omega_p is n x b from the SAME counter
                                    RNG as every other path, at a per-panel
                                    seed offset (seed + panel index); on a
                                    device-resident dense source the fused
                                    sketch kernel generates Omega in VMEM
  deflate    Y -= Q (Q^T Y)         project out the accumulated basis
  power      q stabilized iterations (orthonormalize / rmatmat / matmat),
                                    re-deflating after each touch of A
  reorth     Q_p = orth(Y); CGS2 second pass against Q, CholeskyQR-family
                                    orthonormalization throughout
  project    B_p = (A^T Q_p)^T      the b x n panel of B = Q^T A
  estimate   remaining -= ||B_p||_F^2

The stopping rule is the posterior identity the panel-wise residual
(`repro.linalg.residual`) is built on: for orthonormal Q,

  ||A - Q Q^T A||_F^2 = ||A||_F^2 - ||Q^T A||_F^2 = ||A||_F^2 - ||B||_F^2,

so tracking the Frobenius mass of the B panels gives the exact residual
(up to roundoff) with zero extra passes over A.  ||A||_F^2 itself is
accumulated one row panel at a time (`fro_norm_sq`), so host-resident and
composed sources (Centered / LowRankUpdate / Scaled over a HostOp) keep
their streaming residency.

Everything is phrased through the LinOp protocol (matmat / rmatmat /
row_panels) — this module deliberately imports nothing from repro.linalg,
the operators arrive duck-typed.  Out-of-core overlap rides that protocol:
host-resident sources stream their matmat/rmatmat (and the ||A||_F^2 walk
below) through `prefetch_panels`, so the growth loop's every touch of A
double-buffers host->device transfer against compute at the ambient
`pipeline.default_depth` — the executing plan's `pipeline_depth` — and a
mid-stream early stop (tolerance met) just abandons the in-flight prefetch.
The per-panel deflation update is a donated jitted step (`_deflate_step`).

Precision floor: the estimator subtracts O(norm)-sized fp32 sums, so it
cannot resolve relative residuals much below ~sqrt(eps_f32) ≈ 3e-4 (f64
sources go correspondingly lower).  Near that floor a deflated sketch panel
is pure cancellation noise; appending it would corrupt the basis and make
the estimator double-count energy, so growth stops as soon as a
re-orthogonalized panel still overlaps the accumulated basis above
O(sqrt(eps)) (`_overlap_tol`) — the rank-trim then keeps everything the
estimator cannot certify, which in practice lands the TRUE residual well
under a floor-adjacent tolerance.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod


@dataclass(frozen=True)
class QBResult:
    """A ~= Q @ B with Q (m x r) orthonormal, plus the growth record.

    `norm_sq` / `remaining_sq` / `err_history` carry the posterior estimator
    and are None/empty for untracked (fixed-rank, threshold-free) runs —
    those skip the ||A||_F^2 pass entirely."""

    Q: jax.Array
    B: jax.Array
    norm_sq: Optional[float]        # ||A||_F^2 (panel-accumulated)
    remaining_sq: Optional[float]   # estimated ||A - Q B||_F^2
    rank_history: Tuple[int, ...]   # basis size after each panel
    err_history: Tuple[float, ...]  # relative fro residual estimate per panel

    @property
    def rank(self) -> int:
        return int(self.Q.shape[1])


#: default row-panel height for the ||A||_F^2 walk: composed sources
#: (CenteredOp etc.) build a per-panel temporary, so an unbounded default
#: would materialize the full centered matrix — exactly what this layer
#: promises never to form
DEFAULT_NORM_PANEL_ROWS = 4096


def fro_norm_sq(op, block_rows: Optional[int] = None) -> float:
    """||A||_F^2 accumulated one row panel at a time (the `linalg.residual`
    walk, numerator-free) — no m x n temporary for any panel-capable source
    (the default panel height is bounded, so composed operators' per-panel
    temporaries stay panel-sized).  Panels are summed in their own (>= fp32)
    precision — an f64 source keeps the f64 estimator floor — and ACROSS
    panels the accumulation is host f64, keeping the floor at the per-panel
    roundoff rather than growing with the panel count.

    The walk is prefetched when the source offers it (LinOp sources do:
    `prefetch_panels` overlaps panel i+1's host->device copy with panel i's
    square-and-sum; the ambient `pipeline.default_depth` scope — set by the
    executing plan — picks the depth) — the float(...) sync per panel would
    otherwise stall the link, making this transfer-bound pass the worst
    serialization in the adaptive path."""
    b = block_rows or getattr(op, "block_rows", None) or DEFAULT_NORM_PANEL_ROWS
    prefetch = getattr(op, "prefetch_panels", None)
    panels = prefetch(b) if prefetch is not None else op.row_panels(b)
    total = 0.0
    for panel in panels:
        P = panel.astype(jnp.promote_types(panel.dtype, jnp.float32))
        total += float(jnp.sum(P * P))
    return total


def _panel_sketch(op, b: int, seed_p, kind: str, fused: bool, fdtype) -> jax.Array:
    """Y = A @ Omega_p for one growth panel.

    Device-resident dense sources take the fused Pallas kernel (Omega tiles
    generated in VMEM, same counter-RNG layout as `sketch_matrix(n, b)` —
    bit-identical, kernels/sketch_matmul.py); everything else materializes
    only the n x b panel and goes through the operator product."""
    arr = getattr(op, "array", None)
    if (
        fused
        and isinstance(arr, jax.Array)
        and arr.ndim == 2
        and arr.dtype != jnp.float64
    ):
        from repro.kernels.ops import sketch_matmul

        return sketch_matmul(arr, b, seed_p, kind=kind).astype(fdtype)
    omega = sketch_mod.sketch_matrix(op.shape[1], b, seed_p, kind, dtype=fdtype)
    return op.matmat(omega)


@functools.partial(jax.jit, donate_argnums=(0,))
def _deflate_step(Y: jax.Array, Q: jax.Array) -> jax.Array:
    """Y - Q (Qᵀ Y) with Y's buffer donated: the growth loop re-deflates
    after every touch of A, and every call rebinds Y — donation reuses the
    m x b panel buffer instead of allocating a fresh one per projection
    (the launch/dryrun.py donation pattern; kept out of shard_map bodies,
    see core/blocked.py)."""
    return Y - Q @ (Q.T @ Y)


def _deflate(Y: jax.Array, Q: Optional[jax.Array]) -> jax.Array:
    """Project the accumulated basis out of Y (no-op before the first panel)."""
    if Q is None:
        return Y
    return _deflate_step(Y, Q)


def _record_step_finite(step: int, Bp: jax.Array) -> None:
    """Guard probe: per-growth-step finiteness of the projection panel (a
    reduction over bytes the estimator reads anyway).  Reached through
    sys.modules so this module still imports nothing from repro.linalg —
    if the guard was never imported, no sink can be active."""
    import sys

    g = sys.modules.get("repro.linalg.guard")
    sink = None if g is None else g.active_sink()
    if sink is not None:
        sink.record_panel(step, jnp.isfinite(Bp).all())


def _growth_token(m, n, panel, max_rank, threshold_sq, seed, power_iters,
                  qr_method, sketch_kind, fused_sketch, kernel_backend,
                  fdtype, norm_sq_arg) -> str:
    """Fingerprint of everything the growth numerics depend on — a snapshot
    resumes only a run that would replay the identical panel sequence
    (repr() keeps float thresholds exact; counter-RNG offsets are implied
    by ``seed`` + the saved step index)."""
    return "|".join(str(x) for x in (
        "adaptive", m, n, panel, max_rank, repr(threshold_sq), int(seed),
        power_iters, qr_method, sketch_kind, bool(fused_sketch),
        kernel_backend, jnp.dtype(fdtype).name, repr(norm_sq_arg)))


def _growth_boundary(step: int, capture) -> None:
    """Panel-group boundary of the growth loop: fault/cancel/deadline checks
    plus the due-snapshot save, through ``sys.modules`` (this module imports
    nothing from repro.linalg — the `_record_step_finite` pattern; with the
    snapshot module never imported this is one dict probe)."""
    import sys

    snap = sys.modules.get("repro.linalg.snapshot")
    if snap is not None:
        snap.boundary(step, capture)


def _growth_resume(token: str):
    import sys

    snap = sys.modules.get("repro.linalg.snapshot")
    if snap is None:
        return None
    found = snap.resume(token)
    if found is None:
        return None
    _ref, arrays, meta = found
    return arrays, meta


def _overlap_tol(fdtype) -> float:
    """Max tolerable |Q^T Q_p| entry after re-orthogonalization.  A healthy
    CGS2 pass lands at O(eps); an entry near sqrt(eps) means the deflated
    panel was pure cancellation noise — the spectrum is exhausted at this
    precision and appending the panel would corrupt the basis AND the
    posterior estimator (its energy double-counts directions already
    captured)."""
    return 10.0 * float(jnp.sqrt(jnp.finfo(fdtype).eps))


def adaptive_qb(
    op,
    *,
    panel: int,
    max_rank: int,
    threshold_sq: Optional[float] = None,
    seed: int = 0,
    power_iters: int = 2,
    qr_method: str = "cqr2",
    sketch_kind: str = "gaussian",
    fused_sketch: bool = False,
    kernel_backend: str = "jnp",
    norm_sq: Optional[float] = None,
) -> QBResult:
    """Grow Q in `panel`-wide blocks until the estimated residual energy
    drops to `threshold_sq` (absolute, Frobenius-squared) or the basis
    reaches `max_rank` (the full-rank fallback; `threshold_sq=None` runs
    straight to `max_rank` — the fixed-rank QB used by the non-SVD
    registry kinds, which skips the ||A||_F^2 pass and the estimator
    entirely unless the caller supplies `norm_sq`).

    The loop is eager Python — panel shapes grow, and host/streamed sources
    must move data per panel — but every per-panel op (sketch, CholeskyQR,
    operator products) traces through the active kernel backend exactly as
    the fixed-rank paths do.
    """
    if panel <= 0:
        raise ValueError(f"growth panel must be positive, got {panel}")
    m, n = op.shape
    max_rank = min(max_rank, m, n)
    fdtype = jnp.promote_types(op.dtype, jnp.float32)

    token = _growth_token(m, n, panel, max_rank, threshold_sq, seed,
                          power_iters, qr_method, sketch_kind, fused_sketch,
                          kernel_backend, fdtype, norm_sq)

    with qr_mod.kernel_backend(kernel_backend):
        saved = _growth_resume(token)
        if saved is not None:
            # Resume: rehydrate the exact saved bytes; norm_sq / remaining
            # round-trip exactly through the JSON manifest (repr-based float
            # serialization), so the estimator continues bit-identically and
            # the ||A||_F^2 pass is NOT re-run.
            arrays, saved_meta = saved
            norm_sq = saved_meta["norm_sq"]
            track = saved_meta["track"]
            remaining = float(saved_meta["remaining"])
            Q = jnp.asarray(arrays["Q"]) if "Q" in arrays else None
            B_panels = [jnp.asarray(arrays[f"B{i:04d}"])
                        for i in range(int(saved_meta["n_b"]))]
            rank_hist = [int(x) for x in saved_meta["rank_hist"]]
            err_hist = [float(x) for x in saved_meta["err_hist"]]
            r, step = int(saved_meta["r"]), int(saved_meta["step"])
        else:
            if norm_sq is None and threshold_sq is not None:
                norm_sq = fro_norm_sq(op)
            track = norm_sq is not None
            remaining = float(norm_sq) if track else 0.0
            Q = None
            B_panels = []
            rank_hist = []
            err_hist = []
            r, step = 0, 0

        def _capture():
            """Live growth state as (arrays, meta) — reads the loop's locals
            at save time (closure), exact host bytes."""
            arrays = {f"B{i:04d}": np.asarray(bp)
                      for i, bp in enumerate(B_panels)}
            if Q is not None:
                arrays["Q"] = np.asarray(Q)
            meta = {"token": token, "engine": "adaptive",
                    "remaining": remaining,
                    "norm_sq": float(norm_sq) if track else None,
                    "track": track, "r": r, "step": step,
                    "n_b": len(B_panels), "rank_hist": list(rank_hist),
                    "err_hist": list(err_hist)}
            return arrays, meta

        while r < max_rank:
            b = min(panel, max_rank - r)
            seed_p = jnp.asarray(seed, jnp.uint32) + jnp.uint32(step)
            Y = _panel_sketch(op, b, seed_p, sketch_kind, fused_sketch, fdtype)
            Y = _deflate(_deflate(Y, Q), Q)             # CGS2 projection
            for _ in range(power_iters):
                Qy = qr_mod.orthonormalize(Y, qr_method)
                Z = op.rmatmat(Qy)
                Qz = qr_mod.orthonormalize(Z, qr_method)
                Y = _deflate(op.matmat(Qz), Q)
            Qp = qr_mod.orthonormalize(Y, qr_method)
            if Q is not None:
                # CGS2: one more pass against the accumulated basis keeps
                # ||Q^T Q - I|| at O(eps), which the posterior estimator
                # (exact only for orthonormal Q) depends on.
                Qp = qr_mod.orthonormalize(_deflate(Qp, Q), qr_method)
                if float(jnp.max(jnp.abs(Q.T @ Qp))) > _overlap_tol(fdtype):
                    # precision floor: the panel is cancellation noise, no
                    # independent directions remain — stop growing (the
                    # estimator already sits at the smallest resolvable
                    # residual for this dtype)
                    break
            Bp = op.rmatmat(Qp).T                       # b x n, no read of Q
            _record_step_finite(step, Bp)
            if track:
                Bpf = Bp.astype(fdtype)
                remaining = max(0.0, remaining - float(jnp.sum(Bpf * Bpf)))
            Q = Qp if Q is None else jnp.concatenate([Q, Qp], axis=1)
            B_panels.append(Bp)
            r += b
            step += 1
            rank_hist.append(r)
            if track:
                err_hist.append(
                    math.sqrt(remaining / norm_sq) if norm_sq > 0.0 else 0.0
                )
            if threshold_sq is not None and remaining <= threshold_sq:
                break
            # boundary AFTER the stop check: a snapshot is only ever taken
            # of a run that will compute at least one more panel, so a
            # resumed run can never overshoot the tolerance
            _growth_boundary(step, _capture)
        B = B_panels[0] if len(B_panels) == 1 else jnp.concatenate(B_panels, axis=0)
        return QBResult(
            Q=Q,
            B=B,
            norm_sq=float(norm_sq) if track else None,
            remaining_sq=remaining if track else None,
            rank_history=tuple(rank_hist),
            err_history=tuple(err_hist),
        )
