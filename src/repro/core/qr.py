"""Orthonormalization strategies for the randomized SVD range finder.

The paper relies on the GPU QR (Householder panels, BLAS-2-heavy).  On TPU
Householder panel factorization serializes the MXU, so the framework's fast
path is CholeskyQR2 — Gram matrix (GEMM) + small Cholesky + triangular solve
— which makes orthonormalization itself a BLAS-3 operation.  This is the
paper's own "everything is a GEMM" philosophy applied *more* aggressively
than the paper.

Numerical contract (Yamamoto et al. 2015; Fukaya et al. 2020):
  * CholeskyQR:   ||Q^T Q - I|| = O(kappa(Y)^2 * eps)  -> only for well-cond Y
  * CholeskyQR2:  ||Q^T Q - I|| = O(eps)   whenever kappa(Y) <~ eps^{-1/2}
  * shifted CholeskyQR3: works up to kappa(Y) <~ eps^{-1} (adds a diagonal
    shift on the first pass to keep the Gram matrix positive definite).

The randomized range finder with power/subspace iteration produces Y with
modest condition number, so CQR2 is the right default.  Nothing HERE falls
back automatically: each function computes exactly the variant it names.
Breakdown detection and escalation (cqr2 -> shifted cqr3 -> householder ->
f64 recompute) live in the guard layer — `linalg/guard.py`, driven by the
`GuardPolicy` on an `ExecutionPlan`.  When a guard probe sink is active,
`cholesky_r_from_gram` records a breakdown flag (non-finite / non-positive
Cholesky diagonal) and a condition proxy from the factor's diagonal ratio,
and `cholesky_qr2` records ||Q1ᵀQ1 - I||_F from its second Gram — all
byproducts the algorithm already computes.
"""
from __future__ import annotations

import contextlib
import sys
import threading
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

QRMethod = Literal["householder", "cqr", "cqr2", "cqr3"]

KernelBackend = Literal["jnp", "pallas"]

# ---------------------------------------------------------------------------
# Pluggable kernel backend for the CholeskyQR primitives (Gram + TRSM).
#
# The CholeskyQR family reduces to exactly two large-matrix primitives —
# G = YᵀY (SYRK) and Q = Y R⁻¹ (TRSM) — shared by the dense (core/rsvd.py),
# blocked (core/blocked.py), and distributed (core/distributed.py) paths.
# `kernel_backend("pallas")` routes both through the Pallas kernels
# (kernels/gram.py, kernels/trsm.py); the default "jnp" uses plain XLA ops.
# The flag is read at TRACE time (a Python contextvar-style module global),
# so it composes with jit / vmap / shard_map: whichever backend is active
# while a program is being traced is baked into that program.
#
# float64 inputs always take the jnp path — the Pallas kernels accumulate in
# fp32, which would silently downgrade the paper's f64 faithful setting.
# ---------------------------------------------------------------------------

# Thread-local: concurrent service worker threads may trace under different
# backends at once; a scope opened on one thread must not leak into another.
_backend_state = threading.local()


@contextlib.contextmanager
def kernel_backend(name: KernelBackend):
    """Trace-time scope: route Gram/TRSM through the named backend."""
    if name not in ("jnp", "pallas"):
        raise ValueError(f"unknown kernel backend: {name}")
    prev = getattr(_backend_state, "active", "jnp")
    _backend_state.active = name
    try:
        yield
    finally:
        _backend_state.active = prev


def active_kernel_backend() -> KernelBackend:
    return getattr(_backend_state, "active", "jnp")


def _use_pallas(Y: jax.Array) -> bool:
    return active_kernel_backend() == "pallas" and Y.dtype != jnp.float64


def gram(Y: jax.Array) -> jax.Array:
    """G = Y^T Y through the active kernel backend.

    The Pallas route computes the upper block triangle on the MXU (SYRK
    saving) and accumulates fp32; the jnp route is a plain GEMM in the
    input precision (f64 under enable_x64 — the faithful setting)."""
    if _use_pallas(Y):
        from repro.kernels.ops import gram as _pallas_gram

        return _pallas_gram(Y, out_dtype=Y.dtype)
    return Y.T @ Y


def tri_solve_right(Y: jax.Array, R: jax.Array) -> jax.Array:
    """Q = Y R^{-1} for upper-triangular R (a BLAS-3 triangular solve)."""
    if _use_pallas(Y):
        from repro.kernels.ops import tri_solve_right as _pallas_trsm

        return _pallas_trsm(Y, R.astype(Y.dtype))
    # Solve R^T X^T = Y^T  (lower-triangular, many RHS), then transpose.
    Qt = jax.scipy.linalg.solve_triangular(R.T, Y.T, lower=True)
    return Qt.T


# Backwards-compatible private aliases (pre-backend names).
_gram = gram
_tri_solve_right = tri_solve_right


# ---------------------------------------------------------------------------
# Guard probes.  core/ must not import repro.linalg at module load (cycle:
# linalg imports core), so the sink is reached through sys.modules — if the
# guard module was never imported, no sink can possibly be active and the
# probes cost one dict lookup.
# ---------------------------------------------------------------------------


def _guard_sink():
    g = sys.modules.get("repro.linalg.guard")
    return None if g is None else g.active_sink()


def _faults_mod():
    return sys.modules.get("repro.linalg.faults")


def record_ortho_gram(G: jax.Array) -> None:
    """Record ||G - I||_F^2 of an orthonormality Gram (G = QᵀQ) into the
    active guard sink, if any.  Called where the algorithm has ALREADY
    computed G — CQR2's second pass here, the accumulated second-pass Gram
    in core/blocked.py — so report mode adds reductions only, never a GEMM."""
    sink = _guard_sink()
    if sink is not None:
        D = G - jnp.eye(G.shape[0], dtype=G.dtype)
        sink.record_ortho_sq(jnp.sum(D * D))


def cholesky_r_from_gram(G: jax.Array, shift: jax.Array | float = 0.0) -> jax.Array:
    """Upper-triangular R from an already-reduced Gram matrix G = Y^T Y.

    This is the shared core of every CholeskyQR variant in the codebase: the
    single-device path forms G locally, the distributed path all-reduces the
    per-shard Grams (core/distributed.py), and the blocked/streaming path sums
    the per-panel Grams (core/blocked.py) — all three then factor the SAME
    s x s matrix here and apply R^{-1} to their local rows of Y.

    A trace-scaled floor shift is always applied so the Cholesky succeeds on
    *exactly rank-deficient* panels (e.g. sketching data that lies in a
    k-dim subspace with sketch width s > k).  The floor is O(s * eps * ||Y||^2),
    so for full-rank panels it perturbs R at the eps level only, and the
    second CQR2 pass restores orthogonality to O(eps) regardless.  Deficient
    directions come out as tiny-norm columns that the downstream small-SVD
    sorts last — mirroring LAPACK's rank-revealing behavior.

    The floor CANNOT rescue a non-finite Gram (poisoned input, f32
    overflow), and it rescues a kappa^2 >~ 1/eps Gram only *finitely* —
    the resulting R is garbage.  Under an active guard sink this is made
    detectable: the factor diagonal's finiteness/positivity becomes the
    breakdown flag and its max/min ratio (squared) the condition proxy,
    both free byproducts of the factor itself.
    """
    s = G.shape[0]
    sink = _guard_sink()
    if sink is not None:
        flt = _faults_mod()
        if flt is not None:
            G = flt.poison_gram(G)  # forced-breakdown fault (guarded runs only)
    eps = jnp.finfo(G.dtype).eps
    floor = (s * eps) * (jnp.trace(G) / s + eps)
    total_shift = jnp.maximum(jnp.asarray(shift, G.dtype), floor.astype(G.dtype))
    G = G + total_shift * jnp.eye(s, dtype=G.dtype)
    L = jnp.linalg.cholesky(G)  # lower
    if sink is not None:
        d = jnp.diagonal(L)
        sink.record_breakdown(~(jnp.all(jnp.isfinite(d)) & jnp.all(d > 0)))
        a = jnp.abs(d)
        # diag(L)^2 are the pivots of G: their spread lower-bounds kappa(G)
        # = kappa(Y)^2
        sink.record_cond((jnp.max(a) / jnp.min(a)) ** 2)
    return L.T


def cholesky_qr(Y: jax.Array, shift: jax.Array | float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """Single-pass CholeskyQR (optionally shifted). Returns (Q, R).

    See `cholesky_r_from_gram` for the floor-shift contract."""
    R = cholesky_r_from_gram(gram(Y), shift)
    Q = tri_solve_right(Y, R)
    return Q, R


def cholesky_qr2(Y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """CholeskyQR2: two passes; R = R2 @ R1.

    The second pass's Gram G2 = Q1ᵀQ1 *is* the first pass's orthogonality
    residual (||G2 - I||_F ~ kappa(Y)^2 eps) — recorded into the guard sink
    when one is active, at no extra GEMM.  Op-for-op identical to the
    historical two-call form (guard off pins bit-identity)."""
    Q1, R1 = cholesky_qr(Y)
    G2 = gram(Q1)
    record_ortho_gram(G2)
    R2 = cholesky_r_from_gram(G2)
    Q = tri_solve_right(Q1, R2)
    return Q, R2 @ R1


def _frobenius_shift(Y: jax.Array) -> jax.Array:
    """Shift from Fukaya et al. 2020: 11 (m s + s(s+1)) eps ||Y||_2^2, with
    ||Y||_2 bounded by ||Y||_F (cheap, no SVD needed)."""
    m, s = Y.shape
    eps = jnp.finfo(Y.dtype).eps
    norm2 = jnp.sum(Y * Y)  # ||Y||_F^2 >= ||Y||_2^2
    return 11.0 * (m * s + s * (s + 1)) * eps * norm2


def shifted_cholesky_qr3(Y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Shifted CholeskyQR3 for ill-conditioned Y (kappa up to ~1/eps)."""
    Q0, R0 = cholesky_qr(Y, shift=_frobenius_shift(Y))
    Q, R21 = cholesky_qr2(Q0)
    return Q, R21 @ R0


def householder_qr(Y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """LAPACK-style Householder QR (the paper's baseline orthonormalizer)."""
    return jnp.linalg.qr(Y, mode="reduced")


def orthonormalize(Y: jax.Array, method: QRMethod = "cqr2") -> jax.Array:
    """Return Q with orthonormal columns spanning range(Y)."""
    if method == "householder":
        return householder_qr(Y)[0]
    if method == "cqr":
        return cholesky_qr(Y)[0]
    if method == "cqr2":
        return cholesky_qr2(Y)[0]
    if method == "cqr3":
        return shifted_cholesky_qr3(Y)[0]
    raise ValueError(f"unknown qr method: {method}")


def qr_decompose(Y: jax.Array, method: QRMethod = "cqr2") -> Tuple[jax.Array, jax.Array]:
    if method == "householder":
        return householder_qr(Y)
    if method == "cqr":
        return cholesky_qr(Y)
    if method == "cqr2":
        return cholesky_qr2(Y)
    if method == "cqr3":
        return shifted_cholesky_qr3(Y)
    raise ValueError(f"unknown qr method: {method}")
