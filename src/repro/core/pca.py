"""Principal Component Analysis via randomized SVD (paper experiment 2).

The paper's PCA experiment computes the top 1-30% principal components of
flattened CelebA images at resolutions 8x8 ... 52x52.  PCA reduces to the
SVD of the centered data matrix: for X in R^{N x d} with column means mu,
the principal axes are the right singular vectors of (X - mu) and the
explained variances are sigma_i^2 / (N - 1).

The centered matrix is an OPERATOR, not an array: `pca` runs the range
finder over `linalg.CenteredOp(X)` (matmat/rmatmat carry the -1 muᵀ
correction), so the N x d centered temporary this module used to
materialize is gone — and host-resident X streams row panels.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.rsvd import RSVDConfig


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PCAResult:
    components: jax.Array          # (k, d)  principal axes (rows)
    explained_variance: jax.Array  # (k,)
    singular_values: jax.Array     # (k,)
    mean: jax.Array                # (d,)


def pca(X, k, cfg: RSVDConfig = RSVDConfig.fast(), seed: int = 0) -> PCAResult:
    """Principal components of X (N x d) via randomized SVD on the centered
    operator (X itself may be a device array, a host numpy array, or any
    2-D LinOp).

    `k` is a component count (int) or an accuracy spec: the paper's "top
    1-30% of components" experiments state a variance contract, which is
    `linalg.Energy(p)` — e.g. ``pca(X, linalg.Energy(0.95))`` keeps the
    smallest rank explaining 95% of the variance (the adaptive QB engine
    grows the basis until the posterior estimator says so)."""
    from repro import linalg

    return linalg.pca(X, k, overrides=cfg, seed=seed)


@functools.partial(jax.jit, static_argnames=("k", "cfg", "seed"))
def batched_pca(
    X: jax.Array, k: int, cfg: RSVDConfig = RSVDConfig(), seed: int = 0
) -> PCAResult:
    """Per-channel PCA: X [C, N, d] -> PCAResult with a leading C axis on
    every field.  One vmapped randomized SVD (the StackedOp execution path)
    instead of C sequential solves — the many-small-matrices workload from
    DESIGN.md §"Blocked & batched execution"."""
    from repro import linalg

    mu = jnp.mean(X, axis=1)                      # (C, d)
    Xc = X - mu[:, None, :]
    _, S, Vt = linalg.svd(linalg.StackedOp(Xc), k, overrides=cfg, seed=seed)
    n = X.shape[1]
    return PCAResult(
        components=Vt,
        explained_variance=S**2 / (n - 1),
        singular_values=S,
        mean=mu,
    )


def pca_exact(X: jax.Array, k: int) -> PCAResult:
    """Dense-SVD PCA (the GESVD baseline column in the paper's Fig. 1)."""
    mu = jnp.mean(X, axis=0)
    Xc = X - mu[None, :]
    _, S, Vt = jnp.linalg.svd(Xc, full_matrices=False)  # repro: noqa[RL006]: pca_exact IS the paper's dense GESVD baseline
    n = X.shape[0]
    return PCAResult(Vt[:k], S[:k] ** 2 / (n - 1), S[:k], mu)


def transform(res: PCAResult, X: jax.Array) -> jax.Array:
    return (X - res.mean[None, :]) @ res.components.T


def inverse_transform(res: PCAResult, Z: jax.Array) -> jax.Array:
    return Z @ res.components + res.mean[None, :]


def synthetic_image_dataset(
    n_images: int, height: int, width: int, seed: int = 0, rank_frac: float = 0.25
) -> jax.Array:
    """Image-statistics-like synthetic stand-in for CelebA (offline container):
    low-rank structure plus pixel noise, matching the PCA benchmark shapes.
    d = 3 * h * w as in the paper's RGB flattening."""
    from repro.core.sketch import sketch_matrix

    d = 3 * height * width
    r = max(4, int(d * rank_frac))
    # Smoothly decaying spectrum typical of natural-image patches.
    basis = sketch_matrix(d, r, seed + 1)
    coeff = sketch_matrix(n_images, r, seed + 2)
    sig = 1.0 / jnp.arange(1, r + 1, dtype=jnp.float32) ** 1.2
    X = (coeff * sig[None, :]) @ basis.T
    noise = 0.01 * sketch_matrix(n_images, d, seed + 3)
    return X + noise
