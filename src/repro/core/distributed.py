"""Multi-device randomized SVD via shard_map (beyond-paper contribution).

The paper's implementation is single-GPU.  This module distributes
Algorithm 1 across a device mesh with A *row-sharded* ((m/P) x n per device)
and keeps the communication volume independent of m:

  step                         collective                 payload (floats)
  ------------------------------------------------------------------------
  sketch   C = A @ Omega       none (counter-RNG: every      0
                               device regenerates its
                               rows of the same Omega)
  power    Z = A^T Y           all-reduce                 n * s   (x q iters)
           CholeskyQR Gram     all-reduce                 s * s   (x q+2)
  project  B = Q^T A           all-reduce                 s * n
  small SVD of B               replicated                    0
  ------------------------------------------------------------------------
  total:   O(q * n * s) — independent of the tall dimension m.

CholeskyQR is the enabling trick: Householder QR of a row-sharded panel
requires sequential panel broadcasts, whereas the Gram matrix is a plain
all-reduce of an s x s block.  This mirrors (and justifies at scale) the
paper's BLAS-3 reformulation.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.rsvd import RSVDConfig


def _dist_cholesky_qr(Y: jax.Array, axis: str, shift: float = 0.0):
    """One distributed CholeskyQR pass on row-sharded Y.

    Identical to the single-device and blocked (core/blocked.py) passes
    except for how the Gram matrix is reduced: psum here, a panel sum there —
    all three factor the reduced Gram via `qr.cholesky_r_from_gram`, and all
    three route the local Gram (SYRK) and the R⁻¹ application (TRSM) through
    the active kernel backend (qr.kernel_backend): with "pallas" the
    per-shard work runs on the same kernels as the dense path.
    """
    G = jax.lax.psum(qr_mod.gram(Y), axis)
    R = qr_mod.cholesky_r_from_gram(G, shift)
    Q = qr_mod.tri_solve_right(Y, R)
    return Q, R


def _dist_cholesky_qr2(Y: jax.Array, axis: str):
    Q1, R1 = _dist_cholesky_qr(Y, axis)
    Q, R2 = _dist_cholesky_qr(Q1, axis)
    return Q, R2 @ R1


def _local_rsvd_body(
    A_loc: jax.Array,
    k: int,
    s: int,
    q: int,
    seed: int,
    axis: str,
    n_shards: int,
):
    """Executed per device under shard_map; A_loc is this device's row block."""
    m_loc, n = A_loc.shape
    idx = jax.lax.axis_index(axis)
    row_offset = (idx * m_loc).astype(jnp.uint32)

    # Sketch: every device generates the SAME global Omega columns for ITS
    # use of A columns — Omega is n x s, indexed by global element id, so no
    # broadcast is needed and determinism is mesh-shape independent.
    omega = sketch_mod.sketch_matrix(n, s, seed, dtype=A_loc.dtype)
    Y = A_loc @ omega  # (m_loc, s)

    for _ in range(q):
        Q, _ = _dist_cholesky_qr2(Y, axis)
        Z = jax.lax.psum(A_loc.T @ Q, axis)       # (n, s) replicated
        Qz, _ = jnp.linalg.qr(Z, mode="reduced")  # repro: noqa[RL006]: replicated sketch-width operand (n x s), local compute
        Y = A_loc @ Qz

    Q, _ = _dist_cholesky_qr2(Y, axis)            # (m_loc, s)
    B = jax.lax.psum(Q.T @ A_loc, axis)           # (s, n) replicated
    Ub, S, Vt = jnp.linalg.svd(B, full_matrices=False)  # repro: noqa[RL006]: sketch-width projection (s x n) finisher
    U_loc = Q @ Ub[:, :k]
    return U_loc, S[:k], Vt[:k, :]


def svd_sharded(
    A: jax.Array,
    k: int,
    mesh: jax.sharding.Mesh,
    axis: str = "data",
    cfg: RSVDConfig = RSVDConfig.fast(),
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of row-sharded A on `mesh` along `axis`.

    Returns (U, S, Vt); U is row-sharded like A, S and Vt are replicated.
    The facade spelling is `linalg.svd(ShardedOp(A, mesh, axis), k)`.
    """
    m, n = A.shape
    s = min(k + cfg.oversample, min(m, n))
    n_shards = mesh.shape[axis]

    body = functools.partial(
        _local_rsvd_body,
        k=k,
        s=s,
        q=cfg.power_iters,
        seed=seed,
        axis=axis,
        n_shards=n_shards,
    )
    f = _shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(axis, None), P(), P()),
        # pallas_call has no replication rule, so the per-shard kernel route
        # needs the VMA/replication check off; the collectives are unchanged.
        check_vma=(False if cfg.kernel_backend == "pallas" else None),
    )
    # Backend choice is trace-time state; the context must be live while the
    # shard_map body traces (the first jit call below).
    with qr_mod.kernel_backend(cfg.kernel_backend):
        return jax.jit(f)(A)


# Pre-facade name, kept importable for downstream code.
distributed_randomized_svd = svd_sharded


def collective_bytes_estimate(n: int, k: int, cfg: RSVDConfig, dtype_bytes: int = 4) -> int:
    """Analytic collective volume per device pair (documented in DESIGN.md)."""
    s = k + cfg.oversample
    q = cfg.power_iters
    per_cqr2 = 2 * s * s
    vol = q * (n * s + per_cqr2) + per_cqr2 + s * n
    return vol * dtype_bytes
