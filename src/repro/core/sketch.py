"""Sketching operators for randomized linear algebra.

The paper's second pillar (besides BLAS-3 reformulation) is a *fast parallel
random number generator* (cuRAND on GPU, reported up to 3x speedup of the
sketch step).  On TPU we go one step further: a *counter-based* stateless RNG
(murmur3-finalizer hash over the element index) that can be evaluated

  * in pure jnp (this module — the oracle / host path), and
  * inside a Pallas kernel tile loop (kernels/sketch_matmul.py), bit-exactly,

so the Gaussian sketch matrix never has to be materialized in HBM, and the
distributed implementation can regenerate identical sketch columns on every
device without any broadcast collective.
"""
from __future__ import annotations

import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Counter-based RNG primitive (murmur3 finalizer, 2 rounds with distinct keys)
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def _murmur_fmix(x: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer; x is uint32."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_u32(idx: jax.Array, seed: jax.Array | int) -> jax.Array:
    """Stateless counter hash: (index, seed) -> uint32.

    Two mixing rounds; the seed enters both rounds so that low-entropy seeds
    still decorrelate streams.
    """
    idx = idx.astype(jnp.uint32)
    seed = jnp.asarray(seed, jnp.uint32)
    h = _murmur_fmix(idx * _GOLDEN + seed)
    h = _murmur_fmix(h ^ (seed * _M1 + np.uint32(0x27220A95)))
    return h


def _u32_to_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 uniform in (0, 1]  (never 0, so log() is safe)."""
    # Take the top 24 bits -> [0, 2^24), then (x + 1) / 2^24 in (0, 1].
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(
        1.0 / 16777216.0
    ) + np.float32(1.0 / 16777216.0)


def uniform_from_index(idx: jax.Array, seed) -> jax.Array:
    return _u32_to_unit(hash_u32(idx, seed))


def normal_from_index(idx: jax.Array, seed) -> jax.Array:
    """Standard normal via Box-Muller on two decorrelated uniform streams.

    Element i uses streams (i, seed) and (i, seed ^ 0x5BF03635); both jnp and
    the Pallas kernel call this exact function body, so results are bit-equal.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    u1 = _u32_to_unit(hash_u32(idx, seed))
    u2 = _u32_to_unit(hash_u32(idx, seed ^ np.uint32(0x5BF03635)))
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
    theta = np.float32(2.0 * np.pi) * u2
    return r * jnp.cos(theta)


def rademacher_from_index(idx: jax.Array, seed) -> jax.Array:
    bits = hash_u32(idx, seed)
    return jnp.where(bits & np.uint32(1), np.float32(1.0), np.float32(-1.0))


# ---------------------------------------------------------------------------
# Structured sketches: SRHT and CountSketch
# ---------------------------------------------------------------------------
#
# The Gaussian sketch costs O(m n s) to apply.  The structured families cut
# that without giving up the subspace-embedding property the range finder
# needs:
#
#   SRHT         Omega = D H[:, J] * sqrt(n_pad / s): random signs D, the
#                normalized Hadamard transform H, and a without-replacement
#                column sample J.  Applied fast (sign flip + FWHT + column
#                subsample) it costs O(m n log n); every entry is +-1/sqrt(s).
#   CountSketch  one +-1 per row at a hashed bucket column: applying it is a
#                signed segment-sum over A's columns — O(m n), no GEMM at all.
#
# Both are derived from the SAME counter RNG as the Gaussian sketch (distinct
# salted streams), so they are deterministic in (n, s, seed) and traceable
# (the seed may be a traced scalar — sampling uses hash + argsort, never a
# host RNG).

#: salts decorrelating the structured streams from the Gaussian one
_SRHT_SIGN_SALT = np.uint32(0x7F4A7C15)
_SRHT_SAMPLE_SALT = np.uint32(0x94D049BB)
_CS_SIGN_SALT = np.uint32(0xBF58476D)
_CS_BUCKET_SALT = np.uint32(0x2545F491)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fwht(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh-Hadamard transform along the LAST axis.

    The axis length must be a power of two; the result equals ``x @ H`` for
    the symmetric normalized Hadamard matrix (entries +-1/sqrt(n)), computed
    in O(n log n) butterflies instead of an O(n^2) GEMM."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length must be a power of two, got {n}")
    lead = x.shape[:-1]
    h = 1
    while h < n:
        y = x.reshape(lead + (n // (2 * h), 2, h))
        a, b = y[..., 0, :], y[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2).reshape(lead + (n,))
        h *= 2
    return x * np.float32(1.0 / math.sqrt(n))


def srht_sample_cols(n_pad: int, s: int, seed) -> jax.Array:
    """The SRHT column sample J: `s` distinct Hadamard columns out of
    ``n_pad``, drawn by ranking counter-hash keys (deterministic in seed,
    traceable, without replacement)."""
    seed = jnp.asarray(seed, jnp.uint32)
    keys = hash_u32(jnp.arange(n_pad, dtype=jnp.uint32), seed ^ _SRHT_SAMPLE_SALT)
    return jnp.argsort(keys)[:s]


def srht_matrix(n: int, s: int, seed, dtype=jnp.float32) -> jax.Array:
    """Materialize the n x s SRHT Omega = D H[:, J] * sqrt(n_pad / s).

    ``H`` is the n_pad-point normalized Hadamard matrix (n_pad = next power
    of two >= n; the missing rows correspond to zero-padding A's columns, so
    truncation loses nothing).  Entry (i, j) is
    ``d_i * (-1)^popcount(i & J_j) / sqrt(s)`` — exactly the map the fast
    `apply_srht` path computes, materialized for operator (matrix-free)
    sources."""
    n_pad = _next_pow2(n)
    rows = jnp.arange(n, dtype=jnp.uint32)
    seed = jnp.asarray(seed, jnp.uint32)
    d = rademacher_from_index(rows, seed ^ _SRHT_SIGN_SALT)
    cols = srht_sample_cols(n_pad, s, seed).astype(jnp.uint32)
    parity = jax.lax.population_count(rows[:, None] & cols[None, :]) & 1
    signs = jnp.where(parity == 1, np.float32(-1.0), np.float32(1.0))
    return (d[:, None] * signs * np.float32(1.0 / math.sqrt(s))).astype(dtype)


def countsketch_buckets(n: int, s: int, seed) -> jax.Array:
    """Bucket assignment h: [n] -> [s], BALANCED by ranking hash keys (each
    bucket receives ceil(n/s) or floor(n/s) rows when n >= s).  A raw
    ``hash % s`` leaves a bucket empty with non-negligible probability at
    panel widths, which would hand the range finder an exactly-zero sketch
    column; the ranked assignment keeps every column populated."""
    seed = jnp.asarray(seed, jnp.uint32)
    keys = hash_u32(jnp.arange(n, dtype=jnp.uint32), seed ^ _CS_BUCKET_SALT)
    h = jnp.zeros((n,), jnp.int32)
    return h.at[jnp.argsort(keys)].set(jnp.arange(n, dtype=jnp.int32) % s)


def countsketch_matrix(n: int, s: int, seed, dtype=jnp.float32) -> jax.Array:
    """Materialize the n x s CountSketch Omega: row i holds a single +-1 at
    column h(i)."""
    seed = jnp.asarray(seed, jnp.uint32)
    signs = rademacher_from_index(jnp.arange(n, dtype=jnp.uint32),
                                  seed ^ _CS_SIGN_SALT)
    h = countsketch_buckets(n, s, seed)
    onehot = (h[:, None] == jnp.arange(s, dtype=jnp.int32)[None, :])
    return (signs[:, None] * onehot.astype(jnp.float32)).astype(dtype)


def apply_srht(A: jax.Array, s: int, seed) -> jax.Array:
    """Y = A @ Omega_srht via the fast path: sign-flip A's columns, FWHT
    (zero-padded to a power of two), subsample s columns — O(m n log n)
    instead of the O(m n s) GEMM.  Same linear map as
    ``A @ srht_matrix(n, s, seed)`` (different summation order)."""
    n = A.shape[-1]
    n_pad = _next_pow2(n)
    seed = jnp.asarray(seed, jnp.uint32)
    d = rademacher_from_index(jnp.arange(n, dtype=jnp.uint32),
                              seed ^ _SRHT_SIGN_SALT).astype(A.dtype)
    Ad = A * d[None, :]
    if n_pad > n:
        pad = [(0, 0)] * (A.ndim - 1) + [(0, n_pad - n)]
        Ad = jnp.pad(Ad, pad)
    H = fwht(Ad.astype(jnp.promote_types(A.dtype, jnp.float32)))
    cols = srht_sample_cols(n_pad, s, seed)
    return (H[..., cols] * np.float32(math.sqrt(n_pad) / math.sqrt(s))).astype(A.dtype)


def apply_countsketch(A: jax.Array, s: int, seed) -> jax.Array:
    """Y = A @ Omega_countsketch via a signed segment-sum over A's columns —
    O(m n), no GEMM.  Same linear map as ``A @ countsketch_matrix(...)``."""
    n = A.shape[-1]
    seed = jnp.asarray(seed, jnp.uint32)
    signs = rademacher_from_index(jnp.arange(n, dtype=jnp.uint32),
                                  seed ^ _CS_SIGN_SALT).astype(A.dtype)
    h = countsketch_buckets(n, s, seed)
    signed = jnp.moveaxis(A * signs[None, :], -1, 0)       # (n, ...)
    out = jax.ops.segment_sum(signed, h, num_segments=s)   # (s, ...)
    return jnp.moveaxis(out, 0, -1)


def apply_structured(A: jax.Array, s: int, seed, kind: str) -> jax.Array:
    """Fast application Y = A @ Omega for a structured sketch kind."""
    if kind == "srht":
        return apply_srht(A, s, seed)
    if kind == "countsketch":
        return apply_countsketch(A, s, seed)
    raise ValueError(f"not a structured sketch kind: {kind}")


# ---------------------------------------------------------------------------
# Materialized sketch matrices (host/oracle path)
# ---------------------------------------------------------------------------

SketchKind = Literal["gaussian", "rademacher", "srht", "countsketch"]

#: kinds applied by transform, not GEMM; the fused RNG+GEMM Pallas kernels
#: only generate the elementwise-i.i.d. kinds, so planners must not claim a
#: fused sketch for these
STRUCTURED_KINDS = ("srht", "countsketch")

#: every kind `sketch_matrix` accepts (config validation pins against this)
SKETCH_KINDS = ("gaussian", "rademacher") + STRUCTURED_KINDS


def sketch_matrix(
    n: int,
    s: int,
    seed: int,
    kind: SketchKind = "gaussian",
    dtype=jnp.float32,
    row_offset: int = 0,
) -> jax.Array:
    """Materialize the n x s sketch Omega.

    ``row_offset`` lets a row-sharded device generate *its* rows of the same
    global sketch (element (i, j) depends only on the global flat index
    i * s + j and the seed).  The structured kinds (srht / countsketch) are
    NOT row-decomposable — their sample/bucket draws need the global row
    count — so they reject a nonzero offset; the planner falls back to
    gaussian on the paths that stream panel-offset sketches."""
    if kind in STRUCTURED_KINDS:
        if row_offset:
            raise ValueError(
                f"sketch kind {kind!r} is not row-decomposable (its column "
                "sample / bucket assignment is global) — row_offset must be 0"
            )
        if kind == "srht":
            return srht_matrix(n, s, seed, dtype=dtype)
        return countsketch_matrix(n, s, seed, dtype=dtype)
    rows = jnp.arange(n, dtype=jnp.uint32)[:, None] + np.uint32(row_offset)
    cols = jnp.arange(s, dtype=jnp.uint32)[None, :]
    idx = rows * np.uint32(s) + cols
    if kind == "gaussian":
        vals = normal_from_index(idx, seed)
    elif kind == "rademacher":
        vals = rademacher_from_index(idx, seed)
    else:
        raise ValueError(f"unknown sketch kind: {kind}")
    return vals.astype(dtype)


@functools.partial(jax.jit, static_argnames=("s", "kind"))
def apply_sketch(A: jax.Array, s: int, seed, kind: SketchKind = "gaussian"):
    """C = A @ Omega with Omega materialized (reference path).

    The fused-no-materialization path lives in kernels/sketch_matmul.py.
    """
    n = A.shape[-1]
    omega = sketch_matrix(n, s, seed, kind, dtype=A.dtype)
    return A @ omega
