"""Sketching operators for randomized linear algebra.

The paper's second pillar (besides BLAS-3 reformulation) is a *fast parallel
random number generator* (cuRAND on GPU, reported up to 3x speedup of the
sketch step).  On TPU we go one step further: a *counter-based* stateless RNG
(murmur3-finalizer hash over the element index) that can be evaluated

  * in pure jnp (this module — the oracle / host path), and
  * inside a Pallas kernel tile loop (kernels/sketch_matmul.py), bit-exactly,

so the Gaussian sketch matrix never has to be materialized in HBM, and the
distributed implementation can regenerate identical sketch columns on every
device without any broadcast collective.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Counter-based RNG primitive (murmur3 finalizer, 2 rounds with distinct keys)
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def _murmur_fmix(x: jax.Array) -> jax.Array:
    """murmur3 32-bit finalizer; x is uint32."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_u32(idx: jax.Array, seed: jax.Array | int) -> jax.Array:
    """Stateless counter hash: (index, seed) -> uint32.

    Two mixing rounds; the seed enters both rounds so that low-entropy seeds
    still decorrelate streams.
    """
    idx = idx.astype(jnp.uint32)
    seed = jnp.asarray(seed, jnp.uint32)
    h = _murmur_fmix(idx * _GOLDEN + seed)
    h = _murmur_fmix(h ^ (seed * _M1 + np.uint32(0x27220A95)))
    return h


def _u32_to_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 uniform in (0, 1]  (never 0, so log() is safe)."""
    # Take the top 24 bits -> [0, 2^24), then (x + 1) / 2^24 in (0, 1].
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(
        1.0 / 16777216.0
    ) + np.float32(1.0 / 16777216.0)


def uniform_from_index(idx: jax.Array, seed) -> jax.Array:
    return _u32_to_unit(hash_u32(idx, seed))


def normal_from_index(idx: jax.Array, seed) -> jax.Array:
    """Standard normal via Box-Muller on two decorrelated uniform streams.

    Element i uses streams (i, seed) and (i, seed ^ 0x5BF03635); both jnp and
    the Pallas kernel call this exact function body, so results are bit-equal.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    u1 = _u32_to_unit(hash_u32(idx, seed))
    u2 = _u32_to_unit(hash_u32(idx, seed ^ np.uint32(0x5BF03635)))
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
    theta = np.float32(2.0 * np.pi) * u2
    return r * jnp.cos(theta)


def rademacher_from_index(idx: jax.Array, seed) -> jax.Array:
    bits = hash_u32(idx, seed)
    return jnp.where(bits & np.uint32(1), np.float32(1.0), np.float32(-1.0))


# ---------------------------------------------------------------------------
# Materialized sketch matrices (host/oracle path)
# ---------------------------------------------------------------------------

SketchKind = Literal["gaussian", "rademacher"]


def sketch_matrix(
    n: int,
    s: int,
    seed: int,
    kind: SketchKind = "gaussian",
    dtype=jnp.float32,
    row_offset: int = 0,
) -> jax.Array:
    """Materialize the n x s sketch Omega.

    ``row_offset`` lets a row-sharded device generate *its* rows of the same
    global sketch (element (i, j) depends only on the global flat index
    i * s + j and the seed).
    """
    rows = jnp.arange(n, dtype=jnp.uint32)[:, None] + np.uint32(row_offset)
    cols = jnp.arange(s, dtype=jnp.uint32)[None, :]
    idx = rows * np.uint32(s) + cols
    if kind == "gaussian":
        vals = normal_from_index(idx, seed)
    elif kind == "rademacher":
        vals = rademacher_from_index(idx, seed)
    else:
        raise ValueError(f"unknown sketch kind: {kind}")
    return vals.astype(dtype)


@functools.partial(jax.jit, static_argnames=("s", "kind"))
def apply_sketch(A: jax.Array, s: int, seed, kind: SketchKind = "gaussian"):
    """C = A @ Omega with Omega materialized (reference path).

    The fused-no-materialization path lives in kernels/sketch_matmul.py.
    """
    n = A.shape[-1]
    omega = sketch_matrix(n, s, seed, kind, dtype=A.dtype)
    return A @ omega
