"""Randomized k-SVD — the paper's Algorithm 1, faithful and optimized paths.

Faithful path (defaults mirror the paper / cuSOLVER ``gesvdr`` semantics):

  1. draw Gaussian sketch Omega in R^{n x s},   s = k + oversampling
  2. Y = (A A^T)^q A Omega                      (chain of GEMMs)
  3. Q = QR(Y).Q                                (orthonormal range basis)
  4. B = Q^T A                                  (GEMM)
  5. B = U S V^T                                (small SVD, s x n)
  6. U~ = Q U                                   (GEMM)
  -> A_k ~= U~[:, :k] S[:k] V[:k, :]^T

Optimized (beyond-paper, TPU-native) switches — see DESIGN.md §2:
  * qr_method='cqr2'        CholeskyQR2 instead of Householder QR (BLAS-3)
  * small_svd='gram_jacobi' Gram + parallel-order Jacobi instead of LAPACK
  * power_scheme='stabilized'  re-orthonormalized subspace iteration
  * fused sketch            kernels/sketch_matmul.py generates Omega in VMEM

`randomized_eigvals` implements the paper's "only the k largest eigenvalues"
mode (steps 1-5, Sigma only), used in the PCA / spectra experiments.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.eigh_jacobi import svd_via_gram

SmallSVD = Literal["lapack", "gram", "gram_jacobi"]


@dataclass(frozen=True)
class RSVDConfig:
    """Algorithm configuration. Defaults = paper-faithful Algorithm 1.

    Note on step 2: the paper says "Compute q steps of *QR iteration*
    Y = (A A^H)^q A Omega" — i.e. power iteration with QR re-orthonormalization
    between applications (what cuSOLVER gesvdr implements), NOT a raw GEMM
    chain.  The raw chain is available as power_scheme='plain' for ablation;
    it demonstrably loses the tail singular values to round-off (the sigma^(2q+1)
    underflow documented in EXPERIMENTS.md)."""

    oversample: int = 10          # s = k + oversample   (paper: s = O(k/eps))
    power_iters: int = 2          # q in Algorithm 1 step 2
    power_scheme: str = "stabilized"  # paper: "q steps of QR iteration"
    qr_method: qr_mod.QRMethod = "householder"
    small_svd: SmallSVD = "lapack"
    sketch_kind: sketch_mod.SketchKind = "gaussian"
    fused_sketch: bool = False    # Pallas fused RNG+GEMM (TPU fast path)

    @staticmethod
    def faithful() -> "RSVDConfig":
        return RSVDConfig()

    @staticmethod
    def fast() -> "RSVDConfig":
        """The TPU-optimized configuration (beyond-paper)."""
        return RSVDConfig(
            power_scheme="stabilized",
            qr_method="cqr2",
            small_svd="gram_jacobi",
            fused_sketch=True,
        )


def _small_svd(B: jax.Array, method: SmallSVD):
    if method == "lapack":
        return jnp.linalg.svd(B, full_matrices=False)
    if method == "gram":
        return svd_via_gram(B, use_jacobi=False)
    if method == "gram_jacobi":
        return svd_via_gram(B, use_jacobi=True)
    raise ValueError(f"unknown small_svd: {method}")


def _sketch(A: jax.Array, s: int, seed: int, cfg: RSVDConfig) -> jax.Array:
    if cfg.fused_sketch:
        # Fused RNG+GEMM Pallas kernel — Omega never materialized in HBM.
        from repro.kernels.ops import sketch_matmul

        return sketch_matmul(A, s, seed, kind=cfg.sketch_kind)
    omega = sketch_mod.sketch_matrix(A.shape[1], s, seed, cfg.sketch_kind, dtype=A.dtype)
    return A @ omega


@functools.partial(
    jax.jit, static_argnames=("k", "cfg", "seed")
)
def randomized_svd(
    A: jax.Array,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD of A (m x n). Returns (U, S, Vt) with
    U: m x k, S: k, Vt: k x n.

    Orientation: the range finder works on the *taller* side; if m < n we
    factor A^T and swap factors at the end (same flop count, better sketch).
    """
    m, n = A.shape
    if m < n:
        V, S, Ut = randomized_svd(A.T, k, cfg, seed)
        return Ut.T, S, V.T

    s = min(k + cfg.oversample, min(m, n))
    Y = _sketch(A, s, seed, cfg)                       # step 1-2a: A @ Omega
    if cfg.power_iters > 0:
        if cfg.power_scheme == "plain":
            for _ in range(cfg.power_iters):           # step 2: (A A^T)^q
                Y = A @ (A.T @ Y)
        else:
            Y = _stabilized_power(A, Y, cfg)
    Q = qr_mod.orthonormalize(Y, cfg.qr_method)        # step 3
    B = Q.T @ A                                        # step 4
    U_b, S, Vt = _small_svd(B, cfg.small_svd)          # step 5
    U = Q @ U_b                                        # step 6
    return U[:, :k], S[:k], Vt[:k, :]


def _stabilized_power(A: jax.Array, Y: jax.Array, cfg: RSVDConfig) -> jax.Array:
    for _ in range(cfg.power_iters):
        Q = qr_mod.orthonormalize(Y, cfg.qr_method)
        Z = A.T @ Q
        Qz = qr_mod.orthonormalize(Z, cfg.qr_method)
        Y = A @ Qz
    return Y


@functools.partial(jax.jit, static_argnames=("k", "cfg", "seed"))
def randomized_eigvals(
    A: jax.Array, k: int, cfg: RSVDConfig = RSVDConfig(), seed: int = 0
) -> jax.Array:
    """k largest singular values only (paper's eigenvalue-benchmark mode:
    steps 1-5 of Algorithm 1, discarding U and V)."""
    m, n = A.shape
    if m < n:
        return randomized_eigvals(A.T, k, cfg, seed)
    s = min(k + cfg.oversample, min(m, n))
    Y = _sketch(A, s, seed, cfg)
    if cfg.power_iters > 0:
        if cfg.power_scheme == "plain":
            for _ in range(cfg.power_iters):
                Y = A @ (A.T @ Y)
        else:
            Y = _stabilized_power(A, Y, cfg)
    Q = qr_mod.orthonormalize(Y, cfg.qr_method)
    B = Q.T @ A
    if cfg.small_svd == "lapack":
        S = jnp.linalg.svd(B, compute_uv=False)
    else:
        G = B @ B.T
        if cfg.small_svd == "gram_jacobi":
            from repro.core.eigh_jacobi import jacobi_eigh

            w, _ = jacobi_eigh(G)
        else:
            w = jnp.linalg.eigvalsh(G)[::-1]
        S = jnp.sqrt(jnp.maximum(w, 0.0))
    return S[:k]


def low_rank_error(A: jax.Array, U: jax.Array, S: jax.Array, Vt: jax.Array) -> jax.Array:
    """Relative Frobenius error ||A - U S Vt||_F / ||A||_F (paper's metric)."""
    R = A - (U * S[None, :]) @ Vt
    return jnp.sqrt(jnp.sum(R * R) / jnp.sum(A * A))


def truncation_error(S_full: jax.Array, k: int) -> jax.Array:
    """||A - A_k||_F / ||A||_F from the exact spectrum (the 1+eps reference)."""
    tail = jnp.sum(S_full[k:] ** 2)
    return jnp.sqrt(tail / jnp.sum(S_full**2))
