"""Randomized k-SVD — the paper's Algorithm 1, faithful and optimized paths.

Faithful path (defaults mirror the paper / cuSOLVER ``gesvdr`` semantics):

  1. draw Gaussian sketch Omega in R^{n x s},   s = k + oversampling
  2. Y = (A A^T)^q A Omega                      (chain of GEMMs)
  3. Q = QR(Y).Q                                (orthonormal range basis)
  4. B = Q^T A                                  (GEMM)
  5. B = U S V^T                                (small SVD, s x n)
  6. U~ = Q U                                   (GEMM)
  -> A_k ~= U~[:, :k] S[:k] V[:k, :]^T

Optimized (beyond-paper, TPU-native) switches — see DESIGN.md §2:
  * qr_method='cqr2'        CholeskyQR2 instead of Householder QR (BLAS-3)
  * small_svd='gram_jacobi' Gram + parallel-order Jacobi instead of LAPACK
  * power_scheme='stabilized'  re-orthonormalized subspace iteration
  * fused sketch            kernels/sketch_matmul.py generates Omega in VMEM

`randomized_eigvals` implements the paper's "only the k largest eigenvalues"
mode (steps 1-5, Sigma only), used in the PCA / spectra experiments.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod
from repro.core import sketch as sketch_mod
from repro.core.eigh_jacobi import svd_via_gram

SmallSVD = Literal["lapack", "gram", "gram_jacobi"]


@dataclass(frozen=True)
class RSVDConfig:
    """Algorithm configuration. Defaults = paper-faithful Algorithm 1.

    Note on step 2: the paper says "Compute q steps of *QR iteration*
    Y = (A A^H)^q A Omega" — i.e. power iteration with QR re-orthonormalization
    between applications (what cuSOLVER gesvdr implements), NOT a raw GEMM
    chain.  The raw chain is available as power_scheme='plain' for ablation;
    it demonstrably loses the tail singular values to round-off (the sigma^(2q+1)
    underflow documented in EXPERIMENTS.md).

    Execution-shape switches (DESIGN.md §"Blocked & batched execution"):
      * block_rows  — stream the tall dimension in row panels of this height
                      through the blocked range finder (core/blocked.py): A
                      itself (host numpy accepted) is device-resident one
                      block_rows x n panel at a time, and only sketch-width
                      (m x s) state stays on device — an n/s reduction vs.
                      holding A, see core/blocked.py for the exact contract.
      * block_cols  — optional inner column-panel width for the streamed
                      sketch accumulation Y += A_panel @ Omega_panel (panel-
                      offset counter RNG; Omega never materialized whole).
      * batched     — declare the workload a fleet of small SVDs: the input
                      MUST be 3-D [B, m, n] (ValueError otherwise) and runs
                      under one vmap (per-channel PCA, per-layer GaLore /
                      PowerSGD compression).  3-D inputs take the batched
                      path automatically even without the flag; setting it
                      makes an accidental 2-D input fail loudly instead of
                      silently running one big dense SVD."""

    oversample: int = 10          # s = k + oversample   (paper: s = O(k/eps))
    power_iters: int = 2          # q in Algorithm 1 step 2
    power_scheme: str = "stabilized"  # paper: "q steps of QR iteration"
    qr_method: qr_mod.QRMethod = "householder"
    small_svd: SmallSVD = "lapack"
    sketch_kind: sketch_mod.SketchKind = "gaussian"
    fused_sketch: bool = False    # Pallas fused RNG+GEMM (TPU fast path)
    fused_power: bool = False     # one-pass Aᵀ(A·X) power step (EXPERIMENTS.md)
    kernel_backend: str = "jnp"   # "pallas" routes CQR Gram+TRSM through kernels
    block_rows: int | None = None  # panel-stream the tall dimension
    block_cols: int | None = None  # panel-stream the sketch reduction
    batched: bool = False          # vmap over a leading batch dimension
    pipeline_depth: int | None = None  # streamed-panel prefetch depth (None =
    #                               auto: double-buffered for host sources,
    #                               1 — fully synchronous — otherwise; the
    #                               planner stamps the effective value on
    #                               every streamed/adaptive ExecutionPlan)

    @staticmethod
    def faithful() -> "RSVDConfig":
        return RSVDConfig()

    @staticmethod
    def fast() -> "RSVDConfig":
        """The TPU-optimized configuration (beyond-paper): CholeskyQR2 with
        Pallas-backed Gram + TRSM, the in-VMEM RNG sketch fused with its
        first Gram, and the one-pass-per-iteration fused power step."""
        return RSVDConfig(
            power_scheme="stabilized",
            qr_method="cqr2",
            small_svd="gram_jacobi",
            fused_sketch=True,
            fused_power=True,
            kernel_backend="pallas",
        )

    @staticmethod
    def streaming(block_rows: int = 4096) -> "RSVDConfig":
        """Out-of-core configuration: CholeskyQR2 accumulation over row
        panels (Householder QR of a panel-split Y is not expressible as a
        panel-local op; the Gram trick is — see core/blocked.py), with the
        panel prefetch DOUBLE-BUFFERED — panel i+1's host->device copy
        overlaps panel i's compute (linalg/pipeline.py; the planner still
        clamps the depth to what the HBM budget and panel count allow)."""
        return RSVDConfig(
            power_scheme="stabilized",
            qr_method="cqr2",
            small_svd="lapack",
            block_rows=block_rows,
            pipeline_depth=2,
        )


def _small_svd(B: jax.Array, method: SmallSVD):
    if method == "lapack":
        return jnp.linalg.svd(B, full_matrices=False)  # repro: noqa[RL006]: B is sketch-width (s x n), Algorithm 1 step 5
    if method == "gram":
        return svd_via_gram(B, use_jacobi=False)
    if method == "gram_jacobi":
        return svd_via_gram(B, use_jacobi=True)
    raise ValueError(f"unknown small_svd: {method}")


def _sketch(A: jax.Array, s: int, seed, cfg: RSVDConfig) -> jax.Array:
    if cfg.sketch_kind in sketch_mod.STRUCTURED_KINDS:
        # SRHT / CountSketch apply by transform (sign flip + FWHT + column
        # subsample / signed segment-sum) — O(mn log n) / O(mn) instead of
        # the O(mns) GEMM, and nothing to fuse: there is no RNG tile.
        return sketch_mod.apply_structured(A, s, seed, cfg.sketch_kind)
    if cfg.fused_sketch and A.dtype != jnp.float64:
        # Fused RNG+GEMM Pallas kernel — Omega never materialized in HBM.
        # The seed is a traced SMEM scalar: seed sweeps / GaLore refreshes /
        # the batched vmap path all reuse one compiled program.
        from repro.kernels.ops import sketch_matmul

        return sketch_matmul(A, s, seed, kind=cfg.sketch_kind)
    omega = sketch_mod.sketch_matrix(A.shape[1], s, seed, cfg.sketch_kind, dtype=A.dtype)
    return A @ omega


def _use_fused_power(
    A: jax.Array, cfg: RSVDConfig, s: int, vmem_budget: int | None = None
) -> bool:
    """The one-pass power path needs fp32-accumulating kernels (not the f64
    faithful setting), a CholeskyQR-family range finder (the Y-side
    re-orthonormalization is expressed through Gram + TRSM), and a working
    set — the A strip plus the n x s accumulators — that fits real-TPU
    VMEM (interpret mode has no limit, but the config path must not select
    a kernel that cannot compile on hardware; beyond the budget the
    blocked/streaming and distributed paths are the intended scale-out).
    The execution planner (repro/linalg/planner.py) evaluates the same gate
    at plan time, parameterized by its Budget — `vmem_budget` keeps the two
    in lockstep."""
    from repro.kernels.ops import _block, _select_blocks
    from repro.kernels.power_step import VMEM_BUDGET_BYTES, fused_power_vmem_bytes

    if vmem_budget is None:
        vmem_budget = VMEM_BUDGET_BYTES
    m, n = A.shape
    # Model the kernel's ACTUAL footprint: the bm the wrapper will select
    # (autotune cache included) and the padded dims it will allocate.
    bm = _select_blocks("power_step", (m, n, s), A.dtype)[0]
    n_pad = n + (-n) % _block(n)
    s_pad = s + (-s) % _block(s)
    # cqr3 (shifted, for kappa up to ~1/eps) and single-pass cqr are
    # deliberately excluded: the fused body hardwires CQR2-style
    # re-orthonormalization, and a caller asking for a different variant
    # should get exactly that, unfused.
    return (
        cfg.fused_power
        and A.dtype != jnp.float64
        and (cfg.power_scheme == "plain" or cfg.qr_method == "cqr2")
        and fused_power_vmem_bytes(n_pad, s_pad, bm=bm) <= vmem_budget
    )


def _cqr2_factor(Y: jax.Array, G1: jax.Array | None):
    """CholeskyQR2 of Y reusing an already-accumulated first Gram.

    Returns (Q1, R2, R_tot): Q1 is the first-pass basis, Q = Q1 R2⁻¹ is
    materialized lazily by callers that actually need it, and R_tot = R2 R1
    satisfies Y ≈ Q R_tot.  G1 comes for free from the fused kernels'
    Gram epilogue (sketch_gram / power_step), killing CQR's first pass
    over Y; when None it is computed through the active kernel backend.
    """
    if G1 is None:
        G1 = qr_mod.gram(Y)
    R1 = qr_mod.cholesky_r_from_gram(G1.astype(Y.dtype))
    Q1 = qr_mod.tri_solve_right(Y, R1)
    G2 = qr_mod.gram(Q1).astype(Y.dtype)
    qr_mod.record_ortho_gram(G2)  # first-pass health probe, free byproduct
    R2 = qr_mod.cholesky_r_from_gram(G2)
    return Q1, R2, R2 @ R1


def _rsvd_body_fused(
    A: jax.Array, k: int, cfg: RSVDConfig, seed
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 1 with the one-pass range finder (kernels/power_step.py).

    Each stabilized power iteration does exactly ONE read of A: the fused
    kernel returns Y = A·Qz, W = AᵀY, and G = YᵀY together, and CholeskyQR
    turns W into the next projection without touching A again —
    Q = Y R⁻¹  ⇒  AᵀQ = W R⁻¹  (a sketch-width TRSM), and the final
    projection B = QᵀA = (W R⁻¹)ᵀ falls out of the last W.  The sketch pass
    itself emits W (sketch_power), so reads of A total 1 + q, vs 2q + 2
    unfused (two per iteration plus the sketch and the final projection).
    """
    from repro.kernels import ops

    m, n = A.shape
    s = min(k + cfg.oversample, min(m, n))

    if cfg.power_scheme == "plain":
        # Ablation path: Y = A (AᵀA)^q Ω as a chain of fused steps (each one
        # read of A), materialized Omega (the plain scheme is the paper's
        # raw-GEMM ablation, not the production path).
        omega = sketch_mod.sketch_matrix(n, s, seed, cfg.sketch_kind, dtype=A.dtype)
        X = omega
        for _ in range(cfg.power_iters):
            _, X = ops.power_step(A, X)
        Y = A @ X
        Q = qr_mod.orthonormalize(Y, cfg.qr_method)
        B = Q.T @ A
        U_b, S, Vt = _small_svd(B, cfg.small_svd)
        U = Q @ U_b
        return U[:, :k], S[:k], Vt[:k, :]

    # Stabilized scheme, CholeskyQR-family orthonormalization on the Y side.
    # The sketch pass already emits W = AᵀY (sketch_power strip layout), so
    # even the FIRST power iteration closes through a sketch-width TRSM
    # instead of re-reading A: reads of A = 1 + q exactly.
    if cfg.fused_sketch and cfg.sketch_kind not in sketch_mod.STRUCTURED_KINDS:
        Y, W, G1 = ops.sketch_power(A, s, seed, kind=cfg.sketch_kind)
    else:
        # structured kinds have no in-kernel RNG — materialize Omega and
        # still take the one-pass strip kernel for Y / W / G
        omega = sketch_mod.sketch_matrix(n, s, seed, cfg.sketch_kind, dtype=A.dtype)
        Y, W, G1 = ops.power_step(A, omega, with_gram=True)
    for _ in range(cfg.power_iters):
        Q1, R2, R_tot = _cqr2_factor(Y, G1)
        Z = qr_mod.tri_solve_right(W, R_tot)           # AᵀQ without reading A
        Qz = qr_mod.orthonormalize(Z, cfg.qr_method)   # n x s, sketch-width
        Y, W, G1 = ops.power_step(A, Qz, with_gram=True)
    Q1, R2, R_tot = _cqr2_factor(Y, G1)
    Q = qr_mod.tri_solve_right(Q1, R2)                 # step 3 basis
    B = qr_mod.tri_solve_right(W, R_tot).T             # step 4 without reading A
    U_b, S, Vt = _small_svd(B, cfg.small_svd)          # step 5
    U = Q @ U_b                                        # step 6
    return U[:, :k], S[:k], Vt[:k, :]


def _rsvd_body(
    A: jax.Array, k: int, cfg: RSVDConfig, seed
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 1 steps 1-6 with the range finder on the given orientation.

    ``seed`` is always traced (the counter RNG takes it as data, in jnp and
    in the Pallas kernels alike).
    """
    m, n = A.shape
    s = min(k + cfg.oversample, min(m, n))
    if _use_fused_power(A, cfg, s):
        return _rsvd_body_fused(A, k, cfg, seed)
    Y = _sketch(A, s, seed, cfg)                       # step 1-2a: A @ Omega
    if cfg.power_iters > 0:
        if cfg.power_scheme == "plain":
            for _ in range(cfg.power_iters):           # step 2: (A A^T)^q
                Y = A @ (A.T @ Y)
        else:
            Y = _stabilized_power(A, Y, cfg)
    Q = qr_mod.orthonormalize(Y, cfg.qr_method)        # step 3
    B = Q.T @ A                                        # step 4
    U_b, S, Vt = _small_svd(B, cfg.small_svd)          # step 5
    U = Q @ U_b                                        # step 6
    return U[:, :k], S[:k], Vt[:k, :]


@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def _randomized_svd_dense(
    A: jax.Array, seed: jax.Array, k: int, cfg: RSVDConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-device in-memory path.  The seed is TRACED: changing it
    (GaLore refreshes, per-slice loops, seed sweeps) reuses the compiled
    program — the counter RNG takes the seed as data, including inside the
    fused Pallas sketch (an SMEM scalar operand)."""
    with qr_mod.kernel_backend(cfg.kernel_backend):
        m, n = A.shape
        if m < n:
            V, S, Ut = _rsvd_body(A.T, k, cfg, seed)
            return Ut.T, S, V.T
        return _rsvd_body(A, k, cfg, seed)


@functools.partial(jax.jit, static_argnames=("k", "cfg", "fault_key"))
def _randomized_svd_dense_probed(
    A: jax.Array, seed: jax.Array, k: int, cfg: RSVDConfig, fault_key=()
) -> Tuple[Tuple[jax.Array, jax.Array, jax.Array], dict]:
    """Guarded compiled twin of `_randomized_svd_dense`: traces the SAME
    body under an open guard probe sink and returns (factors, probes) —
    the probes (breakdown / ortho / cond scalars, see linalg/guard.py) are
    extra jit outputs the driver folds back into its own sink.

    `fault_key` (= linalg.faults.fingerprint(), static) keys the compile
    cache on the active fault set so a fault-injected trace can never
    shadow a clean entry.  The unprobed twin keeps its own cache untouched,
    so guard `off` stays bit-identical and re-trace-free."""
    del fault_key
    from repro.linalg import guard as guard_mod

    with qr_mod.kernel_backend(cfg.kernel_backend), guard_mod.collecting() as sink:
        m, n = A.shape
        if m < n:
            V, S, Ut = _rsvd_body(A.T, k, cfg, seed)
            out = (Ut.T, S, V.T)
        else:
            out = _rsvd_body(A, k, cfg, seed)
    return out, sink.traced()


def _as_plannable(A):
    """Wrap a raw array the way the historical dispatch understood it:
    3-D -> StackedOp; 2-D -> DenseOp even for host numpy (the old entry
    point moved host arrays to device wholesale unless cfg.block_rows
    streamed them, and the planner's `overrides` dispatch keys on
    cfg.block_rows/batched, not on residency)."""
    from repro.linalg.operators import DenseOp, StackedOp

    if getattr(A, "ndim", 2) == 3:
        return StackedOp(A)
    return DenseOp(A)


def randomized_svd(
    A: jax.Array,
    k: int,
    cfg: RSVDConfig = RSVDConfig(),
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """DEPRECATED shim over `repro.linalg.svd` — kept so pre-facade callers
    keep working unchanged.  The planner reproduces this entry point's
    historical dispatch exactly (3-D -> batched, cfg.block_rows ->
    streamed, else dense), so fixed-seed results are bit-identical.
    """
    warnings.warn(
        "randomized_svd is deprecated; use repro.linalg.svd (operator sources"
        " + execution plans — see DESIGN.md §'API: operators and plans')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import linalg

    return linalg.svd(_as_plannable(A), k, overrides=cfg, seed=seed)


def _stabilized_power(A: jax.Array, Y: jax.Array, cfg: RSVDConfig) -> jax.Array:
    for _ in range(cfg.power_iters):
        Q = qr_mod.orthonormalize(Y, cfg.qr_method)
        Z = A.T @ Q
        Qz = qr_mod.orthonormalize(Z, cfg.qr_method)
        Y = A @ Qz
    return Y


def randomized_eigvals(
    A: jax.Array, k: int, cfg: RSVDConfig = RSVDConfig(), seed: int = 0
) -> jax.Array:
    """DEPRECATED shim over `repro.linalg.eigvals` (paper's eigenvalue-
    benchmark mode: steps 1-5 of Algorithm 1, discarding U and V)."""
    warnings.warn(
        "randomized_eigvals is deprecated; use repro.linalg.eigvals (operator"
        " sources + execution plans — see DESIGN.md §'API: operators and plans')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import linalg

    return linalg.eigvals(_as_plannable(A), k, overrides=cfg, seed=seed)


@functools.partial(jax.jit, static_argnames=("k", "cfg"))
def _randomized_eigvals_dense(
    A: jax.Array, seed, k: int, cfg: RSVDConfig = RSVDConfig()
) -> jax.Array:
    m, n = A.shape
    if m < n:
        return _randomized_eigvals_dense(A.T, seed, k, cfg)
    with qr_mod.kernel_backend(cfg.kernel_backend):
        s = min(k + cfg.oversample, min(m, n))
        Y = _sketch(A, s, seed, cfg)
        if cfg.power_iters > 0:
            if cfg.power_scheme == "plain":
                for _ in range(cfg.power_iters):
                    Y = A @ (A.T @ Y)
            else:
                Y = _stabilized_power(A, Y, cfg)
        Q = qr_mod.orthonormalize(Y, cfg.qr_method)
        B = Q.T @ A
        if cfg.small_svd == "lapack":
            S = jnp.linalg.svd(B, compute_uv=False)  # repro: noqa[RL006]: B is sketch-width (s x n), sigma-only finisher
        else:
            G = B @ B.T
            if cfg.small_svd == "gram_jacobi":
                from repro.core.eigh_jacobi import jacobi_eigh

                w, _ = jacobi_eigh(G)
            else:
                w = jnp.linalg.eigvalsh(G)[::-1]
            S = jnp.sqrt(jnp.maximum(w, 0.0))
    return S[:k]


def low_rank_error(A: jax.Array, U: jax.Array, S: jax.Array, Vt: jax.Array) -> jax.Array:
    """Relative Frobenius error ||A - U S Vt||_F / ||A||_F (paper's metric).

    Materializes the full m x n reconstruction — fine for in-core arrays.
    Streamed/host/composed sources should use `repro.linalg.residual`, the
    panel-wise version that never forms an m x n temporary."""
    R = A - (U * S[None, :]) @ Vt
    return jnp.sqrt(jnp.sum(R * R) / jnp.sum(A * A))


def truncation_error(S_full: jax.Array, k: int) -> jax.Array:
    """||A - A_k||_F / ||A||_F from the exact spectrum (the 1+eps reference)."""
    tail = jnp.sum(S_full[k:] ** 2)
    return jnp.sqrt(tail / jnp.sum(S_full**2))
