"""Power-method and randomized range-finder building blocks.

Implements step 2 of the paper's Algorithm 1, Y = (A A^T)^q A Omega, in two
flavors:

  * ``plain``       — the literal chain of GEMMs from the paper's pseudo-code.
                      Fast but loses small-singular-value information to
                      round-off when the spectrum decays slowly.
  * ``stabilized``  — orthonormalize between applications (Halko et al.,
                      Alg. 4.4).  Each stabilization is a CholeskyQR (still
                      BLAS-3), trading ~2x flops on the skinny panel for
                      numerical robustness.  This is the production default.

Also provides the classical power method (single dominant eigenpair) used as
a baseline in benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qr as qr_mod


def randomized_range_finder(
    A: jax.Array,
    omega: jax.Array,
    q: int = 2,
    scheme: str = "stabilized",
    qr_method: qr_mod.QRMethod = "cqr2",
) -> jax.Array:
    """Y = (A A^T)^q A Omega, optionally re-orthonormalized between steps.

    Returns Y (m x s); the caller orthonormalizes the final result.
    """
    Y = A @ omega
    if scheme == "plain":
        for _ in range(q):
            Y = A @ (A.T @ Y)
        return Y
    if scheme == "stabilized":
        for _ in range(q):
            Q = qr_mod.orthonormalize(Y, qr_method)
            Z = A.T @ Q
            Qz = qr_mod.orthonormalize(Z, qr_method)
            Y = A @ Qz
        return Y
    raise ValueError(f"unknown power scheme: {scheme}")


def power_method(
    A: jax.Array, iters: int = 100, seed: int = 0
) -> tuple[jax.Array, jax.Array]:
    """Dominant eigenpair of symmetric A by Von Mises iteration (baseline)."""
    from repro.core.sketch import sketch_matrix

    v = sketch_matrix(A.shape[0], 1, seed)[:, 0]
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        w = A @ v
        return w / jnp.linalg.norm(w)

    v = jax.lax.fori_loop(0, iters, body, v)
    lam = v @ (A @ v)
    return lam, v


def block_power_method(
    A: jax.Array,
    k: int,
    iters: int = 20,
    seed: int = 0,
    qr_method: qr_mod.QRMethod = "cqr2",
) -> tuple[jax.Array, jax.Array]:
    """Subspace (block power) iteration for the k dominant eigenpairs of
    symmetric A — the classical deterministic baseline the paper compares
    randomized methods against."""
    from repro.core.sketch import sketch_matrix

    Q = qr_mod.orthonormalize(sketch_matrix(A.shape[0], k, seed, dtype=A.dtype), qr_method)

    def body(_, Q):
        return qr_mod.orthonormalize(A @ Q, qr_method)

    Q = jax.lax.fori_loop(0, iters, body, Q)
    T = Q.T @ (A @ Q)  # Rayleigh quotient (k x k)
    w, U = jnp.linalg.eigh(T)  # repro: noqa[RL006]: Rayleigh quotient T is k x k
    order = jnp.argsort(-w)
    return w[order], Q @ U[:, order]
