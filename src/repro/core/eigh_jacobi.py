"""Parallel-order cyclic Jacobi eigensolver for small symmetric matrices.

Used for the "small SVD" step of the randomized k-SVD (step 5 of the paper's
Algorithm 1): instead of calling a LAPACK-style bidiagonalization SVD on the
s x n sketch B, we form the s x s Gram matrix B B^T (a GEMM — BLAS-3) and
diagonalize it here.

The classical cyclic Jacobi applies one 2x2 rotation at a time (sequential).
The *parallel ordering* (round-robin tournament) groups s/2 disjoint pivots
per step; disjoint rotations commute, so each step is expressible as a single
orthogonal matrix J (block-diagonal up to permutation) and the update
A <- J^T A J is two s x s GEMMs.  This turns Jacobi itself into a BLAS-3
algorithm — the paper's reformulation philosophy applied to the eigensolver.

The rotation *bookkeeping* is pure control flow (no MXU work), so this stays
in jax.lax rather than Pallas; the GEMMs inside dominate and XLA maps them to
the MXU directly.  DESIGN.md records this decision.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _round_robin_schedule(s: int) -> Tuple[np.ndarray, np.ndarray]:
    """All (s-1) rounds of the circle-method tournament for s players.

    Returns (pp, qq), each of shape [s-1, s//2], with pp < qq elementwise.
    """
    assert s % 2 == 0
    fixed = 0
    rest = list(range(1, s))
    pps, qqs = [], []
    for _ in range(s - 1):
        lineup = [fixed] + rest
        pairs = [
            (min(lineup[i], lineup[s - 1 - i]), max(lineup[i], lineup[s - 1 - i]))
            for i in range(s // 2)
        ]
        pps.append([p for p, _ in pairs])
        qqs.append([q for _, q in pairs])
        rest = [rest[-1]] + rest[:-1]
    return np.asarray(pps, np.int32), np.asarray(qqs, np.int32)


def _build_rotation(A: jax.Array, pp: jax.Array, qq: jax.Array) -> jax.Array:
    """Orthogonal J applying s/2 disjoint Givens rotations chosen to
    annihilate A[pp, qq] (symmetric Schur decomposition, Golub & Van Loan)."""
    s = A.shape[0]
    dt = A.dtype
    app = A[pp, pp]
    aqq = A[qq, qq]
    apq = A[pp, qq]

    # t = sign(tau) / (|tau| + sqrt(1 + tau^2)),  tau = (aqq - app) / (2 apq)
    safe_apq = jnp.where(jnp.abs(apq) > 0, apq, jnp.ones((), dt))
    tau = (aqq - app) / (2.0 * safe_apq)
    t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
    t = jnp.where(jnp.sign(tau) == 0, 1.0 / (tau + jnp.sqrt(1.0 + tau * tau)), t)
    c = 1.0 / jnp.sqrt(1.0 + t * t)
    sn = t * c
    # Identity rotation where the pivot is already (numerically) zero.
    eps = jnp.finfo(dt).eps
    tiny = jnp.abs(apq) <= eps * jnp.sqrt(jnp.abs(app * aqq) + eps)
    c = jnp.where(tiny, jnp.ones((), dt), c)
    sn = jnp.where(tiny, jnp.zeros((), dt), sn)

    J = jnp.eye(s, dtype=dt)
    J = J.at[pp, pp].set(c)
    J = J.at[qq, qq].set(c)
    J = J.at[pp, qq].set(sn)
    J = J.at[qq, pp].set(-sn)
    return J


def _offdiag_norm2(A: jax.Array) -> jax.Array:
    return jnp.sum(A * A) - jnp.sum(jnp.diag(A) ** 2)


def jacobi_eigh(
    A: jax.Array, max_sweeps: int = 30, tol_factor: float = 10.0
) -> Tuple[jax.Array, jax.Array]:
    """Eigendecomposition of a symmetric matrix by parallel-order Jacobi.

    Returns (eigenvalues_desc, eigenvectors) with A @ v = w * v, columns of
    the second output being eigenvectors, sorted by descending eigenvalue.
    """
    s0 = A.shape[0]
    dt = A.dtype
    s = s0 + (s0 % 2)  # pad to even; pad stays exactly isolated (zero coupling)
    if s != s0:
        A = jnp.pad(A, ((0, 1), (0, 1)))
    pp_all, qq_all = _round_robin_schedule(s)
    pp_all = jnp.asarray(pp_all)
    qq_all = jnp.asarray(qq_all)
    n_rounds = s - 1

    tol = tol_factor * jnp.finfo(dt).eps ** 2 * jnp.sum(A * A)

    def round_body(r, carry):
        Acur, Vcur = carry
        J = _build_rotation(Acur, pp_all[r], qq_all[r])
        Anew = J.T @ Acur @ J
        Vnew = Vcur @ J
        return (Anew, Vnew)

    def sweep_cond(carry):
        Acur, _, it = carry
        return jnp.logical_and(it < max_sweeps, _offdiag_norm2(Acur) > tol)

    def sweep_body(carry):
        Acur, Vcur, it = carry
        Acur, Vcur = jax.lax.fori_loop(0, n_rounds, round_body, (Acur, Vcur))
        return (Acur, Vcur, it + 1)

    V0 = jnp.eye(s, dtype=dt)
    Af, Vf, _ = jax.lax.while_loop(sweep_cond, sweep_body, (A, V0, 0))

    w = jnp.diag(Af)[:s0]
    V = Vf[:s0, :s0]
    order = jnp.argsort(-w)
    return w[order], V[:, order]


def svd_via_gram(B: jax.Array, use_jacobi: bool = True, max_sweeps: int = 30):
    """SVD of a short-fat B (s x n, s <= n) via the s x s Gram matrix.

    B = U S V^T  with  B B^T = U S^2 U^T  and  V^T = S^{-1} U^T B.

    The Gram product is a GEMM; the eigensolver sees only an s x s matrix.
    Accuracy note: squaring halves the usable precision for *small* singular
    values; the randomized SVD only consumes the k *largest* of an
    oversampled sketch, where this loss is immaterial (validated in tests).
    """
    s = B.shape[0]
    G = B @ B.T
    if use_jacobi:
        w, U = jacobi_eigh(G, max_sweeps=max_sweeps)
    else:
        w, U = jnp.linalg.eigh(G)  # repro: noqa[RL006]: s x s Gram, the LAPACK ablation arm
        w, U = w[::-1], U[:, ::-1]
    w = jnp.maximum(w, 0.0)
    sv = jnp.sqrt(w)
    safe = jnp.maximum(sv, jnp.finfo(B.dtype).eps * jnp.max(sv) * s)
    Vt = (U.T @ B) / safe[:, None]
    return U, sv, Vt
