"""TPU v5e hardware constants (the assignment's target platform)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (assignment figure)
HOST_LINK_BW = 32e9             # bytes/s host<->device (PCIe gen4 x16 per
#                                 direction — the out-of-core streaming link
#                                 the overlap model in rsvd_model.py prices)
HBM_BYTES = 16 * 2**30          # 16 GiB per chip
VMEM_BYTES = 128 * 2**20        # ~128 MiB vector memory

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
