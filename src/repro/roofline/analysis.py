"""Three-term roofline from dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute    = FLOPs_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_LINK_BW

cost_analysis() reports PER-DEVICE quantities after SPMD partitioning
(verified empirically — DESIGN.md §7), so no further division by chip count
is needed.  Scan trip-count correction: XLA cost analysis counts a while
body once, so every scanned-arch artifact carries a `mini` record (one unit
lowered standalone with identical shardings) and the composed total is

  total = full + (n_scan - 1) * mini.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.roofline import hw


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per device, composed
    bytes_accessed: float        # per device, composed
    coll_bytes: float            # per device, composed
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6*N*D analytic (global)
    useful_ratio: float          # model_flops / (flops * n_devices)
    memory_fit: Dict[str, float]
    n_devices: int
    skipped: Optional[str] = None

    def dominant_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def roofline_fraction(self) -> float:
        """How close the dominant term is to pure-compute: T_comp / T_dom.
        1.0 = compute-bound at peak; lower = memory/collective overheads."""
        d = self.dominant_time()
        return self.t_compute / d if d > 0 else 0.0


def _composed(rec: Dict, field_path, default=0.0) -> float:
    def get(d, path):
        for p in path:
            d = d.get(p, {})
        return d if isinstance(d, (int, float)) else default

    full = get(rec.get("full", {}), field_path)
    mini = get(rec.get("mini", {}), field_path) if "mini" in rec else 0.0
    n = max(rec.get("n_scan_units", 1), 1)
    if full is None or full < 0:
        return -1.0
    return float(full) + (n - 1) * float(mini or 0.0)


def _attn_flops(cfg, shape) -> float:
    """Attention score/value FLOPs not captured by 6*N*D (global, per step)."""
    B, T = shape.global_batch, shape.seq_len
    H, Dh = cfg.num_heads, cfg.head_dim_()
    kinds = list(cfg.block_pattern)
    n_units, rem = cfg.num_units_()
    counts = {k: kinds.count(k) * n_units + list(rem).count(k) for k in set(kinds + list(rem))}
    total = 0.0
    for kind, n_layers in counts.items():
        if kind == "global":
            ctx = T
        elif kind == "local":
            ctx = min(cfg.window_size or T, T)
        else:
            continue  # recurrent kinds are linear — inside 6ND already
        if shape.kind == "decode":
            total += n_layers * B * 4 * H * Dh * ctx          # one query token
        else:
            mult = 6 if shape.kind == "train" else 2          # fwd(+bwd)
            total += n_layers * B * mult * 2 * H * Dh * T * ctx / 2  # causal half
    return total


def analytic_model_flops(cfg, shape, params_total: float, params_active: float) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * params_active * tokens + _attn_flops(cfg, shape)


def analytic_transient_gb(cfg, shape, n_devices: int) -> float:
    """First-principles per-device transient memory (the XLA-CPU temp number
    double-counts bf16 buffers as f32 — EXPERIMENTS.md §Dry-run artifact)."""
    model = 16
    n_dp = max(n_devices // model, 1)
    B = shape.global_batch
    B_loc = max(B // n_dp, 1)
    d = cfg.d_model
    if shape.kind == "decode":
        return 0.2 + B_loc * d * 4 * 8 / 1e9  # a handful of token-width buffers
    T = shape.seq_len + (cfg.vision_tokens if cfg.vision_stub else 0)
    T_loc = T // model if (cfg.seq_shard and shape.kind == "train") else T
    n_scan = max(cfg.num_units_()[0] - cfg.first_k_dense // max(len(cfg.block_pattern), 1), 0)
    stack = n_scan * B_loc * T_loc * d * 2 if shape.kind == "train" else 0
    width = max(cfg.d_ff, cfg.moe_d_ff_() * 2 if cfg.num_experts else 0, 4 * d)
    working = 3 * B_loc * T_loc * width * 4
    vloc = cfg.padded_vocab_() // model if cfg.padded_vocab_() % model == 0 else cfg.padded_vocab_()
    logits = (2 * B_loc * T_loc * vloc * 4) if shape.kind == "train" else 0
    moe = 0
    if cfg.num_experts:
        C = 1.25 * B * shape.seq_len * cfg.num_experts_per_tok / cfg.num_experts
        moe = 2 * (cfg.num_experts // model) * C * d * 2  # EP-sharded buffers
    return (stack + working + logits + moe) / 1e9


def analyze_record(rec: Dict) -> Roofline:
    if "skipped" in rec:
        return Roofline(
            rec["arch"], rec["shape"], rec["mesh"], 0, 0, 0, 0, 0, 0, "skipped",
            0, 0, {}, rec.get("n_devices", 0), skipped=rec["skipped"],
        )
    flops = _composed(rec, ("cost", "flops"))
    bytes_acc = _composed(rec, ("cost", "bytes_accessed"))
    coll = _composed(rec, ("collectives", "total"))

    t_c = flops / hw.PEAK_FLOPS_BF16
    t_m = bytes_acc / hw.HBM_BW
    t_x = coll / hw.ICI_LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    from repro.configs import get_config
    from repro.configs.base import SHAPES

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mem = rec["full"]["memory"]
    n_dev = rec["n_devices"]
    transient = analytic_transient_gb(cfg, shape, n_dev)
    fit = {
        "argument_gb": mem["argument_bytes"] / 1e9,
        "temp_gb": mem["temp_bytes"] / 1e9,               # raw XLA-CPU (inflated)
        "analytic_transient_gb": transient,                # first-principles
        "total_gb": mem["argument_bytes"] / 1e9 + transient,
        "hbm_gb": hw.HBM_BYTES / 1e9,
    }
    model_flops = analytic_model_flops(
        cfg, shape,
        rec["analytic"]["params_total"], rec["analytic"]["params_active"],
    )
    useful = model_flops / (flops * n_dev) if flops > 0 else 0.0
    return Roofline(
        rec["arch"], rec["shape"], rec["mesh"], flops, bytes_acc, coll,
        t_c, t_m, t_x, bottleneck, model_flops, useful, fit, n_dev,
    )


def load_all(art_dir: str = "artifacts/dryrun") -> List[Roofline]:
    out = []
    for p in sorted(pathlib.Path(art_dir).glob("*.json")):
        out.append(analyze_record(json.loads(p.read_text())))
    return out


def format_table(rows: List[Roofline], mesh: str = "single") -> str:
    """Markdown roofline table (single-pod per the assignment)."""
    hdr = (
        "| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | bottleneck | "
        "roofline-frac | useful-FLOP ratio | mem GB/chip (XLA-raw) | fits? |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.mesh != mesh:
            continue
        if r.skipped:
            lines.append(f"| {r.arch} | {r.shape} | — | — | — | skipped | — | — | — | {r.skipped} |")
            continue
        fits = "yes" if r.memory_fit["total_gb"] <= r.memory_fit["hbm_gb"] else "NO"
        raw = r.memory_fit["argument_gb"] + r.memory_fit["temp_gb"]
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute*1e3:.2f} | {r.t_memory*1e3:.2f} | "
            f"{r.t_collective*1e3:.2f} | {r.bottleneck} | {r.roofline_fraction():.3f} | "
            f"{r.useful_ratio:.3f} | {r.memory_fit['total_gb']:.1f} ({raw:.1f}) | {fits} |"
        )
    return hdr + "\n".join(lines)


def pick_hillclimb_cells(rows: List[Roofline]) -> Dict[str, Roofline]:
    """worst roofline fraction / most collective-bound / most paper-representative."""
    live = [r for r in rows if not r.skipped and r.mesh == "single"]
    worst = min(live, key=lambda r: r.roofline_fraction())
    coll = max(live, key=lambda r: r.t_collective / max(r.dominant_time(), 1e-12))
    return {"worst_fraction": worst, "most_collective": coll}
