"""Analytic HBM-traffic model for randomized SVD execution plans.

This is the structural model the fused one-pass range finder is built on
(DESIGN.md §2, EXPERIMENTS.md §Perf).  It lives in the roofline layer so the
execution planner (repro/linalg/planner.py) can stamp every `ExecutionPlan`
with its predicted HBM bytes, and so benchmarks/bench_rsvd.py asserts the
fused-vs-unfused saving against the SAME numbers the planner reports.

Counting convention: fp32 words x `dtype_bytes`, reads AND writes of every
large operand; s x s Grams are dropped (O(s^2) << m*s).  A is m x n (tall
orientation — callers pass the post-orientation dims), sketch width s.

Beyond bytes, the model prices WALLTIME: in-core paths at HBM bandwidth
(`hbm_walltime_s`), and the out-of-core streamed path with the overlap
model (`streamed_walltime_s`) — per panel, max(host-link transfer, HBM
compute) plus pipeline fill/drain when the prefetch pipeline
(linalg/pipeline.py) is at depth >= 2, or their SUM when synchronous.
benchmarks/bench_rsvd.py measures the real transfer/compute split against
these same numbers (schema v4).
"""
from __future__ import annotations

#: bytes per stored nonzero index in the sparse formats we price (BCOO /
#: block-ELL tile ids are int32 either way)
INDEX_BYTES = 4


def sparse_read_bytes(nnz: int, dtype_bytes: int = 4, index_bytes: int = INDEX_BYTES) -> int:
    """HBM traffic of ONE full read of a sparse A: every stored nonzero
    ships its value plus its index — nnz * (value + index) bytes, replacing
    the dense m * n * dtype_bytes term wherever A is touched."""
    return nnz * (dtype_bytes + index_bytes)


def spmm_sketch_bytes(
    m: int, n: int, s: int, nnz: int, fused_sketch: bool, dtype_bytes: int = 4
) -> int:
    """HBM traffic of the sparse sketch pass Y = A @ Omega (one SpMM).

    Mirrors `sketch_bytes` with the dense read of A swapped for the
    nnz-proportional read; the fused kernel (kernels/spmm_sketch.py) still
    generates Omega tiles in VMEM for free, the unfused path round-trips the
    materialized n x s factor."""
    base = sparse_read_bytes(nnz, dtype_bytes) + m * s * dtype_bytes
    omega = 0 if fused_sketch else 2 * n * s * dtype_bytes
    return base + omega


def sparse_hbm_bytes_per_power_iter(
    m: int, n: int, s: int, nnz: int, dtype_bytes: int = 4
) -> int:
    """HBM traffic of ONE stabilized power iteration over a sparse A.

    The sparse path always runs the unfused operator body (Z = AᵀQ and
    Y' = A·Qz are two SpMMs — A is read twice per iteration, at nnz cost);
    the CQR2 terms are identical to the dense model."""
    spmms = 2 * sparse_read_bytes(nnz, dtype_bytes) + (2 * m * s + 2 * n * s) * dtype_bytes
    cqr = 6 * m * s * dtype_bytes   # CQR2 of Y
    small = 6 * n * s * dtype_bytes  # orthonormalize(Z)
    return spmms + cqr + small


def sparse_projection_bytes(m: int, n: int, s: int, nnz: int, dtype_bytes: int = 4) -> int:
    """Post-loop traffic for the sparse path: final CQR2 of Y plus
    B = QᵀA — one more SpMM read of A."""
    cqr = 6 * m * s * dtype_bytes
    b = sparse_read_bytes(nnz, dtype_bytes) + (m * s + n * s) * dtype_bytes
    return cqr + b


def sparse_predicted_hbm_bytes(
    m: int,
    n: int,
    s: int,
    power_iters: int,
    nnz: int,
    fused_sketch: bool = False,
    dtype_bytes: int = 4,
) -> int:
    """Whole-algorithm HBM bytes for one rank-s solve over a sparse A:
    the dense `predicted_hbm_bytes` with every read of A priced at
    nnz * (value + index) instead of m * n words.  Callers pass
    post-orientation dims (m >= n); nnz is orientation-invariant."""
    total = spmm_sketch_bytes(m, n, s, nnz, fused_sketch, dtype_bytes)
    total += power_iters * sparse_hbm_bytes_per_power_iter(m, n, s, nnz, dtype_bytes)
    total += sparse_projection_bytes(m, n, s, nnz, dtype_bytes)
    total += 2 * m * s * dtype_bytes  # U = Q @ U_b
    return total


def hbm_bytes_per_power_iter(
    m: int, n: int, s: int, fused: bool, dtype_bytes: int = 4
) -> int:
    """HBM traffic of ONE stabilized power iteration.

      unfused:  Z = AᵀQ and Y' = A·Qz are separate GEMMs  -> A read TWICE
                + CQR2 of Y reads Y twice and round-trips Q1/Q
      fused:    kernels/power_step.py reads A ONCE, returns (Y, W=AᵀY, G=YᵀY);
                Z = W R⁻¹ is a sketch-width TRSM, G kills CQR's first pass
    """
    if fused:
        # power_step: read A + read Qz + write Y + write W (G is s x s, ~0)
        kernel = m * n + n * s + m * s + n * s
        # CQR2 with free first Gram: TRSM(Y)->Q1 (read Y, write Q1), gram(Q1)
        cqr = 3 * m * s
        # Z = W R^-1 (read W, write Z) + orthonormalize(Z) ~ CQR2 on n x s
        small = 2 * n * s + 6 * n * s
        return (kernel + cqr + small) * dtype_bytes
    # Z = A^T Q (read A, read Q, write Z) + Y' = A Qz (read A, read Qz, write Y)
    gemms = (m * n + m * s + n * s) + (m * n + n * s + m * s)
    # CQR2 of Y: gram(Y) + TRSM(Y)->Q1 + gram(Q1) + TRSM(Q1)->Q
    cqr = 6 * m * s
    small = 6 * n * s  # orthonormalize(Z)
    return (gemms + cqr + small) * dtype_bytes


def sketch_bytes(
    m: int, n: int, s: int, fused_sketch: bool, dtype_bytes: int = 4
) -> int:
    """HBM traffic of the sketch pass Y = A @ Omega.

    Materialized Omega costs an extra write+read of the n x s factor; the
    fused kernel generates Omega tiles in VMEM for free (the paper's RNG
    pillar, TPU edition — DESIGN.md §2)."""
    base = m * n + m * s  # read A, write Y
    omega = 0 if fused_sketch else 2 * n * s
    return (base + omega) * dtype_bytes


def projection_bytes(m: int, n: int, s: int, fused_power: bool, dtype_bytes: int = 4) -> int:
    """Step-3/4 traffic after the power loop: the final CQR2 + B = QᵀA.

    The fused path's last W already holds AᵀY, so B = (W R⁻¹)ᵀ is a
    sketch-width TRSM instead of a full read of A."""
    cqr = (3 if fused_power else 6) * m * s  # final orthonormalization of Y
    if fused_power:
        b = 2 * n * s                         # TRSM on W
    else:
        b = m * n + m * s + n * s             # B = QᵀA reads A once more
    return (cqr + b) * dtype_bytes


def adaptive_panel_bytes(
    m: int,
    n: int,
    b: int,
    r_prev: int,
    power_iters: int,
    dtype_bytes: int = 4,
    fused_sketch: bool = False,
    nnz: int | None = None,
) -> int:
    """HBM traffic of ONE adaptive growth panel (core/adaptive.py), with an
    accumulated basis of `r_prev` columns already on device.

      sketch    Y = A @ Omega_p         read A, Omega panel (free if fused),
                                        write Y (m x b)
      deflate   Y -= Q (Q^T Y)          read Q twice + round-trip Y — the
                                        term that grows linearly in r_prev
      power     q x { orth(Y), Z = A^T Q_y, orth(Z), Y = A Q_z, deflate }
                                        TWO reads of A per iteration (the
                                        adaptive loop runs the unfused
                                        operator body) + panel-width CQR2s
      reorth    orth(Y) + CGS2 pass against Q + orth  (panel CQR2s + one
                                        more deflation)
      project   B_p = (A^T Q_p)^T       one more read of A
      estimate  ||B_p||_F^2             re-read of the b x n panel

    Panel-width CQR2 on an m x b block costs ~6 m b (two Grams + two TRSMs,
    matching `hbm_bytes_per_power_iter`'s counting convention); s x s and
    b x b Grams are dropped as O(b^2).

    With ``nnz`` set (a sparse source), every read of A is priced at
    nnz * (value + index) bytes instead of m * n words — the panel touches A
    ``2 * power_iters + 2`` times (sketch, two SpMMs per power iteration,
    projection); every other term is unchanged.
    """
    a_reads = 2 * power_iters + 2
    if nnz is None:
        a_read_bytes = m * n * dtype_bytes
    else:
        a_read_bytes = sparse_read_bytes(nnz, dtype_bytes)
    deflate = 2 * m * r_prev + 2 * m * b
    sketch = m * b + (0 if fused_sketch else 2 * n * b)
    power = power_iters * (
        6 * m * b            # orth(Y), CQR2
        + (m * b + n * b)    # Z = A^T Q_y (A read counted separately)
        + 6 * n * b          # orth(Z), CQR2 on n x b
        + (n * b + m * b)    # Y = A Q_z (A read counted separately)
        + deflate
    )
    reorth = 6 * m * b + deflate + 6 * m * b
    project = m * b + n * b
    estimate = n * b
    words = sketch + deflate + power + reorth + project + estimate
    return words * dtype_bytes + a_reads * a_read_bytes


def adaptive_schedule_bytes(
    m: int,
    n: int,
    rank_schedule: tuple,
    power_iters: int,
    dtype_bytes: int = 4,
    fused_sketch: bool = False,
    nnz: int | None = None,
) -> tuple:
    """Per-growth-step bytes for a cumulative `rank_schedule` (r_1, r_2, ...):
    step i grows the basis from r_{i-1} to r_i.  The planner stamps this
    tuple on adaptive ExecutionPlans; summing it gives the full-schedule
    (worst-case, tolerance never met) prediction.  ``nnz`` switches every
    read of A to the sparse nnz * (value + index) pricing."""
    out = []
    r_prev = 0
    for r in rank_schedule:
        out.append(
            adaptive_panel_bytes(
                m, n, r - r_prev, r_prev, power_iters,
                dtype_bytes=dtype_bytes, fused_sketch=fused_sketch, nnz=nnz,
            )
        )
        r_prev = r
    return tuple(out)


def streamed_pass_count(power_iters: int) -> int:
    """Host->device passes over A's row panels per streamed solve
    (core/blocked.py): the sketch, TWO per stabilized power iteration (the
    Z = ΣAᵀQ accumulation and the Y = A·Qz rebuild), and the projection
    B = ΣQᵀA.  Every pass re-transfers every panel — out-of-core A has no
    device residency to amortize."""
    return 2 + 2 * power_iters


def hbm_walltime_s(total_bytes: int, hbm_bw: float | None = None) -> float:
    """Bandwidth-bound walltime of an in-core solve: every path in this
    model is BLAS-3 with arithmetic intensity past the roofline ridge only
    for tiny s, so HBM traffic over HBM bandwidth is the floor the kernels
    chase."""
    from repro.roofline import hw

    return total_bytes / (hbm_bw or hw.HBM_BW)


def streamed_walltime_s(
    m: int,
    n: int,
    s: int,
    block_rows: int,
    power_iters: int,
    pipeline_depth: int,
    dtype_bytes: int = 4,
    fused_sketch: bool = False,
    link_bw: float | None = None,
    hbm_bw: float | None = None,
) -> float:
    """Overlap-aware walltime of a streamed out-of-core solve.

    Per pass over A, every panel costs a host->device transfer
    ``t_x = block_rows * n * dtype_bytes / HOST_LINK_BW`` (the staging ring
    ships the tail zero-padded, so transfers are uniform) and a compute
    slice ``t_c`` = the pass's share of the solve's HBM traffic at HBM
    bandwidth.  Synchronous (depth 1) pays ``n_panels * (t_x + t_c)``;
    the double-buffered pipeline pays the FILL (first transfer, nothing to
    overlap it with), ``max(t_x, t_c)`` for each interior panel, and the
    DRAIN (last panel's compute after its transfer) —

        t_x + (n_panels - 1) * max(t_x, t_c) + t_c

    — the Lu et al. (arXiv:1706.07191) overlap bound.  Depth >= 2 is all
    the model distinguishes: one panel in flight already hides the link
    behind compute (deeper rings only absorb jitter, which a bandwidth
    model has none of)."""
    from repro.roofline import hw

    link_bw = link_bw or hw.HOST_LINK_BW
    hbm_bw = hbm_bw or hw.HBM_BW
    n_panels = -(-m // block_rows)  # ceil
    passes = streamed_pass_count(power_iters)
    t_x = block_rows * n * dtype_bytes / link_bw
    compute_bytes = predicted_hbm_bytes(
        m, n, s, power_iters, False, fused_sketch, dtype_bytes
    )
    t_c = compute_bytes / (passes * n_panels) / hbm_bw
    if pipeline_depth >= 2 and n_panels > 1:
        per_pass = t_x + (n_panels - 1) * max(t_x, t_c) + t_c
    else:
        per_pass = n_panels * (t_x + t_c)
    return passes * per_pass


def predicted_hbm_bytes(
    m: int,
    n: int,
    s: int,
    power_iters: int,
    fused_power: bool,
    fused_sketch: bool,
    dtype_bytes: int = 4,
    batch: int = 1,
) -> int:
    """Whole-algorithm HBM bytes for one rank-s range-finder solve.

    sketch + q power iterations + final projection + step-6 assembly
    (U = Q @ U_b: read Q, write U).  `batch` scales the total for the
    stacked (vmapped) execution path — per-slice traffic is independent.
    Callers pass post-orientation dims (m >= n).
    """
    total = sketch_bytes(m, n, s, fused_sketch, dtype_bytes)
    total += power_iters * hbm_bytes_per_power_iter(m, n, s, fused_power, dtype_bytes)
    total += projection_bytes(m, n, s, fused_power, dtype_bytes)
    total += 2 * m * s * dtype_bytes  # U = Q @ U_b
    return batch * total
