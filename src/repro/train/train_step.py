"""Loss + train-step factory (remat, scan, RSVD-based optimizer tricks).

train_step(params, opt_state, batch) -> (params, opt_state, metrics)

Technique integration points (selected by config — DESIGN.md §4):
  * cfg.powersgd_rank  > 0: gradients of 2-D dense weights are rank-k
    compressed (power iteration + CholeskyQR — the paper's primitives)
    before the data-parallel mean, shrinking cross-pod collective bytes.
  * cfg.galore_rank    > 0: Adam moments for 2-D weights live in an RSVD
    subspace (handled in optim/galore.py wrapper).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import forward_model
from repro.optim import adamw
from repro.optim.powersgd import compress_tree_grads

Params = Any


def cross_entropy_loss(
    logits: jax.Array,  # [B, T, V]
    labels: jax.Array,  # [B, T]
    mask: jax.Array | None = None,
    z_loss_coef: float = 1e-4,
    logits_sharding=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Vocab-sharding-friendly xent: no gather along V (a gather on a
    'model'-sharded vocab axis forces an all-gather of the full logits —
    the dominant memory term at 128k vocab).  All V-reductions are
    elementwise-into-reduce, which XLA fuses and partially reduces per
    shard + small all-reduce."""
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1).astype(jnp.float32)
    nll = lse - ll
    z = z_loss_coef * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((nll + z) * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, {"nll": jnp.sum(nll * mask) / denom, "accuracy": acc}


def compute_loss(params, batch, cfg, logits_sharding=None):
    logits, aux = forward_model(params, batch, cfg, mode="train")
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.vision_stub:
        # logits cover [vision; text]; predictions for text tokens only
        logits = logits[:, cfg.vision_tokens :, :]
    loss, metrics = cross_entropy_loss(logits, labels, mask, logits_sharding=logits_sharding)
    if aux:
        loss = loss + 0.01 * aux.get("moe_lb_loss", 0.0) + 1e-3 * aux.get("moe_z_loss", 0.0)
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(
    cfg, opt_cfg: adamw.AdamWConfig, dp_axes: tuple[str, ...] = (), logits_sharding=None
):
    """Returns train_step(params, opt_state, batch, psgd_state).

    Under jit-with-shardings the gradient mean over data parallelism is
    implicit in SPMD; `dp_axes` is only used by the explicit shard_map path
    and the PowerSGD hook (which compresses before the 'pod' reduction).
    `logits_sharding` keeps the vocab axis model-sharded through the loss.
    """

    def train_step(params, opt_state, batch, psgd_state=None):
        grad_fn = jax.value_and_grad(compute_loss, has_aux=True)
        (loss, metrics), grads = grad_fn(params, batch, cfg, logits_sharding)

        if cfg.powersgd_rank > 0 and psgd_state is not None:
            grads, psgd_state, psgd_metrics = compress_tree_grads(
                grads, psgd_state, rank=cfg.powersgd_rank
            )
            metrics.update(psgd_metrics)

        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics, psgd_state

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        _, metrics = compute_loss(params, batch, cfg)
        return metrics

    return eval_step
