"""Training loop with the fault-tolerance features of a production deployment:

  * periodic async checkpoints + atomic publish (checkpoint/),
  * SIGTERM/SIGINT -> synchronous final save, auto-resume on restart,
  * straggler watchdog: EWMA step time, flags hosts whose step exceeds
    `straggler_factor` x the EWMA (on real fleets this triggers eviction +
    the elastic-restart path; here it logs and counts),
  * loss/metric logging to JSONL (greppable, no tensorboard dependency).
"""
from __future__ import annotations

import json
import pathlib
import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.optim import adamw
from repro.optim import powersgd
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 200
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


@dataclass
class StragglerStats:
    ewma: float = 0.0
    flagged_steps: int = 0
    worst_ratio: float = 0.0


class Trainer:
    def __init__(
        self,
        cfg,                     # ModelConfig
        opt_cfg: adamw.AdamWConfig,
        tcfg: TrainerConfig,
        *,
        step_fn: Optional[Callable] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.step_fn = step_fn or jax.jit(make_train_step(cfg, opt_cfg))
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir, keep_last=tcfg.keep_last)
        self.straggler = StragglerStats()
        self._stop = False
        self.log_path = pathlib.Path(tcfg.checkpoint_dir) / "train_log.jsonl"

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True  # finish the current step, then save + exit

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def _watchdog(self, dt: float, step: int) -> bool:
        s = self.straggler
        if s.ewma == 0.0:
            s.ewma = dt
            return False
        flagged = dt > self.tcfg.straggler_factor * s.ewma and step > 3
        s.worst_ratio = max(s.worst_ratio, dt / s.ewma)
        if flagged:
            s.flagged_steps += 1
        s.ewma = (1 - self.tcfg.ewma_alpha) * s.ewma + self.tcfg.ewma_alpha * dt
        return flagged

    def _log(self, record: Dict[str, Any]):
        with open(self.log_path, "a") as f:
            f.write(json.dumps(record) + "\n")

    def run(
        self,
        params,
        data_iter: Iterator[Dict],
        *,
        resume: bool = True,
        psgd_state=None,
    ):
        self._install_signals()
        opt_state = adamw.init_state(params)
        if self.cfg.powersgd_rank > 0 and psgd_state is None:
            psgd_state = powersgd.init_state(params, self.cfg.powersgd_rank)
        start_step = 0

        if resume and self.ckpt.latest_step() is not None:
            (params, opt_state), start_step = self.ckpt.restore((params, opt_state))
            start_step += 1
            self._log({"event": "resumed", "step": start_step})

        metrics = {}
        for step in range(start_step, self.tcfg.total_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            params, opt_state, metrics, psgd_state = self.step_fn(
                params, opt_state, batch, psgd_state
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            flagged = self._watchdog(dt, step)

            if step % self.tcfg.log_every == 0 or flagged:
                rec = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "step_time_s": dt,
                    "straggler_flag": bool(flagged),
                }
                self._log(rec)

            if step > 0 and step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step, (params, opt_state))
                self._log({"event": "checkpoint", "step": step})

            if self._stop:
                self.ckpt.save(step, (params, opt_state), blocking=True)
                self._log({"event": "preempted_save", "step": step})
                break

        self.ckpt.save(self.tcfg.total_steps - 1, (params, opt_state), blocking=True)
        return params, opt_state, metrics
