"""Cross-pod PowerSGD gradient synchronization (hillclimb: collective term).

Baseline multi-pod training all-reduces FULL gradients across the 'pod' axis
(DCN — the slowest links in the fleet).  This step keeps the intra-pod
data/model axes on automatic SPMD but takes MANUAL control of 'pod' via
shard_map(axis_names={'pod'}): backward produces pod-local gradients, and the
only cross-pod traffic is the PowerSGD factor pair

    P (m x k) and Q (n x k)   instead of   M (m x n)

orthonormalized with the paper's CholeskyQR2 — i.e. the paper's randomized
range finder, warm-started, used as a gradient codec.  Error feedback is
pod-local state.  Bytes ratio per weight: k(m+n)/(mn) (phi3 d_ff matrix at
k=32: 1.44%).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core import qr as qr_mod
from repro.core.sketch import sketch_matrix
from repro.optim import adamw
from repro.train.train_step import compute_loss

Params = Any


def _compressible(leaf, rank: int) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim in (2, 3) and min(leaf.shape[-2:]) > 4 * rank


def init_podsgd_state(params: Params, rank: int, n_pods: int, seed: int = 29):
    """(e, q): e is pod-local (leading pod dim), q is pod-replicated."""

    def mk_e(p):
        if _compressible(p, rank):
            return jnp.zeros((n_pods,) + p.shape, jnp.float32)
        return None

    def mk_q(p):
        if not _compressible(p, rank):
            return None
        q = sketch_matrix(p.shape[-1], rank, seed, dtype=jnp.float32)
        if p.ndim == 3:
            q = jnp.broadcast_to(q[None], (p.shape[0],) + q.shape).copy()
        return q

    return jax.tree.map(mk_e, params), jax.tree.map(mk_q, params)


def _compress_one_pod(g, q, e, rank):
    """One PowerSGD round; cross-pod traffic = pmean of P and Q only."""
    gf = g.astype(jnp.float32) + e
    p = gf @ q
    p = jax.lax.pmean(p, "pod")                  # (m, k) over DCN
    p_hat, _ = qr_mod.cholesky_qr2(p)            # paper's BLAS-3 orthonormalizer
    q_new = jnp.swapaxes(gf, -1, -2) @ p_hat
    q_new = jax.lax.pmean(q_new, "pod")          # (n, k) over DCN
    g_hat = p_hat @ jnp.swapaxes(q_new, -1, -2)
    return g_hat.astype(g.dtype), q_new, gf - g_hat


def make_podsgd_train_step(cfg, opt_cfg: adamw.AdamWConfig, mesh, logits_sharding=None):
    rank = cfg.powersgd_rank
    assert rank > 0, "podsgd requires cfg.powersgd_rank > 0"
    assert "pod" in mesh.axis_names, "podsgd needs the multi-pod mesh"

    def per_pod(params, opt_state, batch, psgd_e, psgd_q):
        grad_fn = jax.value_and_grad(compute_loss, has_aux=True)
        (loss, metrics), grads = grad_fn(params, batch, cfg, logits_sharding)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(psgd_e)
        flat_q = treedef.flatten_up_to(psgd_q)
        out_g, out_e, out_q = [], [], []
        for g, e, q in zip(flat_g, flat_e, flat_q):
            if q is None:
                # small leaves: plain cross-pod mean (negligible bytes)
                out_g.append(jax.lax.pmean(g, "pod"))
                out_e.append(None)
                out_q.append(None)
                continue
            e_loc = e[0]  # manual pod axis: local block has leading dim 1
            if g.ndim == 3:
                g_hat, q_new, e_new = jax.vmap(
                    functools.partial(_compress_one_pod, rank=rank)
                )(g, q, e_loc)
            else:
                g_hat, q_new, e_new = _compress_one_pod(g, q, e_loc, rank)
            out_g.append(g_hat)
            out_e.append(e_new[None])
            out_q.append(q_new)
        grads = jax.tree.unflatten(treedef, out_g)
        psgd_e = jax.tree.unflatten(treedef, out_e)
        psgd_q = jax.tree.unflatten(treedef, out_q)

        new_params, new_opt, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        metrics.update(om)
        metrics = jax.tree.map(lambda t: jax.lax.pmean(t, "pod"), metrics)
        return new_params, new_opt, metrics, psgd_e, psgd_q

    # None leaves are empty subtrees: plain tree.map keeps spec/arg structures
    # congruent (specs exist only where arrays exist)
    rep = lambda tree: jax.tree.map(lambda _: P(), tree)
    podded = lambda tree: jax.tree.map(lambda _: P("pod"), tree)

    def wrap(params, opt_state, batch, psgd_e, psgd_q):
        return _shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(rep(params), rep(opt_state), podded(batch), podded(psgd_e), rep(psgd_q)),
            out_specs=(rep(params), rep(opt_state), P(), podded(psgd_e), rep(psgd_q)),
            axis_names={"pod"},
            check_vma=False,
        )(params, opt_state, batch, psgd_e, psgd_q)

    return wrap
