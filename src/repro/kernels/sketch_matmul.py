"""Fused RNG + GEMM sketch kernel: C = A @ Omega with Omega generated in VMEM.

The paper materializes the Gaussian sketch with cuRAND and then runs a GEMM
— two passes over HBM for Omega (write, then read).  At sketch width s << n
the GEMM A @ Omega is *memory-bound*, so on TPU we fuse: each (bk x bn)
Omega tile is generated directly in VMEM from the counter-based RNG
(murmur3-finalizer hash + Box-Muller, bit-identical to core/sketch.py) inside
the reduction loop, so Omega never exists in HBM at all.

HBM traffic: paper scheme reads A (m*n) + writes/reads Omega (2*n*s);
fused scheme reads A only.  This is the 'beyond-paper' optimization whose
roofline effect is recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)
_STREAM2 = np.uint32(0x5BF03635)


def _fmix(x):
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def _hash_u32(idx, seed):
    h = _fmix(idx * _GOLDEN + seed)
    h = _fmix(h ^ (seed * _M1 + np.uint32(0x27220A95)))
    return h


def _u32_to_unit(bits):
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(
        1.0 / 16777216.0
    ) + np.float32(1.0 / 16777216.0)


def _omega_tile(row0, col0, bk, bn, s, seed, kind):
    """Generate the (bk x bn) Omega tile starting at (row0, col0) in VMEM.

    Matches core.sketch element-for-element: element (r, c) is a function of
    the flat index r * s + c only.
    """
    rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
    idx = rows * np.uint32(s) + cols
    seed_u = jnp.asarray(seed, jnp.uint32)
    if kind == "gaussian":
        u1 = _u32_to_unit(_hash_u32(idx, seed_u))
        u2 = _u32_to_unit(_hash_u32(idx, seed_u ^ _STREAM2))
        r = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
        theta = np.float32(2.0 * np.pi) * u2
        return r * jnp.cos(theta)
    if kind == "rademacher":
        bits = _hash_u32(idx, seed_u)
        return jnp.where(bits & np.uint32(1), np.float32(1.0), np.float32(-1.0))
    raise ValueError(kind)


def _sketch_kernel(off_ref, seed_ref, a_ref, o_ref, acc_ref, *, nk, bk, bn, s, kind):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row0 = (kk * bk).astype(jnp.uint32) + off_ref[0, 0]
    col0 = (pl.program_id(1) * bn).astype(jnp.uint32)
    omega = _omega_tile(row0, col0, bk, bn, s, seed_ref[0, 0], kind)
    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32), omega, preferred_element_type=jnp.float32
    )

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sketch_matmul_padded(
    a: jax.Array,
    s: int,
    seed: int,
    *,
    s_padded: int,
    kind: str = "gaussian",
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
    row_offset: int = 0,
) -> jax.Array:
    """C = A @ Omega for A already padded to (m, k) block multiples.

    `s` is the LOGICAL sketch width (used in the flat RNG index so results
    are independent of padding); `s_padded` is the padded output width.
    Padded Omega columns (>= s) produce finite garbage that the caller
    slices off; padded A rows are zero so they contribute nothing.

    `row_offset` shifts the RNG row index: the kernel consumes rows
    [row_offset, row_offset + k) of the logical Omega, so a column-panel
    of A streamed in a separate call regenerates ITS panel of the same
    global sketch bit-identically (the out-of-core / blocked contract,
    mirroring ``core.sketch.sketch_matrix(row_offset=...)``).  Both
    `row_offset` AND `seed` are TRACED scalars (SMEM operands), so panel
    streams, seed sweeps, GaLore refreshes, and the batched vmap path all
    share ONE compiled program.
    """
    m, k = a.shape
    assert m % bm == 0 and k % bk == 0 and s_padded % bn == 0
    nk = k // bk
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(
        _sketch_kernel, nk=nk, bk=bk, bn=bn, s=s, kind=kind
    )
    off = jnp.asarray(row_offset, jnp.uint32).reshape(1, 1)
    sd = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, s_padded // bn, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, s_padded), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(off, sd, a)


# ---------------------------------------------------------------------------
# Sketch + Gram epilogue: Y = A @ Omega and G = Y^T Y in ONE pass over A
# ---------------------------------------------------------------------------

def _sketch_gram_kernel(
    off_ref, seed_ref, a_ref, y_ref, g_ref, yacc_ref, gacc_ref,
    *, ni, nk, bk, sp, s, kind,
):
    i, kk = pl.program_id(0), pl.program_id(1)

    @pl.when(kk == 0)
    def _init_y():
        yacc_ref[...] = jnp.zeros_like(yacc_ref)

    @pl.when((i == 0) & (kk == 0))
    def _init_g():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)

    row0 = (kk * bk).astype(jnp.uint32) + off_ref[0, 0]
    omega = _omega_tile(row0, jnp.uint32(0), bk, sp, s, seed_ref[0, 0], kind)
    yacc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32), omega, preferred_element_type=jnp.float32
    )

    @pl.when(kk == nk - 1)
    def _row_done():
        y = yacc_ref[...]
        y_ref[...] = y.astype(y_ref.dtype)
        # Gram epilogue: Y's block row is complete and still resident in
        # VMEM — accumulate its contribution to G = Y^T Y with no extra
        # pass over Y (CQR's first Gram rides along for free).
        gacc_ref[...] += jnp.dot(y.T, y, preferred_element_type=jnp.float32)

    @pl.when((i == ni - 1) & (kk == nk - 1))
    def _flush_g():
        g_ref[...] = gacc_ref[...].astype(g_ref.dtype)


def sketch_gram_padded(
    a: jax.Array,
    s: int,
    seed,
    *,
    s_padded: int,
    kind: str = "gaussian",
    bm: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
    row_offset=0,
):
    """(Y, G) = (A @ Omega, Y^T Y) with Omega generated in VMEM — one pass.

    The sketch width is held as a single block (``s_padded`` columns, no j
    grid axis), so the completed (bm x s_padded) block row of Y is resident
    when its Gram contribution is accumulated; sketch widths are small
    (s = k + oversampling), so this fits VMEM comfortably.  G is fp32 and
    includes padded columns (garbage that the wrapper slices off); logical
    entries are uncontaminated because padded A rows/cols are zero.
    """
    m, k = a.shape
    assert m % bm == 0 and k % bk == 0
    ni, nk = m // bm, k // bk
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(
        _sketch_gram_kernel, ni=ni, nk=nk, bk=bk, sp=s_padded, s=s, kind=kind
    )
    off = jnp.asarray(row_offset, jnp.uint32).reshape(1, 1)
    sd = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(ni, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
        ],
        out_specs=[
            pl.BlockSpec((bm, s_padded), lambda i, kk: (i, 0)),
            pl.BlockSpec((s_padded, s_padded), lambda i, kk: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, s_padded), out_dtype),
            jax.ShapeDtypeStruct((s_padded, s_padded), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, s_padded), jnp.float32),
            pltpu.VMEM((s_padded, s_padded), jnp.float32),
        ],
        interpret=interpret,
    )(off, sd, a)
