"""JIT'd public wrappers for the Pallas kernels: padding, dtype policy, and
interpret-mode selection (CPU container validates in interpret mode; on real
TPU the same call sites compile the kernels natively).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as _at
from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram
from repro.kernels import matmul as _mm
from repro.kernels import power_step as _ps
from repro.kernels import sketch_matmul as _sm
from repro.kernels import spmm_sketch as _spmm
from repro.kernels import trsm as _trsm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    # Kernels execute in interpret mode everywhere except real TPUs.
    return not _on_tpu()


def _backend_name() -> str:
    """Autotune-cache namespace: execution mode PLUS the device kind.

    The device kind matters on both sides of the split: "interpret:cpu"
    timings can't shadow real-TPU winners, and winners recorded on one TPU
    generation (v5e) can't shadow another (v6e) — different VMEM/MXU
    envelopes want different tiles."""
    kind = jax.devices()[0].device_kind.lower().replace(" ", "-")
    return ("tpu" if _on_tpu() else "interpret") + f":{kind}"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _block(dim: int, pref: int = 128) -> int:
    """Hardware-aligned block size: 128 where possible, else the padded dim."""
    return pref if dim >= pref else max(8, int(2 ** np.ceil(np.log2(max(dim, 1)))))


def _select_blocks(kernel: str, shape: tuple[int, ...], dtype) -> tuple[int, int, int]:
    """(bm, bn, bk) for a kernel call: the autotuner cache if it has an entry
    for this (shape-bucket, dtype, backend), else the 128 heuristic.

    Runs at trace time (pure Python over static shapes); `shape` is the
    logical problem shape (m, n, k) and tuned sizes are clamped per-dim so a
    cache entry recorded at a bigger bucket still yields a legal tiling.
    """
    m, n, k = shape
    tuned = _at.lookup(kernel, shape, jnp.dtype(dtype).name, _backend_name())
    if tuned is None:
        return _block(m), _block(n), _block(k)
    return _block(m, tuned.bm), _block(n, tuned.bn), _block(k, tuned.bk)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def matmul(x: jax.Array, y: jax.Array, out_dtype=None):
    """C = X @ Y via the tiled Pallas kernel (padded to MXU tiles)."""
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = _select_blocks("matmul", (m, n, k), x.dtype)
    xp = _pad_to(x, (bm, bk))
    yp = _pad_to(y, (bk, bn))
    out = _mm.matmul_padded(
        xp, yp, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype or x.dtype, interpret=_interpret(),
    )
    return out[:m, :n]


@functools.partial(
    jax.jit, static_argnames=("s", "kind", "out_dtype")
)
def sketch_matmul(
    a: jax.Array,
    s: int,
    seed=0,
    kind: str = "gaussian",
    out_dtype=None,
    row_offset=0,
):
    """C = A @ Omega[row_offset : row_offset + n, :s] with Omega generated
    inside the kernel.  ``row_offset=0`` is the monolithic sketch; a nonzero
    offset lets a column-panel of A consume its panel of the same logical
    Omega (blocked / out-of-core streaming).  ``row_offset`` AND ``seed``
    are traced (SMEM scalars) — panel streams, seed sweeps, and the batched
    vmap path all cost ONE kernel compile."""
    m, n = a.shape
    bm, bn, bk = _select_blocks("sketch_matmul", (m, s, n), a.dtype)
    bn = min(bn, _block(s))
    ap = _pad_to(a, (bm, bk))
    s_padded = s + (-s) % bn
    out = _sm.sketch_matmul_padded(
        ap, s, seed, s_padded=s_padded, kind=kind,
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype or a.dtype,
        interpret=_interpret(), row_offset=row_offset,
    )
    return out[:m, :s]


def spmm_blocks(shape: tuple[int, int], s: int, dtype) -> tuple[int, int]:
    """(bm, bk) tile shape for the block-ELL pack feeding `spmm_sketch`:
    the autotune cache's "spmm_sketch" entry for this (m, s, n) bucket if
    one exists (same `"<mode>:<device-kind>"` namespace as the dense
    kernels), else the 128-aligned heuristic.  Exposed separately because
    the PACK happens host-side in SparseOp, before any kernel call."""
    m, n = shape
    bm, _, bk = _select_blocks("spmm_sketch", (m, s, n), dtype)
    return bm, bk


@functools.partial(jax.jit, static_argnames=("s", "kind", "m", "out_dtype"))
def spmm_sketch(
    data: jax.Array,
    tilecols: jax.Array,
    s: int,
    seed=0,
    kind: str = "gaussian",
    *,
    m: int,
    out_dtype=None,
):
    """Y = A @ Omega for a block-ELL packed sparse A (`pack_block_ell`),
    with Omega tiles generated in VMEM per occupied tile — A's zero blocks
    are never read and Omega never exists in HBM.  ``m`` is the logical row
    count (the pack pads to block multiples); ``seed`` is traced."""
    sp = s + (-s) % _block(s)
    out = _spmm.spmm_sketch_padded(
        data, tilecols, s, seed, s_padded=sp, kind=kind,
        out_dtype=out_dtype or data.dtype, interpret=_interpret(),
    )
    return out[:m, :s]


@functools.partial(jax.jit, static_argnames=("s", "kind", "out_dtype"))
def sketch_gram(
    a: jax.Array,
    s: int,
    seed=0,
    kind: str = "gaussian",
    out_dtype=None,
    row_offset=0,
):
    """(Y, G) = (A @ Omega, Yᵀ Y) in ONE pass over A: the fused sketch with
    a Gram epilogue, so CholeskyQR's first Gram costs no extra pass over Y.
    G is fp32.  ``seed`` / ``row_offset`` are traced, as in `sketch_matmul`."""
    m, n = a.shape
    bm, _, bk = _select_blocks("sketch_gram", (m, s, n), a.dtype)
    ap = _pad_to(a, (bm, bk))
    s_padded = s + (-s) % _block(s)
    y, g = _sm.sketch_gram_padded(
        ap, s, seed, s_padded=s_padded, kind=kind,
        bm=bm, bk=bk, out_dtype=out_dtype or a.dtype,
        interpret=_interpret(), row_offset=row_offset,
    )
    return y[:m, :s], g[:s, :s]


@functools.partial(jax.jit, static_argnames=("s", "kind", "out_dtype"))
def sketch_power(
    a: jax.Array,
    s: int,
    seed=0,
    kind: str = "gaussian",
    out_dtype=None,
):
    """(Y, W, G) = (A @ Omega, Aᵀ Y, Yᵀ Y) in ONE pass over A: the fused
    RNG sketch through the power-step strip layout, so the stabilized
    one-pass range finder starts with W = AᵀY already accumulated."""
    m, n = a.shape
    bm, _, _ = _select_blocks("power_step", (m, n, s), a.dtype)
    nlane = _block(n)
    ap = _pad_to(a, (bm, nlane))
    sp = s + (-s) % _block(s)
    y, w, g = _ps.sketch_power_padded(
        ap, s, seed, s_padded=sp, kind=kind, bm=bm,
        out_dtype=out_dtype or a.dtype, interpret=_interpret(),
    )
    return y[:m, :s], w[:n, :s], g[:s, :s]


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def gram(y: jax.Array, out_dtype=jnp.float32):
    """G = Y^T Y via the symmetric (SYRK-style) kernel."""
    m, s = y.shape
    _, bs, bk = _select_blocks("gram", (s, s, m), y.dtype)
    bs = min(bs, _block(s))
    yp = _pad_to(y, (bk, bs))
    upper = _gram.gram_padded(yp, bs=bs, bk=bk, out_dtype=out_dtype, interpret=_interpret())
    full = _gram.symmetrize_upper(upper, bs=bs)
    return full[:s, :s]


@functools.partial(jax.jit, static_argnames=("with_gram", "out_dtype"))
def power_step(a: jax.Array, x: jax.Array, with_gram: bool = False, out_dtype=None):
    """(Y, Z[, G]) = (A @ X, Aᵀ @ Y[, Yᵀ Y]) — the fused two-sided power
    step: each A tile is read once per pass (see kernels/power_step.py).

    ``a`` is A (m x n, tall), ``x`` is X (n x s, sketch-width)."""
    m, n = a.shape
    _, s = x.shape
    bm, _, _ = _select_blocks("power_step", (m, n, s), a.dtype)
    sp = _block(s)
    nlane = _block(n)
    ap = _pad_to(a, (bm, nlane))
    xp = _pad_to(x, (nlane, sp))
    outs = _ps.power_step_padded(
        ap, xp, bm=bm, out_dtype=out_dtype or a.dtype,
        with_gram=with_gram, interpret=_interpret(),
    )
    if with_gram:
        y, z, g = outs
        return y[:m, :s], z[:n, :s], g[:s, :s]
    y, z = outs
    return y[:m, :s], z[:n, :s]


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def tri_solve_right(y: jax.Array, r: jax.Array, out_dtype=None):
    """Q = Y R⁻¹ for upper-triangular R via the tiled TRSM kernel
    (forward substitution over column blocks, inverted diagonal blocks)."""
    m, s = y.shape
    bm, bs, _ = _select_blocks("trsm", (m, s, s), y.dtype)
    bs = min(bs, _block(s))
    yp = _pad_to(y, (bm, bs))
    sp = yp.shape[1]
    rp = jnp.zeros((sp, sp), r.dtype).at[:s, :s].set(r)
    if sp > s:
        # identity on the padded diagonal keeps every block invertible
        pad_diag = jnp.arange(sp) >= s
        rp = rp + jnp.diag(pad_diag.astype(r.dtype))
    dinv = _trsm.invert_diag_blocks(rp, bs)
    q = _trsm.tri_solve_right_padded(
        yp, rp, dinv, bm=bm, bs=bs,
        out_dtype=out_dtype or y.dtype, interpret=_interpret(),
    )
    return q[:m, :s]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
):
    """Flash attention. q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D]."""
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    bq = _block(Tq)
    bk = _block(Tk)
    qp = _pad_to(q, (1, 1, bq, D))
    kp = _pad_to(k, (1, 1, bk, D))
    vp = _pad_to(v, (1, 1, bk, D))
    out = _fa.flash_attention_padded(
        qp, kp, vp, tq=Tq, tk=Tk, causal=causal, window=window,
        softcap=softcap, scale=scale, bq=bq, bk=bk, interpret=_interpret(),
    )
    return out[:, :, :Tq, :]
