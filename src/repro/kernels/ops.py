"""JIT'd public wrappers for the Pallas kernels: padding, dtype policy, and
interpret-mode selection (CPU container validates in interpret mode; on real
TPU the same call sites compile the kernels natively).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram
from repro.kernels import matmul as _mm
from repro.kernels import sketch_matmul as _sm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    # Kernels execute in interpret mode everywhere except real TPUs.
    return not _on_tpu()


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _block(dim: int, pref: int = 128) -> int:
    """Hardware-aligned block size: 128 where possible, else the padded dim."""
    return pref if dim >= pref else max(8, int(2 ** np.ceil(np.log2(max(dim, 1)))))


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def matmul(x: jax.Array, y: jax.Array, out_dtype=None):
    """C = X @ Y via the tiled Pallas kernel (padded to MXU tiles)."""
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = _block(m), _block(n), _block(k)
    xp = _pad_to(x, (bm, bk))
    yp = _pad_to(y, (bk, bn))
    out = _mm.matmul_padded(
        xp, yp, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype or x.dtype, interpret=_interpret(),
    )
    return out[:m, :n]


@functools.partial(
    jax.jit, static_argnames=("s", "seed", "kind", "out_dtype")
)
def sketch_matmul(
    a: jax.Array,
    s: int,
    seed: int = 0,
    kind: str = "gaussian",
    out_dtype=None,
    row_offset=0,
):
    """C = A @ Omega[row_offset : row_offset + n, :s] with Omega generated
    inside the kernel.  ``row_offset=0`` is the monolithic sketch; a nonzero
    offset lets a column-panel of A consume its panel of the same logical
    Omega (blocked / out-of-core streaming).  ``row_offset`` is traced —
    streaming p panels costs ONE kernel compile, not p."""
    m, n = a.shape
    bm, bk = _block(m), _block(n)
    bn = _block(s)
    ap = _pad_to(a, (bm, bk))
    s_padded = s + (-s) % bn
    out = _sm.sketch_matmul_padded(
        ap, s, seed, s_padded=s_padded, kind=kind,
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype or a.dtype,
        interpret=_interpret(), row_offset=row_offset,
    )
    return out[:m, :s]


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def gram(y: jax.Array, out_dtype=jnp.float32):
    """G = Y^T Y via the symmetric (SYRK-style) kernel."""
    m, s = y.shape
    bs, bk = _block(s), _block(m)
    yp = _pad_to(y, (bk, bs))
    upper = _gram.gram_padded(yp, bs=bs, bk=bk, out_dtype=out_dtype, interpret=_interpret())
    full = _gram.symmetrize_upper(upper, bs=bs)
    return full[:s, :s]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
):
    """Flash attention. q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D]."""
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    bq = _block(Tq)
    bk = _block(Tk)
    qp = _pad_to(q, (1, 1, bq, D))
    kp = _pad_to(k, (1, 1, bk, D))
    vp = _pad_to(v, (1, 1, bk, D))
    out = _fa.flash_attention_padded(
        qp, kp, vp, tq=Tq, tk=Tk, causal=causal, window=window,
        softcap=softcap, scale=scale, bq=bq, bk=bk, interpret=_interpret(),
    )
    return out[:, :, :Tq, :]
