"""Tiled right-triangular solve: Q = Y R⁻¹ for upper-triangular R (s x s).

This is the CholeskyQR "apply" step (BLAS TRSM, side=right).  The GPU-BLAS
formulation (also cuBLAS's): invert only the small diagonal blocks of R on
the host (s is the sketch width, so each block is a tiny triangular solve
against I), then the whole solve becomes a short sequence of GEMMs per row
strip — forward substitution over column blocks:

  Q_c = (Y_c − Σ_{c'<c} Q_{c'} R_{c',c}) · inv(R_{c,c})

Row strips are independent, so the grid is (i) over bm-row strips; R and
the inverted diagonal blocks have constant index maps (fetched once for the
whole grid).  The column-block loop is a static Python loop — the number of
column blocks is s_padded / bs, at most a handful for sketch widths.

Numerics: the diagonal blocks inherit R's conditioning (kappa(R) = kappa(Y)
for a CholeskyQR factor), so the block-inverse is as stable as the TRSM it
replaces at first order; CQR2's second pass restores O(eps) orthogonality
either way (Yamamoto et al. 2015).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _trsm_kernel(y_ref, r_ref, dinv_ref, q_ref, *, nc, bs):
    rf = r_ref[...].astype(jnp.float32)
    for c in range(nc):  # static: nc = s_padded // bs, small
        lo = c * bs
        acc = y_ref[:, lo : lo + bs].astype(jnp.float32)
        for cp in range(c):
            lo_p = cp * bs
            # Q blocks already written this grid step are VMEM-resident.
            acc -= jnp.dot(
                q_ref[:, lo_p : lo_p + bs].astype(jnp.float32),
                rf[lo_p : lo_p + bs, lo : lo + bs],
                preferred_element_type=jnp.float32,
            )
        qc = jnp.dot(
            acc,
            dinv_ref[c].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        q_ref[:, lo : lo + bs] = qc.astype(q_ref.dtype)


def invert_diag_blocks(r: jax.Array, bs: int) -> jax.Array:
    """[nc, bs, bs] inverses of R's diagonal blocks (host-side, tiny solves).

    R must be padded to a multiple of bs with IDENTITY on the padded
    diagonal (the ops.py wrapper does this) so every block is invertible.
    """
    s = r.shape[0]
    nc = s // bs
    blocks = jnp.stack([r[c * bs : (c + 1) * bs, c * bs : (c + 1) * bs] for c in range(nc)])
    eye = jnp.eye(bs, dtype=r.dtype)
    return jax.vmap(lambda b: jax.scipy.linalg.solve_triangular(b, eye, lower=False))(blocks)


def tri_solve_right_padded(
    y: jax.Array,
    r: jax.Array,
    dinv: jax.Array,
    *,
    bm: int = 128,
    bs: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Q = Y R⁻¹ for block-padded Y (m x s), upper-triangular R (s x s)."""
    m, s = y.shape
    assert m % bm == 0 and s % bs == 0 and r.shape == (s, s)
    nc = s // bs
    out_dtype = out_dtype or y.dtype
    kernel = functools.partial(_trsm_kernel, nc=nc, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, s), lambda i: (i, 0)),
            pl.BlockSpec((s, s), lambda i: (0, 0)),
            pl.BlockSpec((nc, bs, bs), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, s), out_dtype),
        interpret=interpret,
    )(y, r, dinv)
