"""Symmetric Gram matrix kernel: G = Y^T Y (the CholeskyQR hot spot).

Exploits symmetry: only output blocks with j >= i are computed on the MXU
(upper block triangle); lower blocks are written as zeros and the wrapper
reconstructs G = U + U^T - diag(diag(U)).  This halves the MXU work versus a
generic matmul — the SYRK-vs-GEMM trick of BLAS, restated for Pallas tiles.

Grid (i, j, kk) over (S/bs, S/bs, M/bk); the reduction over the tall
dimension m is innermost with a VMEM fp32 accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(yl_ref, yr_ref, o_ref, acc_ref, *, nk: int):
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j >= i)  # upper block-triangle only: SYRK saving
    def _mxu():
        acc_ref[...] += jnp.dot(
            yl_ref[...].astype(jnp.float32).T,
            yr_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(kk == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _left_index(i, j, kk):
    # Pruned (j < i) sweeps clamp the reduction index to 0 so the whole
    # skipped kk sweep maps onto ONE already-resident block: consecutive
    # grid steps with an unchanged block index issue no DMA, so the lower
    # triangle costs at most one fetch per (i, j) cell instead of nk.
    k_eff = jnp.where(j < i, 0, kk)
    return (k_eff, i)


def _right_index(i, j, kk):
    k_eff = jnp.where(j < i, 0, kk)
    j_eff = jnp.where(j < i, i, j)  # also pin the column: constant across the skipped prefix j = 0..i-1
    return (k_eff, j_eff)


def gram_padded(
    y: jax.Array,
    *,
    bs: int = 128,
    bk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Upper-triangular (block-wise) part of Y^T Y; wrapper symmetrizes."""
    m, s = y.shape
    assert m % bk == 0 and s % bs == 0
    nk = m // bk
    kernel = functools.partial(_gram_kernel, nk=nk)
    upper = pl.pallas_call(
        kernel,
        grid=(s // bs, s // bs, nk),
        in_specs=[
            # left operand: block column i of Y (transposed in-kernel)
            pl.BlockSpec((bk, bs), _left_index),
            # right operand: block column j of Y
            pl.BlockSpec((bk, bs), _right_index),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, s), out_dtype),
        scratch_shapes=[pltpu.VMEM((bs, bs), jnp.float32)],
        interpret=interpret,
    )(y, y)
    return upper


def symmetrize_upper(upper: jax.Array, bs: int = 128) -> jax.Array:
    """Reconstruct full G from the block-upper-triangular kernel output.

    Off-diagonal *blocks* below the diagonal are zero; diagonal blocks are
    full (they were computed entirely).  So G = U + U^T - D where D is the
    block-diagonal part (counted twice by U + U^T).
    """
    s = upper.shape[0]
    blk = jnp.arange(s) // bs
    block_diag_mask = blk[:, None] == blk[None, :]
    block_diag = jnp.where(block_diag_mask, upper, jnp.zeros((), upper.dtype))
    return upper + upper.T - block_diag
