"""Tiled MXU matmul — the BLAS-3 workhorse of the paper's reformulation.

Grid (i, j, kk) over (M/bm, N/bn, K/bk) with the reduction dimension
innermost; a VMEM fp32 accumulator is zeroed at kk == 0 and flushed to the
output block at the last kk step.  Block sizes default to 128 (MXU native
tile); callers pad via ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_padded(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = X @ Y for shapes already padded to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    out_dtype = out_dtype or x.dtype
    kernel = functools.partial(_matmul_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
