"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sketch_mod


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with fp32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def sketch_matmul_ref(
    a: jax.Array, s: int, seed: int, kind: str = "gaussian", out_dtype=None,
    row_offset: int = 0,
) -> jax.Array:
    """C = A @ Omega(n, s, seed) — Omega materialized (the kernel never does)."""
    out_dtype = out_dtype or a.dtype
    n = a.shape[1]
    omega = sketch_mod.sketch_matrix(
        n, s, seed, kind, dtype=jnp.float32, row_offset=row_offset
    )
    return jnp.matmul(
        a.astype(jnp.float32), omega, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def gram_ref(y: jax.Array, out_dtype=None) -> jax.Array:
    """G = Y^T Y with fp32 accumulation (symmetric output)."""
    out_dtype = out_dtype or y.dtype
    yf = y.astype(jnp.float32)
    return jnp.matmul(yf.T, yf, preferred_element_type=jnp.float32).astype(out_dtype)


def sketch_gram_ref(
    a: jax.Array, s: int, seed, kind: str = "gaussian", out_dtype=None,
    row_offset: int = 0,
):
    """(Y, G) oracle for the one-pass sketch+gram kernel: Y materialized via
    the jnp sketch, G = YᵀY in fp32."""
    y = sketch_matmul_ref(a, s, seed, kind, jnp.float32, row_offset)
    g = gram_ref(y, jnp.float32)
    return y.astype(out_dtype or a.dtype), g


def sketch_power_ref(
    a: jax.Array, s: int, seed, kind: str = "gaussian", out_dtype=None
):
    """(Y, W, G) = (A Ω, Aᵀ Y, Yᵀ Y) with Ω materialized — the one-pass
    sketch+power kernel's oracle."""
    out_dtype = out_dtype or a.dtype
    omega = sketch_mod.sketch_matrix(a.shape[1], s, seed, kind, dtype=jnp.float32)
    af = a.astype(jnp.float32)
    y = jnp.matmul(af, omega, preferred_element_type=jnp.float32)
    w = jnp.matmul(af.T, y, preferred_element_type=jnp.float32)
    g = jnp.matmul(y.T, y, preferred_element_type=jnp.float32)
    return y.astype(out_dtype), w.astype(out_dtype), g


def power_step_ref(a: jax.Array, x: jax.Array, with_gram: bool = False, out_dtype=None):
    """(Y, Z[, G]) = (A X, Aᵀ Y[, Yᵀ Y]) — the two unfused GEMMs the fused
    kernel replaces, fp32 accumulation throughout."""
    out_dtype = out_dtype or a.dtype
    af = a.astype(jnp.float32)
    y = jnp.matmul(af, x.astype(jnp.float32), preferred_element_type=jnp.float32)
    z = jnp.matmul(af.T, y, preferred_element_type=jnp.float32)
    if with_gram:
        g = jnp.matmul(y.T, y, preferred_element_type=jnp.float32)
        return y.astype(out_dtype), z.astype(out_dtype), g
    return y.astype(out_dtype), z.astype(out_dtype)


def tri_solve_right_ref(y: jax.Array, r: jax.Array, out_dtype=None) -> jax.Array:
    """Q = Y R⁻¹ via the LAPACK triangular solve (the TRSM kernel's oracle)."""
    out_dtype = out_dtype or y.dtype
    qt = jax.scipy.linalg.solve_triangular(
        r.T.astype(jnp.float32), y.T.astype(jnp.float32), lower=True
    )
    return qt.T.astype(out_dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference attention. q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D].

    GQA: Hq is a multiple of Hkv; query head h reads kv head h // (Hq//Hkv).
    window: local (sliding-window) attention of that many past positions.
    softcap: gemma2-style tanh logit soft-capping.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    Tk = k.shape[2]
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)  # right-aligned queries
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
