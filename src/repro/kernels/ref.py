"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sketch_mod


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with fp32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def sketch_matmul_ref(
    a: jax.Array, s: int, seed: int, kind: str = "gaussian", out_dtype=None,
    row_offset: int = 0,
) -> jax.Array:
    """C = A @ Omega(n, s, seed) — Omega materialized (the kernel never does)."""
    out_dtype = out_dtype or a.dtype
    n = a.shape[1]
    omega = sketch_mod.sketch_matrix(
        n, s, seed, kind, dtype=jnp.float32, row_offset=row_offset
    )
    return jnp.matmul(
        a.astype(jnp.float32), omega, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def gram_ref(y: jax.Array, out_dtype=None) -> jax.Array:
    """G = Y^T Y with fp32 accumulation (symmetric output)."""
    out_dtype = out_dtype or y.dtype
    yf = y.astype(jnp.float32)
    return jnp.matmul(yf.T, yf, preferred_element_type=jnp.float32).astype(out_dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference attention. q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D].

    GQA: Hq is a multiple of Hkv; query head h reads kv head h // (Hq//Hkv).
    window: local (sliding-window) attention of that many past positions.
    softcap: gemma2-style tanh logit soft-capping.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    Tk = k.shape[2]
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)  # right-aligned queries
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
