"""Fused two-sided power-step kernel: one pass over A per power iteration.

The unfused power iteration pays two full passes over the tall matrix per
step — ``Y = A @ X`` reads A (m·n), then ``Z = A.T @ Y`` reads A again.  Lu
et al. (arXiv:1706.07191) restructure the out-of-core block rSVD so every
pass over A does maximal work; this kernel is that idea on Pallas tiles:

  grid (i) over row strips of A (bm x n each).  Per strip:
    Y_i = A_i @ X            (bm x s)   — written to the Y output
    Z  += A_i^T @ Y_i        (n  x s)   — VMEM accumulator, flushed at the end
    G  += Y_i^T @ Y_i        (s  x s)   — optional Gram epilogue (free: Y_i
                                          is still VMEM-resident)

so each A tile is read ONCE and the step yields Y = A·X, Z = Aᵀ(A·X), and
(optionally) G = YᵀY.  The stabilized scheme consumes all three: with
CholeskyQR, Q = Y R⁻¹ means AᵀQ = Z R⁻¹ — Q never has to be re-multiplied
against A, and the first CQR Gram comes out of the epilogue.  The final
projection B = QᵀA = R⁻ᵀ Zᵀ also falls out of the last step's Z, so the
whole post-sketch rSVD does exactly one pass over A per power iteration.

HBM bytes per power step (fp32, the DESIGN.md §2 table):
  unfused   2·m·n + 3·m·s + 2·n·s      (two A passes + Y/Q round-trips)
  fused       m·n +   m·s + 2·n·s      (one A pass; G rides along)

VMEM working set per grid step: the (bm x n) A strip + X + the Z
accumulator (both n x s).  X and Z have constant index maps, so they are
fetched/flushed once for the whole grid, not per strip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Conservative per-core VMEM budget for the working-set guard below (real
# TPUs have ~16 MB; leave headroom for double buffering and the Y block).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def fused_power_vmem_bytes(n: int, s: int, bm: int = 128, dtype_bytes: int = 4) -> int:
    """Working-set estimate of one grid step: the (bm x n) A strip, the
    (n x s) X input block, the (n x s) Z accumulator + its output block,
    and the fp32 Y/G scratch.  Callers (core/rsvd.py) fall back to the
    unfused path when this exceeds VMEM_BUDGET_BYTES — interpret mode has
    no such limit, but the guard keeps the config-driven path honest about
    what compiles on real hardware; beyond it, the blocked/streaming or
    distributed paths are the intended scale-out."""
    strip = bm * n * dtype_bytes
    ns = n * s
    return strip + 3 * ns * 4 + bm * s * 4 + s * s * 4


def _power_step_kernel(a_ref, x_ref, y_ref, z_ref, zacc_ref, *, ni):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    af = a_ref[...].astype(jnp.float32)
    y = jnp.dot(af, x_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    zacc_ref[...] += jnp.dot(af.T, y, preferred_element_type=jnp.float32)

    @pl.when(i == ni - 1)
    def _flush():
        z_ref[...] = zacc_ref[...].astype(z_ref.dtype)


def _power_step_gram_kernel(a_ref, x_ref, y_ref, z_ref, g_ref, zacc_ref, gacc_ref, *, ni):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        zacc_ref[...] = jnp.zeros_like(zacc_ref)
        gacc_ref[...] = jnp.zeros_like(gacc_ref)

    af = a_ref[...].astype(jnp.float32)
    y = jnp.dot(af, x_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    zacc_ref[...] += jnp.dot(af.T, y, preferred_element_type=jnp.float32)
    gacc_ref[...] += jnp.dot(y.T, y, preferred_element_type=jnp.float32)

    @pl.when(i == ni - 1)
    def _flush():
        z_ref[...] = zacc_ref[...].astype(z_ref.dtype)
        g_ref[...] = gacc_ref[...].astype(g_ref.dtype)


def _sketch_power_kernel(
    seed_ref, a_ref, y_ref, z_ref, g_ref, omega_ref, zacc_ref, gacc_ref,
    *, ni, s, sp, kind,
):
    """power_step with X = Omega generated in VMEM from the counter RNG.

    Omega is generated ONCE (first grid step) into a persistent VMEM scratch
    and reused by every strip, so the sketch pass yields Y = A·Ω, W = AᵀY,
    and G = YᵀY from a single read of A — the stabilized fused path starts
    its first power iteration with W already in hand (reads of A for the
    whole rSVD: 1 + q, the DESIGN.md §2 claim)."""
    from repro.kernels.sketch_matmul import _omega_tile

    i = pl.program_id(0)
    n_p = omega_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        omega_ref[...] = _omega_tile(
            jnp.uint32(0), jnp.uint32(0), n_p, sp, s, seed_ref[0, 0], kind
        )
        zacc_ref[...] = jnp.zeros_like(zacc_ref)
        gacc_ref[...] = jnp.zeros_like(gacc_ref)

    af = a_ref[...].astype(jnp.float32)
    y = jnp.dot(af, omega_ref[...], preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    zacc_ref[...] += jnp.dot(af.T, y, preferred_element_type=jnp.float32)
    gacc_ref[...] += jnp.dot(y.T, y, preferred_element_type=jnp.float32)

    @pl.when(i == ni - 1)
    def _flush():
        z_ref[...] = zacc_ref[...].astype(z_ref.dtype)
        g_ref[...] = gacc_ref[...].astype(g_ref.dtype)


def sketch_power_padded(
    a: jax.Array,
    s: int,
    seed,
    *,
    s_padded: int,
    kind: str = "gaussian",
    bm: int = 128,
    out_dtype=None,
    interpret: bool = False,
):
    """(Y, W, G) = (A Ω, Aᵀ Y, Yᵀ Y) with Ω generated in VMEM — one pass.

    Padded Ω rows (>= n) produce finite garbage but multiply zero-padded A
    columns; padded Ω columns (>= s) produce garbage Y/W/G columns the
    wrapper slices off."""
    m, n = a.shape
    assert m % bm == 0
    ni = m // bm
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(
        _sketch_power_kernel, ni=ni, s=s, sp=s_padded, kind=kind
    )
    sd = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, s_padded), lambda i: (i, 0)),
            pl.BlockSpec((n, s_padded), lambda i: (0, 0)),
            pl.BlockSpec((s_padded, s_padded), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, s_padded), out_dtype),
            jax.ShapeDtypeStruct((n, s_padded), out_dtype),
            jax.ShapeDtypeStruct((s_padded, s_padded), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, s_padded), jnp.float32),
            pltpu.VMEM((n, s_padded), jnp.float32),
            pltpu.VMEM((s_padded, s_padded), jnp.float32),
        ],
        interpret=interpret,
    )(sd, a)


def power_step_padded(
    a: jax.Array,
    x: jax.Array,
    *,
    bm: int = 128,
    out_dtype=None,
    with_gram: bool = False,
    interpret: bool = False,
):
    """(Y, Z[, G]) = (A @ X, Aᵀ @ Y[, Yᵀ Y]) for block-padded A (m x n), X (n x s).

    One read of each A tile; Z and G live in VMEM accumulators across the
    whole strip grid and are flushed once.  Padded rows/cols of A are zero,
    so logical regions of Y/Z/G are uncontaminated (padding of X likewise
    must be zero — the ops.py wrapper guarantees it).
    """
    m, n = a.shape
    n2, s = x.shape
    assert n == n2 and m % bm == 0
    ni = m // bm
    out_dtype = out_dtype or a.dtype
    if with_gram:
        kernel = functools.partial(_power_step_gram_kernel, ni=ni)
        out_specs = [
            pl.BlockSpec((bm, s), lambda i: (i, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
            pl.BlockSpec((s, s), lambda i: (0, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((m, s), out_dtype),
            jax.ShapeDtypeStruct((n, s), out_dtype),
            jax.ShapeDtypeStruct((s, s), jnp.float32),
        ]
        scratch = [
            pltpu.VMEM((n, s), jnp.float32),
            pltpu.VMEM((s, s), jnp.float32),
        ]
    else:
        kernel = functools.partial(_power_step_kernel, ni=ni)
        out_specs = [
            pl.BlockSpec((bm, s), lambda i: (i, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((m, s), out_dtype),
            jax.ShapeDtypeStruct((n, s), out_dtype),
        ]
        scratch = [pltpu.VMEM((n, s), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(a, x)
