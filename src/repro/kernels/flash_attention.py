"""Flash attention (online-softmax) Pallas kernel for the LM substrate.

Supports the attention variants of every assigned architecture:
  * causal masking (decoder LMs) or full (encoder / whisper encoder),
  * GQA — Hq a multiple of Hkv, mapped in the k/v BlockSpec index_map
    (no jnp.repeat materialization),
  * sliding-window local attention (gemma2 local layers, recurrentgemma),
  * gemma2 tanh logit soft-capping.

Grid (bh, iq, kk) = (B*Hq, Tq/bq, Tk/bk); the key/value loop is innermost
with running (m, l, acc) streaming-softmax state in VMEM.  Causal/window
block skipping: key blocks entirely outside the visible band are skipped
before touching the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = np.float32(-1e30)


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    nk: int,
    bq: int,
    bk: int,
    tq: int,
    tk: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
):
    iq, kk = pl.program_id(1), pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Query rows are right-aligned against the key timeline (decode support).
    qpos0 = iq * bq + (tk - tq)
    kpos0 = kk * bk

    # Block-level visibility test (skip = no MXU work for this key block).
    visible = True
    if causal:
        visible = jnp.asarray(kpos0 <= qpos0 + bq - 1)
    else:
        visible = jnp.asarray(True)
    if window is not None:
        visible = jnp.logical_and(visible, kpos0 + bk - 1 > qpos0 - window)

    @pl.when(visible)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * np.float32(scale)  # (bq, bk)
        if softcap is not None:
            s = np.float32(softcap) * jnp.tanh(s / np.float32(softcap))

        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < tk  # key padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _flush():
        l = l_ref[...]
        safe_l = jnp.where(l > 0, l, 1.0)  # fully-masked (padded) query rows
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_padded(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    tq: int,
    tk: int,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, Hq, Tq_pad, D]; k, v: [B, Hkv, Tk_pad, D]; returns [B, Hq, Tq_pad, D].

    tq/tk are the VALID lengths (<= padded); padded keys are masked in-kernel,
    padded query rows produce zeros (caller slices them off).
    """
    B, Hq, Tqp, D = q.shape
    Hkv, Tkp = k.shape[1], k.shape[2]
    assert Tqp % bq == 0 and Tkp % bk == 0
    rep = Hq // Hkv
    nk = Tkp // bk
    scale = scale if scale is not None else 1.0 / float(np.sqrt(D))
    kernel = functools.partial(
        _flash_kernel,
        nk=nk,
        bq=bq,
        bk=bk,
        tq=tq,
        tk=tk,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
    )
    grid = (B * Hq, Tqp // bq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, bq, D), lambda bh, iq, kk: (bh // Hq, bh % Hq, iq, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda bh, iq, kk: (bh // Hq, (bh % Hq) // rep, kk, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda bh, iq, kk: (bh // Hq, (bh % Hq) // rep, kk, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, D), lambda bh, iq, kk: (bh // Hq, bh % Hq, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denominator
            pltpu.VMEM((bq, D), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
