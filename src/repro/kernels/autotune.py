"""Block-size autotuner for the Pallas kernels.

The kernels historically hard-coded 128 (the MXU-native tile).  That is the
right default, but the best (bm, bn, bk) depends on shape, dtype, and
backend (VMEM pressure vs. pipeline depth), so this module provides:

  * a persistent cache: JSON keyed by backend -> kernel -> (shape-bucket,
    dtype) -> {"bm": ..., "bn": ..., "bk": ...}, loaded lazily and
    consulted by ops.py on every wrapper call (trace-time, pure Python);
  * ``autotune(...)``: sweep candidate block sizes for a kernel closure,
    time each (wall clock, ``block_until_ready``), record the winner.

Shapes are bucketed to the next power of two per dimension so one sweep
covers a neighborhood of shapes instead of a single point.  Lookups happen
at jit TRACE time: results recorded after a shape/dtype has already been
traced do not retroactively retune live executables (run the sweep before
the hot loop, or clear jax's jit caches).

On the CPU container the kernels run in interpret mode, so recorded timings
are correctness-proxy numbers; the cache mechanics (bucketing, hit/miss,
JSON round-trip) are identical on real TPUs.  The backend namespace is
``"<mode>:<device-kind>"`` (ops._backend_name — e.g. "interpret:cpu",
"tpu:tpu-v5e"): the device kind is part of the bucket so CPU-interpret
timings can never shadow TPU winners, nor one TPU generation another.
Persistence is OPT-IN: nothing touches the filesystem unless a cache path
is given (or $REPRO_AUTOTUNE_CACHE is set).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Sequence

_ENV_VAR = "REPRO_AUTOTUNE_CACHE"

# In-memory table: {backend: {kernel: {bucket_key: {"bm":..,"bn":..,"bk":..}}}}
# Guarded by _lock: lookups happen at jit TRACE time, and the service traces
# from multiple worker threads concurrently (RLock because save() loads
# under the same lock).
_table: dict = {}
_loaded_from: str | None = None
_lock = threading.RLock()


@dataclass(frozen=True)
class BlockSizes:
    bm: int
    bn: int
    bk: int

    def astuple(self) -> tuple[int, int, int]:
        return (self.bm, self.bn, self.bk)


def shape_bucket(shape: Sequence[int]) -> tuple[int, ...]:
    """Round each dim up to the next power of two (1 stays 1)."""
    out = []
    for d in shape:
        b = 1
        while b < d:
            b *= 2
        out.append(b)
    return tuple(out)


def _bucket_key(shape: Sequence[int], dtype) -> str:
    return "x".join(str(d) for d in shape_bucket(shape)) + f"_{str(dtype)}"


def cache_path() -> str | None:
    return os.environ.get(_ENV_VAR) or None


def _ensure_loaded(path: str | None = None) -> None:
    global _loaded_from
    path = path or cache_path()
    with _lock:
        if path is None or _loaded_from == path:
            return
        if os.path.exists(path):
            with open(path) as f:
                loaded = json.load(f)
            for backend, kernels in loaded.items():
                dst = _table.setdefault(backend, {})
                for kernel, entries in kernels.items():
                    bucket = dst.setdefault(kernel, {})
                    for key, entry in entries.items():
                        # In-memory entries win: anything recorded this
                        # process (a fresh autotune sweep) is newer than
                        # the file.
                        bucket.setdefault(key, entry)
        _loaded_from = path


def save(path: str | None = None) -> str | None:
    """Persist the table; returns the path written (or None).

    Merges the existing file first (in-memory entries winning) so saving a
    sweep for one kernel never drops previously persisted entries for
    other kernels/shapes/backends."""
    path = path or cache_path()
    if path is None:
        return None
    with _lock:
        _ensure_loaded(path)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(_table, f, indent=1, sort_keys=True)
    return path


def clear() -> None:
    """Drop the in-memory table (tests; does not delete any JSON file)."""
    global _loaded_from
    with _lock:
        _table.clear()
        _loaded_from = None


def record(
    kernel: str,
    shape: Sequence[int],
    dtype,
    blocks: BlockSizes,
    backend: str,
    us: float | None = None,
) -> None:
    entry = {"bm": blocks.bm, "bn": blocks.bn, "bk": blocks.bk}
    if us is not None:
        entry["us"] = us
    with _lock:
        _table.setdefault(backend, {}).setdefault(kernel, {})[
            _bucket_key(shape, dtype)
        ] = entry


def lookup(
    kernel: str, shape: Sequence[int], dtype, backend: str
) -> BlockSizes | None:
    """Tuned block sizes for (kernel, shape-bucket, dtype, backend), or None."""
    _ensure_loaded()
    with _lock:
        entry = (
            _table.get(backend, {}).get(kernel, {})
            .get(_bucket_key(shape, dtype))
        )
    if entry is None:
        return None
    return BlockSizes(entry["bm"], entry["bn"], entry["bk"])


def autotune(
    kernel: str,
    run: Callable[[BlockSizes], object],
    shape: Sequence[int],
    dtype,
    backend: str,
    candidates: Iterable[tuple[int, int, int]] = ((128, 128, 128), (256, 128, 128), (128, 128, 256), (256, 256, 256)),
    reps: int = 1,
) -> BlockSizes:
    """Time ``run(blocks)`` for each candidate, record + return the winner.

    ``run`` must execute the kernel end-to-end and return a jax array (we
    block on it).  Candidates that raise (e.g. a block size exceeding the
    padded dim) are skipped; at least one must survive.
    """
    import jax

    best, best_t = None, float("inf")
    for cand in candidates:
        blocks = BlockSizes(*cand)
        try:
            jax.block_until_ready(run(blocks))  # compile + warm
            t0 = time.perf_counter()
            for _ in range(reps):
                out = run(blocks)
            jax.block_until_ready(out)
            t = (time.perf_counter() - t0) / reps
        except Exception:
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        raise ValueError(f"no candidate block size succeeded for {kernel} {shape}")
    record(kernel, shape, dtype, best, backend, us=best_t * 1e6)
    return best
