"""Fused RNG + SpMM sketch kernel: Y = A @ Omega for SPARSE A.

The dense fused sketch (kernels/sketch_matmul.py) reads every A element; for
a sparse A that wastes (1 - density) of the traffic.  Here A is packed once
into a block-ELL layout — for each bm-row block, the list of (bm x bk) tiles
that contain at least one nonzero, stored dense and zero-padded to the
longest list — and the kernel walks only those tiles.  The tile's matching
(bk x s) Omega slab is generated in VMEM from the SAME counter RNG as the
dense kernels (`_omega_tile`, bit-identical to core/sketch.py), keyed by the
tile's column id, so Omega never exists in HBM and A's zero blocks are never
read.

HBM traffic: ~nnz * (value + index) for A (plus block padding) + the m x s
output — the roofline model's `spmm_sketch_bytes` (repro/roofline/rsvd_model).
The pack is host-side numpy, cached per tile shape by SparseOp; matrices
whose padding would exceed the `max_fill` fraction of the dense footprint
are rejected (None) and take the materialized-Omega BCOO path instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sketch_matmul import _omega_tile


def pack_block_ell(bcoo, bm: int, bk: int, max_fill: float | None = None):
    """Pack a 2-D BCOO into block-ELL tiles for `spmm_sketch_padded`.

    Returns ``(data, tilecols)`` — data [nrb, nt, bm, bk] holds the dense
    tiles (zero-padded; nt = max occupied tiles over row blocks), tilecols
    [nrb, nt] int32 holds each tile's COLUMN-BLOCK id (padding slots point
    at block 0 with all-zero data, contributing nothing).  Returns None when
    the padded tile footprint exceeds ``max_fill * m * n`` — the matrix is
    too dense / too scattered for the tiled kernel to beat a dense read.

    Host-side numpy (runs once per (bm, bk), cached by SparseOp); duplicate
    coordinates are summed, out-of-range padding indices dropped.
    """
    m, n = bcoo.shape
    rows = np.asarray(bcoo.indices[:, 0], dtype=np.int64)
    cols = np.asarray(bcoo.indices[:, 1], dtype=np.int64)
    vals = np.asarray(bcoo.data)
    keep = (rows >= 0) & (rows < m) & (cols >= 0) & (cols < n)
    rows, cols, vals = rows[keep], cols[keep], vals[keep]

    nrb = -(-m // bm)
    ncb = -(-n // bk)
    rb, cb = rows // bm, cols // bk
    tile_id = rb * ncb + cb
    uniq, inv = np.unique(tile_id, return_inverse=True)
    uniq_rb = uniq // ncb
    counts = np.bincount(uniq_rb, minlength=nrb)
    nt = max(int(counts.max()) if uniq.size else 0, 1)
    if max_fill is not None and nrb * nt * bm * bk > max_fill * m * n:
        return None

    # slot of each occupied tile within its row block: uniq is sorted, so
    # tiles of one row block are contiguous — rank minus the block's start
    first = np.searchsorted(uniq_rb, np.arange(nrb), side="left")
    slot = np.arange(uniq.size) - first[uniq_rb]

    data = np.zeros((nrb, nt, bm, bk), dtype=vals.dtype)
    tilecols = np.zeros((nrb, nt), dtype=np.int32)
    tilecols[uniq_rb, slot] = (uniq % ncb).astype(np.int32)
    np.add.at(data, (rb, slot[inv], rows % bm, cols % bk), vals)
    return jnp.asarray(data), jnp.asarray(tilecols)


def _spmm_sketch_kernel(cols_ref, seed_ref, data_ref, o_ref, acc_ref,
                        *, nt, bk, sp, s, kind):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # this tile holds A columns [c*bk, (c+1)*bk) -> Omega rows of the same
    # range; generate that slab in VMEM, keyed by the prefetched tile id
    row0 = cols_ref[0, 0].astype(jnp.uint32) * np.uint32(bk)
    omega = _omega_tile(row0, jnp.uint32(0), bk, sp, s, seed_ref[0, 0], kind)
    acc_ref[...] += jnp.dot(
        data_ref[0, 0].astype(jnp.float32), omega,
        preferred_element_type=jnp.float32,
    )

    @pl.when(t == nt - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def spmm_sketch_padded(
    data: jax.Array,
    tilecols: jax.Array,
    s: int,
    seed,
    *,
    s_padded: int,
    kind: str = "gaussian",
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Y = A @ Omega over a block-ELL packed A (`pack_block_ell`).

    Grid (nrb, nt): row block i accumulates its nt tile products into a
    VMEM scratch and flushes once — Y's block row is written exactly once.
    `s` is the LOGICAL sketch width (the RNG flat index uses it, so results
    are independent of padding); columns >= s of the padded output are
    garbage the caller slices off.  Zero-padded tiles multiply a valid Omega
    slab by zeros, so they are numerically inert.  ``seed`` is a traced SMEM
    scalar — seed sweeps share one compiled program, as in the dense kernels.
    """
    nrb, nt, bm, bk = data.shape
    out_dtype = out_dtype or data.dtype
    kernel = functools.partial(
        _spmm_sketch_kernel, nt=nt, bk=bk, sp=s_padded, s=s, kind=kind
    )
    sd = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=(nrb, nt),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, t: (i, t), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, t: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bm, bk), lambda i, t: (i, t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, s_padded), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrb * bm, s_padded), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, s_padded), jnp.float32)],
        interpret=interpret,
    )(tilecols, sd, data)
