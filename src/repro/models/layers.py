"""Shared neural-net layers for every assigned architecture (pure JAX pytrees).

Conventions:
  * params are plain nested dicts of jnp arrays; every layer is a pair of
    (init_fn(key, ...) -> params, apply_fn(params, x, ...) -> y);
  * compute dtype follows the input; params are stored in the config dtype;
  * all matmul dims that shard over the 'model' mesh axis keep that axis
    LAST in the weight (d_in, d_out) so sharding rules stay uniform.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm_init(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layer_norm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., T, D] (D even); positions: [..., T] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


def sinusoidal_positions(T: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [T, d]."""
    pos = np.arange(T)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(table, dtype)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------

def mm(x: jax.Array, w) -> jax.Array:
    """Matmul that transparently consumes RSVD-factorized weights
    ({'lr_a': A, 'lr_b': B} from serve/lowrank.py): two skinny GEMMs."""
    if isinstance(w, dict) and "lr_a" in w:
        return (x @ w["lr_a"]) @ w["lr_b"]
    return x @ w


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "gelu_tanh":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def swiglu_init(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def swiglu(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    g = _act(act)(mm(x, params["w_gate"]))
    return mm(g * mm(x, params["w_up"]), params["w_down"])


def mlp_init(key, d: int, f: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, f, dtype), "w_out": dense_init(k2, f, d, dtype)}


def mlp(params: Params, x: jax.Array, act: str = "gelu") -> jax.Array:
    return mm(_act(act)(mm(x, params["w_in"])), params["w_out"])


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def causal_conv1d_init(key, width: int, channels: int, dtype) -> Params:
    return {
        "w": (jax.random.normal(key, (width, channels), jnp.float32) / np.sqrt(width)).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(params: Params, x: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: [B, T, C].

    Training: state=None, zero left-padding.
    Decode:   state is the last (width-1) inputs [B, width-1, C]; returns
              (y, new_state).
    """
    w = params["w"]
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : width - 1])
        xp = jnp.concatenate([pad, x], axis=1)
        y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(width))
        return y + params["b"], xp[:, -(width - 1) :] if width > 1 else None
    xp = jnp.concatenate([state, x], axis=1)  # [B, width-1+T, C]
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(width))
    return y + params["b"], xp[:, -(width - 1) :]


def unembed_logits(
    x: jax.Array,
    embed: jax.Array,
    head: jax.Array | None,
    cap: float | None,
    pad_to: int = 1,
):
    """Final logits; ties to the embedding when no separate head exists.

    When the vocab is not divisible by the model-parallel degree, `pad_to`
    pads the logits axis; padded ids are biased to -1e9 so softmax / argmax /
    sampling never see them, while the axis becomes shardable (the
    difference between a replicated 151k-vocab f32 logits tensor and a
    16-way-sharded one)."""
    w = embed.T if head is None else head
    v = w.shape[-1]
    pad = (-v) % pad_to
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    logits = x @ w
    if pad:
        bias = jnp.concatenate(
            [jnp.zeros((v,), logits.dtype), jnp.full((pad,), -1e9, logits.dtype)]
        )
        logits = logits + bias
    return softcap(logits, cap)
