"""Unified model entry points dispatching on config family."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models import vlm as V
from repro.models import whisper as W

Params = Dict[str, Any]


def init_model(cfg, key) -> Params:
    if cfg.is_encoder_decoder:
        return W.init_whisper(cfg, key)
    return T.init_lm(cfg, key)


def forward_model(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg,
    mode: str = "train",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {'tokens': [B, T]} plus modality extras.

    Returns (logits, aux).  Logits cover the positions that predict
    batch['labels'] (the trainer aligns them).
    """
    if cfg.is_encoder_decoder:
        return W.forward_whisper(params, batch["tokens"], batch["audio_features"], cfg, mode)
    if cfg.vision_stub:
        return V.forward_vlm(params, batch["tokens"], batch["vision_embeds"], cfg, mode)
    return T.forward_lm(params, batch["tokens"], cfg, mode=mode)


def abstract_params(cfg) -> Params:
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_model(cfg, k), jax.random.key(0))
