"""Mixture-of-Experts layer with sort-based capacity dispatch (EP-shardable).

Dispatch is the Megablocks-style sort route, NOT the GShard one-hot einsum:
the (tokens x experts x capacity) one-hot dispatch tensor at 32k tokens is
exactly the BLAS-1/2-shaped memory hog the paper teaches us to avoid.  Here:

  1. router top-k -> (token, expert) pairs, flattened to N*K entries;
  2. argsort by expert id -> contiguous runs per expert;
  3. position-in-run via cumsum; entries beyond capacity C are dropped;
  4. scatter into an [E, C, d] buffer — sharded over the 'model' (EP) axis,
     so under pjit the scatter lowers to an all-to-all;
  5. per-expert batched GEMMs [E, C, d] @ [E, d, f] — pure MXU work;
  6. gather back and combine with router weights.

Aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]


def moe_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    f = cfg.moe_d_ff_()
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": L.dense_init(ks[0], d, E, dtype, scale=0.02),
        "w_gate": (
            jax.random.normal(ks[1], (E, d, f), jnp.float32) / np.sqrt(d)
        ).astype(dtype),
        "w_up": (
            jax.random.normal(ks[2], (E, d, f), jnp.float32) / np.sqrt(d)
        ).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)
        ).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = L.swiglu_init(
            ks[4], d, f * cfg.num_shared_experts, dtype
        )
    return p


def moe_ffn(
    params: Params,
    x: jax.Array,  # [B, T, d]
    cfg,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    N = B * T
    xf = x.reshape(N, d)

    logits = (xf @ params["router"]).astype(jnp.float32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                # [N, K]
    if cfg.moe_renormalize:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- sort-based dispatch -------------------------------------------
    C = int(np.ceil(capacity_factor * N * K / E))
    C = max(C, 1)
    flat_e = top_e.reshape(-1)                            # [N*K]
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)

    order = jnp.argsort(flat_e, stable=True)              # runs per expert
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    # position within the expert run
    counts = jnp.bincount(flat_e, length=E)               # [E]
    run_start = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_run = jnp.arange(N * K, dtype=jnp.int32) - run_start[e_sorted].astype(jnp.int32)
    keep = pos_in_run < C                                 # capacity drop

    # scatter tokens into the [E, C, d] buffer (EP all-to-all under pjit)
    from repro.models.sharding_hints import BATCH, hint

    slot = e_sorted * C + jnp.where(keep, pos_in_run, 0)
    buf = jnp.zeros((E * C, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[tok_sorted], 0)
    # keep the (N*K, d) dispatch intermediates sharded over the DP axes —
    # without the hint SPMD replicates them (measured: the difference between
    # 113 GB/chip and fitting at train_4k for the MoE archs)
    contrib = hint(contrib, BATCH, None)
    buf = buf.at[slot].add(contrib)                       # unique slots when kept
    buf = hint(buf.reshape(E, C, d), "model", None, None)  # EP layout

    # ---- expert compute: batched GEMMs ---------------------------------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])  # [E, C, d]
    y = hint(y, "model", None, None)

    # ---- combine --------------------------------------------------------
    y_flat = y.reshape(E * C, d)
    gathered = y_flat[slot] * (w_sorted * keep)[:, None].astype(y_flat.dtype)
    gathered = hint(gathered, BATCH, None)
    out = jnp.zeros((N, d), y_flat.dtype).at[tok_sorted].add(gathered)
    out = out.reshape(B, T, d)

    if cfg.num_shared_experts > 0:
        out = out + L.swiglu(params["shared"], x)

    # ---- aux losses ------------------------------------------------------
    # load balance (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                          # mean router prob
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0
    )                                                      # fraction routed
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": dropped}
    return out, aux
