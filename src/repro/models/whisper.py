"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: `input_specs()` provides
precomputed frame embeddings [B, T_audio, d_model] (what the two conv layers
would emit).  The encoder is a bidirectional transformer; the decoder is the
unified stack with cross-attention, absolute sinusoidal positions, GELU MLPs
and LayerNorm (whisper's original choices).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T

Params = Dict[str, Any]


def init_whisper(cfg, key) -> Params:
    k_enc, k_dec = jax.random.split(key)
    dtype = cfg.param_dtype()
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers + 1)
    encoder = {
        "blocks": [
            T.block_init(enc_keys[i], cfg, "global", dtype) for i in range(cfg.encoder_layers)
        ],
        "final_norm": L.layer_norm_init(cfg.d_model, dtype)
        if cfg.norm_kind == "layer"
        else L.rms_norm_init(cfg.d_model, dtype),
    }
    decoder = T.init_lm(cfg, k_dec, cross_attn=True)
    return {"encoder": encoder, "decoder": decoder}


def encode(params: Params, audio_features: jax.Array, cfg) -> jax.Array:
    """audio_features: [B, T_audio, d_model] (frontend stub output)."""
    B, Ta, d = audio_features.shape
    x = audio_features + L.sinusoidal_positions(Ta, d, audio_features.dtype)[None]
    pos = jnp.arange(Ta, dtype=jnp.int32)
    for bp in params["encoder"]["blocks"]:
        x, _, _ = T.block_apply(bp, x, cfg, "global", positions=pos, mode="encode")
    norm = params["encoder"]["final_norm"]
    x = (
        L.layer_norm(norm, x, cfg.norm_eps)
        if cfg.norm_kind == "layer"
        else L.rms_norm(norm, x, cfg.norm_eps)
    )
    return x


def forward_whisper(
    params: Params,
    tokens: jax.Array,           # [B, T_text]
    audio_features: jax.Array,   # [B, T_audio, d_model]
    cfg,
    mode: str = "train",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc_out = encode(params, audio_features, cfg)
    x = T.embed_tokens(params["decoder"], tokens, cfg)
    Tt = x.shape[1]
    x = x + L.sinusoidal_positions(Tt, cfg.d_model, x.dtype)[None]
    pos = jnp.arange(Tt, dtype=jnp.int32)
    x, _, aux = T.apply_stack(
        params["decoder"], x, cfg, positions=pos, encoder_out=enc_out, mode=mode
    )
    return T.logits_from_hidden(params["decoder"], x, cfg), aux
