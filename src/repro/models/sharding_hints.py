"""Best-effort sharding hints, safe without a mesh.

`hint(x, specs...)` applies with_sharding_constraint iff an ambient mesh is
active (the dry-run / launcher `with mesh:` context) AND every requested
axis exists and divides the dimension; otherwise it's the identity — so the
same model code runs in single-device CPU tests and under the production
mesh.

The key hint is sequence sharding of the residual stream: activations carry
(batch=('pod','data'), seq='model') through the scanned stack, so the
remat-saved per-unit residual stack shrinks by the model-axis size (16x) —
the difference between fitting and not fitting HBM at train_4k for the
larger dense archs (measured in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import manual_axis_names


def current_mesh():
    from jax._src.mesh import thread_resources

    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def _usable_axes(mesh):
    """Mesh axes a with_sharding_constraint may mention: under shard_map the
    Manual axes (e.g. 'pod' in the podsgd step) must not appear in specs."""
    manual = manual_axis_names()
    return {n for n in mesh.axis_names if n not in manual}


def hint(x: jax.Array, *spec_axes) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    usable = _usable_axes(mesh)
    if len(spec_axes) < x.ndim:
        spec_axes = spec_axes + (None,) * (x.ndim - len(spec_axes))
    clean = []
    for dim, s in zip(x.shape, spec_axes):
        if s is None:
            clean.append(None)
            continue
        names = tuple(
            n for n in ((s,) if isinstance(s, str) else tuple(s)) if n in usable
        )
        size = math.prod(mesh.shape[n] for n in names) if names else 1
        clean.append(names if (names and dim % size == 0 and dim >= size) else None)
    if all(c is None for c in clean):
        return x
    return jax.lax.with_sharding_constraint(x, P(*clean))


BATCH = ("pod", "data")


def hint_residual(x: jax.Array, seq_shard: bool = True) -> jax.Array:
    """[B, T, d] residual stream: batch over DP axes, seq over 'model'."""
    return hint(x, BATCH, "model" if seq_shard else None, None)
