"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM (xLSTM).

All three expose (init, apply_train, apply_decode):
  * apply_train consumes a full sequence.  RG-LRU and mLSTM are linear (or
    linearizable) recurrences evaluated with jax.lax.associative_scan /
    masked-quadratic forms — log-depth, MXU/VPU friendly.  sLSTM has true
    hidden-to-hidden nonlinearity, so it scans sequentially (lax.scan); it is
    the minority block in the assigned xlstm-350m stack.
  * apply_decode consumes one token and a carried state — O(1) per step, the
    reason these archs run the long_500k cell.

Simplifications vs the exact papers are recorded in DESIGN.md:
  - RG-LRU gates are elementwise (diagonal) rather than block-diagonal dense;
  - mLSTM uses the stabilized parallel (quadratic masked) training form.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]

_C_RGLRU = 8.0  # Griffin's fixed exponent scale


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma recurrent block: conv1d + gated linear rec.)
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: jax.Array           # [B, R] recurrence state
    conv: jax.Array        # [B, width-1, R] conv tail


def rglru_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    r = cfg.lru_width_()
    ks = jax.random.split(key, 7)
    return {
        "w_x": L.dense_init(ks[0], d, r, dtype),
        "w_y": L.dense_init(ks[1], d, r, dtype),
        "conv": L.causal_conv1d_init(ks[2], cfg.conv1d_width, r, dtype),
        # elementwise gates
        "w_ig": (jax.random.normal(ks[3], (r,), jnp.float32) * 0.1).astype(dtype),
        "b_ig": jnp.zeros((r,), dtype),
        "w_rg": (jax.random.normal(ks[4], (r,), jnp.float32) * 0.1).astype(dtype),
        "b_rg": jnp.zeros((r,), dtype),
        # Lambda parametrized so a = sigmoid(lam)^(c*r_t) starts near 0.9-0.99
        "lam": (jnp.linspace(2.0, 5.0, r)).astype(dtype),
        "w_o": L.dense_init(ks[5], r, d, dtype),
    }


def _rglru_coeffs(params: Params, xc: jax.Array):
    """Per-step recurrence coefficients. xc: [..., R] (post-conv)."""
    xf = xc.astype(jnp.float32)
    rg = jax.nn.sigmoid(xf * params["w_rg"].astype(jnp.float32) + params["b_rg"].astype(jnp.float32))
    ig = jax.nn.sigmoid(xf * params["w_ig"].astype(jnp.float32) + params["b_ig"].astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    log_a = _C_RGLRU * rg * log_a_base          # a = sigmoid(lam)^(c*rg)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (ig * xf)
    return a, b


def rglru_train(params: Params, x: jax.Array, cfg, return_state: bool = False):
    """x: [B, T, d] -> [B, T, d] (optionally also the final recurrent state,
    used by the serve prefill to seed decoding)."""
    xb = x @ params["w_x"]                       # [B, T, R]
    yb = x @ params["w_y"]
    xc, conv_tail = L.causal_conv1d(params["conv"], xb)
    a, b = _rglru_coeffs(params, xc)             # [B, T, R] each

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (hh.astype(x.dtype) * jax.nn.gelu(yb)) @ params["w_o"]
    if return_state:
        return out, RGLRUState(h=hh[:, -1], conv=conv_tail)
    return out


def rglru_decode(
    params: Params, x: jax.Array, state: RGLRUState, cfg
) -> Tuple[jax.Array, RGLRUState]:
    """x: [B, 1, d]; O(1) step."""
    xb = x @ params["w_x"]
    yb = x @ params["w_y"]
    xc, conv_state = L.causal_conv1d(params["conv"], xb, state.conv)
    a, b = _rglru_coeffs(params, xc[:, 0])       # [B, R]
    h = a * state.h + b
    out = h[:, None].astype(x.dtype) * jax.nn.gelu(yb)
    return out @ params["w_o"], RGLRUState(h, conv_state)


def rglru_init_state(cfg, batch: int, dtype) -> RGLRUState:
    r = cfg.lru_width_()
    return RGLRUState(
        h=jnp.zeros((batch, r), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, r), dtype),
    )


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix-memory cell, stabilized)
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array   # [B, H, Dh, Dh] matrix memory
    n: jax.Array   # [B, H, Dh]     normalizer
    m: jax.Array   # [B, H]         stabilizer (log-scale)


def _mlstm_dims(cfg):
    inner = cfg.d_model * cfg.mlstm_proj_factor
    H = cfg.num_heads
    return inner, H, inner // H


def mlstm_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    inner, H, Dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        # xLSTM block: up-project, run the cell on `inner`, gate with z, down.
        "w_up": L.dense_init(ks[0], d, inner, dtype),
        "w_z": L.dense_init(ks[1], d, inner, dtype),
        "wq": L.dense_init(ks[2], inner, H * Dh, dtype),
        "wk": L.dense_init(ks[3], inner, H * Dh, dtype),
        "wv": L.dense_init(ks[4], inner, H * Dh, dtype),
        "w_i": L.dense_init(ks[5], inner, H, dtype, scale=0.02),
        "w_f": L.dense_init(ks[6], inner, H, dtype, scale=0.02),
        "b_f": jnp.full((H,), 3.0, dtype),  # forget-gate bias -> long memory
        "w_down": L.dense_init(ks[7], H * Dh, d, dtype),
    }


def mlstm_train_chunked(
    params: Params, x0: jax.Array, cfg, chunk: int = 2048, return_state: bool = False
):
    """Chunkwise-parallel mLSTM (flash-linear-attention style, stabilized).

    The masked-quadratic form materializes a T x T decay matrix — O(T^2)
    compute AND memory, hopeless at 32k+.  Chunkwise: carry the (C, n, m)
    recurrent state across chunks of length c; within a chunk use the local
    quadratic form plus the state contribution.  Cost: O(T*c + (T/c)*Dh^2)
    — at T=32k, c=2k this is 16x fewer FLOPs than quadratic, and the HLO is
    an unrolled python loop so the dry-run cost analysis counts every chunk
    (EXPERIMENTS.md §Perf hillclimb 'xlstm').
    """
    B, T, d = x0.shape
    if T <= chunk:
        return mlstm_train(params, x0, cfg, return_state=return_state)
    assert T % chunk == 0, (T, chunk)
    inner, H, Dh = _mlstm_dims(cfg)
    x = x0 @ params["w_up"]
    q = (x @ params["wq"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3).astype(jnp.float32)
    k = ((x @ params["wk"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3) / np.sqrt(Dh)).astype(jnp.float32)
    v = (x @ params["wv"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3).astype(jnp.float32)
    i_pre = (x @ params["w_i"]).astype(jnp.float32).transpose(0, 2, 1)   # [B,H,T]
    f_pre = (x @ params["w_f"] + params["b_f"]).astype(jnp.float32).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(f_pre)

    n_chunks = T // chunk
    state = mlstm_init_state(cfg, B)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    outs = []
    for j in range(n_chunks):
        sl = slice(j * chunk, (j + 1) * chunk)
        qj, kj, vj = q[:, :, sl], k[:, :, sl], v[:, :, sl]
        ij, lfj = i_pre[..., sl], log_f[..., sl]
        F = jnp.cumsum(lfj, axis=-1)                        # local decay prefix
        # log weight of in-chunk source s for query t: F_t - F_s + i_s
        logD = F[..., :, None] - F[..., None, :] + ij[..., None, :]
        logD = jnp.where(mask[None, None], logD, -jnp.inf)
        # incoming-state coefficient for query t: F_t + m_prev
        c_in = F + state.m[..., None]                       # [B,H,c]
        m_t = jnp.maximum(jnp.max(logD, axis=-1), c_in)
        Dmat = jnp.exp(logD - m_t[..., None])
        w_in = jnp.exp(c_in - m_t)                          # [B,H,c]

        s_qk = jnp.einsum("bhqd,bhkd->bhqk", qj, kj)
        num = jnp.einsum("bhqk,bhkv->bhqv", s_qk * Dmat, vj) + w_in[..., None] * jnp.einsum(
            "bhvk,bhqk->bhqv", state.C, qj
        )
        den = jnp.sum(s_qk * Dmat, axis=-1) + w_in * jnp.einsum("bhk,bhqk->bhq", state.n, qj)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        outs.append(num / den[..., None])

        # end-of-chunk state update (same algebra as the prefill hand-off)
        c_end = F[..., -1:] - F + ij                        # [B,H,c]
        m_new = jnp.maximum(F[..., -1] + state.m, jnp.max(c_end, axis=-1))
        wgt = jnp.exp(c_end - m_new[..., None])
        carry_scale = jnp.exp(F[..., -1] + state.m - m_new)
        C_new = carry_scale[..., None, None] * state.C + jnp.einsum(
            "bht,bhtv,bhtk->bhvk", wgt, vj, kj
        )
        n_new = carry_scale[..., None] * state.n + jnp.einsum("bht,bhtk->bhk", wgt, kj)
        state = MLSTMState(C=C_new, n=n_new, m=m_new)

    h = jnp.concatenate(outs, axis=2)                       # [B,H,T,Dh]
    z = jax.nn.silu((x0 @ params["w_z"]).astype(jnp.float32)).reshape(
        B, T, H, Dh
    ).transpose(0, 2, 1, 3)
    out = (h * z).transpose(0, 2, 1, 3).reshape(B, T, H * Dh).astype(x0.dtype)
    out = out @ params["w_down"]
    if return_state:
        return out, state
    return out


def mlstm_train(params: Params, x0: jax.Array, cfg, return_state: bool = False):
    """Stabilized parallel (masked quadratic) form. x0: [B, T, d]."""
    x = x0 @ params["w_up"]                               # [B, T, inner]
    B, T, _ = x.shape
    inner, H, Dh = _mlstm_dims(cfg)
    q = (x @ params["wq"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3) / np.sqrt(Dh)
    v = (x @ params["wv"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    i_pre = (x @ params["w_i"]).astype(jnp.float32).transpose(0, 2, 1)          # [B,H,T]
    f_pre = (x @ params["w_f"] + params["b_f"]).astype(jnp.float32).transpose(0, 2, 1)

    log_f = jax.nn.log_sigmoid(f_pre)                     # [B, H, T]
    F = jnp.cumsum(log_f, axis=-1)                        # prefix sums
    # log D_ij = F_i - F_j + i_pre_j   for j <= i
    logD = F[..., :, None] - F[..., None, :] + i_pre[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    logD = jnp.where(mask[None, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=-1)                            # [B, H, T] stabilizer
    m = jnp.maximum(m, 0.0)
    Dmat = jnp.exp(logD - m[..., None])

    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    w = s * Dmat
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1)), jnp.exp(-m))
    h = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)) / norm[..., None]

    z = jax.nn.silu((x0 @ params["w_z"]).astype(jnp.float32)).reshape(
        B, T, H, Dh
    ).transpose(0, 2, 1, 3)
    out = (h * z).transpose(0, 2, 1, 3).reshape(B, T, H * Dh).astype(x0.dtype)
    out = out @ params["w_down"]
    if not return_state:
        return out
    # Final recurrent state for decode hand-off: with c_j = sum_{k>j} log f_k
    # + i_j, the running stabilizer satisfies m_T = max_j c_j, and
    # C = sum_j e^{c_j - m_T} v_j k_j^T,  n = sum_j e^{c_j - m_T} k_j.
    c = F[..., -1:] - F + i_pre                       # [B, H, T]
    # decode recurrence starts at m_0 = 0, so the F_T term participates
    m_T = jnp.maximum(jnp.max(c, axis=-1), F[..., -1])  # [B, H]
    wgt = jnp.exp(c - m_T[..., None])                  # [B, H, T]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = jnp.einsum("bht,bhtv,bhtk->bhvk", wgt, vf, kf)
    n = jnp.einsum("bht,bhtk->bhk", wgt, kf)
    return out, MLSTMState(C=C, n=n, m=m_T)


def mlstm_decode(
    params: Params, x0: jax.Array, state: MLSTMState, cfg
) -> Tuple[jax.Array, MLSTMState]:
    """x0: [B, 1, d]; recurrent O(1) step with matrix memory."""
    B = x0.shape[0]
    inner, H, Dh = _mlstm_dims(cfg)
    xt = (x0 @ params["w_up"])[:, 0]                      # [B, inner]
    q = (xt @ params["wq"]).reshape(B, H, Dh).astype(jnp.float32)
    k = ((xt @ params["wk"]).reshape(B, H, Dh) / np.sqrt(Dh)).astype(jnp.float32)
    v = (xt @ params["wv"]).reshape(B, H, Dh).astype(jnp.float32)
    i_pre = (xt @ params["w_i"]).astype(jnp.float32)             # [B, H]
    f_pre = (xt @ params["w_f"] + params["b_f"]).astype(jnp.float32)

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    f_sc = jnp.exp(log_f + state.m - m_new)[..., None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]

    C = f_sc[..., None] * state.C + i_sc[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_sc * state.n + i_sc * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]

    z = jax.nn.silu((x0[:, 0] @ params["w_z"]).astype(jnp.float32)).reshape(B, H, Dh)
    out = (h * z).reshape(B, H * Dh).astype(x0.dtype)[:, None]
    return out @ params["w_down"], MLSTMState(C, n, m_new)


def mlstm_init_state(cfg, batch: int) -> MLSTMState:
    _, H, Dh = _mlstm_dims(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        n=jnp.zeros((batch, H, Dh), jnp.float32),
        m=jnp.zeros((batch, H), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, exponential gating, true recurrence)
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, Dh]
    n: jax.Array  # [B, H, Dh]
    h: jax.Array  # [B, H, Dh]
    m: jax.Array  # [B, H, Dh]


def slstm_init(key, cfg, dtype) -> Params:
    d, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim_()
    ks = jax.random.split(key, 6)
    return {
        "w_z": L.dense_init(ks[0], d, H * Dh, dtype),
        "w_i": L.dense_init(ks[1], d, H * Dh, dtype, scale=0.02),
        "w_f": L.dense_init(ks[2], d, H * Dh, dtype, scale=0.02),
        "w_og": L.dense_init(ks[3], d, H * Dh, dtype, scale=0.02),
        "b_f": jnp.full((H * Dh,), 3.0, dtype),
        # per-head recurrent mixing (block-diagonal hidden-to-hidden)
        "r_z": (jax.random.normal(ks[4], (H, Dh, Dh), jnp.float32) / np.sqrt(Dh)).astype(dtype),
        "w_o": L.dense_init(ks[5], H * Dh, d, dtype),
    }


def _slstm_step(params: Params, cfg, state: SLSTMState, xt: jax.Array):
    """xt: [B, d] one timestep. True sequential recurrence."""
    B = xt.shape[0]
    H, Dh = cfg.num_heads, cfg.head_dim_()
    rec = jnp.einsum("bhd,hde->bhe", state.h.astype(jnp.float32), params["r_z"].astype(jnp.float32))
    z_pre = (xt @ params["w_z"]).astype(jnp.float32).reshape(B, H, Dh) + rec
    i_pre = (xt @ params["w_i"]).astype(jnp.float32).reshape(B, H, Dh)
    f_pre = (xt @ params["w_f"] + params["b_f"]).astype(jnp.float32).reshape(B, H, Dh)
    o_pre = (xt @ params["w_og"]).astype(jnp.float32).reshape(B, H, Dh)

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + state.m - m_new)
    z = jnp.tanh(z_pre)
    c = f_sc * state.c + i_sc * z
    n = jnp.maximum(f_sc * state.n + i_sc, 1e-6)
    h = jax.nn.sigmoid(o_pre) * (c / n)
    return SLSTMState(c, n, h, m_new)


def slstm_train(params: Params, x: jax.Array, cfg, return_state: bool = False):
    """Sequential lax.scan over time (the honest sLSTM)."""
    B, T, d = x.shape
    H, Dh = cfg.num_heads, cfg.head_dim_()
    s0 = slstm_init_state(cfg, B)

    def step(state, xt):
        new = _slstm_step(params, cfg, state, xt)
        return new, new.h

    final, hs = jax.lax.scan(step, s0, x.transpose(1, 0, 2))
    out = hs.transpose(1, 0, 2, 3).reshape(B, T, H * Dh).astype(x.dtype)
    out = out @ params["w_o"]
    if return_state:
        return out, final
    return out


def slstm_decode(
    params: Params, x: jax.Array, state: SLSTMState, cfg
) -> Tuple[jax.Array, SLSTMState]:
    new = _slstm_step(params, cfg, state, x[:, 0])
    B = x.shape[0]
    H, Dh = cfg.num_heads, cfg.head_dim_()
    out = new.h.reshape(B, H * Dh).astype(x.dtype)[:, None]
    return out @ params["w_o"], new


def slstm_init_state(cfg, batch: int) -> SLSTMState:
    H, Dh = cfg.num_heads, cfg.head_dim_()
    z = jnp.zeros((batch, H, Dh), jnp.float32)
    return SLSTMState(c=z, n=jnp.ones_like(z) * 1e-6, h=z, m=z)
