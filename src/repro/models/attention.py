"""Attention layers: GQA (with qk-norm, soft-capping, sliding window) and
DeepSeek-style MLA (multi-head latent attention).

The inner product is computed by `chunked_attention` — a pure-jnp
online-softmax streamed over key/value chunks (Rabe & Staats).  It is
differentiable (training path) and memory-O(T * chunk); the Pallas flash
kernel (kernels/flash_attention.py) implements the same math for TPU
forward-only paths and is cross-checked against this in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]


class KVCache(NamedTuple):
    k: jax.Array  # [B, Hkv, Tmax, Dh]
    v: jax.Array  # [B, Hkv, Tmax, Dh]
    length: jax.Array  # scalar int32 — valid prefix


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (differentiable reference-grade impl)
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,               # [B, Hq, Tq, D]
    k: jax.Array,               # [B, Hkv, Tk, D]
    v: jax.Array,               # [B, Hkv, Tk, Dv]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    kv_valid_len: jax.Array | None = None,
    chunk: int = 1024,
    remat_chunks: bool = True,
) -> jax.Array:
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = Hq // Hkv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(D))
    chunk = min(chunk, Tk)
    n_chunks = (Tk + chunk - 1) // chunk
    Tk_pad = n_chunks * chunk
    if Tk_pad != Tk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tk_pad - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tk_pad - Tk), (0, 0)))
    valid = kv_valid_len if kv_valid_len is not None else jnp.asarray(Tk, jnp.int32)

    qf = q.astype(jnp.float32) * np.float32(scale)
    # fold GQA: [B, Hkv, rep, Tq, D]
    qf = qf.reshape(B, Hkv, rep, Tq, D)
    kc = k.astype(jnp.float32).reshape(B, Hkv, n_chunks, chunk, D)
    vc = v.astype(jnp.float32).reshape(B, Hkv, n_chunks, chunk, Dv)

    qpos = jnp.arange(Tq, dtype=jnp.int32) + (valid - Tq)  # right-aligned

    def body(carry, kb, vb, idx):
        m, l, acc = carry
        s = jnp.einsum("bhrqd,bhkd->bhrqk", qf, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = idx * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = kpos[None, :] < valid
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: rows with all -inf so far
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
        alpha = jnp.where(jnp.isinf(m), 0.0, alpha)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum("bhrqk,bhkv->bhrqv", p, vb)
        return (m_new, l_new, acc_new)

    # Per-chunk remat: without it, backward saves the (n_chunks, B, H, Tq,
    # chunk) attention weights — the exact quadratic buffer chunking exists
    # to avoid (measured: 2.1 GB/layer/device at 4k train).  With it, each
    # chunk's s/p are recomputed in backward: the flash-backward pattern.
    #
    # The chunk loop is UNROLLED (python loop), not lax.scan: XLA's cost
    # analysis counts a while body once, which under-reports attention FLOPs
    # by n_chunks, and unrolling also lets the scheduler overlap chunk
    # compute with the k/v loads of the next chunk.
    if remat_chunks:
        body = jax.checkpoint(body, prevent_cse=False)

    m0 = jnp.full((B, Hkv, rep, Tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Tq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, rep, Tq, Dv), jnp.float32)
    carry = (m0, l0, acc0)
    for j in range(n_chunks):
        carry = body(carry, kc[:, :, j], vc[:, :, j], jnp.asarray(j, jnp.int32))
    m, l, acc = carry
    l_safe = jnp.where(l > 0, l, 1.0)
    out = (acc / l_safe[..., None]).reshape(B, Hq, Tq, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_()
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": L.dense_init(ks[0], d, H * Dh, dtype),
        "wk": L.dense_init(ks[1], d, Hkv * Dh, dtype),
        "wv": L.dense_init(ks[2], d, Hkv * Dh, dtype),
        "wo": L.dense_init(ks[3], H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rms_norm_init(Dh, dtype)
        p["k_norm"] = L.rms_norm_init(Dh, dtype)
    return p


def gqa_attention(
    params: Params,
    x: jax.Array,                     # [B, T, d]
    cfg,
    *,
    positions: jax.Array,             # [T] or [B, T]
    window: int | None = None,
    cache: Optional[KVCache] = None,
    causal: bool = True,
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[KVCache]]:
    B, T, d = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_()
    # Un-shard the sequence ONCE here: otherwise each of the q/k/v projections
    # all-gathers the seq-sharded x independently (3x the gather bytes).
    from repro.models.sharding_hints import BATCH, hint

    x = hint(x, BATCH, None, None)
    q = (x @ params["wq"]).reshape(B, T, H, Dh)
    k = (x @ params["wk"]).reshape(B, T, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, T, Hkv, Dh)
    if cfg.qk_norm:
        q = L.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = L.rms_norm(params["k_norm"], k, cfg.norm_eps)
    pos = positions if positions.ndim == 2 else positions[None, :]
    q = q.transpose(0, 2, 1, 3)  # [B, H, T, Dh]
    k = k.transpose(0, 2, 1, 3)
    if use_rope:
        q = L.rope(q, pos[:, None, :], cfg.rope_theta)
        k = L.rope(k, pos[:, None, :], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    # Head-sharded attention layout: with a sequence-sharded residual stream
    # XLA otherwise carries T-sharding into k/v and then all-gathers FULL-head
    # k/v chunks inside the attention loop (measured 1.6 GB/unit vs 0.4 GB for
    # gathering the heads-sharded layout once) — EXPERIMENTS.md §Perf.
    q = hint(q, BATCH, "model", None, None)
    k = hint(k, BATCH, "model", None, None)
    v = hint(v, BATCH, "model", None, None)

    new_cache = None
    if cache is not None:
        kf = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, cache.length, 0))
        vf = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, cache.length, 0))
        new_cache = KVCache(kf, vf, cache.length + T)
        k_att, v_att = kf, vf
        valid = cache.length + T
    else:
        k_att, v_att = k, v
        valid = None

    out = chunked_attention(
        q,
        k_att,
        v_att,
        causal=causal,
        window=window,
        softcap=cfg.attn_softcap,
        kv_valid_len=valid,
        scale=cfg.attn_scale_(),
        chunk=cfg.attn_chunk,
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * Dh)
    return L.mm(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — low-rank latent KV; the cache stores only the latent.
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array    # [B, Tmax, kv_lora]
    k_rope: jax.Array  # [B, Tmax, rope_dim]
    length: jax.Array


def mla_init(key, cfg, dtype) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], d, H * (nope + rdim), dtype),
        "w_dkv": L.dense_init(ks[1], d, lora + rdim, dtype),
        "kv_norm": L.rms_norm_init(lora, dtype),
        "w_uk": L.dense_init(ks[2], lora, H * nope, dtype),
        "w_uv": L.dense_init(ks[3], lora, H * vdim, dtype),
        "wo": L.dense_init(ks[4], H * vdim, d, dtype),
    }


def mla_attention_absorbed(
    params: Params,
    x: jax.Array,                    # [B, 1, d] — decode only
    cfg,
    *,
    positions: jax.Array,
    cache: MLACache,
) -> Tuple[jax.Array, MLACache]:
    """Decode-time MLA with weight absorption (DeepSeek-V2 §'absorb').

    The naive decode path re-up-projects the ENTIRE latent cache to per-head
    k/v every step: O(T * lora * H * nope) FLOPs + the collectives to
    redistribute them (measured: the most collective-bound cell of the
    baseline sweep).  Absorption folds w_uk into the query and w_uv into the
    output: attention runs directly against the (B, T, lora) latent —
    per-step cost drops by ~nope x and no cache-wide tensor is ever built.
    """
    B, T1, d = x.shape
    assert T1 == 1
    H = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank

    q = (x @ params["wq"]).reshape(B, 1, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos = positions if positions.ndim == 2 else positions[None, :]
    q_rope = L.rope(q_rope.transpose(0, 2, 1, 3), pos[:, None, :], cfg.rope_theta)  # [B,H,1,r]

    # new token's latent entry
    dkv = x @ params["w_dkv"]
    c_new = L.rms_norm(params["kv_norm"], dkv[..., :lora], cfg.norm_eps)
    k_rope_new = L.rope(
        dkv[..., None, lora:].transpose(0, 2, 1, 3), pos[:, None, :], cfg.rope_theta
    )[:, 0]
    c_full = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, cache.length, 0)
    )
    r_full = jax.lax.dynamic_update_slice(
        cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, cache.length, 0)
    )
    new_cache = MLACache(c_full, r_full, cache.length + 1)
    valid = cache.length + 1
    Tk = c_full.shape[1]

    # absorb w_uk into q: q_abs[b,h,l] = sum_n q_nope[b,h,n] * w_uk[l, h, n]
    w_uk = params["w_uk"].reshape(lora, H, nope)
    q_abs = jnp.einsum(
        "bhn,lhn->bhl", q_nope[:, 0].astype(jnp.float32), w_uk.astype(jnp.float32)
    )
    cf = c_full.astype(jnp.float32)
    scores = jnp.einsum("bhl,btl->bht", q_abs, cf)
    scores = scores + jnp.einsum(
        "bhr,btr->bht", q_rope[:, :, 0].astype(jnp.float32), r_full.astype(jnp.float32)
    )
    scores = scores / np.float32(np.sqrt(nope + rdim))
    mask = jnp.arange(Tk, dtype=jnp.int32)[None, None, :] < valid
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bht,btl->bhl", probs, cf)             # attend in latent space
    w_uv = params["w_uv"].reshape(lora, H, vdim)
    out = jnp.einsum("bhl,lhv->bhv", ctx, w_uv.astype(jnp.float32))  # absorb w_uv
    out = out.reshape(B, 1, H * vdim).astype(x.dtype)
    return L.mm(out, params["wo"]), new_cache


def mla_attention(
    params: Params,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    cache: Optional[MLACache] = None,
) -> Tuple[jax.Array, Optional[MLACache]]:
    B, T, d = x.shape
    H = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank

    q = (x @ params["wq"]).reshape(B, T, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos = positions if positions.ndim == 2 else positions[None, :]
    q_rope = L.rope(q_rope.transpose(0, 2, 1, 3), pos[:, None, :], cfg.rope_theta)

    dkv = x @ params["w_dkv"]                      # [B, T, lora + rdim]
    c_kv = L.rms_norm(params["kv_norm"], dkv[..., :lora], cfg.norm_eps)
    k_rope = L.rope(dkv[..., None, lora:].transpose(0, 2, 1, 3), pos[:, None, :], cfg.rope_theta)[
        :, 0
    ]  # [B, T, rdim] — single shared rope head

    new_cache = None
    if cache is not None:
        c_full = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache.length, 0)
        )
        r_full = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache.length, 0)
        )
        new_cache = MLACache(c_full, r_full, cache.length + T)
        c_att, r_att = c_full, r_full
        valid = cache.length + T
    else:
        c_att, r_att = c_kv, k_rope
        valid = None

    Tk = c_att.shape[1]
    # Up-project latent -> per-head keys/values (recomputed; cache stays tiny).
    k_nope = (c_att @ params["w_uk"]).reshape(B, Tk, H, nope).transpose(0, 2, 1, 3)
    vv = (c_att @ params["w_uv"]).reshape(B, Tk, H, vdim).transpose(0, 2, 1, 3)
    k_rope_h = jnp.broadcast_to(r_att[:, None], (B, H, Tk, rdim))

    qq = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], axis=-1)
    kk = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = chunked_attention(
        qq,
        kk,
        vv,
        causal=True,
        kv_valid_len=valid,
        scale=1.0 / float(np.sqrt(nope + rdim)),
        chunk=cfg.attn_chunk,
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * vdim)
    return L.mm(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype) -> Params:
    d, H, Dh = cfg.d_model, cfg.num_heads, cfg.head_dim_()
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, H * Dh, dtype),
        "wk": L.dense_init(ks[1], d, H * Dh, dtype),
        "wv": L.dense_init(ks[2], d, H * Dh, dtype),
        "wo": L.dense_init(ks[3], H * Dh, d, dtype),
    }


def cross_attention(params: Params, x: jax.Array, enc: jax.Array, cfg) -> jax.Array:
    B, T, d = x.shape
    Te = enc.shape[1]
    H, Dh = cfg.num_heads, cfg.head_dim_()
    q = (x @ params["wq"]).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    k = (enc @ params["wk"]).reshape(B, Te, H, Dh).transpose(0, 2, 1, 3)
    v = (enc @ params["wv"]).reshape(B, Te, H, Dh).transpose(0, 2, 1, 3)
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return out.transpose(0, 2, 1, 3).reshape(B, T, H * Dh) @ params["wo"]
