"""Unified decoder stack covering all ten assigned architectures.

Layer kinds (cfg.block_pattern):
  'global'  full causal GQA (or MLA when cfg.use_mla) + FFN (dense or MoE)
  'local'   sliding-window causal GQA + FFN
  'rglru'   RecurrentGemma recurrent block + FFN
  'mlstm'   xLSTM matrix-memory block (no FFN when d_ff == 0)
  'slstm'   xLSTM scalar-memory block (no FFN when d_ff == 0)

Stack layout = [prefix (first_k_dense, unrolled)] + [scan over repeating
units] + [remainder (unrolled)].  Scanning the repeating unit keeps compile
time O(|unit|) instead of O(L) — essential for the 512-device dry-run — and
the cost model composes per-unit costs exactly (DESIGN.md §7).

Whisper (enc-dec) and the VLM wrapper live in whisper.py / vlm.py and call
into this stack for their decoder/backbone.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Block init/apply by kind
# ---------------------------------------------------------------------------

def _norm_init(cfg, dtype):
    return (
        L.rms_norm_init(cfg.d_model, dtype)
        if cfg.norm_kind == "rms"
        else L.layer_norm_init(cfg.d_model, dtype)
    )


def _norm(cfg, p, x):
    return (
        L.rms_norm(p, x, cfg.norm_eps)
        if cfg.norm_kind == "rms"
        else L.layer_norm(p, x, cfg.norm_eps)
    )


def block_init(key, cfg, kind: str, dtype, *, dense_ffn: bool = False, cross_attn: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": _norm_init(cfg, dtype)}
    if kind in ("global", "local"):
        p["attn"] = (
            A.mla_init(ks[0], cfg, dtype) if cfg.use_mla else A.gqa_init(ks[0], cfg, dtype)
        )
        if cross_attn:
            p["xattn"] = A.cross_attn_init(ks[3], cfg, dtype)
            p["ln_x"] = _norm_init(cfg, dtype)
        if cfg.d_ff > 0 or cfg.num_experts > 0:
            p["ln2"] = _norm_init(cfg, dtype)
            if cfg.num_experts > 0 and not dense_ffn:
                p["ffn"] = M.moe_init(ks[1], cfg, dtype)
            elif cfg.act == "gelu" and cfg.norm_kind == "layer":
                p["ffn"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
            else:
                p["ffn"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.use_post_norm:
            p["post_ln1"] = _norm_init(cfg, dtype)
            p["post_ln2"] = _norm_init(cfg, dtype)
    elif kind == "rglru":
        p["rec"] = R.rglru_init(ks[0], cfg, dtype)
        p["ln2"] = _norm_init(cfg, dtype)
        p["ffn"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "mlstm":
        p["cell"] = R.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["cell"] = R.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def block_apply(
    params: Params,
    x: jax.Array,
    cfg,
    kind: str,
    *,
    positions: jax.Array,
    cache: Any = None,
    encoder_out: Optional[jax.Array] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Any, Dict[str, jax.Array]]:
    aux: Dict[str, jax.Array] = {}
    new_cache = cache
    h = _norm(cfg, params["ln1"], x)

    if kind in ("global", "local"):
        window = cfg.window_size if kind == "local" else None
        if cfg.use_mla:
            if mode == "decode" and cache is not None and cfg.mla_absorb:
                attn_out, new_cache = A.mla_attention_absorbed(
                    params["attn"], h, cfg, positions=positions, cache=cache
                )
            else:
                attn_out, new_cache = A.mla_attention(
                    params["attn"], h, cfg, positions=positions, cache=cache
                )
        else:
            attn_out, new_cache = A.gqa_attention(
                params["attn"], h, cfg, positions=positions, window=window,
                cache=cache, causal=(mode != "encode"), use_rope=cfg.use_rope,
            )
        if cfg.use_post_norm:
            attn_out = _norm(cfg, params["post_ln1"], attn_out)
        x = x + attn_out
        if "xattn" in params:
            assert encoder_out is not None
            x = x + A.cross_attention(params["xattn"], _norm(cfg, params["ln_x"], x), encoder_out, cfg)
        if "ffn" in params:
            h2 = _norm(cfg, params["ln2"], x)
            if cfg.num_experts > 0 and "router" in params["ffn"]:
                ffn_out, aux = M.moe_ffn(params["ffn"], h2, cfg, cfg.capacity_factor)
            elif "w_in" in params["ffn"]:
                ffn_out = L.mlp(params["ffn"], h2, cfg.act)
            else:
                ffn_out = L.swiglu(params["ffn"], h2, cfg.act)
            if cfg.use_post_norm:
                ffn_out = _norm(cfg, params["post_ln2"], ffn_out)
            x = x + ffn_out
        return x, new_cache, aux

    if kind == "rglru":
        if mode == "decode":
            rec_out, new_cache = R.rglru_decode(params["rec"], h, cache, cfg)
        elif mode == "prefill":
            rec_out, new_cache = R.rglru_train(params["rec"], h, cfg, return_state=True)
        else:
            rec_out = R.rglru_train(params["rec"], h, cfg)
            new_cache = cache
        x = x + rec_out
        h2 = _norm(cfg, params["ln2"], x)
        x = x + L.swiglu(params["ffn"], h2, cfg.act)
        return x, new_cache, aux

    if kind == "mlstm":
        if mode == "decode":
            out, new_cache = R.mlstm_decode(params["cell"], h, cache, cfg)
        elif mode == "prefill":
            out, new_cache = R.mlstm_train_chunked(
                params["cell"], h, cfg, cfg.mlstm_chunk, return_state=True
            )
        else:
            out = R.mlstm_train_chunked(params["cell"], h, cfg, cfg.mlstm_chunk)
        return x + out, new_cache, aux

    if kind == "slstm":
        if mode == "decode":
            out, new_cache = R.slstm_decode(params["cell"], h, cache, cfg)
        elif mode == "prefill":
            out, new_cache = R.slstm_train(params["cell"], h, cfg, return_state=True)
        else:
            out = R.slstm_train(params["cell"], h, cfg)
        return x + out, new_cache, aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-stack init
# ---------------------------------------------------------------------------

def init_lm(cfg, key, *, cross_attn: bool = False) -> Params:
    dtype = cfg.param_dtype()
    n_units, rem_pattern = cfg.num_units_()
    keys = jax.random.split(key, 8)

    params: Params = {
        "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": _norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype, scale=0.02)

    # prefix: first_k_dense dense-FFN blocks (outside the scan)
    if cfg.first_k_dense:
        pk = jax.random.split(keys[2], cfg.first_k_dense)
        params["prefix"] = [
            block_init(pk[i], cfg, "global", dtype, dense_ffn=True, cross_attn=cross_attn)
            for i in range(cfg.first_k_dense)
        ]

    # scanned units: stack each pattern element's params along axis 0
    def one_unit(k):
        uks = jax.random.split(k, len(cfg.block_pattern))
        return tuple(
            block_init(uks[i], cfg, kind, dtype, cross_attn=cross_attn)
            for i, kind in enumerate(cfg.block_pattern)
        )

    # account for prefix layers: they replace the first layers of the stack
    n_prefixed_units = cfg.first_k_dense // max(len(cfg.block_pattern), 1)
    n_scan = n_units - n_prefixed_units
    unit_keys = jax.random.split(keys[3], max(n_scan, 1))
    units = [one_unit(unit_keys[i]) for i in range(n_scan)]
    if units:
        params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)

    if rem_pattern:
        rk = jax.random.split(keys[4], len(rem_pattern))
        params["remainder"] = [
            block_init(rk[i], cfg, kind, dtype, cross_attn=cross_attn)
            for i, kind in enumerate(rem_pattern)
        ]
    return params


def count_params(params: Params) -> int:
    leaves = jax.tree.leaves(
        {k: v for k, v in params.items() if not k.startswith("_")}
    )
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


# ---------------------------------------------------------------------------
# Whole-stack apply
# ---------------------------------------------------------------------------

def _apply_unit(unit_params, x, cfg, positions, unit_caches, encoder_out, mode):
    new_caches = []
    aux_acc = None
    for i, kind in enumerate(cfg.block_pattern):
        cache_i = unit_caches[i] if unit_caches is not None else None
        x, nc, aux = block_apply(
            unit_params[i], x, cfg, kind,
            positions=positions, cache=cache_i, encoder_out=encoder_out, mode=mode,
        )
        new_caches.append(nc)
        if aux:
            aux_acc = aux if aux_acc is None else jax.tree.map(jnp.add, aux_acc, aux)
    return x, tuple(new_caches), aux_acc


def scan_units(
    units_params,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    unit_caches=None,
    encoder_out: Optional[jax.Array] = None,
    mode: str = "train",
):
    """The scanned repeating-unit stack — factored out so the dry-run can
    lower EXACTLY this body standalone for per-unit cost extraction
    (DESIGN.md §7 scan trip-count correction)."""

    from repro.models.sharding_hints import hint_residual

    def scan_body(carry, xs):
        h, aux_c = carry
        unit_p, unit_c = xs
        # carry boundary = remat-save point: keep it sequence-sharded so the
        # per-unit residual stack is 1/|model| of the full activation
        h = hint_residual(h, seq_shard=cfg.seq_shard and mode == "train")
        h, ncs, aux = _apply_unit(unit_p, h, cfg, positions, unit_c, encoder_out, mode)
        if aux is not None:
            aux_c = jax.tree.map(jnp.add, aux_c, aux) if aux_c else aux
        return (h, aux_c), ncs

    body = scan_body
    if cfg.remat and mode == "train":
        # nothing_saveable: residuals are ONLY the bf16 carry + params refs;
        # without the explicit policy XLA keeps an extra f32 x-shaped stack
        # per unit (measured 2x the activation bytes at train_4k).
        body = jax.checkpoint(
            scan_body,
            prevent_cse=False,
            policy=jax.checkpoint_policies.nothing_saveable,
        )

    aux0 = None
    if cfg.num_experts > 0:  # MoE aux emitted in every mode
        aux0 = {
            "moe_lb_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32),
        }
    return jax.lax.scan(body, (x, aux0), (units_params, unit_caches))


def apply_stack(
    params: Params,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    caches: Optional[Dict[str, Any]] = None,
    encoder_out: Optional[jax.Array] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[Dict[str, Any]], Dict[str, jax.Array]]:
    """Runs prefix + scanned units + remainder. Returns (x, caches, aux)."""
    aux_total: Dict[str, jax.Array] = {}
    new_caches: Dict[str, Any] = {}

    def acc_aux(aux):
        nonlocal aux_total
        if aux:
            aux_total = (
                aux if not aux_total else jax.tree.map(jnp.add, aux_total, aux)
            )

    if "prefix" in params:
        pc = []
        for i, bp in enumerate(params["prefix"]):
            c = caches["prefix"][i] if caches else None
            x, nc, aux = block_apply(
                bp, x, cfg, "global",
                positions=positions, cache=c, encoder_out=encoder_out, mode=mode,
            )
            pc.append(nc)
            acc_aux(aux)
        new_caches["prefix"] = pc

    if "units" in params:
        unit_caches_stacked = caches["units"] if caches else None
        (x, aux_scan), scanned_caches = scan_units(
            params["units"], x, cfg,
            positions=positions, unit_caches=unit_caches_stacked,
            encoder_out=encoder_out, mode=mode,
        )
        new_caches["units"] = scanned_caches
        if aux_scan:
            acc_aux(aux_scan)

    if "remainder" in params:
        _, rem_pattern = cfg.num_units_()
        rc = []
        for i, kind in enumerate(rem_pattern):
            c = caches["remainder"][i] if caches else None
            x, nc, aux = block_apply(
                params["remainder"][i], x, cfg, kind,
                positions=positions, cache=c, encoder_out=encoder_out, mode=mode,
            )
            rc.append(nc)
            acc_aux(aux)
        new_caches["remainder"] = rc

    x = _norm(cfg, params["final_norm"], x)
    return x, (new_caches if caches is not None else None), aux_total


def embed_tokens(params: Params, tokens: jax.Array, cfg) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return x


def logits_from_hidden(params: Params, x: jax.Array, cfg) -> jax.Array:
    head = params.get("head")
    return L.unembed_logits(
        x, params["embed"], head, cfg.final_softcap, pad_to=cfg.logits_pad_to
    )


def forward_lm(
    params: Params,
    tokens: jax.Array,
    cfg,
    *,
    vision_embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    mode: str = "train",
) -> jax.Array:
    """Full-sequence forward -> logits [B, T(, +Tv), vocab]."""
    x = embed_tokens(params, tokens, cfg)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    T = x.shape[1]
    pos = positions if positions is not None else jnp.arange(T, dtype=jnp.int32)
    x, _, aux = apply_stack(params, x, cfg, positions=pos, mode=mode)
    return logits_from_hidden(params, x, cfg), aux
