"""VLM wrapper (internvl2 family): InternViT frontend STUB + LM backbone.

Per the assignment, the vision tower is a stub: `input_specs()` supplies
precomputed patch embeddings [B, T_vision, d_model] (what InternViT + the
mlp projector would emit).  They are prepended to the text embeddings; the
loss masks the vision positions.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T

Params = Dict[str, Any]


def init_vlm(cfg, key) -> Params:
    return T.init_lm(cfg, key)


def forward_vlm(
    params: Params,
    tokens: jax.Array,          # [B, T_text]
    vision_embeds: jax.Array,   # [B, T_vision, d_model]
    cfg,
    mode: str = "train",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns logits over the FULL (vision + text) sequence; the trainer
    slices off the vision positions when building the loss."""
    return T.forward_lm(
        params, tokens, cfg, vision_embeds=vision_embeds, mode=mode
    )
