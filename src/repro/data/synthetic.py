"""Deterministic synthetic LM data pipeline.

Generates token streams with enough structure to make the loss learnable
(a mixture of Markov bigram chains), deterministically from (seed, step),
so every host can produce ITS shard of the global batch independently —
the same counter-based philosophy as the sketch RNG: no data coordination
collectives, ever.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch import hash_u32


def synthetic_batch(
    cfg,
    shape,
    step: int,
    *,
    seed: int = 0,
    host_index: int = 0,
    host_count: int = 1,
) -> Dict[str, jax.Array]:
    """One host's shard of the global batch for `step`."""
    B = shape.global_batch // host_count
    T = shape.seq_len
    base = np.uint32((step * 0x9E3779B9 + host_index * 7919) & 0xFFFFFFFF)

    idx = (
        jnp.arange(B * T, dtype=jnp.uint32).reshape(B, T)
        + jnp.uint32(host_index) * np.uint32(B * T)
    )
    bits = hash_u32(idx + base, seed)
    # Learnable structure: a position-periodic base pattern (period 32, phase
    # per sequence) + 15% uniform noise.  A model that learns the pattern
    # reaches ~0.15*ln(V) loss; uniform-random data would pin loss at ln(V).
    phase = (hash_u32(jnp.arange(B, dtype=jnp.uint32) + base, seed + 3) % 32)[:, None]
    pattern = ((jnp.arange(T, dtype=jnp.uint32)[None, :] + phase) * np.uint32(2654435761)) % np.uint32(cfg.vocab_size)
    noise_mask = (bits % np.uint32(100)) < 15
    noise = hash_u32(idx + base + np.uint32(0x1234), seed) % np.uint32(cfg.vocab_size)
    tokens = jnp.where(noise_mask, noise, pattern).astype(jnp.int32)

    batch: Dict[str, jax.Array] = {
        "tokens": tokens,
        "labels": jnp.roll(tokens, -1, axis=1),
        "loss_mask": jnp.ones((B, T), jnp.float32).at[:, -1].set(0.0),
    }
    if cfg.vision_stub:
        vis_idx = jnp.arange(B * cfg.vision_tokens * cfg.d_model, dtype=jnp.uint32)
        vis = (hash_u32(vis_idx + base, seed + 1).astype(jnp.float32) * np.float32(1.0 / 2**32) - 0.5).reshape(
            B, cfg.vision_tokens, cfg.d_model
        )
        batch["vision_embeds"] = vis * 0.02
    if cfg.is_encoder_decoder:
        Ta = cfg.encoder_seq_len
        aud_idx = jnp.arange(B * Ta * cfg.d_model, dtype=jnp.uint32)
        aud = (hash_u32(aud_idx + base, seed + 2).astype(jnp.float32) * np.float32(1.0 / 2**32) - 0.5).reshape(
            B, Ta, cfg.d_model
        )
        batch["audio_features"] = aud * 0.02
    return batch


def data_iterator(cfg, shape, *, seed=0, host_index=0, host_count=1) -> Iterator[Dict]:
    step = 0
    while True:
        yield synthetic_batch(
            cfg, shape, step, seed=seed, host_index=host_index, host_count=host_count
        )
        step += 1
