"""Cache construction for serving: dense KV, sliding-window KV, MLA latent,
and recurrent state — matching each block kind of each architecture.

Cache sizing policy per kind:
  global  -> dense KV        [B, Hkv, Tmax, Dh]        (quadratic archs)
  local   -> windowed KV     [B, Hkv, min(Tmax, W+chunk), Dh]
  (MLA)   -> latent          [B, Tmax, kv_lora + rope]  (DeepSeek: tiny)
  rglru   -> RGLRUState      [B, R] + conv tail          O(1)
  mlstm   -> MLSTMState      [B, H, Dh, Dh]              O(1)
  slstm   -> SLSTMState      [B, H, Dh]                  O(1)

For long_500k this is the structural reason only the SSM/hybrid archs run:
their state is O(1)/O(W) in sequence length.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache, MLACache
from repro.models import recurrent as R


def _kv_len_for(cfg, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.window_size is not None:
        return min(max_len, cfg.window_size)
    return max_len


def make_block_cache(cfg, kind: str, batch: int, max_len: int, dtype) -> Any:
    if kind in ("global", "local"):
        if cfg.use_mla:
            return MLACache(
                c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                k_rope=jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
                length=jnp.zeros((), jnp.int32),
            )
        Dh = cfg.head_dim_()
        # NOTE: we allocate the window+prefill length for local layers only
        # when the shape engine asks for it (ring-buffer update is a serve
        # optimization recorded in EXPERIMENTS.md §Perf).
        return KVCache(
            k=jnp.zeros((batch, cfg.num_kv_heads, max_len, Dh), dtype),
            v=jnp.zeros((batch, cfg.num_kv_heads, max_len, Dh), dtype),
            length=jnp.zeros((), jnp.int32),
        )
    if kind == "rglru":
        return R.rglru_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return R.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return R.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Cache pytree congruent with the transformer stack layout
    (prefix list / scanned-unit stacked leaves / remainder list)."""
    n_units, rem_pattern = cfg.num_units_()
    n_prefixed_units = cfg.first_k_dense // max(len(cfg.block_pattern), 1)
    n_scan = n_units - n_prefixed_units

    caches: Dict[str, Any] = {}
    if cfg.first_k_dense:
        caches["prefix"] = [
            make_block_cache(cfg, "global", batch, max_len, dtype)
            for _ in range(cfg.first_k_dense)
        ]
    if n_scan > 0:
        unit = tuple(
            make_block_cache(cfg, kind, batch, max_len, dtype)
            for kind in cfg.block_pattern
        )
        caches["units"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (n_scan,) + leaf.shape).copy(),
            unit,
        )
    if rem_pattern:
        caches["remainder"] = [
            make_block_cache(cfg, kind, batch, max_len, dtype) for kind in rem_pattern
        ]
    return caches


def cache_bytes(caches) -> int:
    return sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(caches) if hasattr(l, "size")
    )
