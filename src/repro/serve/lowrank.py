"""Low-rank serve-time weight compression via the paper's randomized SVD.

W (m x n) ~= A @ B with A = U_k sqrt(S_k), B = sqrt(S_k) V_k^T computed by
core.rsvd.randomized_svd.  At decode batch sizes the two skinny GEMMs are
memory-bound wins: HBM reads drop from mn to k(m+n) per token.

Applied to the large projection matrices (FFN + attention out) whose spectra
decay; the embedding and router stay exact.  Quality is the caller's choice
of rank — `compression_report` gives per-matrix relative error so the choice
is informed (the paper's 1+eps guarantee, applied to weights).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core.rsvd import RSVDConfig, low_rank_error

_RSVD = RSVDConfig(oversample=16, power_iters=2, qr_method="cqr2", small_svd="gram")

_TARGET_KEYS = ("w_gate", "w_up", "w_down", "wo", "w_o", "w_down", "w_in", "w_out")


def _is_target(path: Tuple, leaf) -> bool:
    # 2-D weights, or scan-stacked 3-D weights (leading axis = scanned units)
    if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
        return False
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    return any(n in _TARGET_KEYS for n in names)


def _factorize_2d(W: jax.Array, rank: int):
    U, S, Vt = linalg.svd(W, rank, overrides=_RSVD)
    root = jnp.sqrt(S)
    # panel-wise residual: the error report never forms the m x n
    # reconstruction (linalg.residual), so factorizing huge projections
    # doesn't momentarily double their memory.
    err = linalg.residual(W, (U, S, Vt), block_rows=2048)
    return U * root[None, :], root[:, None] * Vt, err


def _factorize_stacked(W: jax.Array, rank: int):
    """[units, m, n] leaf: one batched RSVD (the StackedOp execution path)
    for all units, with per-unit decorrelated sketch seeds."""
    U, S, Vt = linalg.svd(linalg.StackedOp(W), rank, overrides=_RSVD)
    root = jnp.sqrt(S)
    A = U * root[:, None, :]
    B = root[:, :, None] * Vt
    err = jax.vmap(low_rank_error)(W, U, S, Vt)
    return A, B, err


def factorize_params(params, rank: int) -> Tuple[Any, Dict[str, float]]:
    """Replace each target weight W with {'lr_a': A, 'lr_b': B}.

    Scan-stacked leaves [U, m, n] are factorized with a vmapped RSVD so the
    per-unit slices that lax.scan extracts are already the two skinny GEMM
    factors.  Leaves with min(m, n) <= 2*rank stay dense (no saving)."""
    report: Dict[str, float] = {}

    def visit(path, leaf):
        if not _is_target(path, leaf) or min(leaf.shape[-2:]) <= 2 * rank:
            return leaf
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        W = leaf.astype(jnp.float32)
        if leaf.ndim == 2:
            A, B, err = _factorize_2d(W, rank)
            report[name] = float(err)
        else:
            A, B, err = _factorize_stacked(W, rank)
            report[name] = float(jnp.mean(err))
        return {"lr_a": A.astype(leaf.dtype), "lr_b": B.astype(leaf.dtype)}

    new_params = jax.tree_util.tree_map_with_path(visit, params)
    return new_params, report


def dense_equivalent(params) -> Any:
    """Re-densify factorized leaves (for testing / exact comparison)."""

    def visit(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"lr_a", "lr_b"}:
            return leaf["lr_a"] @ leaf["lr_b"]
        return leaf

    return jax.tree.map(
        visit, params, is_leaf=lambda l: isinstance(l, dict) and set(l) == {"lr_a", "lr_b"}
    )


def memory_report(params, factorized) -> Dict[str, int]:
    def nbytes(t):
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t) if hasattr(l, "size"))

    return {"dense_bytes": nbytes(params), "factorized_bytes": nbytes(factorized)}
