"""Low-rank serve-time weight compression via the paper's randomized SVD.

W (m x n) ~= A @ B with A = U_k sqrt(S_k), B = sqrt(S_k) V_k^T.  At decode
batch sizes the two skinny GEMMs are memory-bound wins: HBM reads drop from
mn to k(m+n) per token.

Applied to the large projection matrices (FFN + attention out) whose spectra
decay; the embedding and router stay exact.  Quality is stated either as a
rank (`factorize_params(params, rank=64)` — the caller reads the error
report and iterates) or, since the spec redesign, directly as an accuracy:
`factorize_params(params, tol=0.02)` lets the adaptive QB engine
(`linalg.Tolerance`) pick each matrix's OWN rank for a uniform 2% relative
error — spectra differ per layer, so a single global rank over- or
under-compresses somewhere.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core.rsvd import RSVDConfig, low_rank_error

_RSVD = RSVDConfig(oversample=16, power_iters=2, qr_method="cqr2", small_svd="gram")

_TARGET_KEYS = ("w_gate", "w_up", "w_down", "wo", "w_o", "w_down", "w_in", "w_out")


def _is_target(path: Tuple, leaf) -> bool:
    # 2-D weights, or scan-stacked 3-D weights (leading axis = scanned units)
    if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
        return False
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    return any(n in _TARGET_KEYS for n in names)


def _factorize_2d(W: jax.Array, rank: int):
    U, S, Vt = linalg.svd(W, rank, overrides=_RSVD)
    root = jnp.sqrt(S)
    # panel-wise residual: the error report never forms the m x n
    # reconstruction (linalg.residual), so factorizing huge projections
    # doesn't momentarily double their memory.
    err = linalg.residual(W, (U, S, Vt), block_rows=2048)
    return U * root[None, :], root[:, None] * Vt, err


def _factors_from_svd(W: jax.Array, U, S, Vt):
    """(A, B, err) from already-computed SVD factors (the service path)."""
    root = jnp.sqrt(S)
    err = linalg.residual(W, (U, S, Vt), block_rows=2048)
    return U * root[None, :], root[:, None] * Vt, err


def _factorize_2d_tol(W: jax.Array, tol: float):
    """Accuracy-first factorization: the adaptive QB engine grows the rank
    until ||W - A B||_F <= tol ||W||_F, so every matrix lands on its own
    (smallest) rank for the requested error."""
    dec = linalg.decompose(W, linalg.Tolerance(tol), overrides=_RSVD)
    U, S, Vt = dec.factors
    root = jnp.sqrt(S)
    err = linalg.residual(W, dec.factors, block_rows=2048)
    return U * root[None, :], root[:, None] * Vt, err, dec.rank


def _factorize_stacked(W: jax.Array, rank: int):
    """[units, m, n] leaf: one batched RSVD (the StackedOp execution path)
    for all units, with per-unit decorrelated sketch seeds."""
    U, S, Vt = linalg.svd(linalg.StackedOp(W), rank, overrides=_RSVD)
    root = jnp.sqrt(S)
    A = U * root[:, None, :]
    B = root[:, :, None] * Vt
    err = jax.vmap(low_rank_error)(W, U, S, Vt)
    return A, B, err


def _leaf_name(path: Tuple) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def factorize_params(
    params, rank: Optional[int] = None, *, tol: Optional[float] = None,
    service=None,
) -> Tuple[Any, Dict[str, float]]:
    """Replace each target weight W with {'lr_a': A, 'lr_b': B}.

    Exactly one of `rank` / `tol` picks the quality contract: a fixed rank
    for every leaf, or a relative Frobenius tolerance that lets each leaf
    find its own rank (adaptive QB).  Stacked leaves probe slice 0
    adaptively and run every unit at that rank under one vmap (per-unit
    ragged ranks would break the scan layout); since other slices may need
    MORE rank, the reported error is the WORST slice and the stack-wide
    rank is escalated until that worst slice meets `tol` (or the dense
    fallback triggers).

    Scan-stacked leaves [U, m, n] are factorized with a vmapped RSVD so the
    per-unit slices that lax.scan extracts are already the two skinny GEMM
    factors.  Leaves whose selected rank r has min(m, n) <= 2*r stay dense
    (no saving).

    Faults are isolated per leaf: a weight carrying NaN/Inf (corrupt
    checkpoint shard), a factorization that raises, or one that produces
    non-finite factors leaves THAT leaf dense with ``report[name] = nan``
    instead of sinking the whole tree — one bad shard should cost one
    layer's compression, not the batch.

    `service` (a `repro.serve.decomp.DecompositionService`, rank mode only):
    2-D target leaves are pre-submitted before the tree walk, so the
    service's coalescer batches SAME-SHAPED layers (transformer stacks are
    full of them) into single StackedOp solves.  Factors then come from the
    batched executors — bit-identical to a batch-of-1 submission through
    the same service whatever the coalescing (the service's invariant), and
    agreeing with the serial dense path to roundoff.  A leaf whose service
    solve fails (`RequestError`) stays dense with ``report[name] = nan`` —
    the same per-leaf isolation as the serial path."""
    if (rank is None) == (tol is None):
        raise ValueError("factorize_params needs exactly one of rank= or tol=")
    report: Dict[str, float] = {}

    futures: Dict[str, Any] = {}
    if service is not None and rank is not None:
        def presubmit(path, leaf):
            if (_is_target(path, leaf) and leaf.ndim == 2
                    and min(leaf.shape) > 2 * rank):
                W = leaf.astype(jnp.float32)
                if bool(jnp.isfinite(W).all()):
                    futures[_leaf_name(path)] = service.submit(
                        W, linalg.Rank(rank), overrides=_RSVD)
            return leaf
        jax.tree_util.tree_map_with_path(presubmit, params)
        service.flush()  # seal part-filled buckets: every future resolvable

    def _compress(W, leaf, name):
        """(A, B, reported error) or None when factorizing wins nothing."""
        if leaf.ndim == 2:
            if tol is not None:
                A, B, err, r = _factorize_2d_tol(W, tol)
                if min(leaf.shape) <= 2 * r:
                    return None  # tolerance needs too much rank: no saving
            elif name in futures:
                U, S, Vt = futures[name].result().factors
                A, B, err = _factors_from_svd(W, U, S, Vt)
            else:
                A, B, err = _factorize_2d(W, rank)
            return A, B, float(err)
        if tol is not None:
            # one adaptive probe seeds the stack-wide rank; the vmapped
            # pass then verifies the WORST slice, and if some unit's
            # spectrum needs more than slice 0 did, THAT slice is
            # probed adaptively and the stack re-run at its rank
            r = linalg.decompose(W[0], linalg.Tolerance(tol), overrides=_RSVD).rank
            while True:
                if min(leaf.shape[-2:]) <= 2 * r:
                    return None  # tolerance needs too much rank: no saving
                A, B, err = _factorize_stacked(W, r)
                worst = float(jnp.max(err))
                if worst <= tol:
                    break
                i = int(jnp.argmax(err))
                r_worst = linalg.decompose(
                    W[i], linalg.Tolerance(tol), overrides=_RSVD).rank
                # progress by at least the oversample margin: the probe
                # can certify a rank the fixed-rank vmapped run (other
                # seeds, trimmed oversampling) just misses, and +1 steps
                # would re-factorize the whole stack O(min(m, n)) times
                r = max(r_worst, r + _RSVD.oversample)
            return A, B, worst
        A, B, err = _factorize_stacked(W, rank)
        return A, B, float(jnp.mean(err))

    def visit(path, leaf):
        if not _is_target(path, leaf):
            return leaf
        if rank is not None and min(leaf.shape[-2:]) <= 2 * rank:
            return leaf
        name = _leaf_name(path)
        W = leaf.astype(jnp.float32)
        if not bool(jnp.isfinite(W).all()):
            report[name] = float("nan")  # poisoned input: keep dense
            return leaf
        try:
            out = _compress(W, leaf, name)
        except (FloatingPointError, ValueError, RuntimeError):
            # RequestError (service path) lands here too: RuntimeError
            report[name] = float("nan")  # factorization failed: keep dense
            return leaf
        if out is None:
            return leaf
        A, B, err = out
        if not (bool(jnp.isfinite(A).all()) and bool(jnp.isfinite(B).all())):
            report[name] = float("nan")  # non-finite factors: keep dense
            return leaf
        report[name] = err
        return {"lr_a": A.astype(leaf.dtype), "lr_b": B.astype(leaf.dtype)}

    new_params = jax.tree_util.tree_map_with_path(visit, params)
    return new_params, report


def dense_equivalent(params) -> Any:
    """Re-densify factorized leaves (for testing / exact comparison)."""

    def visit(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"lr_a", "lr_b"}:
            return leaf["lr_a"] @ leaf["lr_b"]
        return leaf

    return jax.tree.map(
        visit, params, is_leaf=lambda l: isinstance(l, dict) and set(l) == {"lr_a", "lr_b"}
    )


def memory_report(params, factorized) -> Dict[str, int]:
    def nbytes(t):
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t) if hasattr(l, "size"))

    return {"dense_bytes": nbytes(params), "factorized_bytes": nbytes(factorized)}
