"""`DecompositionService`: concurrent decomposition requests behind futures.

    with DecompositionService() as svc:
        futs = [svc.submit(x, linalg.Rank(8), seed=i) for i, x in enumerate(xs)]
        results = [f.result() for f in futs]      # linalg.Decomposition each

What `submit` does with a request:

1. plans it through the LRU plan cache (`linalg.cached_plan`) — repeat
   shapes never re-plan;
2. classifies it: COALESCIBLE small dense svd traffic joins an admission-
   window bucket (coalesce.py) and executes as one `StackedOp` batch with
   per-request slice seeds — every member's result bit-identical to its own
   standalone `decompose(StackedOp(x[None]), ...)` call; everything else
   runs solo, scheduled shortest-predicted-first on the small lane or FIFO
   on the bounded big lane (scheduler.py) with out-of-core jobs yielding
   the device between panel groups;
3. resolves the future with a `linalg.Decomposition` (2-D factors for
   coalescible traffic) or a `RequestError` carrying the guard's
   `HealthReport` when the request's own input poisoned its solve —
   neighbors in the same coalesced batch are unaffected (slice-level
   finiteness screen + per-request retry fallback to uncoalesced,
   guarded execution).

Per-request `GuardPolicy` rides along: guarded requests run solo under
`linalg.decompose(..., guard=...)` (the full report/retry machinery);
coalesced fast-path batches are unguarded by construction (guard "off" is
a coalescing-key field) and fall back to a guarded batch-of-1 only for the
slice that failed its finiteness screen.

`service.metrics.export()` is the bench harness surface: queue/compile/
execute walltimes, coalescing factor, cache hit rate, predicted-vs-measured
walltime error, and the scheduler's observed starvation bound.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.linalg import guard as guard_mod
from repro.linalg import pipeline as pipeline_mod
from repro.linalg import registry as registry_mod
from repro.linalg import snapshot as snapshot_mod
from repro.linalg.api import Decomposition
from repro.linalg.spec import Rank

from repro.serve.decomp.cache import ExecutableCache, timed
from repro.serve.decomp.coalesce import Coalescer, CoalesceKey, pad_batch
from repro.serve.decomp.jobstore import JobStore
from repro.serve.decomp.metrics import MetricsRecorder, RequestRecord
from repro.serve.decomp.scheduler import DeviceGate, TwoLaneQueues


class RequestError(RuntimeError):
    """A single request's solve failed; `.health` carries the guard's
    HealthReport from the isolated (uncoalesced, guarded) retry."""

    def __init__(self, message: str, health=None):
        super().__init__(message)
        self.health = health


class ServiceClosed(RuntimeError):
    pass


class ServiceOverloaded(RuntimeError):
    """The bounded big-job lane is at capacity; retry later."""


class _ServiceFuture(Future):
    """Future with COOPERATIVE cancellation.  `cancel()` on a not-yet-started
    request cancels it outright (stdlib semantics); on a RUNNING request it
    returns False per the stdlib contract but ALSO sets `cancel_event`,
    which the solve observes at its next panel-group boundary
    (snapshot.boundary) — the future then resolves with `linalg.Cancelled`
    carrying the final snapshot path, so the partial solve is resumable."""

    def __init__(self):
        super().__init__()
        self.cancel_event = threading.Event()

    def cancel(self) -> bool:
        self.cancel_event.set()
        return super().cancel()


class _Request:
    __slots__ = ("future", "op", "source", "spec", "kind", "seed", "overrides",
                 "guard", "plan", "lane", "submitted_at", "slices_at_submit",
                 "started_at", "slices_at_start", "deadline_t", "checkpoint",
                 "job_id")

    def __init__(self, **kw):
        for k, v in kw.items():
            setattr(self, k, v)
        self.started_at = None
        self.slices_at_start = None


class _Batch:
    """A sealed coalesced bucket travelling through the small lane."""

    __slots__ = ("members",)

    def __init__(self, members):
        self.members = members


def _checkpoint_dir(checkpoint) -> Optional[str]:
    """The snapshot directory a `checkpoint=` argument names (for the job
    store's write-ahead record), or None when there is nothing durable."""
    if checkpoint is None:
        return None
    if isinstance(checkpoint, snapshot_mod.RunControl):
        ck = checkpoint.checkpointer
        return None if ck is None else str(ck.dir)
    if isinstance(checkpoint, snapshot_mod.Checkpointer):
        return str(checkpoint.dir)
    return str(checkpoint)


class DecompositionService:
    """See module docstring.  All knobs are keyword-only:

    window_s / max_batch      admission window and coalescing bound
    coalesce_max_elems        m*n above which a dense request is no longer
                              "small" (runs solo instead of batching)
    big_threshold_s           predicted walltime that routes a request to
                              the bounded big lane
    big_capacity              queued big jobs beyond which submit raises
                              ServiceOverloaded
    panel_group               big-job panels per scheduler slice (the
                              starvation bound's K is counted in these)
    big_patience_s            optional anti-starvation valve for the BIG
                              lane: longest the gate parks a big job while
                              small traffic keeps arriving (None = park
                              until the small lane drains)
    jobstore                  directory (or `JobStore`) of write-ahead
                              records for admitted array-rooted requests —
                              after a process crash, `restore(dir)` brings
                              the interrupted jobs back (jobstore.py);
                              None (default) keeps the pre-PR-10 in-memory
                              behavior
    """

    def __init__(self, *, window_s: float = 0.002, max_batch: int = 8,
                 coalesce_max_elems: int = 1 << 20,
                 big_threshold_s: float = 0.05, big_capacity: int = 4,
                 panel_group: int = 4, big_patience_s: Optional[float] = None,
                 jobstore=None):
        self._admission = threading.Condition()
        self._coalescer = Coalescer(window_s=window_s, max_batch=max_batch)
        self._queues = TwoLaneQueues(big_capacity=big_capacity)
        self.gate = DeviceGate(panel_group=panel_group,
                               big_patience_s=big_patience_s)
        self.executable_cache = ExecutableCache()
        self.metrics = MetricsRecorder()
        self.coalesce_max_elems = int(coalesce_max_elems)
        self.big_threshold_s = float(big_threshold_s)
        if jobstore is None or isinstance(jobstore, JobStore):
            self._jobstore = jobstore
        else:
            self._jobstore = JobStore(jobstore)
        self._closed = False
        self._inflight = 0          # admitted, future not yet resolved
        self._idle = threading.Condition()
        self._threads = [
            threading.Thread(target=self._admit_loop, name="decomp-admit",
                             daemon=True),
            threading.Thread(target=self._small_loop, name="decomp-small",
                             daemon=True),
            threading.Thread(target=self._big_loop, name="decomp-big",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ API

    def submit(self, source, spec, kind: str = "svd", *, seed: int = 0,
               overrides=None, guard=None, validate: bool = False,
               deadline_s: Optional[float] = None, checkpoint=None,
               _job_id: Optional[str] = None) -> Future:
        """Admit one decomposition request; returns a Future resolving to a
        `linalg.Decomposition` (or raising RequestError / the solve's own
        structural error).

        `deadline_s` bounds the request's TOTAL time from this call: a
        queued request whose deadline lapses resolves with
        `linalg.DeadlineExceeded` without running; a running streamed/
        adaptive solve checks the deadline at panel-group boundaries and
        resolves with `DeadlineExceeded` carrying the final snapshot path
        (when `checkpoint` is set — the partial solve is parked, not lost).
        `checkpoint` is a directory (or Checkpointer) where the solve
        persists panel-granular snapshots (linalg/snapshot.py); the
        returned future's `.cancel()` is cooperative the same way."""
        if self._closed:
            raise ServiceClosed("submit() after close()")
        op = linalg.as_linop(source)
        spec = linalg.as_spec(spec)
        policy = guard_mod.as_guard(guard)
        entry = registry_mod.get(kind)
        plan_op = entry.prepare(op) if entry.prepare is not None else op
        pl = registry_mod.cached_plan(plan_op, spec, kind=kind,
                                      overrides=overrides, guard=policy,
                                      validate=validate)
        fut: Future = _ServiceFuture()
        deadline_t = (None if deadline_s is None
                      else time.monotonic() + float(deadline_s))
        job_id = _job_id
        if self._jobstore is not None and job_id is None:
            # write-ahead: persisted BEFORE the request can execute, removed
            # when its future resolves — a crash in between leaves exactly
            # the records restore() must re-enqueue
            job_id = self._jobstore.record(
                op=op, spec=spec, kind=kind, seed=seed,
                guard_mode=policy.mode, validate=bool(validate),
                plan_fingerprint=pl.fingerprint(),
                checkpoint_dir=_checkpoint_dir(checkpoint),
                deadline_s=deadline_s, overrides=overrides)
        req = _Request(future=fut, op=op, source=source, spec=spec, kind=kind,
                       seed=seed, overrides=overrides, guard=policy, plan=pl,
                       lane="small", submitted_at=time.perf_counter(),
                       slices_at_submit=self.gate.big_slices,
                       deadline_t=deadline_t, checkpoint=checkpoint,
                       job_id=job_id)
        with self._idle:
            self._inflight += 1

        if self._coalescible(op, spec, kind, policy, pl, validate, seed):
            self.gate.note_small_admitted()
            key = CoalesceKey(shape=tuple(op.shape),
                              dtype=jnp.dtype(op.dtype).name, spec=spec,
                              kind=kind, overrides=overrides, guard=policy)
            with self._admission:
                sealed = self._coalescer.add(key, req, time.perf_counter())
                self._admission.notify_all()
            if sealed is not None:
                self._queues.push_small(pl.predicted_walltime_s * len(sealed),
                                        _Batch(sealed))
            return fut

        big = (pl.predicted_walltime_s >= self.big_threshold_s
               or pl.path == "streamed")
        if big:
            req.lane = "big"
            if not self._queues.push_big(req):
                with self._idle:
                    self._inflight -= 1
                if self._jobstore is not None:
                    self._jobstore.complete(job_id)  # never admitted
                raise ServiceOverloaded(
                    f"big lane at capacity ({self._queues.big_capacity} queued)")
        else:
            self.gate.note_small_admitted()
            self._queues.push_small(pl.predicted_walltime_s, req)
        return fut

    @classmethod
    def restore(cls, store_dir, **kwargs) -> "DecompositionService":
        """Bring a crashed service's interrupted jobs back.

        Builds a fresh service over the same write-ahead `JobStore`
        directory and re-submits every pending record — each with its
        original seed, spec, guard and checkpoint directory, so streamed/
        adaptive solves resume from their last panel-group snapshot
        (bit-identical to an uninterrupted run) instead of panel 0.  A
        record whose re-planned execution no longer matches its stored
        plan fingerprint (environment changed under the crash) runs fresh:
        its checkpoint directory is dropped, because its snapshots belong
        to numerics that will not be replayed.  Deadlines restart from the
        re-submission (the original submit-relative instant died with the
        crashed process).  `restored_futures` on the returned service maps
        job_id -> Future for the re-enqueued jobs."""
        svc = cls(jobstore=store_dir, **kwargs)
        svc.restored_futures = {}
        for rec in svc._jobstore.pending():
            source = svc._jobstore.load_source(rec)
            spec = getattr(linalg, rec.spec_type)(**rec.spec_fields())
            overrides = None
            ofields = rec.overrides_fields()
            if ofields is not None:
                from repro.core.rsvd import RSVDConfig

                overrides = RSVDConfig(**ofields)
            op = linalg.as_linop(source)
            entry = registry_mod.get(rec.kind)
            plan_op = entry.prepare(op) if entry.prepare is not None else op
            pl = registry_mod.cached_plan(
                plan_op, spec, kind=rec.kind, overrides=overrides,
                guard=guard_mod.as_guard(rec.guard_mode),
                validate=rec.validate)
            same_plan = pl.fingerprint() == rec.plan_fingerprint
            fut = svc.submit(
                source, spec, kind=rec.kind, seed=rec.seed,
                overrides=overrides, guard=rec.guard_mode,
                validate=rec.validate, deadline_s=rec.deadline_s,
                checkpoint=rec.checkpoint_dir if same_plan else None,
                _job_id=rec.job_id)
            svc.metrics.note_resumed_job()
            svc.restored_futures[rec.job_id] = fut
        return svc

    def flush(self) -> None:
        """Seal every open admission bucket immediately (don't wait for
        windows to expire).  Deterministic batch formation for tests."""
        with self._admission:
            sealed = self._coalescer.flush()
        for members in sealed:
            pred = members[0].plan.predicted_walltime_s * len(members)
            self._queues.push_small(pred, _Batch(members))

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._inflight > 0:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(timeout=remaining)
        return True

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop admitting, flush open buckets, drain in-flight work, join."""
        if self._closed:
            return
        self._closed = True
        self.flush()
        self.drain(timeout=timeout)
        self._queues.close()
        with self._admission:
            self._admission.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -------------------------------------------------------------- routing

    def _coalescible(self, op, spec, kind, policy, pl, validate, seed) -> bool:
        """Small dense fixed-rank svd with guard off — the traffic class
        whose batched execution is provably bit-identical per slice."""
        return (
            kind == "svd"
            and isinstance(spec, Rank)
            and pl.path == "dense"
            and policy.mode == "off"
            and not validate
            and np.ndim(seed) == 0          # one slice seed per request
            and getattr(op, "array", None) is not None
            and len(op.shape) == 2
            and pl.m * pl.n <= self.coalesce_max_elems
        )

    # -------------------------------------------------------------- workers

    def _admit_loop(self):
        """Seals buckets whose admission window expired."""
        while True:
            with self._admission:
                now = time.perf_counter()
                sealed = self._coalescer.pop_due(now)
                if not sealed:
                    if self._closed and self._coalescer.open_buckets() == 0:
                        return
                    deadline = self._coalescer.next_deadline()
                    self._admission.wait(
                        timeout=None if deadline is None else
                        max(0.0, deadline - now) + 1e-4)
                    continue
            for members in sealed:
                pred = members[0].plan.predicted_walltime_s * len(members)
                self._queues.push_small(pred, _Batch(members))

    def _small_loop(self):
        while True:
            item = self._queues.pop_small()
            if item is None:
                return
            if isinstance(item, _Batch):
                with self.gate.small_turn():
                    self._run_batch(item.members)
                for _ in item.members:
                    self.gate.note_small_done()
            else:
                with self.gate.small_turn():
                    self._run_solo(item)
                self.gate.note_small_done()

    def _big_loop(self):
        while True:
            req = self._queues.pop_big()
            if req is None:
                return
            with self.gate.big_turn():
                # the streamed panel walk yields the device between panel
                # groups through the gate's tick (pipeline.panel_hook)
                with pipeline_mod.panel_hook(self.gate.panel_tick):
                    self._run_solo(req)

    # ------------------------------------------------------------ execution

    def _resolve(self, req: _Request, value=None, error=None,
                 execute_s: float = 0.0, coalesced: int = 1,
                 cache_hit: Optional[bool] = None, plan=None,
                 pre_cancelled: bool = False) -> None:
        now = time.perf_counter()
        pl = plan if plan is not None else req.plan
        started = req.started_at if req.started_at is not None else now
        # waited = big-job slices completed between SUBMIT and execution
        # START — the per-request starvation measurement the bound covers
        # (a big job's own slices don't count against itself)
        at_start = (req.slices_at_start if req.slices_at_start is not None
                    else self.gate.big_slices)
        self.metrics.record(RequestRecord(
            kind=req.kind, lane=req.lane, coalesced=coalesced,
            cache_hit=cache_hit,
            queue_s=started - req.submitted_at,
            execute_s=execute_s,
            total_s=now - req.submitted_at,
            predicted_s=pl.predicted_walltime_s,
            big_slices_waited=at_start - req.slices_at_submit,
            failed=error is not None or pre_cancelled,
        ))
        if pre_cancelled or isinstance(error, snapshot_mod.Cancelled):
            self.metrics.note_cancelled()
        elif isinstance(error, snapshot_mod.DeadlineExceeded):
            self.metrics.note_deadline_exceeded()
        if pre_cancelled:
            pass  # Future.cancel() already moved the future to CANCELLED
        elif error is not None:
            req.future.set_exception(error)
        else:
            req.future.set_result(value)
        if self._jobstore is not None:
            # the outcome is delivered (or abandoned by cancel) — the
            # write-ahead record has served its purpose
            self._jobstore.complete(req.job_id)
        with self._idle:
            self._inflight -= 1
            self._idle.notify_all()

    def _run_control(self, req: _Request) -> snapshot_mod.RunControl:
        """The solve's ambient RunControl: the request's checkpointer (if
        any) plus the SERVICE-owned deadline and cancel event — a caller-
        built RunControl's own deadline/cancel fields are overwritten."""
        ctl = snapshot_mod.as_control(req.checkpoint)
        if ctl is None:
            ctl = snapshot_mod.RunControl()
        ctl.deadline_t = req.deadline_t
        ctl.cancel_event = req.future.cancel_event
        return ctl

    def _start(self, req: _Request) -> bool:
        """Transition the request's future to RUNNING; resolve it without
        executing when it was cancelled while queued or its deadline has
        already lapsed.  Returns False when nothing should run."""
        if not req.future.set_running_or_notify_cancel():
            self._resolve(req, pre_cancelled=True)
            return False
        req.started_at = time.perf_counter()
        req.slices_at_start = self.gate.big_slices
        if req.deadline_t is not None and time.monotonic() >= req.deadline_t:
            self._resolve(req, error=snapshot_mod.DeadlineExceeded(
                "deadline exceeded while queued (solve never started)"))
            return False
        return True

    def _run_solo(self, req: _Request) -> None:
        if not self._start(req):
            return
        t0 = req.started_at
        ctl = self._run_control(req)
        try:
            with snapshot_mod.maybe_scope(ctl):
                dec = linalg.decompose(
                    req.op, req.spec, kind=req.kind, seed=req.seed,
                    overrides=req.overrides, guard=req.guard,
                    validate=req.plan.validate or None)
                jax.block_until_ready(dec.factors)
        except Exception as exc:  # structural errors, exhausted ladders,
            #                       Cancelled / DeadlineExceeded verdicts
            if ctl.checkpointer is not None:
                self.metrics.note_checkpoint_overhead(
                    ctl.checkpointer.overhead_s)
            self._resolve(req, error=exc)
            return
        if ctl.checkpointer is not None:
            self.metrics.note_checkpoint_overhead(ctl.checkpointer.overhead_s)
        if dec.health is not None:
            self.metrics.note_restarts(
                sum(a.restarts for a in dec.health.attempts))
        self._resolve(req, value=dec, execute_s=time.perf_counter() - t0,
                      plan=dec.plan)

    def _run_batch(self, members) -> None:
        """Execute one sealed coalesced batch: stack, pad, solve through the
        executable cache, screen per-slice finiteness, resolve members."""
        # cancelled / deadline-lapsed members resolve without running; the
        # batch proceeds with the survivors (their results are unchanged —
        # slice seeds travel per member)
        members = [r for r in members if self._start(r)]
        if not members:
            return
        r0 = members[0]
        try:
            arrays = [self._dense(r.op) for r in members]
            B = len(arrays)
            padded = pad_batch(B, self._coalescer.max_batch)
            stack = jnp.stack(arrays + [arrays[0]] * (padded - B))
            seeds = jnp.asarray(
                [int(r.seed) for r in members] + [0] * (padded - B), jnp.uint32)
            sop = linalg.StackedOp(stack)
            pl = registry_mod.cached_plan(sop, r0.spec, kind="svd",
                                          overrides=r0.overrides)
            fn, hit = self.executable_cache.get(pl)
            (U, S, Vt), dt = timed(fn, stack, seeds)
            if not hit:
                self.executable_cache.note_first_call(pl, dt)
                self.metrics.record_compile(dt)
        except Exception as exc:
            for r in members:
                self._resolve(r, error=exc)
            return
        finite = np.asarray(
            jnp.isfinite(U).all(axis=(1, 2))
            & jnp.isfinite(S).all(axis=1)
            & jnp.isfinite(Vt).all(axis=(1, 2)))
        k = r0.spec.k
        for i, r in enumerate(members):
            if finite[i]:
                dec = Decomposition(
                    kind="svd", spec=r.spec, plan=pl, rank=k,
                    factors=(U[i], S[i], Vt[i]), rank_history=(k,),
                    err_history=(), health=None)
                self._resolve(r, value=dec, execute_s=dt, coalesced=B,
                              cache_hit=hit, plan=pl)
            else:
                # slice-level fault isolation: retry THIS request alone,
                # uncoalesced and guarded, so its HealthReport names what
                # broke; its neighbors keep their (unaffected) results
                self._retry_uncoalesced(r, coalesced=B)

    def _retry_uncoalesced(self, req: _Request, coalesced: int) -> None:
        guard = req.guard if req.guard.mode != "off" else "report"
        t0 = time.perf_counter()
        try:
            dec = linalg.decompose(
                linalg.StackedOp(self._dense(req.op)[None]), req.spec,
                seed=req.seed, overrides=req.overrides, guard=guard)
            jax.block_until_ready(dec.factors)
        except Exception as exc:
            self._resolve(req, error=exc, coalesced=coalesced)
            return
        dt = time.perf_counter() - t0
        health = dec.health
        if health is not None and not health.ok:
            self._resolve(req, coalesced=coalesced, error=RequestError(
                f"request solve unhealthy after uncoalesced retry:\n{health}",
                health=health))
            return
        U, S, Vt = dec.factors
        self._resolve(req, execute_s=dt, coalesced=coalesced, value=Decomposition(
            kind=dec.kind, spec=dec.spec, plan=dec.plan, rank=dec.rank,
            factors=(U[0], S[0], Vt[0]), rank_history=dec.rank_history,
            err_history=dec.err_history, health=health))

    @staticmethod
    def _dense(op):
        arr = op.array
        return arr if isinstance(arr, jnp.ndarray) else jnp.asarray(arr)
