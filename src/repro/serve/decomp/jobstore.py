"""Write-ahead job records: crash-safe restart for the decomposition service.

`DecompositionService` futures live in one process's memory — a crash drops
every queued and in-flight job on the floor, and their partial solves
(linalg/snapshot.py checkpoints) become orphans.  The `JobStore` closes
that gap with a write-ahead record per admitted request:

  record     BEFORE a request is queued, `record()` persists everything
             needed to re-create it — the source array (npz, exact bytes,
             host/device residency and streaming knobs preserved), the
             spec (class name + `dataclasses.asdict` — specs are frozen
             primitives), overrides, guard policy, seed, the plan
             fingerprint (`ExecutionPlan.fingerprint()`), and the job's
             checkpoint directory.  Published with the same atomic
             tmp-write -> fsync -> rename -> parent-fsync pattern as
             `repro.checkpoint` / snapshot.Checkpointer.
  complete   when the request's future resolves (result OR error), the
             record is deleted — the store holds exactly the jobs whose
             outcome nobody has seen yet.
  pending    after a process crash, `DecompositionService.restore(dir)`
             reads the surviving records, re-submits each job with its
             original checkpoint directory — the engines resume from the
             last panel-group snapshot (plan fingerprint re-checked at
             re-plan time), so completed panel groups are never recomputed.

Only array-rooted sources (a dense device array or a host numpy array,
possibly HostOp-wrapped) are persistable; `record()` returns None for
protocol-only / sparse / composed sources and the service simply runs
those unrecorded — resumability is an opt-in durability upgrade, never a
behavior change.

Thread-safety: one `JobStore` is shared by every service worker thread;
all mutation holds the instance lock (the RL002 service-reachable
contract).  `JobRecord` is frozen with hashable fields (RL003).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import threading
import uuid
from typing import List, Optional, Tuple

import numpy as np

from repro.linalg.snapshot import fsync_dir


@dataclasses.dataclass(frozen=True)
class JobRecord:
    """One write-ahead job record, as read back from the store."""

    job_id: str
    kind: str
    spec_type: str                   # "Rank" | "Tolerance" | "Energy"
    spec_json: str                   # asdict of the spec, JSON-encoded
    seed: int
    guard_mode: str
    validate: bool
    plan_fingerprint: str
    residency: str                   # "host" | "device"
    block_rows: Optional[int]
    pipeline_depth: Optional[int]
    checkpoint_dir: Optional[str]
    deadline_s: Optional[float]
    overrides_json: Optional[str]    # asdict of the RSVDConfig, or None
    source_path: str                 # the record's source.npz

    def spec_fields(self) -> dict:
        return json.loads(self.spec_json)

    def overrides_fields(self) -> Optional[dict]:
        return None if self.overrides_json is None else json.loads(self.overrides_json)


def _source_array(op) -> Optional[Tuple[np.ndarray, str]]:
    """(host bytes, residency) for a persistable source, else None."""
    arr = getattr(op, "array", None)
    if arr is None or getattr(arr, "ndim", 0) != 2:
        return None
    if isinstance(arr, np.ndarray):
        return arr, "host"
    return np.asarray(arr), "device"


class JobStore:
    """Directory of `job_<id>/` write-ahead records (see module docstring)."""

    def __init__(self, directory):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._mu = threading.Lock()

    # ---------------- write-ahead -------------------------------------------

    def record(self, *, op, spec, kind: str, seed: int, guard_mode: str,
               validate: bool, plan_fingerprint: str,
               checkpoint_dir: Optional[str], deadline_s: Optional[float],
               overrides=None, job_id: Optional[str] = None) -> Optional[str]:
        """Persist one admitted request; returns its job_id, or None for a
        source this store cannot re-create (nothing is written)."""
        src = _source_array(op)
        if src is None:
            return None
        host_arr, residency = src
        job_id = job_id or uuid.uuid4().hex[:16]
        tmp = self.dir / f"job_{job_id}.tmp"
        final = self.dir / f"job_{job_id}"
        meta = {
            "job_id": job_id,
            "kind": kind,
            "spec_type": type(spec).__name__,
            "spec_json": json.dumps(dataclasses.asdict(spec)),
            "seed": int(seed),
            "guard_mode": guard_mode,
            "validate": bool(validate),
            "plan_fingerprint": plan_fingerprint,
            "residency": residency,
            "block_rows": getattr(op, "block_rows", None),
            "pipeline_depth": getattr(op, "pipeline_depth", None),
            "checkpoint_dir": checkpoint_dir,
            "deadline_s": deadline_s,
            "overrides_json": (None if overrides is None
                               else json.dumps(dataclasses.asdict(overrides))),
        }
        with self._mu:
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            with open(tmp / "source.npz", "wb") as f:
                np.savez(f, a=host_arr)
                f.flush()
                os.fsync(f.fileno())
            with open(tmp / "job.json", "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            fsync_dir(self.dir)
        return job_id

    def complete(self, job_id: Optional[str]) -> None:
        """Drop the record once the job's future has resolved (either way)."""
        if job_id is None:
            return
        with self._mu:
            shutil.rmtree(self.dir / f"job_{job_id}", ignore_errors=True)

    # ---------------- recovery ----------------------------------------------

    def pending(self) -> List[JobRecord]:
        """Records whose outcome was never delivered (crash-interrupted);
        `.tmp` debris from a crash mid-record is skipped AND swept."""
        out = []
        with self._mu:
            for p in sorted(self.dir.glob("job_*")):
                if p.suffix == ".tmp":
                    shutil.rmtree(p, ignore_errors=True)
                    continue
                if not (p / "job.json").exists():
                    continue
                meta = json.loads((p / "job.json").read_text())
                out.append(JobRecord(source_path=str(p / "source.npz"), **meta))
        return out

    def load_source(self, rec: JobRecord):
        """Re-create the job's source with its original residency."""
        with np.load(rec.source_path) as data:
            arr = np.asarray(data["a"])
        if rec.residency == "host":
            from repro.linalg.operators import HostOp

            return HostOp(arr, block_rows=rec.block_rows,
                          pipeline_depth=rec.pipeline_depth)
        import jax.numpy as jnp

        return jnp.asarray(arr)
