# repro.serve.decomp — the decomposition service: plan-cached, coalescing,
# walltime-aware scheduling of concurrent decompose() traffic.
# See DESIGN.md §"Decomposition service".
from repro.serve.decomp.cache import ExecutableCache, trace_count  # noqa: F401
from repro.serve.decomp.coalesce import Coalescer, CoalesceKey  # noqa: F401
from repro.serve.decomp.metrics import MetricsRecorder, RequestRecord  # noqa: F401
from repro.serve.decomp.scheduler import DeviceGate, TwoLaneQueues  # noqa: F401
from repro.serve.decomp.service import (  # noqa: F401
    DecompositionService,
    RequestError,
    ServiceClosed,
    ServiceOverloaded,
)
