"""Request coalescing: same-shape small dense requests -> one StackedOp batch.

Admission-window semantics: the FIRST request of a bucket opens a window of
`window_s`; every compatible request arriving before it closes joins the
bucket.  A bucket seals (becomes an executable batch) when its window
expires or it reaches `max_batch`, whichever first.  Compatibility is exact:
(shape, dtype, spec, kind, overrides, guard) — anything looser would change
the executed program for some member.

Because slice seeds follow their requests through the batched body
(`blocked.slice_seeds`), membership and ORDER inside a batch are
numerically irrelevant: each member's result is bit-identical to its own
batch-of-1 execution (tests/test_service.py pins this, including under
arrival-order permutation).

Batch-size bucketing: sealed batches are padded up to the next power of two
(duplicating slice 0; pad results are discarded) so the executable cache
sees O(log max_batch) distinct batch shapes per request shape instead of
max_batch — fewer traces, no effect on real slices (vmap slices are
independent).  The coalescer is NOT thread-safe by itself; the service
serializes access under its admission lock.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CoalesceKey:
    """Exact-compatibility bucket key (all fields frozen/hashable)."""

    shape: Tuple[int, ...]
    dtype: str
    spec: object          # linalg Spec (frozen)
    kind: str
    overrides: object     # RSVDConfig | None (frozen)
    guard: object         # GuardPolicy (frozen)


class _Bucket:
    def __init__(self, opened_at: float):
        self.opened_at = opened_at
        self.members: List[object] = []


def pad_batch(b: int, max_batch: int) -> int:
    """Next power of two >= b, clamped to max_batch."""
    p = 1
    while p < b:
        p *= 2
    return min(p, max_batch)


class Coalescer:
    """Open buckets, keyed by CoalesceKey; the service's admission loop
    drains sealed batches."""

    def __init__(self, window_s: float = 0.002, max_batch: int = 8):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self._open: Dict[CoalesceKey, _Bucket] = {}

    def add(self, key: CoalesceKey, req, now: float) -> Optional[List[object]]:
        """Admit one request.  Returns the sealed member list when this
        request FILLS its bucket (max_batch), else None (the window timer
        will seal it)."""
        bucket = self._open.get(key)
        if bucket is None:
            bucket = self._open[key] = _Bucket(opened_at=now)
        bucket.members.append(req)
        if len(bucket.members) >= self.max_batch:
            del self._open[key]
            return bucket.members
        return None

    def pop_due(self, now: float) -> List[List[object]]:
        """Seal and return every bucket whose admission window has closed."""
        due = [k for k, b in self._open.items()
               if now - b.opened_at >= self.window_s]
        return [self._open.pop(k).members for k in due]

    def flush(self) -> List[List[object]]:
        """Seal everything immediately (service close / explicit flush)."""
        out = [b.members for b in self._open.values()]
        self._open.clear()
        return out

    def next_deadline(self) -> Optional[float]:
        """Earliest instant any open bucket's window closes (None: no
        open buckets) — what the admission loop sleeps until."""
        if not self._open:
            return None
        return min(b.opened_at for b in self._open.values()) + self.window_s

    def open_buckets(self) -> int:
        return len(self._open)
