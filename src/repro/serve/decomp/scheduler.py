"""Two-lane walltime-aware scheduling with a starvation bound.

Lanes (the Lu et al. out-of-core motivation: one 65536x4096 streamed job
takes seconds and must not starve a thousand millisecond-scale PCA calls):

  small   shortest-predicted-first priority queue ordered by the plan's
          `predicted_walltime_s` (FIFO among ties).  Coalesced batches and
          quick solo requests live here.
  big     bounded FIFO (admission refuses work past `capacity` queued jobs)
          for solves whose predicted walltime crosses the big threshold —
          out-of-core streamed jobs foremost.

`DeviceGate` arbitrates the device between the lanes cooperatively.  A big
job holds the device, but its panel walk calls `panel_tick` once per
produced panel (wired through `pipeline.panel_hook`, which every panel path
funnels through); every `panel_group` panels counts one SLICE, and at each
slice boundary the gate yields the device whenever small-lane work is
admitted, re-acquiring only when the small lane is idle again (or, with
`big_patience_s` set, when the big job has been parked that long — the
anti-starvation valve for the big lane under saturating small traffic).

The starvation bound: once a small request is admitted, the in-flight slice
finishes (<= 1 slice counter increment) and then the gate parks the big job
until the small lane drains — so no admitted request ever waits more than
K = 1 big-job slice (2 with the admission race), independent of how many
panels the big job still has.  `DecompositionService` snapshots
`gate.big_slices` at submit and at execution start; the difference is the
per-request `big_slices_waited` that tests assert against K.

Interruption semantics (PR 10): a big job parked at a slice boundary is
exactly mid-panel-group, which is also where the engines cross their
snapshot boundaries (linalg/snapshot.py) — so the `preempt` /
`device_lost` injected faults, cooperative cancellation and request
deadlines all land at the same natural granularity the gate already
slices by.  A preempted-and-restarted big job re-enters the big lane
with its progress preserved (the guard restarts it under the ambient
checkpointer), so the starvation bound is unaffected by restarts: each
re-run is just a shorter big job.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import List, Optional, Tuple


class DeviceGate:
    """Cooperative small-lane-priority device lock with sliced big jobs."""

    def __init__(self, panel_group: int = 4,
                 big_patience_s: Optional[float] = None):
        self._cond = threading.Condition()
        self._holder: Optional[str] = None
        self._small_pending = 0     # admitted small work not yet completed
        self._panels = 0            # big-job panels seen since acquisition
        self.panel_group = max(1, int(panel_group))
        self.big_patience_s = big_patience_s
        self.big_slices = 0         # completed big-job slices, ever

    # -- small lane ---------------------------------------------------------

    def note_small_admitted(self) -> None:
        with self._cond:
            self._small_pending += 1
            self._cond.notify_all()

    def note_small_done(self) -> None:
        with self._cond:
            self._small_pending -= 1
            self._cond.notify_all()

    def small_turn(self):
        return _Turn(self, "small")

    # -- big lane -----------------------------------------------------------

    def big_turn(self):
        return _Turn(self, "big")

    def _acquire(self, who: str) -> None:
        with self._cond:
            if who == "small":
                while self._holder is not None:
                    self._cond.wait()
            else:
                deadline = (time.monotonic() + self.big_patience_s
                            if self.big_patience_s is not None else None)
                # park while a small holds the device OR small work is
                # admitted — the strict-drain policy behind the K bound
                while self._holder is not None or (
                    self._small_pending > 0 and not _expired(deadline)
                ):
                    self._cond.wait(timeout=_remaining(deadline))
                self._panels = 0
            self._holder = who

    def _release(self) -> None:
        with self._cond:
            self._holder = None
            self._cond.notify_all()

    def panel_tick(self, _ordinal: int = 0) -> None:
        """Big-job per-panel callback (pipeline.panel_hook target).  Every
        `panel_group` panels: count one slice, then yield the device if
        small work is waiting."""
        self._panels += 1
        if self._panels % self.panel_group:
            return
        with self._cond:
            self.big_slices += 1
            if self._small_pending == 0:
                return  # nobody waiting: keep the device, zero overhead
        self._release()
        self._acquire("big")


def _expired(deadline: Optional[float]) -> bool:
    return deadline is not None and time.monotonic() >= deadline


def _remaining(deadline: Optional[float]) -> Optional[float]:
    return None if deadline is None else max(0.0, deadline - time.monotonic())


class _Turn:
    def __init__(self, gate: DeviceGate, who: str):
        self._gate = gate
        self._who = who

    def __enter__(self):
        self._gate._acquire(self._who)
        return self._gate

    def __exit__(self, *exc):
        self._gate._release()
        return False


class TwoLaneQueues:
    """The lanes themselves (thread-safe): a shortest-predicted-first heap
    and a bounded big FIFO.  Workers block on `pop_*`; `close()` wakes
    everyone so worker loops can drain and exit."""

    def __init__(self, big_capacity: int = 4):
        self._cond = threading.Condition()
        self._small: List[Tuple[float, int, object]] = []  # (predicted, seq, item)
        self._big: List[object] = []
        self._seq = itertools.count()
        self._closed = False
        self.big_capacity = int(big_capacity)

    def push_small(self, predicted_s: float, item) -> None:
        with self._cond:
            heapq.heappush(self._small, (float(predicted_s), next(self._seq), item))
            self._cond.notify_all()

    def push_big(self, item) -> bool:
        """False when the big lane is at capacity (admission refused)."""
        with self._cond:
            if len(self._big) >= self.big_capacity:
                return False
            self._big.append(item)
            self._cond.notify_all()
            return True

    def pop_small(self) -> Optional[object]:
        with self._cond:
            while not self._small and not self._closed:
                self._cond.wait()
            if self._small:
                return heapq.heappop(self._small)[2]
            return None  # closed and drained

    def pop_big(self) -> Optional[object]:
        with self._cond:
            while not self._big and not self._closed:
                self._cond.wait()
            if self._big:
                return self._big.pop(0)
            return None

    def small_backlog(self) -> int:
        with self._cond:
            return len(self._small)

    def big_backlog(self) -> int:
        with self._cond:
            return len(self._big)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
