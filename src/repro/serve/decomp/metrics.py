"""Per-request service metrics: queue/compile/execute walltime, coalescing
factor, cache hit rate, predicted-vs-measured walltime error.

One thread-safe recorder per `DecompositionService`.  Workers append a
`RequestRecord` as each request resolves; `export()` reduces the log to the
flat dict the bench harness persists (benchmarks/bench_rsvd.py
`service_rows`) — percentiles for the latency distributions, means for the
ratios.  Records are kept raw (one dataclass per request, bounded by
`max_records`) so tests can assert per-request facts — e.g. the scheduler's
starvation bound: no request's `big_slices_waited` exceeds K.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One resolved request, as the metrics layer saw it."""

    kind: str                      # registry kind ("svd", "pca", ...)
    lane: str                      # "small" | "big"
    coalesced: int                 # real requests sharing the batch (1 = solo)
    cache_hit: Optional[bool]      # executable-cache verdict (None: uncached path)
    queue_s: float                 # submit -> execution start
    execute_s: float               # solve walltime (shared by a whole batch)
    total_s: float                 # submit -> future resolved
    predicted_s: float             # plan.predicted_walltime_s of the executed plan
    big_slices_waited: int         # big-job slices completed while this waited
    failed: bool = False           # future resolved with an error

    @property
    def walltime_error(self) -> Optional[float]:
        """|measured - predicted| / measured (None when unmeasurable)."""
        if self.execute_s <= 0.0:
            return None
        return abs(self.execute_s - self.predicted_s) / self.execute_s


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


class MetricsRecorder:
    """Append-only request log + counter block, exported as one flat dict."""

    def __init__(self, max_records: int = 100_000):
        self._lock = threading.Lock()
        self._records: List[RequestRecord] = []
        self._max = max_records
        self._compile_s = 0.0
        self._compiles = 0
        # resilience counters (PR 10): request lifecycle verdicts and the
        # transparent-recovery work done on behalf of requests
        self._cancelled = 0
        self._deadline_exceeded = 0
        self._restarts = 0
        self._resumed_jobs = 0
        self._checkpoint_overhead_s = 0.0

    def record(self, rec: RequestRecord) -> None:
        with self._lock:
            if len(self._records) < self._max:
                self._records.append(rec)

    def record_compile(self, seconds: float) -> None:
        """First call through a fresh executable-cache entry (trace+compile
        rides on it) — attributed here, not to any single request."""
        with self._lock:
            self._compile_s += float(seconds)
            self._compiles += 1

    def note_cancelled(self) -> None:
        """A request observed its cooperative cancel (before or mid-solve)."""
        with self._lock:
            self._cancelled += 1

    def note_deadline_exceeded(self) -> None:
        with self._lock:
            self._deadline_exceeded += 1

    def note_restarts(self, n: int) -> None:
        """Transient-interruption restarts the guard absorbed for one
        request (summed over its ladder attempts)."""
        if n:
            with self._lock:
                self._restarts += int(n)

    def note_resumed_job(self) -> None:
        """A crash-interrupted job re-enqueued by `Service.restore`."""
        with self._lock:
            self._resumed_jobs += 1

    def note_checkpoint_overhead(self, seconds: float) -> None:
        """Host-side walltime one request spent capturing + persisting
        snapshots (Checkpointer.overhead_s at resolve time)."""
        if seconds:
            with self._lock:
                self._checkpoint_overhead_s += float(seconds)

    def records(self) -> List[RequestRecord]:
        with self._lock:
            return list(self._records)

    def export(self) -> Dict[str, float]:
        """The flat summary dict (bench schema `service_rows`)."""
        recs = self.records()
        done = [r for r in recs if not r.failed]
        queue = [r.queue_s for r in done]
        total = [r.total_s for r in done]
        cached = [r for r in done if r.cache_hit is not None]
        hits = sum(1 for r in cached if r.cache_hit)
        coalescible = [r for r in done if r.lane == "small" and r.cache_hit is not None]
        errs = [e for e in (r.walltime_error for r in done) if e is not None]
        with self._lock:
            compile_s, compiles = self._compile_s, self._compiles
            cancelled = self._cancelled
            deadline_exceeded = self._deadline_exceeded
            restarts = self._restarts
            resumed_jobs = self._resumed_jobs
            checkpoint_overhead_s = self._checkpoint_overhead_s
        return {
            "requests": len(recs),
            "failed": sum(1 for r in recs if r.failed),
            "coalescing_factor": (
                float(np.mean([r.coalesced for r in coalescible])) if coalescible else 1.0
            ),
            "cache_hit_rate": hits / len(cached) if cached else 0.0,
            "compiles": compiles,
            "compile_s_total": compile_s,
            "queue_s_p50": _pct(queue, 50),
            "queue_s_p99": _pct(queue, 99),
            "latency_s_p50": _pct(total, 50),
            "latency_s_p99": _pct(total, 99),
            "execute_s_p50": _pct([r.execute_s for r in done], 50),
            "predicted_walltime_err_p50": _pct(errs, 50),
            "max_big_slices_waited": max((r.big_slices_waited for r in recs), default=0),
            "cancelled": cancelled,
            "deadline_exceeded": deadline_exceeded,
            "restarts": restarts,
            "resumed_jobs": resumed_jobs,
            "checkpoint_overhead_s": checkpoint_overhead_s,
        }
