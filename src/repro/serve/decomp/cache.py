"""Compiled-executable cache: frozen ExecutionPlan -> jitted batched solve.

The service's coalescible traffic always executes through the batched body
(`core.blocked._batched_tall`, a batch of 1 for uncoalesced requests) — the
one program whose per-slice results are bit-identical whatever batch its
slices arrived in.  ExecutionPlans are frozen/hashable, so the plan itself
keys the cache; a hit returns a callable whose underlying jit trace already
exists, making the steady-state hot path re-trace-free.

Trace accounting: `core.blocked._TRACE_COUNTS` is incremented INSIDE the
batched body, so it ticks at trace time only.  `trace_count(plan)` maps a
plan to its body-level trace key (same orientation swap and config
normalization `svd_batched` applies) — tests and the bench assert at most
one trace per distinct plan across N same-plan requests.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Tuple

import jax

from repro.core import blocked
from repro.linalg.planner import ExecutionPlan


def _trace_key_for(pl: ExecutionPlan):
    """The `blocked._TRACE_COUNTS` key this plan's batches trace under.

    Mirrors `svd_batched` exactly: wide stacks are transposed to the tall
    orientation before the jit boundary (pl.m/pl.n are already recorded
    post-orientation), and the config is normalized by `batched_cfg`."""
    cfg = blocked.batched_cfg(pl.to_config())
    return blocked._trace_key((pl.batch, pl.m, pl.n), pl.dtype, pl.k, cfg)


def trace_count(pl: ExecutionPlan) -> int:
    """How many times this plan's batched body has been traced (process-wide)."""
    return blocked.trace_count(_trace_key_for(pl))


class ExecutableCache:
    """plan -> `solve(stack, seeds) -> (U, S, Vt)`, with hit/miss stats.

    The callable routes through `blocked.svd_batched`, so orientation,
    config normalization, and the jit cache are exactly the library path's —
    a standalone `decompose(StackedOp(x[None]), ...)` call and a service
    batch compile (and share) the same program.  What this layer adds is
    plan-granular bookkeeping: hit/miss counts, first-call (compile)
    walltime per entry, and the trace-count assertion surface."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[ExecutionPlan, Callable] = {}
        self._first_call_s: Dict[ExecutionPlan, float] = {}
        self.hits = 0
        self.misses = 0

    def _build(self, pl: ExecutionPlan) -> Callable:
        cfg = pl.to_config()
        k = pl.k

        def solve(stack: jax.Array, seeds: jax.Array):
            return blocked.svd_batched(stack, k, cfg, seed=seeds)

        return solve

    def get(self, pl: ExecutionPlan) -> Tuple[Callable, bool]:
        """(solve callable, was_hit).  Thread-safe; builds at most once per
        plan — concurrent first requests for the same plan race only on a
        cheap closure construction, never on compilation (jax's jit cache
        deduplicates the trace underneath)."""
        with self._lock:
            fn = self._entries.get(pl)
            if fn is not None:
                self.hits += 1
                return fn, True
            self.misses += 1
            fn = self._build(pl)
            self._entries[pl] = fn
            return fn, False

    def note_first_call(self, pl: ExecutionPlan, seconds: float) -> None:
        with self._lock:
            self._first_call_s.setdefault(pl, float(seconds))

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "plans": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / max(1, self.hits + self.misses),
                "first_call_s": dict(self._first_call_s),
                "trace_counts": {
                    repr(p): trace_count(p) for p in self._entries
                },
            }

    def plans(self) -> Tuple[ExecutionPlan, ...]:
        with self._lock:
            return tuple(self._entries)


def timed(fn: Callable, *args):
    """Run fn(*args), block on the result, return (result, walltime_s)."""
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


__all__ = ["ExecutableCache", "trace_count", "timed"]
