"""Batched serving engine: continuous batched generation over a fixed-size
slot table (vLLM-style static batching, simplified to synchronous slots).

Requests queue up; the engine packs up to `max_batch` prompts, prefills them
together (right-padded), then decodes in lock-step until every slot emits EOS
or reaches max_new_tokens.  Weights can be low-rank-compressed with the
paper's RSVD (cfg.lowrank_serve_rank) before the engine starts.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kvcache, serve_step


class EmptyPromptError(ValueError):
    """A generate() request carried an empty prompt.  Raised up-front (before
    any compute): an empty prompt would otherwise left-pad to an all-zeros
    row and decode from pad tokens as if that were the user's input."""


@dataclass
class Request:
    prompt: np.ndarray            # [T] int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1: never stops early


@dataclass
class Completion:
    tokens: np.ndarray
    prompt_len: int


class Engine:
    def __init__(self, params, cfg, *, max_batch: int = 8, max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, t, c, e: serve_step.prefill_step(p, t, cfg, c, extras=e)
        )
        self._decode = jax.jit(
            lambda p, tok, pos, c, enc: serve_step.decode_step(
                p, tok, pos, cfg, c, encoder_out=enc
            )
        )

    def generate(self, requests: List[Request], extras: Optional[Dict] = None) -> List[Completion]:
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise EmptyPromptError(
                    f"request {i} has an empty prompt; every prompt must "
                    "carry at least one token")
        out: List[Completion] = []
        for i in range(0, len(requests), self.max_batch):
            out.extend(self._generate_batch(requests[i : i + self.max_batch], extras))
        return out

    def _generate_batch(self, reqs: List[Request], extras) -> List[Completion]:
        B = len(reqs)
        Tp = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, Tp), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, Tp - len(r.prompt) :] = r.prompt  # left-pad to align ends

        caches = kvcache.init_caches(
            self.cfg, B, self.max_len, dtype=self.cfg.param_dtype()
        )
        logits, caches, enc_out = self._prefill(
            self.params, jnp.asarray(prompts), caches, extras or {}
        )
        max_new = max(r.max_new_tokens for r in reqs)
        tok = serve_step.greedy_sample(logits)
        pos = Tp + (self.cfg.vision_tokens if self.cfg.vision_stub and extras else 0)

        toks = [np.asarray(tok)[:, 0]]
        done = np.zeros(B, bool)
        for step in range(max_new - 1):
            logits, caches = self._decode(
                self.params, tok, jnp.asarray(pos + step, jnp.int32), caches, enc_out
            )
            tok = serve_step.greedy_sample(logits)
            t = np.asarray(tok)[:, 0]
            toks.append(t)
            for i, r in enumerate(reqs):
                if r.eos_id >= 0 and t[i] == r.eos_id:
                    done[i] = True
            if done.all():
                break

        gen = np.stack(toks, axis=1)  # [B, n_generated]
        return [
            Completion(tokens=gen[i, : reqs[i].max_new_tokens], prompt_len=len(reqs[i].prompt))
            for i in range(B)
        ]
