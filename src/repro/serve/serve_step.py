"""Serving steps: prefill (fill caches from a prompt) and decode (one token).

`decode_step` is what the decode_32k / long_500k dry-run cells lower: one new
token against a seq_len-deep cache.  Low-rank serve compression
(cfg.lowrank_serve_rank > 0) factorizes selected weights with the paper's
RSVD before serving — see lowrank.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models import whisper as W


def prefill_step(
    params, tokens: jax.Array, cfg, caches, *, extras: Optional[Dict] = None
) -> Tuple[jax.Array, Any]:
    """Run the prompt through the stack, filling caches.

    Returns (last-position logits [B, vocab], caches)."""
    extras = extras or {}
    if cfg.is_encoder_decoder:
        enc_out = W.encode(params, extras["audio_features"], cfg)
        x = T.embed_tokens(params["decoder"], tokens, cfg)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, caches, _ = T.apply_stack(
            params["decoder"], x, cfg, positions=pos, caches=caches,
            encoder_out=enc_out, mode="prefill",
        )
        logits = T.logits_from_hidden(params["decoder"], x[:, -1:], cfg)
        return logits[:, 0], caches, enc_out

    x = T.embed_tokens(params, tokens, cfg)
    if cfg.vision_stub and "vision_embeds" in extras:
        x = jnp.concatenate([extras["vision_embeds"].astype(x.dtype), x], axis=1)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, caches, _ = T.apply_stack(
        params, x, cfg, positions=pos, caches=caches, mode="prefill"
    )
    logits = T.logits_from_hidden(params, x[:, -1:], cfg)
    return logits[:, 0], caches, None


def decode_step(
    params,
    token: jax.Array,            # [B, 1] the freshly sampled token
    position: jax.Array,         # scalar int32 — current sequence position
    cfg,
    caches,
    *,
    encoder_out: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """One token in, next-token logits out. O(1) state update per layer."""
    p = params["decoder"] if cfg.is_encoder_decoder else params
    x = T.embed_tokens(p, token, cfg)
    if cfg.is_encoder_decoder:
        # absolute positions: gather the one sinusoidal row we need
        table = L.sinusoidal_positions(cfg.trained_len_(), cfg.d_model, x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(table, position, 1)[None]
    pos = jnp.full((1,), position, jnp.int32)
    x, caches, _ = T.apply_stack(
        p, x, cfg, positions=pos, caches=caches, encoder_out=encoder_out,
        mode="decode",
    )
    logits = T.logits_from_hidden(p, x, cfg)
    return logits[:, 0], caches


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def temperature_sample(logits: jax.Array, key, temperature: float = 1.0) -> jax.Array:
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)[
        :, None
    ]
