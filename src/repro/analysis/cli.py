"""Command-line front end: `python -m repro.analysis [paths] [--contracts]`.

Exit codes: 0 clean, 1 lint findings or contract violations, 2 usage error.
The lint pass is stdlib-only and runs before any jax import; `--contracts`
pulls in jax and abstractly traces the golden dispatch table (CPU-safe —
everything is shape-level except the tiny concrete batched re-trace probe).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional

from repro.analysis import engine, rules


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific AST lint + jaxpr contract sweep")
    p.add_argument("paths", nargs="*",
                   help="files/directories to lint (default: src)")
    p.add_argument("--contracts", action="store_true",
                   help="also run the jaxpr contract sweep over the planner's"
                        " golden dispatch table")
    p.add_argument("--no-lint", action="store_true",
                   help="skip the AST lint (contract sweep only)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also print suppressed findings and unused noqa "
                        "comments")
    return p


def _run_lint(paths: List[str], verbose: bool) -> int:
    t0 = time.perf_counter()
    report = engine.lint_paths(paths)
    dt = time.perf_counter() - t0
    for finding in report.findings:
        print(finding.format())
    if verbose:
        for finding, sup in report.suppressed:
            print(f"suppressed: {finding.format()}  [reason: {sup.reason}]")
        for path, sup in report.unused_noqa:
            print(f"unused noqa: {path}:{sup.line} [{', '.join(sup.rules)}]")
    status = "clean" if report.ok else f"{len(report.findings)} finding(s)"
    print(f"repro.analysis lint: {report.files} files, {len(rules.RULES)} "
          f"rules, {len(report.suppressed)} suppression(s) — {status} "
          f"({dt:.2f}s)")
    return 0 if report.ok else 1


def _run_contracts(verbose: bool) -> int:
    from repro.analysis import contracts  # defers the jax import

    t0 = time.perf_counter()
    report = contracts.sweep()
    dt = time.perf_counter() - t0
    for res in report.results:
        if not res.ok or verbose:
            mark = "ok" if res.ok else "VIOLATION"
            print(f"contract {res.contract} [{res.plan_label}] {mark}: "
                  f"{res.detail}")
    print(f"repro.analysis contracts: {len(report.plans)} plans, "
          f"{len(report.results)} checks, "
          f"{len(report.violations)} violation(s) ({dt:.2f}s)")
    return 0 if not report.violations else 1


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for rule in rules.RULES:
            print(f"{rule.id} [{rule.name}] — {rule.doc}")
        return 0
    rc = 0
    if not args.no_lint:
        rc = _run_lint(args.paths or ["src"], args.verbose)
    if args.contracts:
        rc = max(rc, _run_contracts(args.verbose))
    return rc


if __name__ == "__main__":
    sys.exit(main())
